"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain ``jax.numpy`` ops only. ``python/tests/test_kernels.py``
sweeps shapes/dtypes with hypothesis and asserts the kernel output matches
the oracle (``assert_allclose``). The oracles are also used directly by the
L2 model when ``use_pallas=False`` (useful for debugging HLO size).
"""

from __future__ import annotations

import jax.numpy as jnp


def se_excite_ref(pooled, w1, b1, w2, b2):
    """Squeeze-excite gating MLP (paper SE blocks, r=16).

    Args:
      pooled: ``[N, C]`` spatially-pooled features.
      w1: ``[C, Cr]`` squeeze weights (Cr = C // r).
      b1: ``[Cr]``.
      w2: ``[Cr, C]`` excite weights.
      b2: ``[C]``.

    Returns:
      ``[N, C]`` sigmoid gate in (0, 1).
    """
    h = jnp.maximum(pooled @ w1 + b1, 0.0)
    return 1.0 / (1.0 + jnp.exp(-(h @ w2 + b2)))


def lstm_cell_ref(x, h, c, wx, wh, b):
    """Single fused LSTM cell step.

    Weight layout is ``[Din, 4, H]`` / ``[H, 4, H]`` / ``[4, H]`` — gate axis
    second — chosen so the Pallas kernel can BlockSpec-slice the H axis while
    keeping all four gates of a hidden tile together (see lstm_cell.py).
    Gate order: i, f, g, o.

    Returns:
      ``(h_new, c_new)`` each ``[N, H]``.
    """
    gates = (
        jnp.einsum("nd,dgh->ngh", x, wx)
        + jnp.einsum("nk,kgh->ngh", h, wh)
        + b[None, :, :]
    )
    i = 1.0 / (1.0 + jnp.exp(-gates[:, 0]))
    f = 1.0 / (1.0 + jnp.exp(-gates[:, 1]))
    g = jnp.tanh(gates[:, 2])
    o = 1.0 / (1.0 + jnp.exp(-gates[:, 3]))
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def adam_dir_ref(theta, m, v, g, beta1, beta2, eps, lam, bc1, bc2):
    """Adam moment update + Lamb step direction for one layer (paper Eq. 1).

    Returns:
      ``(m_new, v_new, d, theta_sq_sum, d_sq_sum)`` where
      ``d = m_hat / (sqrt(v_hat) + eps) + lam * theta`` is the raw update
      direction (Adam step + decoupled weight decay) and the two sums are the
      squared-norm reductions that feed the trust ratio.
    """
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m_new * bc1
    v_hat = v_new * bc2
    d = m_hat / (jnp.sqrt(v_hat) + eps) + lam * theta
    return m_new, v_new, d, jnp.sum(theta * theta), jnp.sum(d * d)


def trust_ratio_ref(theta_sq_sum, d_sq_sum, rho, phi_cap=10.0):
    """Clipped Lamb trust ratio (paper Eq. 2).

    ``r = clip(phi(||theta||) / ||d||, rho, 1/rho)`` with
    ``phi(x) = min(x, phi_cap)``. ``rho = 1`` degenerates to AdamW (r == 1),
    which the paper uses for bias/fixup/gain parameters.
    """
    theta_norm = jnp.sqrt(theta_sq_sum)
    d_norm = jnp.sqrt(d_sq_sum)
    phi = jnp.minimum(theta_norm, phi_cap)
    # Avoid 0/0 at step 0 for zero-init layers: ratio of zero norms -> 1.
    raw = jnp.where(d_norm > 0.0, phi / jnp.maximum(d_norm, 1e-30), 1.0)
    return jnp.clip(raw, rho, 1.0 / rho)


def apply_update_ref(theta, d, scale):
    """``theta' = theta - scale * d`` where ``scale = lr * r`` (paper Eq. 1)."""
    return theta - scale * d


def lamb_layer_ref(theta, m, v, g, *, lr, beta1, beta2, eps, lam, rho, step):
    """Full single-layer Lamb update, composing the three pieces above.

    ``step`` is the 1-based step count *after* increment (Adam convention).
    """
    bc1 = 1.0 / (1.0 - beta1**step)
    bc2 = 1.0 / (1.0 - beta2**step)
    m_new, v_new, d, tss, dss = adam_dir_ref(
        theta, m, v, g, beta1, beta2, eps, lam, bc1, bc2
    )
    r = trust_ratio_ref(tss, dss, rho)
    return apply_update_ref(theta, d, lr * r), m_new, v_new
