"""L1 Pallas kernels (interpret-mode on CPU; see DESIGN.md §Hardware-Adaptation).

Submodules: ``se_excite``, ``lstm_cell``, ``lamb`` (the kernels), ``ref``
(pure-jnp oracles), ``ad`` (custom_vjp wrappers used by the L2 model so the
training path can differentiate through the Pallas forwards).
"""

from . import ad, lamb, lstm_cell, ref, se_excite  # noqa: F401
