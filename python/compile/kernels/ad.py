"""Autodiff wrappers for the Pallas kernels.

``pallas_call`` has no reverse-mode autodiff rule (even in interpret mode),
but the training (``grad``) artifact must differentiate through the SE gate
and the LSTM cell. We wrap each kernel in ``jax.custom_vjp``:

  forward  — the Pallas kernel (so the fused kernel is what lands in the
             inference *and* the training-forward HLO),
  backward — the VJP of the pure-jnp oracle in ``ref.py`` (mathematically
             identical function, so the cotangents are exact).

On a real TPU the backward would get its own fused kernels; the oracle-VJP
backward keeps the contract honest on this CPU testbed and is validated in
``python/tests/test_kernels.py`` (grad-vs-ref allclose).
"""

from __future__ import annotations

import jax

from . import lstm_cell as _lstm_mod
from . import ref as _ref
from . import se_excite as _se_mod


@jax.custom_vjp
def se_excite(pooled, w1, b1, w2, b2):
    """Differentiable fused SE gate; see ``se_excite.se_excite``."""
    return _se_mod.se_excite(pooled, w1, b1, w2, b2)


def _se_fwd(pooled, w1, b1, w2, b2):
    out = _se_mod.se_excite(pooled, w1, b1, w2, b2)
    return out, (pooled, w1, b1, w2, b2)


def _se_bwd(res, ct):
    _, vjp = jax.vjp(_ref.se_excite_ref, *res)
    return vjp(ct)


se_excite.defvjp(_se_fwd, _se_bwd)


@jax.custom_vjp
def lstm_cell(x, h, c, wx, wh, b):
    """Differentiable fused LSTM cell; see ``lstm_cell.lstm_cell``."""
    return _lstm_mod.lstm_cell(x, h, c, wx, wh, b)


def _lstm_fwd(x, h, c, wx, wh, b):
    out = _lstm_mod.lstm_cell(x, h, c, wx, wh, b)
    return out, (x, h, c, wx, wh, b)


def _lstm_bwd(res, ct):
    _, vjp = jax.vjp(_ref.lstm_cell_ref, *res)
    return vjp(ct)


lstm_cell.defvjp(_lstm_fwd, _lstm_bwd)
