"""Pallas kernels: Lamb optimizer update (paper §3.4, Eq. 1-2).

The paper adapts Lamb (You et al. 2020) — Adam step direction rescaled by a
clipped per-layer trust ratio — to keep sample efficiency at large training
batches. The update is the per-step hot loop of the learner, so it is the L1
hot-spot for the optimizer side of the system.

TPU mapping (DESIGN.md §Hardware-Adaptation): the trust ratio needs *global*
per-layer reductions (‖θ‖, ‖d‖), so a single-pass kernel would need a
cross-block reduction. We use the canonical two-pass structure a real TPU
implementation wants:

  pass 1 ``adam_dir``  — elementwise over VMEM-sized tiles: update m, v,
       emit the raw direction d = m̂/(√v̂+ε) + λθ **and** per-tile partial
       sums of θ² and d² (one scalar pair per grid step).
  (host/XLA) reduce partials, form r = clip(min(‖θ‖,10)/‖d‖, ρ, 1/ρ).
  pass 2 ``apply_update`` — elementwise: θ' = θ − (lr·r)·d.

Layers are processed as slices of the flat parameter vector (see aot.py);
the per-layer loop is unrolled at trace time.

interpret=True for CPU-PJRT execution (see se_excite.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK = 64 * 1024  # 256 KiB/input-array per block: comfortable VMEM


def _adam_dir_kernel(
    theta_ref, m_ref, v_ref, g_ref, sc_ref, m_out, v_out, d_out, tss_out, dss_out
):
    """One tile: Adam moments + Lamb direction + partial norm sums.

    ``sc_ref`` packs the six scalars [beta1, beta2, eps, lam, bc1, bc2] so the
    kernel has a single tiny SMEM-like operand instead of six.
    """
    beta1 = sc_ref[0]
    beta2 = sc_ref[1]
    eps = sc_ref[2]
    lam = sc_ref[3]
    bc1 = sc_ref[4]
    bc2 = sc_ref[5]
    theta = theta_ref[...]
    g = g_ref[...]
    m_new = beta1 * m_ref[...] + (1.0 - beta1) * g
    v_new = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    d = (m_new * bc1) / (jnp.sqrt(v_new * bc2) + eps) + lam * theta
    m_out[...] = m_new
    v_out[...] = v_new
    d_out[...] = d
    tss_out[...] = jnp.sum(theta * theta)[None]
    dss_out[...] = jnp.sum(d * d)[None]


def _apply_kernel(theta_ref, d_ref, scale_ref, out_ref):
    """One tile: θ' = θ − scale·d (scale = lr · trust-ratio)."""
    out_ref[...] = theta_ref[...] - scale_ref[0] * d_ref[...]


def _pad1(x, pad):
    return jnp.pad(x, ((0, pad),)) if pad else x


@functools.partial(jax.jit, static_argnames=("block",))
def adam_dir(theta, m, v, g, scalars, *, block: int = DEFAULT_BLOCK):
    """Pass 1 over one layer (flat ``[P]`` arrays).

    Args:
      scalars: ``[6]`` = [beta1, beta2, eps, lam, bc1, bc2].

    Returns:
      ``(m_new[P], v_new[P], d[P], theta_sq_sum[], d_sq_sum[])``.

    Zero-pad tail contributes 0 to both norm sums (g=θ=0 ⇒ m=v=d=0), so the
    reductions are exact.
    """
    p = theta.shape[0]
    bk = min(block, max(p, 1))
    pad = (-p) % bk
    theta_p, m_p, v_p, g_p = (_pad1(a, pad) for a in (theta, m, v, g))
    tiles = (p + pad) // bk
    m_new, v_new, d, tss, dss = pl.pallas_call(
        _adam_dir_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((bk,), lambda i: (i,)),
            pl.BlockSpec((bk,), lambda i: (i,)),
            pl.BlockSpec((bk,), lambda i: (i,)),
            pl.BlockSpec((bk,), lambda i: (i,)),
            pl.BlockSpec((6,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bk,), lambda i: (i,)),
            pl.BlockSpec((bk,), lambda i: (i,)),
            pl.BlockSpec((bk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p + pad,), jnp.float32),
            jax.ShapeDtypeStruct((p + pad,), jnp.float32),
            jax.ShapeDtypeStruct((p + pad,), jnp.float32),
            jax.ShapeDtypeStruct((tiles,), jnp.float32),
            jax.ShapeDtypeStruct((tiles,), jnp.float32),
        ],
        interpret=True,
    )(theta_p, m_p, v_p, g_p, scalars)
    return m_new[:p], v_new[:p], d[:p], jnp.sum(tss), jnp.sum(dss)


@functools.partial(jax.jit, static_argnames=("block",))
def apply_update(theta, d, scale, *, block: int = DEFAULT_BLOCK):
    """Pass 2 over one layer: ``theta - scale * d``; ``scale`` is ``[1]``."""
    p = theta.shape[0]
    bk = min(block, max(p, 1))
    pad = (-p) % bk
    out = pl.pallas_call(
        _apply_kernel,
        grid=((p + pad) // bk,),
        in_specs=[
            pl.BlockSpec((bk,), lambda i: (i,)),
            pl.BlockSpec((bk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p + pad,), jnp.float32),
        interpret=True,
    )(_pad1(theta, pad), _pad1(d, pad), scale)
    return out[:p]


def lamb_layer(
    theta, m, v, g, *, lr, beta1, beta2, eps, lam, rho, step, block=DEFAULT_BLOCK
):
    """Full single-layer Lamb update via the two Pallas passes.

    ``lr`` and ``step`` may be traced scalars (the AOT update artifact feeds
    them as runtime inputs). Matches ``ref.lamb_layer_ref``.
    """
    stepf = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    bc1 = 1.0 / (1.0 - beta1**stepf)
    bc2 = 1.0 / (1.0 - beta2**stepf)
    scalars = jnp.stack(
        [
            jnp.float32(beta1),
            jnp.float32(beta2),
            jnp.float32(eps),
            jnp.float32(lam),
            jnp.asarray(bc1, jnp.float32),
            jnp.asarray(bc2, jnp.float32),
        ]
    )
    m_new, v_new, d, tss, dss = adam_dir(theta, m, v, g, scalars, block=block)
    r = ref.trust_ratio_ref(tss, dss, rho)
    scale = (jnp.asarray(lr, jnp.float32) * r)[None]
    return apply_update(theta, d, scale, block=block), m_new, v_new


def vmem_bytes(block: int) -> int:
    """Per-block VMEM footprint in bytes (fp32): 4 in + 3 out tile arrays."""
    return 4 * (7 * block + 8)
