"""Pallas kernel: fused LSTM cell (gates + state update in one pass).

The paper's policy runs an LSTM over the visual features (§3.3). On GPU this
is cuDNN's persistent-RNN path; for TPU we block the fused gate GEMMs for the
MXU instead (DESIGN.md §Hardware-Adaptation):

- Weights are stored ``[Din, 4, H]`` / ``[H, 4, H]`` (gate axis *second*) so
  a BlockSpec slice ``[Din, 4, Ht]`` hands the kernel all four gates of one
  hidden tile contiguously — one MXU pass per (N-tile, H-tile) computes the
  4*Ht pre-activations for that tile.
- Gate nonlinearities and the c/h state update happen in-register before the
  single write of ``h'``/``c'`` — no HBM round trip for pre-activations.

Grid: 2-D ``(N/Nt, H/Ht)``. Per-block VMEM (fp32, paper scale Din=H=512,
Nt=128, Ht=128): x ``Nt*Din`` + h ``Nt*H`` + wx ``Din*4*Ht`` + wh ``H*4*Ht``
+ c ``Nt*Ht`` + outs ``2*Nt*Ht`` ≈ 2.7 MiB — fits VMEM with double-buffering
headroom.

interpret=True for CPU-PJRT execution (see se_excite.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h_out_ref, c_out_ref):
    """One (N-tile, H-tile): fused gates + state update. Gate order i,f,g,o."""
    x = x_ref[...]  # [Nt, Din]
    h = h_ref[...]  # [Nt, H]  (full H: both GEMMs reduce over the full axis)
    nt = x.shape[0]
    ht = c_ref.shape[1]
    wx = wx_ref[...].reshape(x.shape[1], 4 * ht)  # [Din, 4*Ht]
    wh = wh_ref[...].reshape(h.shape[1], 4 * ht)  # [H,   4*Ht]
    b = b_ref[...].reshape(4 * ht)
    gates = (
        jnp.dot(x, wx, preferred_element_type=jnp.float32)
        + jnp.dot(h, wh, preferred_element_type=jnp.float32)
        + b[None, :]
    ).reshape(nt, 4, ht)
    i = 1.0 / (1.0 + jnp.exp(-gates[:, 0]))
    f = 1.0 / (1.0 + jnp.exp(-gates[:, 1]))
    g = jnp.tanh(gates[:, 2])
    o = 1.0 / (1.0 + jnp.exp(-gates[:, 3]))
    c_new = f * c_ref[...] + i * g
    h_out_ref[...] = o * jnp.tanh(c_new)
    c_out_ref[...] = c_new


@functools.partial(jax.jit, static_argnames=("block_n", "block_h"))
def lstm_cell(x, h, c, wx, wh, b, *, block_n: int = 128, block_h: int = 128):
    """Fused LSTM step. Shapes as in ``ref.lstm_cell_ref``.

    Returns ``(h_new, c_new)`` each ``[N, H]``. N is padded to a multiple of
    ``block_n`` (rows independent, pads discarded); H must divide by
    ``block_h`` or ``block_h`` is shrunk to H.
    """
    n, din = x.shape
    hdim = h.shape[1]
    bn = min(block_n, max(n, 1))
    bh = min(block_h, hdim)
    if hdim % bh != 0:
        bh = hdim  # fall back to a single H tile
    n_pad = (-n) % bn
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
        h = jnp.pad(h, ((0, n_pad), (0, 0)))
        c = jnp.pad(c, ((0, n_pad), (0, 0)))
    grid = ((n + n_pad) // bn, hdim // bh)
    h_new, c_new = pl.pallas_call(
        _lstm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, din), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, hdim), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, bh), lambda i, j: (i, j)),
            pl.BlockSpec((din, 4, bh), lambda i, j: (0, 0, j)),
            pl.BlockSpec((hdim, 4, bh), lambda i, j: (0, 0, j)),
            pl.BlockSpec((4, bh), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bh), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bh), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + n_pad, hdim), jnp.float32),
            jax.ShapeDtypeStruct((n + n_pad, hdim), jnp.float32),
        ],
        interpret=True,
    )(x, h, c, wx, wh, b)
    return h_new[:n], c_new[:n]


def vmem_bytes(block_n: int, block_h: int, din: int, hdim: int) -> int:
    """Estimated per-block VMEM footprint in bytes (fp32) for DESIGN.md §Perf."""
    floats = (
        block_n * din
        + block_n * hdim
        + block_n * block_h
        + din * 4 * block_h
        + hdim * 4 * block_h
        + 4 * block_h
        + 2 * block_n * block_h
    )
    return 4 * floats


def mxu_macs(block_n: int, block_h: int, din: int, hdim: int) -> int:
    """MACs per block for the two gate GEMMs (MXU utilization estimate)."""
    return block_n * 4 * block_h * (din + hdim)
