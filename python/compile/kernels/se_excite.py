"""Pallas kernel: fused squeeze-excite gating MLP.

The SE block (Hu et al. 2018) used in every stage of the paper's SE-ResNet9
visual encoder (paper §3.3, r=16). On GPU this is two tiny cuBLAS calls plus
elementwise kernels; re-thought for TPU (see DESIGN.md §Hardware-Adaptation)
we fuse both matmuls and both nonlinearities into one kernel so the
``[C, C/r]`` / ``[C/r, C]`` weights stay resident in VMEM and the MXU runs
back-to-back without an HBM round trip.

Grid: 1-D over N tiles. Per-block VMEM footprint (fp32):
``Nt*C (in) + C*Cr + Cr + Cr*C + C (weights) + Nt*C (out)`` — for the paper's
largest stage (C=512, r=16, Nt=128) that is ~0.77 MiB, far under the 16 MiB
VMEM budget, so a single-level tiling suffices.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom calls; interpret mode lowers to plain HLO, which is what the Rust
runtime loads. Structure (BlockSpecs, fusion) is authored for real TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _se_kernel(pooled_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref):
    """One N-tile: sigmoid(relu(p @ w1 + b1) @ w2 + b2)."""
    p = pooled_ref[...]
    h = jnp.maximum(
        jnp.dot(p, w1_ref[...], preferred_element_type=jnp.float32)
        + b1_ref[...][None, :],
        0.0,
    )
    z = (
        jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
        + b2_ref[...][None, :]
    )
    out_ref[...] = 1.0 / (1.0 + jnp.exp(-z))


@functools.partial(jax.jit, static_argnames=("block_n",))
def se_excite(pooled, w1, b1, w2, b2, *, block_n: int = 128):
    """Fused SE gate. Shapes as in ``ref.se_excite_ref``; returns ``[N, C]``.

    N is padded up to a multiple of ``block_n`` (pad rows are computed and
    discarded — SE is row-independent so this is exact for the live rows).
    """
    n, c = pooled.shape
    cr = w1.shape[1]
    bn = min(block_n, max(n, 1))
    n_pad = (-n) % bn
    if n_pad:
        pooled = jnp.pad(pooled, ((0, n_pad), (0, 0)))
    grid = ((n + n_pad) // bn,)
    out = pl.pallas_call(
        _se_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, c), lambda i: (i, 0)),
            pl.BlockSpec((c, cr), lambda i: (0, 0)),
            pl.BlockSpec((cr,), lambda i: (0,)),
            pl.BlockSpec((cr, c), lambda i: (0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad, c), jnp.float32),
        interpret=True,
    )(pooled, w1, b1, w2, b2)
    return out[:n]


def vmem_bytes(block_n: int, c: int, r: int = 16) -> int:
    """Estimated per-block VMEM footprint in bytes (fp32) for DESIGN.md §Perf."""
    cr = max(c // r, 1)
    floats = block_n * c * 2 + c * cr * 2 + cr + c
    return 4 * floats
