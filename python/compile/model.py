"""L2: the paper's policy DNN in JAX (paper §3.3), calling the L1 kernels.

Architecture (BPS): SpaceToDepth stem → SE-ResNet9 visual encoder
(ResNet18 with every other block removed; Squeeze-Excite r=16 in every
stage; **no normalization layers** — Fixup initialization) → FC → concat
goal-sensor embedding → LSTM → actor/critic heads.

The BPS-R50 / WIJMANS20 ablations use a ResNet50 bottleneck encoder at
128×128 input instead (Table 1).

Everything here is build-time only: ``aot.py`` lowers jitted wrappers of
these functions to HLO text, and the Rust runtime executes the artifacts.
Parameters live in an ordered dict; ``flatten_params``/``param_layout``
define the flat ``f32[P]`` vector contract shared with Rust (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import ad as kad
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static policy-network configuration (fixed at AOT time)."""

    encoder: str = "se9"  # "se9" | "r50"
    res: int = 64  # input resolution (square)
    in_ch: int = 1  # 1 = Depth sensor, 3 = RGB camera
    base_c: int = 16  # stage-1 width (paper: 64; CPU default scaled down)
    hidden: int = 256  # LSTM hidden size (paper: 512)
    num_actions: int = 4  # forward / turn_left / turn_right / stop
    se_r: int = 16  # squeeze-excite reduction ratio
    goal_dim: int = 3  # GPS+compass: [dist, cos(theta), sin(theta)]
    goal_emb: int = 32
    use_pallas: bool = True  # False: pure-jnp oracles (debugging)

    @property
    def variant(self) -> str:
        """Short key used in artifact filenames."""
        sensor = "depth" if self.in_ch == 1 else "rgb"
        return f"{self.encoder}_{sensor}_r{self.res}_c{self.base_c}_h{self.hidden}"


Params = Dict[str, jnp.ndarray]

# ---------------------------------------------------------------------------
# Initialization (Fixup: Zhang et al. 2019 — paper §3.3)
# ---------------------------------------------------------------------------


def _he_normal(key, shape, fan_in, gain=1.0):
    std = gain * math.sqrt(2.0 / fan_in)
    return std * jax.random.normal(key, shape, jnp.float32)


def _conv_shape(k, cin, cout):
    return (k, k, cin, cout)  # HWIO


def _se9_stage_plan(cfg: ModelConfig) -> List[Tuple[int, int]]:
    """(channels, stride) per stage for the SE-ResNet9 encoder."""
    c = cfg.base_c
    return [(c, 1), (2 * c, 2), (4 * c, 2), (8 * c, 2)]


def _r50_stage_plan(cfg: ModelConfig) -> List[Tuple[int, int, int]]:
    """(width, stride, blocks) per stage for ResNet50."""
    c = cfg.base_c
    return [(c, 1, 3), (2 * c, 2, 4), (4 * c, 2, 6), (8 * c, 2, 3)]


def init_params(cfg: ModelConfig, key) -> Params:
    """Build the full parameter dict with Fixup initialization.

    Fixup rule for residual nets without normalization: scale the first
    conv(s) of each residual branch by ``L^(-1/(2m-2))`` (L = number of
    residual blocks, m = convs per branch), zero-init the last conv of each
    branch, add scalar biases around each conv and a per-block scale.
    """
    p: Params = {}
    keys = iter(jax.random.split(key, 4096))

    def nk():
        return next(keys)

    if cfg.encoder == "se9":
        stem_in = cfg.in_ch * 16  # SpaceToDepth factor 4 => 16x channels
        p["stem.w"] = _he_normal(
            nk(), _conv_shape(3, stem_in, cfg.base_c), 9 * stem_in
        )
        p["stem.b"] = jnp.zeros((cfg.base_c,), jnp.float32)
        plan = _se9_stage_plan(cfg)
        nblocks = len(plan)
        fixup_gain = nblocks ** (-0.5)  # m=2 convs per branch
        cin = cfg.base_c
        for i, (cout, stride) in enumerate(plan):
            pre = f"s{i}"
            p[f"{pre}.b1a"] = jnp.zeros((), jnp.float32)
            p[f"{pre}.conv1.w"] = _he_normal(
                nk(), _conv_shape(3, cin, cout), 9 * cin, gain=fixup_gain
            )
            p[f"{pre}.b1b"] = jnp.zeros((), jnp.float32)
            p[f"{pre}.b2a"] = jnp.zeros((), jnp.float32)
            p[f"{pre}.conv2.w"] = jnp.zeros(_conv_shape(3, cout, cout), jnp.float32)
            p[f"{pre}.scale"] = jnp.ones((), jnp.float32)
            p[f"{pre}.b2b"] = jnp.zeros((), jnp.float32)
            cr = max(cout // cfg.se_r, 4)
            p[f"{pre}.se.w1"] = _he_normal(nk(), (cout, cr), cout)
            p[f"{pre}.se.b1"] = jnp.zeros((cr,), jnp.float32)
            p[f"{pre}.se.w2"] = _he_normal(nk(), (cr, cout), cr)
            p[f"{pre}.se.b2"] = jnp.zeros((cout,), jnp.float32)
            if stride != 1 or cin != cout:
                p[f"{pre}.proj.w"] = _he_normal(nk(), _conv_shape(1, cin, cout), cin)
                p[f"{pre}.proj.b"] = jnp.zeros((cout,), jnp.float32)
            cin = cout
        feat_hw = cfg.res // 4 // 8  # stem /4, strides 1,2,2,2 => /8
        feat_dim = feat_hw * feat_hw * cin
    elif cfg.encoder == "r50":
        p["stem.w"] = _he_normal(
            nk(), _conv_shape(7, cfg.in_ch, cfg.base_c), 49 * cfg.in_ch
        )
        p["stem.b"] = jnp.zeros((cfg.base_c,), jnp.float32)
        plan = _r50_stage_plan(cfg)
        nblocks = sum(b for _, _, b in plan)
        fixup_gain = nblocks ** (-0.25)  # m=3 convs per branch
        cin = cfg.base_c
        for i, (width, stride, blocks) in enumerate(plan):
            cout = width * 4
            for j in range(blocks):
                pre = f"s{i}b{j}"
                s = stride if j == 0 else 1
                p[f"{pre}.b1a"] = jnp.zeros((), jnp.float32)
                p[f"{pre}.conv1.w"] = _he_normal(
                    nk(), _conv_shape(1, cin, width), cin, gain=fixup_gain
                )
                p[f"{pre}.b1b"] = jnp.zeros((), jnp.float32)
                p[f"{pre}.b2a"] = jnp.zeros((), jnp.float32)
                p[f"{pre}.conv2.w"] = _he_normal(
                    nk(), _conv_shape(3, width, width), 9 * width, gain=fixup_gain
                )
                p[f"{pre}.b2b"] = jnp.zeros((), jnp.float32)
                p[f"{pre}.b3a"] = jnp.zeros((), jnp.float32)
                p[f"{pre}.conv3.w"] = jnp.zeros(
                    _conv_shape(1, width, cout), jnp.float32
                )
                p[f"{pre}.scale"] = jnp.ones((), jnp.float32)
                p[f"{pre}.b3b"] = jnp.zeros((), jnp.float32)
                if s != 1 or cin != cout:
                    p[f"{pre}.proj.w"] = _he_normal(
                        nk(), _conv_shape(1, cin, cout), cin
                    )
                    p[f"{pre}.proj.b"] = jnp.zeros((cout,), jnp.float32)
                cin = cout
        feat_hw = cfg.res // 4 // 8  # stem /2, maxpool /2, strides 1,2,2,2
        feat_dim = feat_hw * feat_hw * cin
    else:
        raise ValueError(f"unknown encoder {cfg.encoder!r}")

    p["fc_vis.w"] = _he_normal(nk(), (feat_dim, cfg.hidden), feat_dim)
    p["fc_vis.b"] = jnp.zeros((cfg.hidden,), jnp.float32)
    p["goal.w"] = _he_normal(nk(), (cfg.goal_dim, cfg.goal_emb), cfg.goal_dim)
    p["goal.b"] = jnp.zeros((cfg.goal_emb,), jnp.float32)

    din = cfg.hidden + cfg.goal_emb
    h = cfg.hidden
    p["lstm.wx"] = _he_normal(nk(), (din, 4, h), din, gain=0.5)
    p["lstm.wh"] = _he_normal(nk(), (h, 4, h), h, gain=0.5)
    b = jnp.zeros((4, h), jnp.float32)
    p["lstm.b"] = b.at[1].set(1.0)  # forget-gate bias 1.0
    p["actor.w"] = _he_normal(nk(), (h, cfg.num_actions), h, gain=0.01)
    p["actor.b"] = jnp.zeros((cfg.num_actions,), jnp.float32)
    p["critic.w"] = _he_normal(nk(), (h, 1), h)
    p["critic.b"] = jnp.zeros((1,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# Flat-vector contract (shared with Rust: DESIGN.md §2)
# ---------------------------------------------------------------------------

_LAYOUT_CACHE: Dict[str, List[Tuple[str, int, Tuple[int, ...]]]] = {}


def param_layout(cfg: ModelConfig) -> List[Tuple[str, int, Tuple[int, ...]]]:
    """``[(name, offset, shape)]`` in flat-vector order (sorted by name)."""
    key = cfg.variant
    if key not in _LAYOUT_CACHE:
        shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        layout = []
        off = 0
        for name in sorted(shapes):
            arr = shapes[name]
            layout.append((name, off, tuple(arr.shape)))
            off += int(math.prod(arr.shape)) if arr.shape else 1
        _LAYOUT_CACHE[key] = layout
    return _LAYOUT_CACHE[key]


def num_params(cfg: ModelConfig) -> int:
    lay = param_layout(cfg)
    name, off, shape = lay[-1]
    return off + (int(math.prod(shape)) if shape else 1)


def flatten_params(params: Params) -> jnp.ndarray:
    """Concatenate all tensors (sorted-key order — the canonical layout,
    stable across jit boundaries since jax pytrees sort dict keys)."""
    return jnp.concatenate([jnp.ravel(params[k]) for k in sorted(params)])


def unflatten_params(cfg: ModelConfig, flat: jnp.ndarray) -> Params:
    """Slice the flat vector back into the parameter dict (trace-time loop)."""
    out: Params = {}
    for name, off, shape in param_layout(cfg):
        size = int(math.prod(shape)) if shape else 1
        out[name] = jax.lax.slice(flat, (off,), (off + size,)).reshape(shape)
    return out


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def space_to_depth(x, factor=4):
    """[N,H,W,C] -> [N,H/f,W/f,C*f*f] (TResNet stem; paper §3.3)."""
    n, h, w, c = x.shape
    x = x.reshape(n, h // factor, factor, w // factor, factor, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(n, h // factor, w // factor, c * factor * factor)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _avg_pool(x, stride):
    if stride == 1:
        return x
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, stride, stride, 1), (1, stride, stride, 1), "SAME"
    ) / float(stride * stride)


def _max_pool(x, k, stride):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, stride, stride, 1), "SAME"
    )


def _se_gate(cfg: ModelConfig, y, w1, b1, w2, b2):
    pooled = jnp.mean(y, axis=(1, 2))
    if cfg.use_pallas:
        gate = kad.se_excite(pooled, w1, b1, w2, b2)
    else:
        gate = kref.se_excite_ref(pooled, w1, b1, w2, b2)
    return y * gate[:, None, None, :]


def _se9_block(cfg: ModelConfig, p: Params, pre: str, x, cout, stride):
    if f"{pre}.proj.w" in p:
        identity = _conv(_avg_pool(x, stride), p[f"{pre}.proj.w"]) + p[f"{pre}.proj.b"]
    else:
        identity = x
    y = _conv(x + p[f"{pre}.b1a"], p[f"{pre}.conv1.w"], stride)
    y = jnp.maximum(y + p[f"{pre}.b1b"], 0.0)
    y = _conv(y + p[f"{pre}.b2a"], p[f"{pre}.conv2.w"])
    y = y * p[f"{pre}.scale"] + p[f"{pre}.b2b"]
    y = _se_gate(
        cfg,
        y,
        p[f"{pre}.se.w1"],
        p[f"{pre}.se.b1"],
        p[f"{pre}.se.w2"],
        p[f"{pre}.se.b2"],
    )
    return jnp.maximum(y + identity, 0.0)


def _r50_block(p: Params, pre: str, x, width, stride):
    if f"{pre}.proj.w" in p:
        identity = _conv(_avg_pool(x, stride), p[f"{pre}.proj.w"]) + p[f"{pre}.proj.b"]
    else:
        identity = x
    y = _conv(x + p[f"{pre}.b1a"], p[f"{pre}.conv1.w"])
    y = jnp.maximum(y + p[f"{pre}.b1b"], 0.0)
    y = _conv(y + p[f"{pre}.b2a"], p[f"{pre}.conv2.w"], stride)
    y = jnp.maximum(y + p[f"{pre}.b2b"], 0.0)
    y = _conv(y + p[f"{pre}.b3a"], p[f"{pre}.conv3.w"])
    y = y * p[f"{pre}.scale"] + p[f"{pre}.b3b"]
    return jnp.maximum(y + identity, 0.0)


def encode_visual(cfg: ModelConfig, p: Params, obs):
    """Visual encoder: ``[N,R,R,C]`` float in [0,1] → ``[N,hidden]``."""
    if cfg.encoder == "se9":
        x = space_to_depth(obs, 4)
        x = jnp.maximum(_conv(x, p["stem.w"]) + p["stem.b"], 0.0)
        for i, (cout, stride) in enumerate(_se9_stage_plan(cfg)):
            x = _se9_block(cfg, p, f"s{i}", x, cout, stride)
    else:
        x = jnp.maximum(_conv(obs, p["stem.w"], 2) + p["stem.b"], 0.0)
        x = _max_pool(x, 3, 2)
        for i, (width, stride, blocks) in enumerate(_r50_stage_plan(cfg)):
            for j in range(blocks):
                x = _r50_block(p, f"s{i}b{j}", x, width, stride if j == 0 else 1)
    n = x.shape[0]
    flat = x.reshape(n, -1)
    return jnp.maximum(flat @ p["fc_vis.w"] + p["fc_vis.b"], 0.0)


def _lstm(cfg: ModelConfig, p: Params, x, h, c):
    if cfg.use_pallas:
        return kad.lstm_cell(x, h, c, p["lstm.wx"], p["lstm.wh"], p["lstm.b"])
    return kref.lstm_cell_ref(x, h, c, p["lstm.wx"], p["lstm.wh"], p["lstm.b"])


def policy_step(cfg: ModelConfig, p: Params, obs, goal, h, c):
    """One inference step (rollout hot path).

    Args:
      obs: ``[N,R,R,C]`` in [0,1]; goal: ``[N,3]``; h, c: ``[N,hidden]``.

    Returns:
      ``(logits[N,A], value[N], h_new, c_new)``.
    """
    vis = encode_visual(cfg, p, obs)
    gemb = jnp.maximum(goal @ p["goal.w"] + p["goal.b"], 0.0)
    x = jnp.concatenate([vis, gemb], axis=-1)
    h_new, c_new = _lstm(cfg, p, x, h, c)
    logits = h_new @ p["actor.w"] + p["actor.b"]
    value = (h_new @ p["critic.w"] + p["critic.b"])[:, 0]
    return logits, value, h_new, c_new


def policy_sequence(cfg: ModelConfig, p: Params, obs, goal, h0, c0, notdone):
    """BPTT forward over an L-step rollout slice (training path).

    Args:
      obs: ``[B,L,R,R,C]``; goal: ``[B,L,3]``; h0, c0: ``[B,hidden]``;
      notdone: ``[B,L]`` — 0 where step t begins a fresh episode (hidden
      state reset, DD-PPO behaviour), else 1.

    Returns:
      ``(logits[B,L,A], values[B,L])``.
    """
    b, l = obs.shape[0], obs.shape[1]
    # Encode all frames at once: better XLA fusion than per-step convs.
    vis = encode_visual(cfg, p, obs.reshape((b * l,) + obs.shape[2:]))
    vis = vis.reshape(b, l, -1)
    gemb = jnp.maximum(goal @ p["goal.w"] + p["goal.b"], 0.0)
    x_seq = jnp.concatenate([vis, gemb], axis=-1)  # [B,L,Din]

    def step(carry, inp):
        h, c = carry
        x_t, nd_t = inp
        h = h * nd_t[:, None]
        c = c * nd_t[:, None]
        h, c = _lstm(cfg, p, x_t, h, c)
        return (h, c), h

    xs = (x_seq.transpose(1, 0, 2), notdone.transpose(1, 0))
    (_, _), hs = jax.lax.scan(step, (h0, c0), xs)
    hs = hs.transpose(1, 0, 2)  # [B,L,H]
    logits = hs @ p["actor.w"] + p["actor.b"]
    values = (hs @ p["critic.w"] + p["critic.b"])[..., 0]
    return logits, values
