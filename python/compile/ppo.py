"""PPO loss and gradient computation (paper §3.4, Table A4 hyper-params).

The ``grad`` AOT artifact wraps :func:`ppo_grad`: given the flat parameter
vector and one minibatch (a slice over the env dimension of a rollout, full
L-step sequences for BPTT), it returns the flat gradient vector and the loss
diagnostics. Gradient *application* is a separate artifact (optim.py) so the
Rust coordinator can average gradients across DD-PPO shards in between —
exactly the paper's multi-GPU dataflow.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import model as M


@dataclasses.dataclass(frozen=True)
class PpoConfig:
    """PPO hyper-parameters (paper Table A4)."""

    clip: float = 0.2
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    max_grad_norm: float = 1.0
    # gamma / gae_lambda live in the Rust coordinator (GAE runs in Rust).


def _log_softmax(logits):
    m = jnp.max(logits, axis=-1, keepdims=True)
    z = logits - m
    return z - jnp.log(jnp.sum(jnp.exp(z), axis=-1, keepdims=True))


def ppo_loss(cfg: M.ModelConfig, pcfg: PpoConfig, params, batch):
    """PPO clipped surrogate + value + entropy losses over one minibatch.

    ``batch`` fields (B = minibatch envs, L = rollout length):
      obs[B,L,R,R,C], goal[B,L,3], h0[B,H], c0[B,H], actions i32[B,L],
      logp_old[B,L], returns[B,L], adv[B,L], notdone[B,L].

    Returns ``(total_loss, aux[4])`` with aux = [policy, value, entropy,
    approx_kl] for the metrics pipeline.
    """
    obs, goal, h0, c0, actions, logp_old, returns, adv, notdone = batch
    logits, values = M.policy_sequence(cfg, params, obs, goal, h0, c0, notdone)
    logp_all = _log_softmax(logits)
    logp = jnp.take_along_axis(logp_all, actions[..., None], axis=-1)[..., 0]

    ratio = jnp.exp(logp - logp_old)
    surr1 = ratio * adv
    surr2 = jnp.clip(ratio, 1.0 - pcfg.clip, 1.0 + pcfg.clip) * adv
    policy_loss = -jnp.mean(jnp.minimum(surr1, surr2))

    value_loss = 0.5 * jnp.mean((returns - values) ** 2)

    probs = jnp.exp(logp_all)
    entropy = -jnp.mean(jnp.sum(probs * logp_all, axis=-1))

    approx_kl = jnp.mean(logp_old - logp)

    total = (
        policy_loss
        + pcfg.value_coef * value_loss
        - pcfg.entropy_coef * entropy
    )
    return total, jnp.stack([policy_loss, value_loss, entropy, approx_kl])


def clip_grad_norm(flat_grad, max_norm):
    """Global-norm gradient clipping over the flat gradient vector."""
    norm = jnp.sqrt(jnp.sum(flat_grad * flat_grad))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return flat_grad * scale


def ppo_grad(cfg: M.ModelConfig, pcfg: PpoConfig, flat_params, batch):
    """Flat-in/flat-out gradient step (the ``grad`` artifact body).

    Returns ``(flat_grads[P], losses[4])``. Gradients are global-norm
    clipped here (Table A4: max grad norm 1.0) so shard averaging in Rust
    composes with clipping the same way DD-PPO does (clip before reduce).
    """

    def loss_fn(flat):
        params = M.unflatten_params(cfg, flat)
        return ppo_loss(cfg, pcfg, params, batch)

    (_, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(flat_params)
    g = clip_grad_norm(g, pcfg.max_grad_norm)
    return g, aux
