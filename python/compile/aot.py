"""AOT export: lower the L2/L1 computations to HLO text + manifest.json.

This is the single point where Python runs in the system — at build time
(``make artifacts``). It lowers jitted wrappers of the model/PPO/optimizer
functions to **HLO text** (not serialized HloModuleProto: jax >= 0.5 emits
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly — see /opt/xla-example/README.md) and
writes ``artifacts/manifest.json`` describing every artifact so the Rust
runtime can load, compile, and execute them without any Python knowledge.

Artifact kinds per variant (DESIGN.md §2):

  init          (seed i32[])                                -> params f32[P]
  infer_n{N}    (params, obs[N,R,R,C], goal[N,3], h, c)     -> (logits, value, h', c')
  grad_b{B}l{L} (params, obs[B,L,R,R,C], goal, h0, c0,
                 act i32[B,L], logp_old, ret, adv, notdone) -> (grads[P], losses[4])
  update_lamb   (params, m, v, step[], grads, lr[])         -> (params', m', v', step')
  update_adam   same signature (Fig. A3 ablation)

Usage: ``python -m compile.aot --out-dir ../artifacts --presets default``
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import optim as O
from . import ppo as P


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


@dataclasses.dataclass(frozen=True)
class Preset:
    """One model variant plus the batch geometries to export for it."""

    name: str
    cfg: M.ModelConfig
    infer_ns: Tuple[int, ...]
    grad_bls: Tuple[Tuple[int, int], ...]  # (B = minibatch envs, L = rollout len)


def presets_table() -> Dict[str, Preset]:
    """All exportable variants. Widths are CPU-scaled (DESIGN.md §1);
    ``base_c=16, hidden=256`` vs the paper's 64/512 — the FLOP ratio between
    systems (SE-ResNet9@64 vs ResNet50@128) is preserved."""
    t = {}

    def add(name, cfg, infer_ns, grad_bls):
        t[name] = Preset(name, cfg, tuple(infer_ns), tuple(grad_bls))

    se9 = dict(encoder="se9", base_c=16, hidden=256)
    r50 = dict(encoder="r50", base_c=16, hidden=256)
    # Tiny variant for fast unit/integration tests on the Rust side.
    add(
        "test",
        M.ModelConfig(encoder="se9", res=32, in_ch=1, base_c=8, hidden=64),
        [4],
        [(2, 4)],
    )
    # Main Depth agent (BPS row of Table 1; e2e training example).
    add("depth64", M.ModelConfig(res=64, in_ch=1, **se9), [4, 16, 64, 128, 256], [(8, 16), (32, 32)])
    # RGB agent (BPS RGB rows).
    add("rgb64", M.ModelConfig(res=64, in_ch=3, **se9), [16, 64, 128], [(8, 16), (32, 32)])
    # Resolution ablation (Table A1): SE-ResNet9 at 128px.
    add("depth128", M.ModelConfig(res=128, in_ch=1, **se9), [16, 64], [(8, 16)])
    add("rgb128", M.ModelConfig(res=128, in_ch=3, **se9), [16, 64], [(8, 16)])
    # BPS-R50 / WIJMANS20 encoder (Table 1, Table A1/A2).
    add("r50_depth128", M.ModelConfig(res=128, in_ch=1, **r50), [16], [(4, 16)])
    add("r50_rgb128", M.ModelConfig(res=128, in_ch=3, **r50), [16], [(4, 16)])
    add("r50_depth64", M.ModelConfig(res=64, in_ch=1, **r50), [16], [(4, 16)])
    return t


DEFAULT_PRESETS = ("test", "depth64")
BENCH_PRESETS = tuple(presets_table().keys())


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_init(cfg: M.ModelConfig) -> str:
    def init_fn(seed):
        key = jax.random.PRNGKey(seed)
        return (M.flatten_params(M.init_params(cfg, key)),)

    return to_hlo_text(jax.jit(init_fn).lower(_sds((), jnp.int32)))


def lower_infer(cfg: M.ModelConfig, n: int) -> str:
    p = M.num_params(cfg)

    def infer_fn(flat, obs, goal, h, c):
        params = M.unflatten_params(cfg, flat)
        return M.policy_step(cfg, params, obs, goal, h, c)

    return to_hlo_text(
        jax.jit(infer_fn).lower(
            _sds((p,)),
            _sds((n, cfg.res, cfg.res, cfg.in_ch)),
            _sds((n, cfg.goal_dim)),
            _sds((n, cfg.hidden)),
            _sds((n, cfg.hidden)),
        )
    )


def lower_grad(cfg: M.ModelConfig, b: int, l: int, pcfg: P.PpoConfig) -> str:
    p = M.num_params(cfg)

    def grad_fn(flat, obs, goal, h0, c0, act, logp_old, ret, adv, notdone):
        batch = (obs, goal, h0, c0, act, logp_old, ret, adv, notdone)
        return P.ppo_grad(cfg, pcfg, flat, batch)

    return to_hlo_text(
        jax.jit(grad_fn).lower(
            _sds((p,)),
            _sds((b, l, cfg.res, cfg.res, cfg.in_ch)),
            _sds((b, l, cfg.goal_dim)),
            _sds((b, cfg.hidden)),
            _sds((b, cfg.hidden)),
            _sds((b, l), jnp.int32),
            _sds((b, l)),
            _sds((b, l)),
            _sds((b, l)),
            _sds((b, l)),
        )
    )


def lower_update(cfg: M.ModelConfig, ocfg: O.OptimConfig, algo: str) -> str:
    p = M.num_params(cfg)

    def update_fn(flat, m, v, step, grads, lr):
        return O.update(cfg, ocfg, flat, m, v, step, grads, lr, algo=algo)

    return to_hlo_text(
        jax.jit(update_fn).lower(
            _sds((p,)), _sds((p,)), _sds((p,)), _sds(()), _sds((p,)), _sds(())
        )
    )


def export_preset(preset: Preset, out_dir: str, verbose: bool = True) -> dict:
    """Lower every artifact of one preset; returns its manifest entry."""
    cfg = preset.cfg
    pcfg = P.PpoConfig()
    ocfg = O.OptimConfig()
    files = {}

    def emit(kind: str, text: str):
        fname = f"{preset.name}_{kind}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[kind] = fname
        if verbose:
            print(f"  {fname}: {len(text) / 1e6:.2f} MB")

    emit("init", lower_init(cfg))
    for n in preset.infer_ns:
        emit(f"infer_n{n}", lower_infer(cfg, n))
    for b, l in preset.grad_bls:
        emit(f"grad_b{b}l{l}", lower_grad(cfg, b, l, pcfg))
    emit("update_lamb", lower_update(cfg, ocfg, "lamb"))
    emit("update_adam", lower_update(cfg, ocfg, "adam"))

    layout = [
        {"name": name, "offset": off, "shape": list(shape)}
        for name, off, shape in M.param_layout(cfg)
    ]
    return {
        "name": preset.name,
        "encoder": cfg.encoder,
        "res": cfg.res,
        "in_ch": cfg.in_ch,
        "base_c": cfg.base_c,
        "hidden": cfg.hidden,
        "num_actions": cfg.num_actions,
        "goal_dim": cfg.goal_dim,
        "num_params": M.num_params(cfg),
        "infer_ns": list(preset.infer_ns),
        "grad_bls": [list(x) for x in preset.grad_bls],
        "ppo": dataclasses.asdict(pcfg),
        "optim": dataclasses.asdict(ocfg),
        "files": files,
        "layout": layout,
    }


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--presets",
        default="default",
        help="comma list of preset names, or 'default' / 'all'",
    )
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    table = presets_table()
    if args.presets == "default":
        names: List[str] = list(DEFAULT_PRESETS)
    elif args.presets == "all":
        names = list(BENCH_PRESETS)
    else:
        names = [s.strip() for s in args.presets.split(",") if s.strip()]
    for n in names:
        if n not in table:
            raise SystemExit(f"unknown preset {n!r}; have {sorted(table)}")

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"version": 1, "variants": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
        manifest.setdefault("variants", {})

    for name in names:
        if not args.quiet:
            print(f"exporting preset {name} ...")
        manifest["variants"][name] = export_preset(
            table[name], args.out_dir, verbose=not args.quiet
        )
        # Write incrementally so a crash keeps completed variants usable.
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1)
    print(f"wrote {manifest_path} ({len(manifest['variants'])} variants)")


if __name__ == "__main__":
    main()
