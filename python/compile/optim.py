"""Optimizer update artifacts: Lamb (paper §3.4) and Adam (Fig. A3 baseline).

Both operate on the flat parameter/moment vectors, looping over the layer
layout at trace time so each layer gets its own trust ratio (Lamb) while the
Rust side only ever sees four flat buffers (params, m, v, step).

Parameter grouping (paper Appendix B): matrix-shaped parameters (ndim >= 2:
convs, FCs, LSTM weights) use the clipped trust ratio with rho = 0.01;
bias / fixup-scalar / gain parameters (ndim < 2) use rho = 1.0, which makes
the update exactly AdamW for those groups.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from . import model as M
from .kernels import lamb as lamb_kernel
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    """Lamb/Adam hyper-parameters (paper Table A4)."""

    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01  # lambda
    rho: float = 0.01  # trust-ratio clip for matrix params
    rho_scalar: float = 1.0  # bias/fixup/gain params -> plain AdamW


def _layer_slices(cfg: M.ModelConfig):
    """Yield (name, offset, size, is_matrix) over the flat layout."""
    for name, off, shape in M.param_layout(cfg):
        size = int(math.prod(shape)) if shape else 1
        yield name, off, size, len(shape) >= 2


def update(
    cfg: M.ModelConfig,
    ocfg: OptimConfig,
    flat_params,
    m,
    v,
    step,
    flat_grads,
    lr,
    *,
    algo: str = "lamb",
    use_pallas: bool = True,
):
    """One optimizer step over the flat vectors.

    Args:
      flat_params, m, v, flat_grads: ``f32[P]``.
      step: ``f32[]`` scalar step count *before* this update.
      lr: ``f32[]`` scalar learning rate (the schedule lives in Rust).
      algo: "lamb" (paper) or "adam" (Fig. A3 ablation; plain AdamW, i.e.
        trust ratio pinned to 1 for every group).

    Returns:
      ``(flat_params', m', v', step')``.
    """
    step_new = step + 1.0
    new_p = []
    new_m = []
    new_v = []
    for name, off, size, is_matrix in _layer_slices(cfg):
        theta = jnp.ravel(jnp.asarray(flat_params[off : off + size]))
        mm = m[off : off + size]
        vv = v[off : off + size]
        g = flat_grads[off : off + size]
        if algo == "lamb":
            rho = ocfg.rho if is_matrix else ocfg.rho_scalar
        else:
            rho = 1.0
        kw = dict(
            lr=lr,
            beta1=ocfg.beta1,
            beta2=ocfg.beta2,
            eps=ocfg.eps,
            lam=ocfg.weight_decay,
            rho=rho,
            step=step_new,
        )
        if use_pallas:
            t2, m2, v2 = lamb_kernel.lamb_layer(theta, mm, vv, g, **kw)
        else:
            t2, m2, v2 = kref.lamb_layer_ref(theta, mm, vv, g, **kw)
        new_p.append(t2)
        new_m.append(m2)
        new_v.append(v2)
    return (
        jnp.concatenate(new_p),
        jnp.concatenate(new_m),
        jnp.concatenate(new_v),
        step_new,
    )
