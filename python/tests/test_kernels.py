"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

Hypothesis sweeps shapes and block sizes; every property asserts
``assert_allclose`` between the interpret-mode Pallas kernel and ``ref.py``.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ad, lamb, lstm_cell, ref, se_excite

SETTINGS = dict(max_examples=25, deadline=None)


def _arr(rng, shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# se_excite
# ---------------------------------------------------------------------------


@hypothesis.given(
    n=st.integers(1, 70),
    c=st.sampled_from([8, 16, 32, 64]),
    r=st.sampled_from([4, 8, 16]),
    block_n=st.sampled_from([4, 16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_se_excite_matches_ref(n, c, r, block_n, seed):
    rng = np.random.default_rng(seed)
    cr = max(c // r, 1)
    pooled = _arr(rng, (n, c))
    w1, b1 = _arr(rng, (c, cr), 0.2), _arr(rng, (cr,), 0.2)
    w2, b2 = _arr(rng, (cr, c), 0.2), _arr(rng, (c,), 0.2)
    out = se_excite.se_excite(pooled, w1, b1, w2, b2, block_n=block_n)
    expect = ref.se_excite_ref(pooled, w1, b1, w2, b2)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)
    assert out.shape == (n, c)
    assert np.all(out >= 0.0) and np.all(out <= 1.0)


def test_se_excite_vmem_budget():
    """Paper-scale largest stage fits VMEM with headroom (DESIGN.md §Perf)."""
    assert se_excite.vmem_bytes(128, 512, 16) < 16 * 1024 * 1024 // 4


def test_se_excite_grad_matches_ref():
    rng = np.random.default_rng(0)
    c, cr = 32, 2
    args = (
        _arr(rng, (8, c)),
        _arr(rng, (c, cr), 0.2),
        _arr(rng, (cr,), 0.2),
        _arr(rng, (cr, c), 0.2),
        _arr(rng, (c,), 0.2),
    )
    for argnum in range(5):
        g = jax.grad(lambda *a: jnp.sum(ad.se_excite(*a)), argnums=argnum)(*args)
        gr = jax.grad(lambda *a: jnp.sum(ref.se_excite_ref(*a)), argnums=argnum)(*args)
        np.testing.assert_allclose(g, gr, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# lstm_cell
# ---------------------------------------------------------------------------


@hypothesis.given(
    n=st.integers(1, 40),
    din=st.sampled_from([8, 24, 64]),
    h=st.sampled_from([16, 32, 64]),
    block_n=st.sampled_from([4, 8, 128]),
    block_h=st.sampled_from([16, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_lstm_cell_matches_ref(n, din, h, block_n, block_h, seed):
    rng = np.random.default_rng(seed)
    x, hh, cc = _arr(rng, (n, din), 0.5), _arr(rng, (n, h), 0.5), _arr(rng, (n, h), 0.5)
    wx, wh, b = _arr(rng, (din, 4, h), 0.2), _arr(rng, (h, 4, h), 0.2), _arr(rng, (4, h), 0.2)
    h_new, c_new = lstm_cell.lstm_cell(
        x, hh, cc, wx, wh, b, block_n=block_n, block_h=block_h
    )
    h_ref, c_ref = ref.lstm_cell_ref(x, hh, cc, wx, wh, b)
    np.testing.assert_allclose(h_new, h_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_new, c_ref, rtol=1e-5, atol=1e-6)


def test_lstm_cell_state_bounded():
    """|h| <= 1 always (o*tanh); c bounded by f*c0 + i*g geometric sum."""
    rng = np.random.default_rng(1)
    n, din, h = 16, 32, 32
    x = _arr(rng, (n, din), 3.0)
    hh = np.zeros((n, h), np.float32)
    cc = np.zeros((n, h), np.float32)
    wx, wh, b = _arr(rng, (din, 4, h), 1.0), _arr(rng, (h, 4, h), 1.0), _arr(rng, (4, h))
    for _ in range(8):
        hh, cc = lstm_cell.lstm_cell(x, hh, cc, wx, wh, b)
        hh, cc = np.asarray(hh), np.asarray(cc)
    assert np.all(np.abs(hh) <= 1.0 + 1e-6)


def test_lstm_cell_grad_matches_ref():
    rng = np.random.default_rng(2)
    n, din, h = 5, 12, 16
    args = (
        _arr(rng, (n, din), 0.5),
        _arr(rng, (n, h), 0.5),
        _arr(rng, (n, h), 0.5),
        _arr(rng, (din, 4, h), 0.2),
        _arr(rng, (h, 4, h), 0.2),
        _arr(rng, (4, h), 0.2),
    )
    for argnum in range(6):
        g = jax.grad(
            lambda *a: jnp.sum(ad.lstm_cell(*a)[0] + ad.lstm_cell(*a)[1]),
            argnums=argnum,
        )(*args)
        gr = jax.grad(
            lambda *a: jnp.sum(ref.lstm_cell_ref(*a)[0] + ref.lstm_cell_ref(*a)[1]),
            argnums=argnum,
        )(*args)
        np.testing.assert_allclose(g, gr, rtol=1e-4, atol=1e-6)


def test_lstm_vmem_budget_paper_scale():
    assert lstm_cell.vmem_bytes(128, 128, 544, 512) < 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# lamb
# ---------------------------------------------------------------------------


@hypothesis.given(
    p=st.integers(1, 5000),
    block=st.sampled_from([64, 256, 65536]),
    step=st.integers(1, 1000),
    rho=st.sampled_from([1e-4, 1e-3, 1e-2, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_lamb_layer_matches_ref(p, block, step, rho, seed):
    rng = np.random.default_rng(seed)
    theta = _arr(rng, (p,))
    m = _arr(rng, (p,), 0.01)
    v = np.abs(_arr(rng, (p,), 0.01))
    g = _arr(rng, (p,), 0.1)
    kw = dict(lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8, lam=0.01, rho=rho, step=step)
    t1, m1, v1 = lamb.lamb_layer(theta, m, v, g, block=block, **kw)
    t2, m2, v2 = ref.lamb_layer_ref(theta, m, v, g, **kw)
    np.testing.assert_allclose(t1, t2, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(m1, m2, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(v1, v2, rtol=1e-5, atol=1e-7)


def test_lamb_zero_init_layer_uses_rho_floor():
    """Zero-init layers (fixup conv2/conv3): phi(0)=0 -> r clipped up to rho.

    This is the paper's observation that the rho clip matters exactly at the
    start of training for zero-initialized layers.
    """
    p = 64
    theta = np.zeros(p, np.float32)
    m = np.zeros(p, np.float32)
    v = np.zeros(p, np.float32)
    g = np.ones(p, np.float32)
    rho = 0.01
    t1, _, _ = lamb.lamb_layer(
        theta, m, v, g, lr=1.0, beta1=0.9, beta2=0.999, eps=1e-8, lam=0.01,
        rho=rho, step=1,
    )
    # direction ~= 1 elementwise; update magnitude must be ~rho * lr
    np.testing.assert_allclose(np.asarray(t1), -rho * np.ones(p), rtol=1e-3)


def test_lamb_rho_one_is_adamw():
    """rho=1 pins the trust ratio to 1: the update equals plain AdamW."""
    rng = np.random.default_rng(3)
    p = 257
    theta, g = _arr(rng, (p,)), _arr(rng, (p,), 0.1)
    m = np.zeros(p, np.float32)
    v = np.zeros(p, np.float32)
    lr, b1, b2, eps, lam = 1e-3, 0.9, 0.999, 1e-8, 0.01
    t1, _, _ = lamb.lamb_layer(
        theta, m, v, g, lr=lr, beta1=b1, beta2=b2, eps=eps, lam=lam, rho=1.0, step=1
    )
    # manual AdamW step
    m2 = (1 - b1) * g
    v2 = (1 - b2) * g * g
    d = (m2 / (1 - b1)) / (np.sqrt(v2 / (1 - b2)) + eps) + lam * theta
    np.testing.assert_allclose(np.asarray(t1), theta - lr * d, rtol=1e-4, atol=1e-6)


def test_trust_ratio_clip_bounds():
    for tss, dss in [(0.0, 1.0), (1e6, 1e-8), (1.0, 1.0), (100.0, 1e4)]:
        r = float(ref.trust_ratio_ref(jnp.float32(tss), jnp.float32(dss), 0.01))
        assert 0.01 - 1e-6 <= r <= 100.0 + 1e-4


def test_adam_dir_partial_sums_exact():
    """Padding tail must not leak into the norm reductions."""
    rng = np.random.default_rng(4)
    p = 100  # not a multiple of block
    theta, g = _arr(rng, (p,)), _arr(rng, (p,), 0.1)
    m = _arr(rng, (p,), 0.01)
    v = np.abs(_arr(rng, (p,), 0.01))
    scal = np.array([0.9, 0.999, 1e-8, 0.01, 10.0, 31.6], np.float32)
    m1, v1, d, tss, dss = lamb.adam_dir(theta, m, v, g, scal, block=64)
    _, _, d_ref, tss_ref, dss_ref = ref.adam_dir_ref(
        theta, m, v, g, *[float(x) for x in scal]
    )
    np.testing.assert_allclose(d, d_ref, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(tss), float(tss_ref), rtol=1e-5)
    np.testing.assert_allclose(float(dss), float(dss_ref), rtol=1e-5)
