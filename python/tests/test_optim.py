"""Optimizer update tests: flat-vector Lamb/Adam over the real layout."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import optim as O
from compile.kernels import ref as kref

TINY = M.ModelConfig(res=32, base_c=8, hidden=64)
OCFG = O.OptimConfig()


@pytest.fixture(scope="module")
def state():
    flat = M.flatten_params(M.init_params(TINY, jax.random.PRNGKey(0)))
    p = flat.shape[0]
    rng = np.random.default_rng(0)
    g = (rng.standard_normal(p) * 0.01).astype(np.float32)
    return flat, np.zeros(p, np.float32), np.zeros(p, np.float32), g


def test_update_changes_params_and_increments_step(state):
    flat, m, v, g = state
    p2, m2, v2, s2 = O.update(
        TINY, OCFG, flat, m, v, jnp.float32(0.0), g, jnp.float32(2.5e-4)
    )
    assert float(s2) == 1.0
    assert float(jnp.max(jnp.abs(p2 - flat))) > 0.0
    assert float(jnp.max(jnp.abs(m2))) > 0.0
    assert np.all(np.asarray(v2) >= 0.0)


def test_update_pallas_matches_ref_path(state):
    flat, m, v, g = state
    a = O.update(TINY, OCFG, flat, m, v, jnp.float32(3.0), g, jnp.float32(1e-3))
    b = O.update(
        TINY, OCFG, flat, m, v, jnp.float32(3.0), g, jnp.float32(1e-3),
        use_pallas=False,
    )
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-7)


def test_lamb_per_layer_matches_manual_loop(state):
    """The flat update must equal applying lamb_layer_ref layer by layer."""
    flat, m, v, g = state
    lr, step = 1e-3, 0.0
    p2, m2, v2, _ = O.update(
        TINY, OCFG, flat, m, v, jnp.float32(step), g, jnp.float32(lr)
    )
    p2 = np.asarray(p2)
    for name, off, shape in M.param_layout(TINY):
        size = int(np.prod(shape)) if shape else 1
        rho = OCFG.rho if len(shape) >= 2 else OCFG.rho_scalar
        t_ref, _, _ = kref.lamb_layer_ref(
            jnp.asarray(flat[off : off + size]),
            jnp.asarray(m[off : off + size]),
            jnp.asarray(v[off : off + size]),
            jnp.asarray(g[off : off + size]),
            lr=lr, beta1=OCFG.beta1, beta2=OCFG.beta2, eps=OCFG.eps,
            lam=OCFG.weight_decay, rho=rho, step=step + 1,
        )
        np.testing.assert_allclose(
            p2[off : off + size], np.asarray(t_ref), rtol=1e-5, atol=1e-7,
            err_msg=name,
        )


def test_adam_mode_ignores_trust_ratio(state):
    """algo='adam' must equal rho=1 (AdamW) for every layer group."""
    flat, m, v, g = state
    a = O.update(
        TINY, OCFG, flat, m, v, jnp.float32(0.0), g, jnp.float32(1e-3), algo="adam"
    )
    ocfg_rho1 = O.OptimConfig(rho=1.0, rho_scalar=1.0)
    b = O.update(
        TINY, ocfg_rho1, flat, m, v, jnp.float32(0.0), g, jnp.float32(1e-3),
        algo="lamb",
    )
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]), rtol=1e-6)


def test_weight_decay_shrinks_weights():
    """With zero gradients, AdamW still decays matrix weights toward 0."""
    cfg = TINY
    flat = M.flatten_params(M.init_params(cfg, jax.random.PRNGKey(1)))
    p = flat.shape[0]
    z = np.zeros(p, np.float32)
    p2, _, _, _ = O.update(
        cfg, OCFG, flat, z, z, jnp.float32(10.0), z, jnp.float32(1e-2), algo="adam"
    )
    # pick a matrix layer with nonzero init (fc_vis.w)
    lay = {n: (o, s) for n, o, s in M.param_layout(cfg)}
    off, shape = lay["fc_vis.w"]
    size = int(np.prod(shape))
    w0 = np.asarray(flat[off : off + size])
    w1 = np.asarray(p2[off : off + size])
    assert float(np.sum(w1 * w1)) < float(np.sum(w0 * w0))


def test_repeated_updates_converge_quadratic():
    """Optimizer sanity: Lamb on a quadratic reaches the minimum region.

    Uses a fake 1-layer 'model' by driving lamb_layer_ref directly through
    optim-style repeated updates.
    """
    rng = np.random.default_rng(2)
    theta = rng.standard_normal(32).astype(np.float32)
    target = rng.standard_normal(32).astype(np.float32)
    m = np.zeros(32, np.float32)
    v = np.zeros(32, np.float32)
    for step in range(1, 400):
        g = theta - target
        theta, m, v = (
            np.asarray(x)
            for x in kref.lamb_layer_ref(
                theta, m, v, g, lr=3e-2, beta1=0.9, beta2=0.999, eps=1e-8,
                lam=0.0, rho=0.01, step=step,
            )
        )
    assert float(np.abs(theta - target).mean()) < 0.15
