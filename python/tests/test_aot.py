"""AOT export tests: HLO text well-formedness + manifest schema.

These run the actual lowering for the tiny test preset (seconds) and verify
the emitted HLO parses structurally (entry computation, parameter counts)
and that the manifest layout matches the model contract — the exact
information the Rust runtime consumes.
"""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M

TEST_PRESET = aot.presets_table()["test"]
CFG = TEST_PRESET.cfg


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    entry = aot.export_preset(TEST_PRESET, out, verbose=False)
    return out, entry


def _param_count(hlo_text: str) -> int:
    """Count parameter instructions in the ENTRY computation."""
    entry = hlo_text[hlo_text.index("ENTRY") :]
    return entry.count("= parameter(") + entry.count(" parameter(")


def test_manifest_entry_schema(exported):
    _, entry = exported
    for key in (
        "name", "encoder", "res", "in_ch", "base_c", "hidden", "num_actions",
        "num_params", "files", "layout", "infer_ns", "grad_bls",
    ):
        assert key in entry, key
    assert entry["num_params"] == M.num_params(CFG)
    assert entry["files"].keys() >= {
        "init", "infer_n4", "grad_b2l4", "update_lamb", "update_adam",
    }


def test_layout_matches_model(exported):
    _, entry = exported
    lay = M.param_layout(CFG)
    assert len(entry["layout"]) == len(lay)
    for got, (name, off, shape) in zip(entry["layout"], lay):
        assert got["name"] == name
        assert got["offset"] == off
        assert tuple(got["shape"]) == shape


def test_hlo_files_exist_and_parse_header(exported):
    out, entry = exported
    for kind, fname in entry["files"].items():
        path = os.path.join(out, fname)
        assert os.path.exists(path), fname
        text = open(path).read()
        assert text.startswith("HloModule"), kind
        assert "ENTRY" in text, kind


def test_infer_artifact_signature(exported):
    out, entry = exported
    text = open(os.path.join(out, entry["files"]["infer_n4"])).read()
    assert _param_count(text) == 5  # params, obs, goal, h, c
    p = entry["num_params"]
    assert f"f32[{p}]" in text
    assert "f32[4,32,32,1]" in text  # obs N=4


def test_grad_artifact_signature(exported):
    out, entry = exported
    text = open(os.path.join(out, entry["files"]["grad_b2l4"])).read()
    assert _param_count(text) == 10
    assert "f32[2,4,32,32,1]" in text  # obs [B=2, L=4]
    assert "s32[2,4]" in text  # actions


def test_update_artifact_signature(exported):
    out, entry = exported
    for kind in ("update_lamb", "update_adam"):
        text = open(os.path.join(out, entry["files"][kind])).read()
        assert _param_count(text) == 6  # params, m, v, step, grads, lr


def test_main_writes_manifest(tmp_path):
    out = str(tmp_path / "arts")
    aot.main(["--out-dir", out, "--presets", "test", "--quiet"])
    man = json.load(open(os.path.join(out, "manifest.json")))
    assert man["version"] == 1
    assert "test" in man["variants"]
    # incremental merge: re-export keeps existing variants
    aot.main(["--out-dir", out, "--presets", "test", "--quiet"])
    man2 = json.load(open(os.path.join(out, "manifest.json")))
    assert man2["variants"].keys() == man["variants"].keys()


def test_unknown_preset_rejected(tmp_path):
    with pytest.raises(SystemExit):
        aot.main(["--out-dir", str(tmp_path), "--presets", "nope"])
