"""PPO loss/grad tests: hand-computed cases + clipping/masking semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import ppo

TINY = M.ModelConfig(res=32, base_c=8, hidden=64)
PCFG = ppo.PpoConfig()


def _batch(b=2, l=3, seed=0):
    rng = np.random.default_rng(seed)
    return (
        rng.random((b, l, 32, 32, 1), dtype=np.float32),
        rng.random((b, l, 3), dtype=np.float32),
        np.zeros((b, 64), np.float32),
        np.zeros((b, 64), np.float32),
        rng.integers(0, 4, (b, l)).astype(np.int32),
        (-np.abs(rng.random((b, l)))).astype(np.float32),
        rng.random((b, l), dtype=np.float32),
        rng.standard_normal((b, l)).astype(np.float32),
        np.ones((b, l), np.float32),
    )


@pytest.fixture(scope="module")
def flat():
    return M.flatten_params(M.init_params(TINY, jax.random.PRNGKey(0)))


def test_log_softmax_normalized():
    logits = np.random.randn(7, 4).astype(np.float32)
    lp = np.asarray(ppo._log_softmax(logits))
    np.testing.assert_allclose(np.exp(lp).sum(-1), np.ones(7), rtol=1e-5)


def test_loss_components_finite(flat):
    params = M.unflatten_params(TINY, flat)
    total, aux = ppo.ppo_loss(TINY, PCFG, params, _batch())
    aux = np.asarray(aux)
    assert np.isfinite(float(total))
    assert np.all(np.isfinite(aux))
    # entropy of a 4-action categorical is in (0, ln 4]
    assert 0.0 < aux[2] <= np.log(4.0) + 1e-5


def test_entropy_near_uniform_at_init(flat):
    """Actor head init gain 0.01 => near-uniform policy => entropy ~ ln(4)."""
    params = M.unflatten_params(TINY, flat)
    _, aux = ppo.ppo_loss(TINY, PCFG, params, _batch())
    assert float(aux[2]) > 0.95 * np.log(4.0)


def test_ppo_clip_manual_case():
    """PPO surrogate on a hand-built single-step case with known ratio."""
    # Construct logits directly: bypass the network, test only the math.
    clip = 0.2
    logp_old = np.float32(np.log(0.25))
    for adv, new_p in [(1.0, 0.5), (1.0, 0.1), (-1.0, 0.5), (-1.0, 0.1)]:
        logp_new = np.log(new_p)
        ratio = new_p / 0.25
        surr1 = ratio * adv
        surr2 = np.clip(ratio, 1 - clip, 1 + clip) * adv
        expect = -min(surr1, surr2)
        got = -float(
            jnp.minimum(
                jnp.exp(logp_new - logp_old) * adv,
                jnp.clip(jnp.exp(logp_new - logp_old), 1 - clip, 1 + clip) * adv,
            )
        )
        np.testing.assert_allclose(got, expect, rtol=1e-5)


def test_grad_shape_and_clipping(flat):
    g, aux = ppo.ppo_grad(TINY, PCFG, flat, _batch())
    assert g.shape == flat.shape
    norm = float(jnp.sqrt(jnp.sum(g * g)))
    assert norm <= PCFG.max_grad_norm + 1e-4


def test_clip_grad_norm_identity_below_threshold():
    g = jnp.asarray(np.array([0.3, 0.4], np.float32))  # norm 0.5
    out = ppo.clip_grad_norm(g, 1.0)
    np.testing.assert_allclose(np.asarray(out), [0.3, 0.4], rtol=1e-6)
    out2 = ppo.clip_grad_norm(g * 10, 1.0)  # norm 5 -> scaled to 1
    np.testing.assert_allclose(float(jnp.linalg.norm(out2)), 1.0, rtol=1e-5)


def test_grad_descends_value_loss(flat):
    """A small step along -grad must reduce the total loss (sanity)."""
    batch = _batch(seed=3)
    params = M.unflatten_params(TINY, flat)
    total0, _ = ppo.ppo_loss(TINY, PCFG, params, batch)
    g, _ = ppo.ppo_grad(TINY, PCFG, flat, batch)
    flat2 = flat - 1e-2 * g
    total1, _ = ppo.ppo_loss(TINY, PCFG, M.unflatten_params(TINY, flat2), batch)
    assert float(total1) < float(total0)


def test_notdone_masks_hidden_carry(flat):
    """Zeroing notdone at t must make steps >= t independent of h0."""
    params = M.unflatten_params(TINY, flat)
    b, l = 1, 3
    rng = np.random.default_rng(5)
    obs = rng.random((b, l, 32, 32, 1), dtype=np.float32)
    goal = rng.random((b, l, 3), dtype=np.float32)
    notdone = np.ones((b, l), np.float32)
    notdone[0, 0] = 0.0  # reset at the first step
    h_a = np.zeros((b, 64), np.float32)
    h_b = rng.standard_normal((b, 64)).astype(np.float32)
    la, va = M.policy_sequence(TINY, params, obs, goal, h_a, h_a, notdone)
    lb, vb = M.policy_sequence(TINY, params, obs, goal, h_b, h_b, notdone)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(va), np.asarray(vb), rtol=1e-4, atol=1e-5)
