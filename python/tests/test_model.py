"""L2 model tests: shapes, flat-param contract, fixup/init properties."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

TINY = M.ModelConfig(res=32, base_c=8, hidden=64)


@pytest.fixture(scope="module")
def tiny_params():
    return M.init_params(TINY, jax.random.PRNGKey(0))


def test_space_to_depth_roundtrip_values():
    x = np.arange(2 * 8 * 8 * 3, dtype=np.float32).reshape(2, 8, 8, 3)
    y = np.asarray(M.space_to_depth(x, 4))
    assert y.shape == (2, 2, 2, 48)
    # every input value appears exactly once
    assert sorted(y.ravel().tolist()) == sorted(x.ravel().tolist())
    # top-left output pixel holds the top-left 4x4 input patch
    patch = x[0, :4, :4, :].reshape(-1)
    np.testing.assert_array_equal(np.sort(y[0, 0, 0]), np.sort(patch))


def test_flat_layout_bijective(tiny_params):
    flat = M.flatten_params(tiny_params)
    assert flat.shape == (M.num_params(TINY),)
    back = M.unflatten_params(TINY, flat)
    assert set(back) == set(tiny_params)
    for k in tiny_params:
        np.testing.assert_array_equal(np.asarray(tiny_params[k]), np.asarray(back[k]))


def test_layout_offsets_contiguous():
    lay = M.param_layout(TINY)
    off = 0
    for name, o, shape in lay:
        assert o == off, name
        off += int(np.prod(shape)) if shape else 1
    assert off == M.num_params(TINY)


def test_fixup_init_properties(tiny_params):
    p = tiny_params
    # last conv of each residual branch is zero-initialized
    for i in range(4):
        assert float(jnp.abs(p[f"s{i}.conv2.w"]).max()) == 0.0
        assert float(p[f"s{i}.scale"]) == 1.0
        assert float(p[f"s{i}.b1a"]) == 0.0
    # forget-gate bias starts at 1
    np.testing.assert_array_equal(np.asarray(p["lstm.b"][1]), np.ones(64))
    np.testing.assert_array_equal(np.asarray(p["lstm.b"][0]), np.zeros(64))


def test_init_deterministic():
    a = M.flatten_params(M.init_params(TINY, jax.random.PRNGKey(7)))
    b = M.flatten_params(M.init_params(TINY, jax.random.PRNGKey(7)))
    c = M.flatten_params(M.init_params(TINY, jax.random.PRNGKey(8)))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(jnp.abs(a - c).max()) > 0.0


@pytest.mark.parametrize("n", [1, 3, 5])
def test_policy_step_shapes(tiny_params, n):
    obs = np.random.rand(n, 32, 32, 1).astype(np.float32)
    goal = np.random.rand(n, 3).astype(np.float32)
    h = np.zeros((n, 64), np.float32)
    c = np.zeros((n, 64), np.float32)
    logits, value, h2, c2 = M.policy_step(TINY, tiny_params, obs, goal, h, c)
    assert logits.shape == (n, 4)
    assert value.shape == (n,)
    assert h2.shape == (n, 64) and c2.shape == (n, 64)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_policy_step_output_sane_at_init(tiny_params):
    """Fixup keeps activations bounded at init: logits near zero (gain .01)."""
    obs = np.random.rand(16, 32, 32, 1).astype(np.float32)
    goal = np.random.rand(16, 3).astype(np.float32)
    z = np.zeros((16, 64), np.float32)
    logits, value, _, _ = M.policy_step(TINY, tiny_params, obs, goal, z, z)
    assert float(np.abs(np.asarray(logits)).max()) < 1.0
    assert float(np.abs(np.asarray(value)).max()) < 5.0


def test_policy_sequence_matches_stepwise(tiny_params):
    """Scan BPTT == manual per-step rollout with identical hidden handling."""
    b, l = 2, 5
    rng = np.random.default_rng(0)
    obs = rng.random((b, l, 32, 32, 1), dtype=np.float32)
    goal = rng.random((b, l, 3), dtype=np.float32)
    h = rng.standard_normal((b, 64)).astype(np.float32) * 0.1
    c = rng.standard_normal((b, 64)).astype(np.float32) * 0.1
    notdone = np.ones((b, l), np.float32)
    notdone[0, 2] = 0.0  # episode reset mid-sequence
    logits_seq, values_seq = M.policy_sequence(
        TINY, tiny_params, obs, goal, h, c, notdone
    )
    hh, cc = jnp.asarray(h), jnp.asarray(c)
    for t in range(l):
        hh = hh * notdone[:, t][:, None]
        cc = cc * notdone[:, t][:, None]
        lg, vv, hh, cc = M.policy_step(
            TINY, tiny_params, obs[:, t], goal[:, t], hh, cc
        )
        np.testing.assert_allclose(
            np.asarray(logits_seq[:, t]), np.asarray(lg), rtol=1e-4, atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(values_seq[:, t]), np.asarray(vv), rtol=1e-4, atol=1e-4
        )


def test_pallas_and_ref_paths_agree(tiny_params):
    cfg_ref = M.ModelConfig(res=32, base_c=8, hidden=64, use_pallas=False)
    obs = np.random.rand(3, 32, 32, 1).astype(np.float32)
    goal = np.random.rand(3, 3).astype(np.float32)
    z = np.zeros((3, 64), np.float32)
    a = M.policy_step(TINY, tiny_params, obs, goal, z, z)
    b = M.policy_step(cfg_ref, tiny_params, obs, goal, z, z)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-4, atol=1e-5)


def test_r50_encoder_shapes():
    cfg = M.ModelConfig(encoder="r50", res=64, base_c=8, hidden=64)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    obs = np.random.rand(2, 64, 64, 1).astype(np.float32)
    feat = M.encode_visual(cfg, p, obs)
    assert feat.shape == (2, 64)
    assert np.all(np.isfinite(np.asarray(feat)))
    # r50 has many more params than se9 at equal base width
    se9 = M.ModelConfig(encoder="se9", res=64, base_c=8, hidden=64)
    assert M.num_params(cfg) > 2 * M.num_params(se9)


def test_rgb_variant_shapes():
    cfg = M.ModelConfig(res=32, in_ch=3, base_c=8, hidden=64)
    p = M.init_params(cfg, jax.random.PRNGKey(1))
    obs = np.random.rand(2, 32, 32, 3).astype(np.float32)
    logits, value, _, _ = M.policy_step(
        cfg, p, obs, np.zeros((2, 3), np.float32),
        np.zeros((2, 64), np.float32), np.zeros((2, 64), np.float32),
    )
    assert logits.shape == (2, 4)


@hypothesis.given(seed=st.integers(0, 2**31 - 1))
@hypothesis.settings(max_examples=5, deadline=None)
def test_variant_key_stable(seed):
    cfg = M.ModelConfig(res=64, in_ch=1)
    assert cfg.variant == "se9_depth_r64_c16_h256"
