//! Standalone batch renderer + environment server demo (paper Appendix
//! A.2 / Fig. A2): renders increasing batch sizes at several resolutions
//! and prints the FPS grid plus an ASCII visualization of one depth frame,
//! then measures the full `EnvBatch` step cycle (sim + render) with the
//! double-buffered pipelined driver against synchronous stepping.
//!
//! Run: cargo run --release --example standalone_renderer

use std::sync::Arc;

use bps::env::EnvBatchConfig;
use bps::render::{BatchRenderer, PipelineMode, RenderConfig, RenderItem, Sensor};
use bps::sim::Task;
use bps::util::pool::WorkerPool;
use bps::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let ds_dir = bps::bench::ensure_dataset("gibson", 4)?;
    let ds = bps::scene::Dataset::open(&ds_dir)?;
    let scene = Arc::new(ds.load_scene(&ds.train[0], true)?);
    println!(
        "scene: {} tris, {:.1} MB geometry, {:.1} MB textures",
        scene.mesh.num_tris(),
        scene.geometry_bytes() as f64 / 1e6,
        scene.texture_bytes() as f64 / 1e6
    );
    let pool = Arc::new(WorkerPool::new(WorkerPool::default_size()));
    let mut rng = Rng::new(11);

    // one ASCII depth frame, for the humans
    let cfg = RenderConfig { res: 48, sensor: Sensor::Depth, scale: 1, mode: PipelineMode::Fused };
    let renderer = BatchRenderer::new(cfg, 1);
    let pos = scene.navmesh.random_point(&mut rng).unwrap();
    let mut obs = vec![0.0f32; cfg.obs_floats()];
    renderer.render_batch(
        &pool,
        &[RenderItem { scene: Arc::clone(&scene), pos, heading: 0.8 }],
        &mut obs,
    );
    let ramp = b"@%#*+=-:. ";
    for y in (0..48).step_by(2) {
        let line: String = (0..48)
            .map(|x| ramp[((obs[y * 48 + x] * 9.0) as usize).min(9)] as char)
            .collect();
        println!("{line}");
    }

    println!("\nFPS vs batch size (64px depth, pipelined culling):");
    for n in [1usize, 8, 32, 128, 512] {
        let cfg = RenderConfig { res: 64, sensor: Sensor::Depth, scale: 1, mode: PipelineMode::Pipelined };
        let renderer = BatchRenderer::new(cfg, n);
        let items: Vec<RenderItem> = (0..n)
            .map(|_| RenderItem {
                scene: Arc::clone(&scene),
                pos: scene.navmesh.random_point(&mut rng).unwrap(),
                heading: rng.range_f32(0.0, std::f32::consts::TAU),
            })
            .collect();
        let mut obs = vec![0.0f32; n * cfg.obs_floats()];
        renderer.render_batch(&pool, &items, &mut obs); // warmup
        let reps = (128 / n).max(1);
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            renderer.render_batch(&pool, &items, &mut obs);
        }
        println!("  N={n:<4} {:>9.0} FPS", (n * reps) as f64 / t0.elapsed().as_secs_f64());
    }

    // full environment step cycle through the request/response API:
    // scripted actions, sim + render per step, overlap on vs off
    println!("\nEnvBatch step FPS (64px depth, sim+render, N=64):");
    for (label, overlap) in [("synchronous", false), ("pipelined  ", true)] {
        let mut env = EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(64))
            .seed(3)
            .overlap(overlap)
            .build_with_scenes(
                (0..64).map(|_| Arc::clone(&scene)).collect(),
                Arc::clone(&pool),
            )?;
        let actions: Vec<u8> = (0..64).map(|i| 1 + (i % 3) as u8).collect();
        env.step(&actions)?; // warmup
        let reps = 20;
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            let handle = env.submit(&actions)?;
            let _ = handle.wait()?;
        }
        println!(
            "  {label} {:>9.0} steps/s",
            (64 * reps) as f64 / t0.elapsed().as_secs_f64()
        );
    }
    Ok(())
}
