//! Quickstart: the smallest end-to-end BPS run — generate a tiny dataset,
//! load the `test` AOT artifacts, train a handful of PPO iterations, and
//! print the FPS + runtime breakdown.
//!
//! Run: make artifacts && cargo run --release --example quickstart

use bps::config::Config;
use bps::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let ds_dir = bps::bench::ensure_dataset("test", 4)?;
    let mut cfg = Config::default();
    cfg.variant = "test".into();
    cfg.artifacts_dir = bps::bench::artifacts_dir();
    cfg.dataset_dir = ds_dir;
    cfg.num_envs = 4;
    cfg.rollout_len = 4;
    cfg.num_minibatches = 2;
    cfg.k_scenes = 2;
    cfg.total_frames = 320;

    println!("== BPS quickstart: PointGoalNav, 4 envs, tiny SE-ResNet9 ==");
    let mut coord = Coordinator::new(cfg)?;
    while coord.frames() < coord.cfg.total_frames {
        let it = coord.train_iteration()?;
        println!(
            "frames {:>5}  reward {:+.3}  entropy {:.3}  value-loss {:.4}",
            coord.frames(),
            coord.stats.reward.mean(),
            it.losses.entropy,
            it.losses.value
        );
    }
    println!("\nFPS (paper methodology): {:.0}", coord.fps());
    for (name, us) in coord.prof.breakdown(coord.frames()) {
        println!("  {name:<10} {us:>8.1} us/frame");
    }
    Ok(())
}
