//! Quickstart: the smallest end-to-end BPS run, in nine acts.
//!
//! Act 1 needs nothing but this repo: it builds an `EnvBatch` — the
//! batched request/response environment API at the heart of the system —
//! over a tiny procedural dataset and drives it with scripted actions
//! through the pipelined `submit → wait` cycle (simulation+rendering of
//! step t+1 overlaps consumption of step t via double buffering).
//!
//! Act 2 shows the multi-client serving layer (`bps::serve`): a
//! `SimServer` puts the same batch behind a session front door, two
//! client threads each lease half the env slots with `connect`, and the
//! per-shard coalescer assembles their partial submissions into full
//! batch steps — one `EnvBatch::submit` serving both tenants.
//!
//! Act 3 shows the scenario engine (`bps::scenario`): a declarative
//! `ScenarioSpec` replaces the pre-generated dataset — scenes stream from
//! procedural generation ahead of demand, and a success-driven
//! `Curriculum` advances the spec's difficulty stages while a scripted
//! GPS+compass policy drives the batch.
//!
//! Act 4 shows the wire transport (`bps::serve::wire`): the same
//! `SimServer` goes behind a TCP listener, and two clients drive
//! `RemoteSession`s over loopback sockets through the identical
//! `submit → wait → view` cycle — observation streams are bitwise
//! identical to in-process serving. A real deployment runs `bps serve
//! --listen` and `bps connect` in separate processes.
//!
//! Act 5 needs the AOT artifacts (`make artifacts`): it loads the `test`
//! model variant, trains a handful of PPO iterations through the
//! coordinator (a pure client of the same `EnvBatch` API), and prints the
//! FPS + runtime breakdown.
//!
//! Act 6 (also artifact-gated) serves *agents*, not just envs: a
//! `SimServer` with a `PolicyVault` leases env slots plus a policy
//! (`connect_with_policy`), runs one coalesced inference per tick for
//! every tenant of the shard, and the client only sets a goal and
//! streams the server-chosen trajectory. Remotely that's `bps serve`
//! plus `bps agent ADDR`.
//!
//! Act 7 needs no artifacts again: observability (`bps::obs`,
//! DESIGN.md §0.10). One metrics registry backs every view of a number
//! — `SimServer::stats()`, a Prometheus `GET /metrics` scrape, and the
//! in-band STATS wire frame all read the *same* atomic cells — while a
//! span ring records the per-tick pipeline timeline (Chrome trace JSON)
//! and a JSONL event log records lease lifecycle. Remotely that's `bps
//! serve --metrics-addr --trace-out --event-log` plus `bps stats ADDR`.
//!
//! Act 8 (also artifact-free) is the diagnosis layer on top (DESIGN.md
//! §0.11): a health watchdog classifies every long-lived thread from
//! cheap heartbeats (`GET /healthz` = real readiness), a flight
//! recorder writes self-contained incident bundles on stall / slow
//! tick / panic / demand (`bps serve --dump-dir`, `bps stats ADDR
//! --dump`), and per-phase latency attribution says *where* each
//! session's submit→result time went.
//!
//! Act 9 (also artifact-free) is a kill-and-resume drill through the
//! fault-tolerance layer (DESIGN.md §0.12): a fault injector severs the
//! client's TCP connection every few frames, the server parks the
//! orphaned lease under `--park-ttl`, and a resume-capable client
//! reconnects with capped exponential backoff and replays the one owed
//! observation — the delivered stream stays bitwise intact. Then a
//! shard panic quarantines one shard (its co-tenant gets a typed
//! `retry_after_ms=` error, the other shard never notices) and
//! `restart_shard` brings it back. Remotely that's `bps serve --fault
//! conn_drop:every=6 --park-ttl 30 --heal-ms 500` plus `bps connect
//! --retries 8`.
//!
//! The concurrency invariants all of this leans on (SAFETY notes, lock
//! order, thread hygiene, wire/doc agreement) are machine-checked:
//! `cargo run --release -- lint` (DESIGN.md §0.13) must exit clean.
//!
//! Run: cargo run --release --example quickstart

use std::sync::Arc;

use bps::config::Config;
use bps::coordinator::Coordinator;
use bps::env::EnvBatchConfig;
use bps::render::RenderConfig;
use bps::scene::Dataset;
use bps::serve::{ShardSpec, SimServer};
use bps::sim::{Task, NUM_ACTIONS};
use bps::util::pool::WorkerPool;

fn main() -> anyhow::Result<()> {
    let ds_dir = bps::bench::ensure_dataset("test", 4)?;

    // -- Act 1: the EnvBatch API, no artifacts required ---------------------
    println!("== EnvBatch quickstart: 8 envs, scripted actions ==");
    let ds = Dataset::open(&ds_dir)?;
    let scene = Arc::new(ds.load_scene(&ds.train[0], false)?);
    let pool = Arc::new(WorkerPool::new(WorkerPool::default_size()));
    let mut env = EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(32))
        .seed(7)
        .overlap(true) // double-buffered pipelined stepping (the default)
        .build_with_scenes((0..8).map(|_| Arc::clone(&scene)).collect(), pool)?;
    let mut reward_sum = 0.0f32;
    let mut episodes = 0u32;
    for t in 0..64usize {
        let actions: Vec<u8> = (0..8).map(|i| ((t + i) % NUM_ACTIONS) as u8).collect();
        let handle = env.submit(&actions)?; // sim+render of t+1 starts here
        let _obs_t = handle.current().obs; // step t stays readable meanwhile
        let view = handle.wait()?; // step t+1: borrowed SoA slices
        reward_sum += view.rewards.iter().sum::<f32>();
        episodes += view.dones.iter().filter(|&&d| d).count() as u32;
    }
    let (sim_d, render_d) = env.drain_timings();
    println!(
        "64 steps x 8 envs: total reward {reward_sum:+.2}, {episodes} episodes, \
         sim {:.1} ms, render {:.1} ms\n",
        sim_d.as_secs_f64() * 1e3,
        render_d.as_secs_f64() * 1e3
    );

    // -- Act 2: two clients multiplexed onto one shard (bps::serve) ---------
    println!("== SimServer quickstart: 2 clients x 4 envs on one shard ==");
    let serve_pool = Arc::new(WorkerPool::new(WorkerPool::default_size()));
    let shard = ShardSpec::with_scenes(
        EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(32)).seed(7),
        (0..8).map(|_| Arc::clone(&scene)).collect(),
    );
    let server = SimServer::start(vec![shard], serve_pool)?;
    // lease 4 slots each *before* spawning, so the first coalesced step
    // already includes both tenants
    let sessions = [
        server.connect(Task::PointNav, 4)?,
        server.connect(Task::PointNav, 4)?,
    ];
    std::thread::scope(|sc| {
        for (c, mut session) in sessions.into_iter().enumerate() {
            sc.spawn(move || {
                let mut reward = 0.0f32;
                for t in 0..32usize {
                    // partial batch: 4 of the shard's 8 actions; the
                    // coalescer steps once both sessions have submitted
                    let actions: Vec<u8> = (0..4).map(|i| (1 + (t + c + i) % 3) as u8).collect();
                    let view = session.step(&actions).expect("served step");
                    reward += view.rewards.iter().sum::<f32>();
                }
                let (p50, p95) = session.latency();
                println!(
                    "client {c}: 32 steps x 4 envs, reward {reward:+.2}, \
                     step latency p50 {:.2} ms / p95 {:.2} ms",
                    p50 * 1e3,
                    p95 * 1e3
                );
            });
        }
    });
    for st in server.stats() {
        println!(
            "shard: {} coalesced batch steps served for both clients\n",
            st.steps
        );
    }
    drop(server);

    // -- Act 3: the scenario engine — streaming procgen + curriculum -------
    println!("== Scenario quickstart: spec-driven worlds, curriculum run ==");
    use bps::render::SceneRotation;
    use bps::scenario::{sensor_policy, Curriculum, ScenarioSpec, ScenarioStream};
    let spec = ScenarioSpec::parse(
        "name=qs task=pointnav stages=3 tris=1k..6k extent=6..9 \
         clutter=0..2 tex=32 max-steps=150",
    )?;
    println!("spec: {}", spec.summary());
    let sc_pool = Arc::new(WorkerPool::new(WorkerPool::default_size()));
    // scenes are synthesized ahead of demand on the shared pool into a
    // bounded prefetch queue — no gen-dataset step, no disk
    let stream = ScenarioStream::new(spec.clone(), 7, 2, false, Arc::clone(&sc_pool));
    let rot = SceneRotation::streaming(stream, 2)?;
    let mut env = EnvBatchConfig::new(spec.task, RenderConfig::depth(32))
        .sim(spec.sim_config())
        .seed(7)
        .pin_rotation(8)
        .build_with_rotation(rot, 8, sc_pool)?;
    let mut curriculum = Curriculum::new(spec.stages, 8, 0.25);
    let mut actions = vec![0u8; 8];
    for t in 0..400usize {
        sensor_policy(env.view().goal, 0.15, t, &mut actions);
        let v = env.step(&actions)?;
        curriculum.observe(v.dones, v.successes, v.spl);
        if let Some(stage) = curriculum.advance_if_ready() {
            env.set_stage(stage)?; // future scenes generate at the new stage
            println!("step {t:>4}: success window cleared the bar -> stage {stage}");
        }
        env.rotate_scenes()?;
    }
    println!(
        "curriculum reached stage {}/{} after {} episodes \
         ({} scene rotations, {} prefetch stalls)\n",
        curriculum.stage(),
        spec.stages - 1,
        curriculum.episodes(),
        env.rotations(),
        env.feed_stalls()
    );
    drop(env);

    // -- Act 4: remote clients — the same sessions over loopback TCP -------
    println!("== Wire quickstart: RemoteSessions on a TCP SimServer ==");
    use bps::serve::{RemoteClient, WireServer};
    let wire_pool = Arc::new(WorkerPool::new(WorkerPool::default_size()));
    let shard = ShardSpec::with_scenes(
        EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(32)).seed(7),
        (0..8).map(|_| Arc::clone(&scene)).collect(),
    );
    let wire_server = Arc::new(SimServer::start(vec![shard], wire_pool)?);
    // the wire layer fronts an existing SimServer; port 0 = ephemeral
    let wire = WireServer::listen("127.0.0.1:0", Arc::clone(&wire_server))?;
    let addr = wire.local_addr().to_string();
    println!("serving on {addr}");
    // a remote process would do exactly this, minus the loopback: dial,
    // lease, then drive the same submit -> wait -> view cycle as Act 2.
    // Lease both sessions before any thread submits (see Act 2's note).
    let mut remotes = Vec::new();
    for _ in 0..2usize {
        let client = RemoteClient::connect(&addr)?;
        let session = client.open_session(Task::PointNav, 4)?;
        remotes.push((client, session));
    }
    std::thread::scope(|sc| -> anyhow::Result<()> {
        let mut handles = Vec::new();
        for (c, (client, mut session)) in remotes.into_iter().enumerate() {
            handles.push(sc.spawn(move || -> anyhow::Result<f32> {
                let mut reward = 0.0f32;
                for t in 0..16usize {
                    let actions: Vec<u8> = (0..4).map(|i| (1 + (t + c + i) % 3) as u8).collect();
                    // the frames cross a socket; observations are bitwise
                    // identical to in-process serving
                    let view = session.step(&actions)?;
                    reward += view.rewards.iter().sum::<f32>();
                }
                session.detach()?;
                drop(client);
                Ok(reward)
            }));
        }
        for (c, h) in handles.into_iter().enumerate() {
            let reward = h.join().expect("remote client thread")?;
            println!("remote client {c}: 16 steps x 4 envs, reward {reward:+.2}");
        }
        Ok(())
    })?;
    for conn in wire.conn_stats() {
        println!(
            "conn {}: {} frames in, {} frames out, {} bytes out",
            conn.id, conn.frames_in, conn.frames_out, conn.bytes_out
        );
    }
    drop(wire);
    drop(wire_server);
    println!();

    // -- Act 5: PPO training through the same API (needs `make artifacts`) --
    let cfg = Config {
        variant: "test".into(),
        artifacts_dir: bps::bench::artifacts_dir(),
        dataset_dir: ds_dir,
        num_envs: 4,
        rollout_len: 4,
        num_minibatches: 2,
        k_scenes: 2,
        total_frames: 320,
        ..Config::default()
    };

    println!("== BPS quickstart: PointGoalNav, 4 envs, tiny SE-ResNet9 ==");
    let mut coord = match Coordinator::new(cfg) {
        Ok(c) => c,
        Err(e) => {
            println!("(training act skipped: {e:#})");
            println!("run `make artifacts` to export the test AOT variant");
            // Acts 5 and 6 need artifacts; observability doesn't.
            return observability_act(&scene);
        }
    };
    while coord.frames() < coord.cfg.total_frames {
        let it = coord.train_iteration()?;
        println!(
            "frames {:>5}  reward {:+.3}  entropy {:.3}  value-loss {:.4}",
            coord.frames(),
            coord.stats.reward.mean(),
            it.losses.entropy,
            it.losses.value
        );
    }
    println!("\nFPS (paper methodology): {:.0}", coord.fps());
    for (name, us) in coord.prof.breakdown(coord.frames()) {
        println!("  {name:<10} {us:>8.1} us/frame");
    }
    drop(coord);

    // -- Act 6: serve agents, not just envs (policy tenancy) ---------------
    println!("\n== Tenant quickstart: the server runs the policy too ==");
    use bps::serve::PolicyVault;
    // a 4-slot shard matches the `test` variant's infer_n4 AOT artifact
    let shard = ShardSpec::with_scenes(
        EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(32)).seed(7),
        (0..4).map(|_| Arc::clone(&scene)).collect(),
    );
    let vault = PolicyVault::open(&bps::bench::artifacts_dir(), None, 1)?;
    println!("vault: {}", vault.describe());
    let tenant_server = Arc::new(SimServer::with_vault(
        vec![shard],
        Arc::new(WorkerPool::new(WorkerPool::default_size())),
        None,
        Some(vault),
    )?);
    // lease env slots *plus* a policy: the server closes the
    // act -> observe loop; this client only sets a goal and streams the
    // trajectory (remotely: `bps serve` + `bps agent ADDR`)
    let mut agent = tenant_server.connect_with_policy(Task::PointNav, 4, "test")?;
    agent.set_goal(16)?;
    let mut reward = 0.0f32;
    let mut stops = 0usize;
    for _ in 0..16usize {
        let ts = agent.next_step()?.expect("goal ended early");
        reward += ts.rewards.iter().sum::<f32>();
        stops += ts
            .actions
            .iter()
            .filter(|&&a| a == bps::sim::ACTION_STOP)
            .count();
    }
    let st = &tenant_server.stats()[0];
    let ten = st.tenant.as_ref().expect("tenant stats");
    println!(
        "16 server-driven steps x 4 envs: reward {reward:+.2}, {stops} STOPs, \
         {} coalesced forwards at batch {} (infer p50 {:.2} ms)",
        ten.infer_runs,
        ten.infer_batch_size,
        ten.infer_p50 * 1e3
    );
    agent.detach();
    drop(tenant_server);

    observability_act(&scene)
}

// -- Act 7: observability — one registry behind every surface --------------
fn observability_act(scene: &Arc<bps::scene::SceneAsset>) -> anyhow::Result<()> {
    println!("\n== Obs quickstart: registry, scrape, trace, events ==");
    use bps::obs::MetricsServer;
    let shard = ShardSpec::with_scenes(
        EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(32)).seed(7),
        (0..8).map(|_| Arc::clone(scene)).collect(),
    );
    let server = Arc::new(SimServer::start(
        vec![shard],
        Arc::new(WorkerPool::new(WorkerPool::default_size())),
    )?);
    // All three sinks are disarmed by default (one atomic load per
    // producer); arm them before the session so its lease events land.
    server.trace().enable();
    let events_path = std::env::temp_dir().join("bps_quickstart_events.jsonl");
    server.events().arm(&events_path, 1 << 20)?;
    let metrics = MetricsServer::listen("127.0.0.1:0", server.registry())?;

    let mut session = server.connect(Task::PointNav, 8)?;
    let mut actions = vec![0u8; 8];
    for t in 0..32usize {
        for (j, a) in actions.iter_mut().enumerate() {
            *a = (1 + (t + j) % 3) as u8;
        }
        session.step(&actions)?;
    }
    drop(session); // -> lease.release in the event log

    // The registry snapshot, SimServer::stats(), and any scrape all read
    // the same cells — compare one counter across two of the views.
    let snap = server.registry().snapshot();
    let steps = snap.counter("serve.shard.steps", &[("shard", "0")]).unwrap();
    assert_eq!(steps, server.stats()[0].steps);
    println!(
        "registry: {steps} shard steps; latency histogram holds {} samples",
        snap.histogram("serve.shard.latency_us", &[("shard", "0")])
            .unwrap()
            .count
    );
    println!(
        "scrape:   curl http://{}/metrics   (a wire server also answers `bps stats ADDR`)",
        metrics.local_addr()
    );
    let trace_path = std::env::temp_dir().join("bps_quickstart_trace.json");
    std::fs::write(&trace_path, server.trace().to_chrome_json())?;
    println!(
        "trace:    {} pipeline spans -> {} (open in chrome://tracing or Perfetto)",
        server.trace().spans().len(),
        trace_path.display()
    );
    println!("events:   lease lifecycle in {}", events_path.display());

    health_act(&server)?;

    fault_act(scene)
}

// -- Act 8: diagnosis — watchdog, flight recorder, phase attribution -------
fn health_act(server: &Arc<SimServer>) -> anyhow::Result<()> {
    println!("\n== Health quickstart: watchdog, incident bundle, phases ==");
    use bps::obs::Trigger;
    // Every long-lived thread heartbeats; the watchdog classifies each
    // role (Healthy/Degraded/Stalled) and `GET /healthz` answers from
    // the same table — 503 names the stalled role.
    let report = server.watchdog().report();
    println!(
        "watchdog: healthy={} -> /healthz would answer {} {}",
        report.healthy(),
        if report.healthy() { 200 } else { 503 },
        report.to_json()
    );

    // Arm the flight recorder (remotely: `bps serve --dump-dir DIR`) and
    // pull a manual incident bundle — the same bundle a watchdog stall,
    // a slow tick, or a panic would have written automatically.
    let dump_dir = std::env::temp_dir().join("bps_quickstart_incidents");
    let recorder = server.arm_recorder(&dump_dir)?;
    let bundle = recorder
        .trigger(Trigger::Manual)?
        .expect("manual dumps bypass the rate limit");
    let mut artifacts: Vec<String> = std::fs::read_dir(&bundle)?
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    artifacts.sort();
    println!("bundle:   {}", bundle.display());
    println!("          [{}]", artifacts.join(", "));

    // Where did submit->result latency go? The phase histograms split it
    // into coalesce-wait / sim / render / publish (plus infer for tenant
    // sessions and wire_encode/wire_flush on the wire) — and the
    // in-process phases sum to the e2e figure by construction.
    let snap = server.registry().snapshot();
    let e2e = snap
        .histogram("serve.shard.latency_us", &[("shard", "0")])
        .expect("latency histogram");
    print!("phases:   e2e {} us ->", e2e.sum);
    for phase in ["coalesce", "sim", "render", "publish"] {
        if let Some(h) = snap.histogram("serve.session.phase_us", &[("phase", phase)]) {
            print!(" {phase} {} us", h.sum);
        }
    }
    println!();
    for row in server.slowest_sessions(4) {
        println!(
            "slowest:  session {} (shard {}): {} steps, mean {:.2} ms, max {:.2} ms",
            row.session,
            row.shard,
            row.steps,
            row.mean_us as f64 / 1e3,
            row.max_us as f64 / 1e3
        );
    }
    Ok(())
}

// -- Act 9: kill-and-resume drill (DESIGN.md §0.12) -------------------------
fn fault_act(scene: &Arc<bps::scene::SceneAsset>) -> anyhow::Result<()> {
    println!("\n== Fault quickstart: conn kills, resume, shard panic+restart ==");
    use bps::serve::{FaultSpec, Injector, RemoteClient, ResumeCfg, WireConfig, WireServer};
    use std::sync::atomic::Ordering;

    // Two identical shards: the remote session lands on shard 0, an
    // in-process co-tenant on shard 1 — so we can panic shard 1 later
    // without disturbing the remote stream.
    let pool = Arc::new(WorkerPool::new(WorkerPool::default_size()));
    let shards: Vec<ShardSpec> = (0..2)
        .map(|_| {
            ShardSpec::with_scenes(
                EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(16)).seed(7),
                (0..4).map(|_| Arc::clone(scene)).collect(),
            )
        })
        .collect();
    let srv = Arc::new(SimServer::start(shards, pool)?);

    // One injector, shared by both layers: the SimServer honors armed
    // shard panics, the wire layer honors conn_drop/delay/corrupt. Here
    // every 6th outbound frame write kills the connection mid-stream —
    // remotely: `bps serve --fault conn_drop:every=6 --park-ttl 30`.
    let inj = Arc::new(Injector::new(FaultSpec::parse("conn_drop:every=6")?));
    srv.arm_faults(Arc::clone(&inj))?;
    let wire = WireServer::listen_with(
        "127.0.0.1:0",
        Arc::clone(&srv),
        WireConfig {
            park_ttl_ticks: Some(30_000), // park orphaned leases 30 s
            fault: Some(Arc::clone(&inj)),
            ..WireConfig::default()
        },
    )?;

    // A resume-capable client: on EOF it reconnects with capped
    // exponential backoff, presents the session's resume token, and the
    // server replays the one owed observation. `session.step` never
    // returns an error for a survivable kill — the outage is invisible
    // except in the resume counters. Remotely: `bps connect --retries 8`.
    let client = RemoteClient::connect_with_resume(
        &wire.local_addr().to_string(),
        ResumeCfg {
            max_retries: 8,
            base_ms: 20,
            cap_ms: 200,
            seed: 1,
        },
    )?;
    let mut session = client.open_session(Task::PointNav, 4)?;
    let mut cotenant = srv.connect(Task::PointNav, 4)?;
    let mut reward = 0.0f32;
    for t in 0..12usize {
        let actions: Vec<u8> = (0..4).map(|i| (1 + (t + i) % 3) as u8).collect();
        let view = session.step(&actions)?; // survives the injected kills
        reward += view.rewards.iter().sum::<f32>();
        cotenant.step(&actions)?;
    }
    let kills = inj.fired_drops.load(Ordering::Relaxed);
    let (resumes, backoff_ms) = client.resume_stats();
    println!(
        "12 steps x 4 envs, reward {reward:+.2} — stream survived {kills} \
         connection kills: resumes={resumes} backoff_ms_total={backoff_ms}"
    );
    let snap = srv.registry().snapshot();
    println!(
        "server:   serve.park.parked={} serve.resume.ok={} (open parks back to {})",
        snap.counter("serve.park.parked", &[]).unwrap_or(0),
        snap.counter("serve.resume.ok", &[]).unwrap_or(0),
        snap.gauge("serve.park.open", &[]).unwrap_or(0.0)
    );

    // Now the other failure class: a driver panic on shard 1. The shard
    // quarantines — its tenant gets a typed error with a retry-after
    // hint, never a hang or a poisoned mutex — while shard 0's stream
    // continues untouched. `restart_shard` (or `bps serve --heal-ms`)
    // rebuilds it in place.
    inj.arm_panic(1);
    let err = cotenant
        .step(&[1u8; 4])
        .expect_err("panicked shard must refuse the step");
    println!("panic:    co-tenant got: {err}");
    drop(cotenant); // release the dead lease before rebuilding
    while !srv.shard_quarantined(1) {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    srv.restart_shard(1)?;
    let mut healed = srv.connect(Task::PointNav, 4)?;
    healed.step(&[1u8; 4])?;
    println!("healed:   shard 1 restarted, fresh lease steps fine");
    let view = session.step(&[1u8; 4])?; // shard 0 never noticed
    println!(
        "isolated: remote stream on shard 0 at step {} throughout",
        view.step
    );

    session.detach()?;
    drop(healed);
    drop(client);
    drop(wire);
    Ok(())
}
