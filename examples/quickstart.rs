//! Quickstart: the smallest end-to-end BPS run, in two acts.
//!
//! Act 1 needs nothing but this repo: it builds an `EnvBatch` — the
//! batched request/response environment API at the heart of the system —
//! over a tiny procedural dataset and drives it with scripted actions
//! through the pipelined `submit → wait` cycle (simulation+rendering of
//! step t+1 overlaps consumption of step t via double buffering).
//!
//! Act 2 needs the AOT artifacts (`make artifacts`): it loads the `test`
//! model variant, trains a handful of PPO iterations through the
//! coordinator (a pure client of the same `EnvBatch` API), and prints the
//! FPS + runtime breakdown.
//!
//! Run: cargo run --release --example quickstart

use std::sync::Arc;

use bps::config::Config;
use bps::coordinator::Coordinator;
use bps::env::EnvBatchConfig;
use bps::render::RenderConfig;
use bps::scene::Dataset;
use bps::sim::{Task, NUM_ACTIONS};
use bps::util::pool::WorkerPool;

fn main() -> anyhow::Result<()> {
    let ds_dir = bps::bench::ensure_dataset("test", 4)?;

    // -- Act 1: the EnvBatch API, no artifacts required ---------------------
    println!("== EnvBatch quickstart: 8 envs, scripted actions ==");
    let ds = Dataset::open(&ds_dir)?;
    let scene = Arc::new(ds.load_scene(&ds.train[0], false)?);
    let pool = Arc::new(WorkerPool::new(WorkerPool::default_size()));
    let mut env = EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(32))
        .seed(7)
        .overlap(true) // double-buffered pipelined stepping (the default)
        .build_with_scenes((0..8).map(|_| Arc::clone(&scene)).collect(), pool)?;
    let mut reward_sum = 0.0f32;
    let mut episodes = 0u32;
    for t in 0..64usize {
        let actions: Vec<u8> = (0..8).map(|i| ((t + i) % NUM_ACTIONS) as u8).collect();
        let handle = env.submit(&actions)?; // sim+render of t+1 starts here
        let _obs_t = handle.current().obs; // step t stays readable meanwhile
        let view = handle.wait()?; // step t+1: borrowed SoA slices
        reward_sum += view.rewards.iter().sum::<f32>();
        episodes += view.dones.iter().filter(|&&d| d).count() as u32;
    }
    let (sim_d, render_d) = env.drain_timings();
    println!(
        "64 steps x 8 envs: total reward {reward_sum:+.2}, {episodes} episodes, \
         sim {:.1} ms, render {:.1} ms\n",
        sim_d.as_secs_f64() * 1e3,
        render_d.as_secs_f64() * 1e3
    );

    // -- Act 2: PPO training through the same API (needs `make artifacts`) --
    let mut cfg = Config::default();
    cfg.variant = "test".into();
    cfg.artifacts_dir = bps::bench::artifacts_dir();
    cfg.dataset_dir = ds_dir;
    cfg.num_envs = 4;
    cfg.rollout_len = 4;
    cfg.num_minibatches = 2;
    cfg.k_scenes = 2;
    cfg.total_frames = 320;

    println!("== BPS quickstart: PointGoalNav, 4 envs, tiny SE-ResNet9 ==");
    let mut coord = match Coordinator::new(cfg) {
        Ok(c) => c,
        Err(e) => {
            println!("(training act skipped: {e:#})");
            println!("run `make artifacts` to export the test AOT variant");
            return Ok(());
        }
    };
    while coord.frames() < coord.cfg.total_frames {
        let it = coord.train_iteration()?;
        println!(
            "frames {:>5}  reward {:+.3}  entropy {:.3}  value-loss {:.4}",
            coord.frames(),
            coord.stats.reward.mean(),
            it.losses.entropy,
            it.losses.value
        );
    }
    println!("\nFPS (paper methodology): {:.0}", coord.fps());
    for (name, us) in coord.prof.breakdown(coord.frames()) {
        println!("  {name:<10} {us:>8.1} us/frame");
    }
    Ok(())
}
