//! End-to-end validation driver (DESIGN.md §3: Table 2 / Fig. 3
//! scaled-down): trains a Depth PointGoalNav agent with the full BPS
//! stack — the coordinator stepping per-shard `EnvBatch` servers through
//! the pipelined submit/wait cycle — on a procedural gibson-like dataset,
//! logs the learning curve to CSV, then evaluates SPL/Success on the val
//! split (`--overlap false` selects synchronous stepping for A/B runs).
//!
//! Run: make artifacts && cargo run --release --example train_pointnav -- \
//!        [--frames 200000] [--envs 64] [--optimizer lamb|adam] [--arch bps|workers]
//!
//! The recorded run lives in EXPERIMENTS.md.

use bps::config::Config;
use bps::coordinator::Coordinator;
use bps::metrics::CsvLogger;
use bps::util::args::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut args = Args::parse(&argv)?;
    let frames = args.u64_or("frames", 200_000)?;
    let eval_episodes = args.usize_or("eval-episodes", 32)?;
    let curve_path = args.opt_or("curve", "runs/train_pointnav_curve.csv");

    let mut cfg = Config {
        variant: "depth64".into(),
        artifacts_dir: bps::bench::artifacts_dir(),
        dataset_dir: bps::bench::ensure_dataset("gibson", 8)?,
        num_envs: 64,
        rollout_len: 32,
        num_minibatches: 2,
        k_scenes: 4,
        total_frames: frames,
        memory_budget_mb: 16 * 1024,
        ..Config::default()
    };
    cfg.apply_args(&mut args)?;
    cfg.validate()?;

    println!(
        "== train_pointnav: {} frames, N={}, L={}, optimizer={}, arch={:?} ==",
        cfg.total_frames, cfg.num_envs, cfg.rollout_len, cfg.optimizer, cfg.arch
    );
    let mut coord = Coordinator::new(cfg)?;
    let mut curve = CsvLogger::create(
        std::path::Path::new(&curve_path),
        "iter,frames,seconds,fps,reward,success,spl,entropy",
    )?;
    let mut iter = 0u64;
    while coord.frames() < coord.cfg.total_frames {
        let it = coord.train_iteration()?;
        iter += 1;
        curve.row(&[
            iter as f64,
            coord.frames() as f64,
            coord.fps.elapsed().as_secs_f64(),
            coord.fps(),
            coord.stats.reward.mean() as f64,
            coord.stats.success.mean() as f64,
            coord.stats.spl.mean() as f64,
            it.losses.entropy as f64,
        ])?;
        if iter % 10 == 0 {
            println!(
                "iter {iter:>4} frames {:>8} fps {:>6.0} | reward {:+.2} success {:.2} spl {:.2} (eps {})",
                coord.frames(),
                coord.fps(),
                coord.stats.reward.mean(),
                coord.stats.success.mean(),
                coord.stats.spl.mean(),
                coord.stats.episodes
            );
        }
    }
    println!(
        "\ntraining done: {} frames, {:.0} FPS; curve -> {curve_path}",
        coord.frames(),
        coord.fps()
    );
    for (name, us) in coord.prof.breakdown(coord.frames()) {
        println!("  {name:<10} {us:>8.1} us/frame");
    }
    let (spl, success, _) = coord.evaluate("val", eval_episodes)?;
    println!(
        "\nval: SPL {:.1}  Success {:.1}  ({} episodes, greedy policy)",
        spl * 100.0,
        success * 100.0,
        eval_episodes
    );
    Ok(())
}
