//! Flee + Explore tasks on AI2-THOR-like scenes (paper Appendix A.1).
//!
//! First drives a *heterogeneous* pair of `EnvBatch` instances (one Flee,
//! one Explore — the multi-task shape `--tasks flee,explore` gives the
//! trainer) with scripted actions; needs no artifacts. Then, when the AOT
//! artifacts are present, runs short training for both tasks through the
//! coordinator, reporting FPS and the training-score window (meters for
//! Flee, visited cells for Explore).
//!
//! Run: cargo run --release --example flee_explore -- [--frames 50000]

use std::sync::Arc;

use bps::bench::{ensure_dataset, taskrow_config};
use bps::coordinator::Coordinator;
use bps::env::EnvBatchConfig;
use bps::render::RenderConfig;
use bps::sim::{SimConfig, Task};
use bps::util::args::Args;
use bps::util::pool::WorkerPool;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut args = Args::parse(&argv)?;
    let frames = args.u64_or("frames", 50_000)?;
    let dir = ensure_dataset("thor", 8)?;

    // heterogeneous EnvBatch pair: same scenes, different tasks, one pool
    println!("== heterogeneous EnvBatch: Flee + Explore, scripted actions ==");
    let ds = bps::scene::Dataset::open(&dir)?;
    let scene = Arc::new(ds.load_scene(&ds.train[0], false)?);
    let pool = Arc::new(WorkerPool::new(WorkerPool::default_size()));
    for task in [Task::Flee, Task::Explore] {
        // short episodes so the 128-step script completes several of them
        // (Flee/Explore only terminate on the step limit, never on STOP)
        let sim_cfg = SimConfig {
            max_steps: 32,
            ..SimConfig::for_task(task)
        };
        let mut env = EnvBatchConfig::new(task, RenderConfig::depth(32))
            .sim(sim_cfg)
            .seed(5)
            .build_with_scenes(
                (0..16).map(|_| Arc::clone(&scene)).collect(),
                Arc::clone(&pool),
            )?;
        let mut score = 0.0f32;
        let mut eps = 0u32;
        for t in 0..128usize {
            let actions: Vec<u8> = (0..16).map(|i| 1 + ((t + i) % 3) as u8).collect();
            let v = env.step(&actions)?;
            for i in 0..16 {
                if v.dones[i] {
                    score += v.scores[i];
                    eps += 1;
                }
            }
        }
        println!(
            "{task:?}: {eps} episodes, mean score {:.2}",
            score / eps.max(1) as f32
        );
    }

    println!("\n== Flee / Explore training on thor-like scenes (Depth agents) ==");
    for task in [Task::Flee, Task::Explore] {
        let mut cfg = taskrow_config(task);
        cfg.artifacts_dir = bps::bench::artifacts_dir();
        cfg.dataset_dir = dir.clone();
        cfg.total_frames = frames;
        let mut coord = match Coordinator::new(cfg) {
            Ok(c) => c,
            Err(e) => {
                println!("({task:?} training skipped: {e:#})");
                continue;
            }
        };
        while coord.frames() < coord.cfg.total_frames {
            coord.train_iteration()?;
        }
        println!(
            "{task:?}: {:.0} FPS, train score {:.2} over {} episodes",
            coord.fps(),
            coord.stats.score.mean(),
            coord.stats.episodes
        );
    }
    Ok(())
}
