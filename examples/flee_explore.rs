//! Flee + Explore tasks on AI2-THOR-like scenes (paper Appendix A.1):
//! short training runs for both auxiliary tasks, reporting FPS and the
//! training-score window (meters for Flee, visited cells for Explore).
//!
//! Run: cargo run --release --example flee_explore -- [--frames 50000]

use bps::bench::{ensure_dataset, taskrow_config};
use bps::coordinator::Coordinator;
use bps::sim::Task;
use bps::util::args::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut args = Args::parse(&argv)?;
    let frames = args.u64_or("frames", 50_000)?;
    let dir = ensure_dataset("thor", 8)?;
    println!("== Flee / Explore on thor-like scenes (Depth agents) ==");
    for task in [Task::Flee, Task::Explore] {
        let mut cfg = taskrow_config(task);
        cfg.artifacts_dir = bps::bench::artifacts_dir();
        cfg.dataset_dir = dir.clone();
        cfg.total_frames = frames;
        let mut coord = Coordinator::new(cfg)?;
        while coord.frames() < coord.cfg.total_frames {
            coord.train_iteration()?;
        }
        println!(
            "{task:?}: {:.0} FPS, train score {:.2} over {} episodes",
            coord.fps(),
            coord.stats.score.mean(),
            coord.stats.episodes
        );
    }
    Ok(())
}
