//! Observability integration tests (DESIGN.md §0.10).
//!
//! Acceptance gates: the STATS wire scrape, the HTTP `/metrics`
//! endpoint, and `SimServer::stats()` must *agree exactly* — all three
//! read the same registry cells, so a remote scrape can never drift
//! from the server's own accounting. Also: enabling every obs sink must
//! not perturb the simulation (bitwise-identical observation streams),
//! and the event log must record the session lifecycle.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use bps::env::EnvBatchConfig;
use bps::obs::{HttpHooks, MetricsServer, Trigger, SNAPSHOT_VERSION};
use bps::render::RenderConfig;
use bps::scene::procgen::{generate, Complexity};
use bps::scene::SceneAsset;
use bps::serve::{RemoteClient, ShardSpec, SimServer, WireServer};
use bps::sim::{Task, NUM_ACTIONS};
use bps::util::pool::WorkerPool;

const SEED: u64 = 0x0B5_CA5E;
const ENVS: usize = 4;
const STEPS: usize = 6;

fn scene() -> Arc<SceneAsset> {
    Arc::new(generate("obs_loopback", 29, Complexity::test()))
}

fn server() -> Arc<SimServer> {
    let s = scene();
    let cfg = EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(16)).seed(SEED);
    let spec = ShardSpec::with_scenes(cfg, (0..ENVS).map(|_| Arc::clone(&s)).collect());
    Arc::new(SimServer::start(vec![spec], Arc::new(WorkerPool::new(2))).unwrap())
}

fn actions_at(t: usize) -> Vec<u8> {
    (0..ENVS)
        .map(|i| (1 + (5 * t + 3 * i) % (NUM_ACTIONS - 1)) as u8)
        .collect()
}

fn wait_until(what: &str, cond: impl Fn() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Value of one series in a Prometheus text page (`name{labels...}` or
/// a bare `name` line).
fn scrape(text: &str, series: &str) -> f64 {
    let line = text
        .lines()
        .find(|l| l.strip_prefix(series).is_some_and(|r| r.starts_with(' ')))
        .unwrap_or_else(|| panic!("series {series:?} missing from scrape:\n{text}"));
    line.rsplit(' ').next().unwrap().parse().unwrap()
}

fn http_get(addr: std::net::SocketAddr, path: &str) -> String {
    let (status, body) = http_get_status(addr, path);
    assert_eq!(status, 200, "{path}: {body}");
    body
}

/// Tolerant variant: returns (status, body) so readiness flips (503) can
/// be asserted rather than panicking.
fn http_get_status(addr: std::net::SocketAddr, path: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    let (head, body) = out.split_once("\r\n\r\n").unwrap();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("bad status line: {head}"));
    (status, body.to_string())
}

/// Drop the `process_uptime_seconds` line: it advances in whole seconds
/// between two renders, so exact-equality comparisons must ignore it.
fn strip_uptime(text: &str) -> String {
    text.lines()
        .filter(|l| !l.starts_with("process_uptime_seconds"))
        .map(|l| format!("{l}\n"))
        .collect()
}

/// The core agreement gate: drive a remote session over loopback, then
/// check the in-band STATS scrape against `SimServer::stats()`, and —
/// after the connection quiesces — the HTTP `/metrics` page against the
/// registry's own rendering and the wire aggregates against
/// `conn_stats()`.
#[test]
fn loopback_scrape_matches_server_stats() {
    let srv = server();
    let metrics = MetricsServer::listen("127.0.0.1:0", srv.registry()).unwrap();
    let wire = WireServer::listen("127.0.0.1:0", Arc::clone(&srv)).unwrap();
    let client = RemoteClient::connect(&wire.local_addr().to_string()).unwrap();
    let mut session = client.open_session(Task::PointNav, ENVS).unwrap();
    for t in 0..STEPS {
        session.step(&actions_at(t)).unwrap();
    }

    // In-band scrape while the lease is live. Nothing is stepping (the
    // only session is idle), so shard counters cannot move between the
    // remote render and the local read.
    let (version, text) = client.stats_text().unwrap();
    assert_eq!(version, SNAPSHOT_VERSION);
    assert!(text.starts_with(&format!("# bps snapshot v{SNAPSHOT_VERSION}\n")));
    let st = &srv.stats()[0];
    assert_eq!(st.steps, STEPS as u64);
    assert_eq!(
        scrape(&text, "serve_shard_steps{shard=\"0\"}") as u64,
        st.steps
    );
    assert_eq!(
        scrape(&text, "serve_shard_leased{shard=\"0\"}") as usize,
        st.leased
    );
    assert_eq!(scrape(&text, "serve_shard_leased{shard=\"0\"}") as usize, ENVS);
    assert_eq!(
        scrape(&text, "serve_shard_straggler_fills{shard=\"0\"}") as u64,
        st.straggler_fills
    );
    assert_eq!(
        scrape(&text, "serve_shard_bad_submits{shard=\"0\"}") as u64,
        st.bad_submits
    );
    // one latency sample per session step landed in the histogram
    assert_eq!(
        scrape(&text, "serve_shard_latency_us_count{shard=\"0\"}") as u64,
        STEPS as u64
    );
    assert!(scrape(&text, "env_sim_us{shard=\"0\"}") > 0.0);
    assert!(scrape(&text, "render_raster_us{shard=\"0\"}") > 0.0);
    assert_eq!(scrape(&text, "wire_sessions_opened") as u64, 1);
    assert_eq!(scrape(&text, "wire_conns_open") as u64, 1);

    // Tear the connection down and let the server notice, then scrape
    // out-of-band over HTTP: with no wire traffic in flight the page is
    // stable and must equal the registry's canonical rendering and the
    // per-conn accounting exactly.
    session.detach().unwrap();
    drop(client);
    wait_until("conn close", || {
        wire.conn_stats().iter().all(|c| c.closed)
    });
    let page = http_get(metrics.local_addr(), "/metrics");
    assert_eq!(
        strip_uptime(&page),
        strip_uptime(&srv.registry().snapshot().to_prometheus())
    );
    assert_eq!(
        strip_uptime(&page),
        strip_uptime(&http_get(metrics.local_addr(), "/metrics"))
    );

    let conns = wire.conn_stats();
    assert_eq!(conns.len(), 1);
    let c = &conns[0];
    assert_eq!(scrape(&page, "wire_frames_in") as u64, c.frames_in);
    assert_eq!(scrape(&page, "wire_frames_out") as u64, c.frames_out);
    assert_eq!(scrape(&page, "wire_bytes_in") as u64, c.bytes_in);
    assert_eq!(scrape(&page, "wire_bytes_out") as u64, c.bytes_out);
    assert_eq!(scrape(&page, "wire_bad_frames") as u64, c.bad_frames);
    assert_eq!(scrape(&page, "wire_bad_frames") as u64, 0);
    assert_eq!(scrape(&page, "wire_conns_accepted") as u64, 1);
    assert_eq!(scrape(&page, "wire_conns_open") as u64, 0);
    assert_eq!(scrape(&page, "wire_sessions_open") as u64, 0);
    assert_eq!(scrape(&page, "serve_shard_leased{shard=\"0\"}") as usize, 0);

    assert_eq!(http_get(metrics.local_addr(), "/healthz"), "ok\n");

    // Build/version metadata rides on every snapshot.
    assert!(
        page.lines()
            .any(|l| l.starts_with("bps_build_info{version=") && l.ends_with(" 1")),
        "{page}"
    );
    assert!(page.contains("process_uptime_seconds"), "{page}");
}

/// Obs sinks must be pure observers: a session driven with tracing +
/// events enabled yields the bitwise-identical reward stream as one on
/// an identically-seeded server with everything disarmed.
#[test]
fn obs_sinks_do_not_perturb_stepping() {
    let run = |armed: bool| -> (Vec<f32>, Vec<bool>) {
        let srv = server();
        let dir = std::env::temp_dir().join("bps_obs_integration");
        std::fs::create_dir_all(&dir).unwrap();
        if armed {
            srv.trace().enable();
            srv.events()
                .arm(&dir.join("events.jsonl"), 1 << 20)
                .unwrap();
            // Watchdog + flight recorder armed too: the whole active obs
            // layer must stay a pure observer.
            srv.arm_recorder(&dir.join("incidents")).unwrap();
        }
        let mut session = srv.connect(Task::PointNav, ENVS).unwrap();
        let mut rewards = Vec::new();
        let mut dones = Vec::new();
        for t in 0..STEPS {
            let v = session.step(&actions_at(t)).unwrap();
            rewards.extend_from_slice(v.rewards);
            dones.extend_from_slice(v.dones);
        }
        (rewards, dones)
    };
    assert_eq!(run(false), run(true));
}

/// Spans from every pipeline stage reach the ring, and the Chrome
/// export is valid JSON naming each stage.
#[test]
fn trace_covers_pipeline_stages() {
    let srv = server();
    srv.trace().enable();
    let mut session = srv.connect(Task::PointNav, ENVS).unwrap();
    for t in 0..STEPS {
        session.step(&actions_at(t)).unwrap();
    }
    let spans = srv.trace().spans();
    for stage in [
        "coalesce",
        "sim",
        "render",
        "render.transform",
        "render.cull",
        "render.raster",
        "render.resolve",
        "publish",
    ] {
        assert!(
            spans.iter().filter(|s| s.name == stage).count() >= STEPS,
            "missing spans for stage {stage}"
        );
    }
    let json = srv.trace().to_chrome_json();
    let root = bps::util::json::Json::parse(&json).unwrap();
    let events = root.req("traceEvents").unwrap().as_arr().unwrap();
    assert!(events.len() >= spans.len());
}

/// Lease lifecycle events land in the JSONL log as parseable lines.
#[test]
fn event_log_records_lease_lifecycle() {
    let dir = std::env::temp_dir().join("bps_obs_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("lease_events.jsonl");
    let srv = server();
    srv.events().arm(&path, 1 << 20).unwrap();
    let mut session = srv.connect(Task::PointNav, ENVS).unwrap();
    session.step(&actions_at(0)).unwrap();
    session.detach();
    let text = std::fs::read_to_string(&path).unwrap();
    let events: Vec<String> = text
        .lines()
        .map(|l| {
            bps::util::json::Json::parse(l)
                .unwrap()
                .req("event")
                .unwrap()
                .as_str()
                .unwrap()
                .to_string()
        })
        .collect();
    assert!(events.contains(&"lease.grant".to_string()), "{events:?}");
    assert!(events.contains(&"lease.release".to_string()), "{events:?}");
}

/// The active layer end-to-end, with a fault injected instead of waited
/// for: pinning a role to Stalled must flip `/healthz` to 503 naming the
/// role, emit a `watchdog.stall` event, and write an incident bundle
/// whose four artifacts all parse; clearing the fault must recover.
#[test]
fn injected_stall_flips_health_and_writes_bundle() {
    let dir = std::env::temp_dir().join(format!("bps_obs_stall_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let srv = server();
    srv.trace().enable();
    let events_path = dir.join("events.jsonl");
    srv.events().arm(&events_path, 1 << 20).unwrap();
    let rec = srv.arm_recorder(&dir).unwrap();

    // The same hooks `bps serve --metrics-addr --dump-dir` installs.
    let mut hooks = HttpHooks::default();
    {
        let wd = srv.watchdog();
        hooks.health = Some(Arc::new(move || {
            let r = wd.report();
            (r.healthy(), r.to_json())
        }));
    }
    {
        let rec = Arc::clone(&rec);
        hooks.dump = Some(Arc::new(move || match rec.trigger(Trigger::Manual) {
            Ok(Some(p)) => Ok(format!("{{\"bundle\":\"{}\"}}", p.display())),
            Ok(None) => Err("suppressed".into()),
            Err(e) => Err(e.to_string()),
        }));
    }
    let metrics = MetricsServer::listen_with("127.0.0.1:0", srv.registry(), hooks).unwrap();

    // Step a little so the trace ring and latency cells have content.
    let mut session = srv.connect(Task::PointNav, ENVS).unwrap();
    for t in 0..STEPS {
        session.step(&actions_at(t)).unwrap();
    }

    let (status, _) = http_get_status(metrics.local_addr(), "/healthz");
    assert_eq!(status, 200);

    srv.watchdog().inject_stall("shard-driver");
    wait_until("healthz 503", || {
        http_get_status(metrics.local_addr(), "/healthz").0 == 503
    });
    let (_, body) = http_get_status(metrics.local_addr(), "/healthz");
    assert!(body.contains("shard-driver"), "{body}");
    // The committed stall auto-triggered an incident bundle.
    let bundles = |d: &std::path::Path| -> Vec<std::path::PathBuf> {
        let mut v: Vec<_> = std::fs::read_dir(d)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.is_dir()
                    && p.file_name()
                        .and_then(|n| n.to_str())
                        .is_some_and(|n| n.starts_with("incident-"))
            })
            .collect();
        v.sort();
        v
    };
    wait_until("stall bundle", || !bundles(&dir).is_empty());
    let stall_count = bundles(&dir).len();

    // A manual dump (as GET /debug/dump) bypasses the auto rate limit.
    let (status, dump_body) = http_get_status(metrics.local_addr(), "/debug/dump");
    assert_eq!(status, 200, "{dump_body}");
    assert!(bundles(&dir).len() > stall_count, "{dump_body}");

    // Every bundle artifact parses: manifest + watchdog table + sessions
    // as JSON, the trace as Chrome trace_event JSON, the metrics page as
    // a snapshot rendering, the event tail as JSONL.
    let bundle = bundles(&dir).pop().unwrap();
    let read = |name: &str| std::fs::read_to_string(bundle.join(name)).unwrap();
    let manifest = bps::util::json::Json::parse(&read("manifest.json")).unwrap();
    assert_eq!(
        manifest.req("snapshot_version").unwrap().as_f64().unwrap() as u32,
        SNAPSHOT_VERSION
    );
    assert!(read("metrics.prom").starts_with(&format!("# bps snapshot v{SNAPSHOT_VERSION}\n")));
    let trace = bps::util::json::Json::parse(&read("trace.json")).unwrap();
    assert!(!trace.req("traceEvents").unwrap().as_arr().unwrap().is_empty());
    for line in read("events.tail.jsonl").lines() {
        bps::util::json::Json::parse(line).unwrap();
    }
    let wd_table = bps::util::json::Json::parse(&read("watchdog.json")).unwrap();
    assert!(wd_table.to_string().contains("shard-driver"), "{wd_table:?}");
    bps::util::json::Json::parse(&read("sessions.json")).unwrap();

    // The bundle's metrics page agrees with a live scrape (modulo the
    // uptime line and any counters that moved since — the shard is idle,
    // so the serve/wire families are stable; spot-check one).
    let live = srv.registry().snapshot().to_prometheus();
    let bundled = read("metrics.prom");
    let steps_line = |text: &str| {
        text.lines()
            .find(|l| l.starts_with("serve_shard_steps{shard=\"0\"}"))
            .unwrap()
            .to_string()
    };
    assert_eq!(steps_line(&bundled), steps_line(&live));

    // Recovery: clear the fault, wait for the debounced rescan.
    srv.watchdog().clear_stall("shard-driver");
    wait_until("healthz 200", || {
        http_get_status(metrics.local_addr(), "/healthz").0 == 200
    });

    // The lifecycle landed in the event log.
    let text = std::fs::read_to_string(&events_path).unwrap();
    let events: Vec<String> = text
        .lines()
        .map(|l| {
            bps::util::json::Json::parse(l)
                .unwrap()
                .req("event")
                .unwrap()
                .as_str()
                .unwrap()
                .to_string()
        })
        .collect();
    assert!(events.contains(&"watchdog.stall".to_string()), "{events:?}");
    assert!(events.contains(&"watchdog.recover".to_string()), "{events:?}");
    assert!(events.contains(&"recorder.bundle".to_string()), "{events:?}");
}

/// Latency attribution: for an in-process session the four shard phases
/// (coalesce residual + sim + render + publish) must sum to the
/// end-to-end submit→result histogram within 5%.
#[test]
fn phase_attribution_sums_to_e2e_latency() {
    let srv = server();
    let mut session = srv.connect(Task::PointNav, ENVS).unwrap();
    for t in 0..STEPS * 4 {
        session.step(&actions_at(t)).unwrap();
    }
    let snap = srv.registry().snapshot();
    let phase = |p: &str| {
        snap.histogram("serve.session.phase_us", &[("phase", p)])
            .unwrap_or_else(|| panic!("phase histogram {p:?} missing"))
    };
    let e2e = snap
        .histogram("serve.shard.latency_us", &[("shard", "0")])
        .unwrap();
    assert_eq!(e2e.count, (STEPS * 4) as u64);
    for p in ["coalesce", "sim", "render", "publish"] {
        assert_eq!(phase(p).count, e2e.count, "phase {p}");
    }
    let parts: u64 = ["coalesce", "sim", "render", "publish"]
        .iter()
        .map(|p| phase(p).sum)
        .sum();
    let diff = (parts as f64 - e2e.sum as f64).abs();
    assert!(
        diff <= (0.05 * e2e.sum as f64).max(1_000.0),
        "phase sums {parts} vs e2e {} (diff {diff})",
        e2e.sum
    );
    // No tenant or wire traffic in this run: those phases exist only if
    // something observed them, and nothing did.
    if let Some(h) = snap.histogram("serve.session.phase_us", &[("phase", "infer")]) {
        assert_eq!(h.count, 0);
    }
}
