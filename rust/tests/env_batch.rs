//! Integration tests for the batched environment API (`bps::env`):
//! the pipelined double-buffered step cycle must be *bitwise identical*
//! to synchronous stepping, heterogeneous task batches must coexist on
//! one worker pool, and (when AOT artifacts are present) full coordinator
//! training must produce identical parameters either way.

use std::sync::Arc;

use bps::env::{EnvBatch, EnvBatchConfig};
use bps::render::{RenderConfig, SceneRotation};
use bps::scene::procgen::{generate, Complexity};
use bps::scene::SceneAsset;
use bps::sim::{Task, NUM_ACTIONS};
use bps::util::pool::WorkerPool;

fn scene(id: &str, seed: u64) -> Arc<SceneAsset> {
    Arc::new(generate(id, seed, Complexity::test()))
}

fn build(task: Task, n: usize, overlap: bool, pool: &Arc<WorkerPool>) -> EnvBatch {
    let s = scene("eqv", 77);
    EnvBatchConfig::new(task, RenderConfig::depth(24))
        .seed(0xBEEF)
        .overlap(overlap)
        .build_with_scenes((0..n).map(|_| Arc::clone(&s)).collect(), Arc::clone(pool))
        .unwrap()
}

/// The acceptance gate: same seed + same action stream → the pipelined
/// path's rollout tensors (obs, goal, rewards, dones, infos) are bitwise
/// equal to the synchronous path's at every step.
#[test]
fn pipelined_equals_sync_bitwise() {
    let n = 12;
    let l = 60;
    let pool = Arc::new(WorkerPool::new(3));
    let mut sync = build(Task::PointNav, n, false, &pool);
    let mut pipe = build(Task::PointNav, n, true, &pool);
    assert!(!sync.is_pipelined() && pipe.is_pipelined());

    // initial observations must already match
    assert_eq!(sync.view().obs, pipe.view().obs);
    assert_eq!(sync.view().goal, pipe.view().goal);

    // accumulate full rollout tensors from both paths
    let (mut obs_a, mut obs_b) = (Vec::new(), Vec::new());
    for t in 0..l {
        let actions: Vec<u8> = (0..n).map(|i| ((7 * t + 3 * i) % NUM_ACTIONS) as u8).collect();
        let va = sync.step(&actions).unwrap();
        obs_a.extend_from_slice(va.obs);
        let (rewards, dones, goal, spl, scores, succ) = (
            va.rewards.to_vec(),
            va.dones.to_vec(),
            va.goal.to_vec(),
            va.spl.to_vec(),
            va.scores.to_vec(),
            va.successes.to_vec(),
        );
        let vb = pipe.step(&actions).unwrap();
        obs_b.extend_from_slice(vb.obs);
        assert_eq!(rewards, vb.rewards, "rewards diverged at step {t}");
        assert_eq!(dones, vb.dones, "dones diverged at step {t}");
        assert_eq!(goal, vb.goal, "goal sensor diverged at step {t}");
        assert_eq!(spl, vb.spl, "spl diverged at step {t}");
        assert_eq!(scores, vb.scores, "scores diverged at step {t}");
        assert_eq!(succ, vb.successes, "successes diverged at step {t}");
    }
    assert_eq!(obs_a, obs_b, "observation megaframes diverged");
    // something actually happened in this rollout
    assert!(obs_a.iter().any(|&x| x > 0.0));
}

/// The overlap window must not corrupt the front buffer: inference-side
/// reads of step t during sim+render of t+1 see frozen data.
#[test]
fn overlap_window_front_buffer_stable() {
    let n = 6;
    let pool = Arc::new(WorkerPool::new(2));
    let mut env = build(Task::PointNav, n, true, &pool);
    for t in 0..30usize {
        let snapshot = env.view().obs.to_vec();
        let actions = vec![((t % 3) + 1) as u8; n];
        let handle = env.submit(&actions).unwrap();
        // repeatedly re-read while the driver is (possibly) mid-step
        for _ in 0..5 {
            assert_eq!(handle.current().obs, &snapshot[..]);
        }
        handle.wait().unwrap();
    }
}

/// Heterogeneous batches (the `--tasks` shape): three tasks, one shared
/// worker pool, all pipelined and stepping concurrently.
#[test]
fn multi_task_env_batches_coexist() {
    let n = 8;
    let pool = Arc::new(WorkerPool::new(3));
    let mut batches: Vec<EnvBatch> = [Task::PointNav, Task::Flee, Task::Explore]
        .into_iter()
        .map(|task| build(task, n, true, &pool))
        .collect();
    // PointNav exposes the GPS+compass goal; Flee/Explore run goal-free
    assert!(batches[0].view().goal.iter().any(|&g| g != 0.0));
    assert!(batches[1].view().goal.iter().all(|&g| g == 0.0));
    assert!(batches[2].view().goal.iter().all(|&g| g == 0.0));
    let mut episodes = [0u32; 3];
    for t in 0..200usize {
        // interleave submits so all three overlap on the shared pool
        let actions: Vec<u8> = (0..n).map(|i| (1 + (t + i) % 3) as u8).collect();
        let handles: Vec<_> = batches
            .iter_mut()
            .map(|b| b.submit(&actions).unwrap())
            .collect();
        for (k, h) in handles.into_iter().enumerate() {
            let v = h.wait().unwrap();
            assert!(v.rewards.iter().all(|r| r.is_finite()));
            episodes[k] += v.dones.iter().filter(|&&d| d).count() as u32;
        }
    }
    assert_eq!(batches[0].task(), Task::PointNav);
    assert_eq!(batches[2].task(), Task::Explore);
    // turn+forward scripts never call STOP, so PointNav envs only end on
    // timeout; 200 < max_steps means no PointNav episode may have ended
    assert_eq!(episodes[0], 0);
}

/// EnvBatch owns the scene rotation: build over a K-slot rotation,
/// step, and drive `rotate_scenes` without touching sim internals.
#[test]
fn rotation_owned_by_env_batch() {
    let dir = std::env::temp_dir().join("bps_envbatch_rot");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let ds =
        bps::scene::dataset::generate_dataset(&dir, 4, 0, 0, Complexity::test(), 31).unwrap();
    let ids = ds.train.clone();
    let rot = SceneRotation::new(ds, ids, 2, false).unwrap();
    let pool = Arc::new(WorkerPool::new(2));
    let mut env = EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(16))
        .seed(9)
        .build_with_rotation(rot, 6, pool)
        .unwrap();
    assert_eq!(env.num_envs(), 6);
    assert!(env.resident_bytes() > 0);
    let actions = vec![2u8; 6];
    for _ in 0..20 {
        env.step(&actions).unwrap();
        env.rotate_scenes().unwrap();
    }
    let (sim_d, _render_d) = env.drain_timings();
    assert!(sim_d.as_nanos() > 0);
}

/// The DESIGN.md §0 determinism caveat, fixed: with prefetch *active*
/// (k < split size) a wall-clock rotation schedule makes pipelined vs
/// synchronous runs diverge whenever a swap lands on a different
/// iteration. Pinning the schedule to call counts
/// (`EnvBatchConfig::pin_rotation`) restores bitwise equivalence.
#[test]
fn pinned_rotation_keeps_pipelined_sync_bitwise() {
    use bps::sim::SimConfig;
    let dir = std::env::temp_dir().join("bps_envbatch_pin");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let ds =
        bps::scene::dataset::generate_dataset(&dir, 5, 0, 0, Complexity::test(), 77).unwrap();
    let n = 6;
    let pool = Arc::new(WorkerPool::new(2));
    let mk = |overlap: bool| {
        // k=2 of 5 train scenes: the prefetcher is active the whole run
        let rot = SceneRotation::new(ds.clone(), ds.train.clone(), 2, false).unwrap();
        EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(16))
            .seed(33)
            .overlap(overlap)
            .pin_rotation(2)
            // short episodes so queued scene swaps actually apply
            .sim(SimConfig {
                max_steps: 6,
                ..SimConfig::pointnav()
            })
            .build_with_rotation(rot, n, Arc::clone(&pool))
            .unwrap()
    };
    let mut sync = mk(false);
    let mut pipe = mk(true);
    for t in 0..40 {
        let actions: Vec<u8> = (0..n).map(|i| (1 + (t + i) % 3) as u8).collect();
        let va = sync.step(&actions).unwrap();
        let (obs, rewards, dones) = (va.obs.to_vec(), va.rewards.to_vec(), va.dones.to_vec());
        let vb = pipe.step(&actions).unwrap();
        assert_eq!(obs, vb.obs, "obs diverged at step {t}");
        assert_eq!(rewards, vb.rewards, "rewards diverged at step {t}");
        assert_eq!(dones, vb.dones, "dones diverged at step {t}");
        sync.rotate_scenes().unwrap();
        pipe.rotate_scenes().unwrap();
    }
}

/// Full-stack gate (needs `make artifacts`): two coordinator training
/// iterations with pipelined vs synchronous env stepping must produce
/// bitwise-identical parameters.
#[test]
fn coordinator_overlap_equivalence() {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    if !root.join("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let ds_dir = std::env::temp_dir().join("bps_envbatch_e2e_dataset");
    if !ds_dir.join("splits.json").exists() {
        std::fs::create_dir_all(&ds_dir).unwrap();
        bps::scene::generate_dataset(&ds_dir, 3, 1, 1, Complexity::test(), 123).unwrap();
    }
    let mk = |overlap: bool| bps::config::Config {
        variant: "test".into(),
        artifacts_dir: root.join("artifacts"),
        dataset_dir: ds_dir.clone(),
        complexity: "test".into(),
        num_envs: 4,
        rollout_len: 4,
        num_minibatches: 2,
        // k == train-scene count disables rotation prefetch, which would
        // otherwise swap scenes at timing-dependent iterations and make
        // the bitwise comparison below flaky (or set rotate_every)
        k_scenes: 3,
        total_frames: 32,
        seed: 5,
        threads: 2,
        overlap,
        ..Default::default()
    };
    let mut a = bps::coordinator::Coordinator::new(mk(true)).unwrap();
    let mut b = bps::coordinator::Coordinator::new(mk(false)).unwrap();
    for _ in 0..2 {
        a.train_iteration().unwrap();
        b.train_iteration().unwrap();
    }
    assert_eq!(
        a.params.flat, b.params.flat,
        "pipelined vs sync training diverged"
    );
    assert_eq!(a.params.step, b.params.step);
}
