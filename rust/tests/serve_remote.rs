//! Integration tests for the wire transport (`bps::serve::wire`).
//!
//! Acceptance gates: a `RemoteSession` over loopback TCP must produce
//! the *bitwise identical* per-step observation/reward stream as an
//! in-process `Session` on an identically seeded `SimServer` (including
//! a two-client interleave and a detach/re-lease cycle), and hostile
//! input — malformed frames, bad slot indices, slow readers — must
//! error cleanly without panicking the shard driver or disturbing
//! co-tenant sessions.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use bps::env::{EnvBatch, EnvBatchConfig};
use bps::render::RenderConfig;
use bps::scene::procgen::{generate, Complexity};
use bps::scene::SceneAsset;
use bps::serve::wire::frame::{self, Frame, ERR_RETRY_AFTER, ERR_SESSION, ERR_SUBMIT};
use bps::serve::{
    FillAction, RemoteClient, ShardSpec, SimServer, StragglerPolicy, WireConfig, WireServer,
};
use bps::sim::{Task, ACTION_FORWARD, NUM_ACTIONS};
use bps::util::pool::WorkerPool;

const SEED: u64 = 0xB17_0E5;

fn scene() -> Arc<SceneAsset> {
    Arc::new(generate("serve_wire_eqv", 93, Complexity::test()))
}

fn env_cfg() -> EnvBatchConfig {
    EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(16)).seed(SEED)
}

fn direct_batch(n: usize, pool: &Arc<WorkerPool>) -> EnvBatch {
    let s = scene();
    env_cfg()
        .overlap(false)
        .build_with_scenes((0..n).map(|_| Arc::clone(&s)).collect(), Arc::clone(pool))
        .unwrap()
}

fn server(n: usize, policy: StragglerPolicy, pool: &Arc<WorkerPool>) -> Arc<SimServer> {
    let s = scene();
    let spec = ShardSpec::with_scenes(env_cfg(), (0..n).map(|_| Arc::clone(&s)).collect())
        .straggler(policy);
    Arc::new(SimServer::start(vec![spec], Arc::clone(pool)).unwrap())
}

fn actions_at(t: usize, n: usize) -> Vec<u8> {
    (0..n).map(|i| ((5 * t + 3 * i) % NUM_ACTIONS) as u8).collect()
}

/// Poll until `cond` holds (10s cap) so socket teardown races can't
/// flake the assertions.
fn wait_until(what: &str, cond: impl Fn() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Divide an iteration count by `BPS_TEST_SCALE` (the CI TSan job sets
/// it — every memory access is instrumented there, so native counts
/// would run for hours). Unset or 1 means full native counts.
fn scaled(n: usize) -> usize {
    match std::env::var("BPS_TEST_SCALE") {
        Ok(v) => (n / v.parse::<usize>().unwrap_or(1).max(1)).max(1),
        Err(_) => n,
    }
}

/// A `RemoteSession` leasing the whole shard over loopback TCP must be
/// bitwise identical to direct `EnvBatch` stepping at every step,
/// starting from the pre-submit initial observation.
#[test]
fn remote_single_session_bitwise_equals_direct() {
    let n = 8;
    let pool = Arc::new(WorkerPool::new(2));
    let mut direct = direct_batch(n, &pool);
    let srv = server(n, StragglerPolicy::Wait, &pool);
    let wire = WireServer::listen("127.0.0.1:0", Arc::clone(&srv)).unwrap();
    let client = RemoteClient::connect(&wire.local_addr().to_string()).unwrap();
    assert_eq!(client.num_shards(), 1);
    let mut session = client.open_session(Task::PointNav, n).unwrap();
    assert_eq!(session.num_envs(), n);
    assert_eq!(session.obs_floats(), direct.obs_floats());
    assert_eq!(session.task(), Task::PointNav);
    assert_eq!(session.slots(), (0..n).collect::<Vec<_>>().as_slice());

    // the initial observation crossed the wire bit-for-bit
    assert_eq!(session.view().step, 0);
    assert_eq!(session.view().obs, direct.view().obs);
    assert_eq!(session.view().goal, direct.view().goal);

    for t in 0..40 {
        let actions = actions_at(t, n);
        let dv = direct.step(&actions).unwrap();
        let (obs, goal, rewards, dones, successes, spl, scores) = (
            dv.obs.to_vec(),
            dv.goal.to_vec(),
            dv.rewards.to_vec(),
            dv.dones.to_vec(),
            dv.successes.to_vec(),
            dv.spl.to_vec(),
            dv.scores.to_vec(),
        );
        let sv = session.step(&actions).unwrap();
        assert_eq!(sv.step, (t + 1) as u64, "shard step counter");
        assert_eq!(obs, sv.obs, "obs diverged at step {t}");
        assert_eq!(goal, sv.goal, "goal diverged at step {t}");
        assert_eq!(rewards, sv.rewards, "rewards diverged at step {t}");
        assert_eq!(dones, sv.dones, "dones diverged at step {t}");
        assert_eq!(successes, sv.successes, "successes diverged at step {t}");
        assert_eq!(spl, sv.spl, "spl diverged at step {t}");
        assert_eq!(scores, sv.scores, "scores diverged at step {t}");
    }
    let stats = srv.stats();
    assert_eq!(stats[0].steps, 40);
    assert_eq!(stats[0].leased, n);
    assert_eq!(stats[0].bad_submits, 0);
    let (p50, p95) = session.latency();
    assert!(p50 > 0.0 && p95 >= p50);

    // per-connection wire stats: hello + lease + 40 submits in,
    // welcome + grant + initial step + 40 step views out. The writer
    // thread counts *after* write_all, so the 43rd outbound tick can
    // land a beat after the client sees the step — poll for it.
    wait_until("writer counter", || wire.conn_stats()[0].frames_out == 43);
    let conns = wire.conn_stats();
    assert_eq!(conns.len(), 1);
    assert_eq!(conns[0].sessions_open, 1);
    assert_eq!(conns[0].sessions_opened, 1);
    assert_eq!(conns[0].frames_in, 42);
    assert_eq!(conns[0].frames_out, 43);
    assert_eq!(conns[0].bad_frames, 0);
    assert!(conns[0].bytes_in > 0 && conns[0].bytes_out > 0);
    assert!(!conns[0].dropped_slow && !conns[0].closed);
}

/// Two remote clients (separate connections) interleaving partial
/// submissions on one shard must jointly reproduce the direct
/// full-batch step; a detach / re-lease cycle then hands the freed
/// slots to a third session without disturbing the survivor.
#[test]
fn remote_two_clients_interleave_detach_and_re_lease() {
    let n = 8;
    let half = n / 2;
    let pool = Arc::new(WorkerPool::new(2));
    let mut direct = direct_batch(n, &pool);
    let srv = server(n, StragglerPolicy::Wait, &pool);
    let wire = WireServer::listen("127.0.0.1:0", Arc::clone(&srv)).unwrap();
    let addr = wire.local_addr().to_string();
    let ca = RemoteClient::connect(&addr).unwrap();
    let cb = RemoteClient::connect(&addr).unwrap();
    let mut a = ca.open_session(Task::PointNav, half).unwrap();
    let mut b = cb.open_session(Task::PointNav, half).unwrap();
    assert_eq!(a.slots(), &[0, 1, 2, 3]);
    assert_eq!(b.slots(), &[4, 5, 6, 7]);
    let of = a.obs_floats();

    for t in 0..20 {
        let actions = actions_at(t, n);
        let dv = direct.step(&actions).unwrap();
        let (d_obs, d_rewards, d_dones) =
            (dv.obs.to_vec(), dv.rewards.to_vec(), dv.dones.to_vec());
        // alternate submission order; the step only fires once both land
        let (va, vb) = if t % 2 == 0 {
            let ta = a.submit(&actions[..half]).unwrap();
            let tb = b.submit(&actions[half..]).unwrap();
            let vb = tb.wait().unwrap();
            let va = ta.wait().unwrap();
            (va, vb)
        } else {
            let tb = b.submit(&actions[half..]).unwrap();
            let ta = a.submit(&actions[..half]).unwrap();
            let va = ta.wait().unwrap();
            let vb = tb.wait().unwrap();
            (va, vb)
        };
        assert_eq!(va.step, vb.step, "both clients see the same batch step");
        assert_eq!(va.obs, &d_obs[..half * of], "client A obs at step {t}");
        assert_eq!(vb.obs, &d_obs[half * of..], "client B obs at step {t}");
        assert_eq!(va.rewards, &d_rewards[..half]);
        assert_eq!(vb.rewards, &d_rewards[half..]);
        assert_eq!(va.dones, &d_dones[..half]);
        assert_eq!(vb.dones, &d_dones[half..]);
    }

    // detach is acked after the release, so the slots are immediately
    // re-leasable — lowest-first, like the in-process path
    a.detach().unwrap();
    assert_eq!(srv.stats()[0].leased, half);
    let mut c = ca.open_session(Task::PointNav, half).unwrap();
    assert_eq!(c.slots(), &[0, 1, 2, 3]);
    assert_eq!(srv.stats()[0].leased, n);

    // both tenants step together again, on the same batch step
    let acts = vec![ACTION_FORWARD; half];
    let tc = c.submit(&acts).unwrap();
    let tb = b.submit(&acts).unwrap();
    let vc = tc.wait().unwrap();
    let vb = tb.wait().unwrap();
    assert_eq!(vc.step, vb.step);
    assert!(vc.rewards.iter().all(|r| r.is_finite()));

    // a detached session refuses further submits, client-side
    assert!(a.submit(&acts).is_err());
    assert_eq!(srv.stats()[0].bad_submits, 0);
}

/// One socket multiplexes several sessions: two leases on one
/// `RemoteClient` jointly reproduce the direct full-batch step.
#[test]
fn remote_sessions_multiplex_over_one_socket() {
    let n = 6;
    let half = n / 2;
    let pool = Arc::new(WorkerPool::new(2));
    let mut direct = direct_batch(n, &pool);
    let srv = server(n, StragglerPolicy::Wait, &pool);
    let wire = WireServer::listen("127.0.0.1:0", Arc::clone(&srv)).unwrap();
    let client = RemoteClient::connect(&wire.local_addr().to_string()).unwrap();
    let mut a = client.open_session(Task::PointNav, half).unwrap();
    let mut b = client.open_session(Task::PointNav, half).unwrap();
    assert_eq!(a.slots(), &[0, 1, 2]);
    assert_eq!(b.slots(), &[3, 4, 5]);

    for t in 0..10 {
        let actions = actions_at(t, n);
        let dv = direct.step(&actions).unwrap();
        let (d_rewards, d_obs) = (dv.rewards.to_vec(), dv.obs.to_vec());
        let ta = a.submit(&actions[..half]).unwrap();
        let tb = b.submit(&actions[half..]).unwrap();
        let va = ta.wait().unwrap();
        let vb = tb.wait().unwrap();
        assert_eq!(va.step, vb.step);
        assert_eq!(va.obs, &d_obs[..half * a.obs_floats()]);
        assert_eq!(vb.obs, &d_obs[half * a.obs_floats()..]);
        assert_eq!(va.rewards, &d_rewards[..half]);
        assert_eq!(vb.rewards, &d_rewards[half..]);
    }
    // wrong action count is rejected client-side without poisoning
    assert!(a.submit(&[ACTION_FORWARD]).is_err());
    let fwd = vec![ACTION_FORWARD; half];
    let ta = a.submit(&fwd).unwrap();
    let tb = b.submit(&fwd).unwrap();
    tb.wait().unwrap();
    let v = ta.wait().unwrap();
    assert!(v.step > 10);

    // a ticket dropped without waiting leaves its Step frame queued; the
    // next wait must drain past it instead of going one-behind forever
    let tb = b.submit(&fwd).unwrap();
    let ta = a.submit(&fwd).unwrap();
    drop(ta); // never waited
    tb.wait().unwrap();
    let ta2 = a.submit(&fwd).unwrap();
    let tb2 = b.submit(&fwd).unwrap();
    let va = ta2.wait().unwrap();
    let step_a = va.step;
    let vb = tb2.wait().unwrap();
    assert_eq!(step_a, vb.step, "dropped ticket desynced the session");

    let conns = wire.conn_stats();
    assert_eq!(conns.len(), 1, "one socket for both sessions");
    assert_eq!(conns[0].sessions_opened, 2);
}

/// Fuzz-style table test: truncated, oversized-length, wrong-version,
/// and mid-stream-garbage frames each error the *connection* cleanly —
/// the co-tenant session on the same shard keeps stepping and the shard
/// driver never panics.
#[test]
fn hostile_frames_error_cleanly_and_co_tenants_survive() {
    let n = 4;
    let pool = Arc::new(WorkerPool::new(2));
    let srv = server(n, StragglerPolicy::Wait, &pool);
    let wire = WireServer::listen("127.0.0.1:0", Arc::clone(&srv)).unwrap();
    let addr = wire.local_addr();
    // in-process co-tenant holds the whole shard and must never notice
    let mut tenant = srv.connect(Task::PointNav, n).unwrap();
    let acts = vec![ACTION_FORWARD; n];
    tenant.step(&acts).unwrap();

    let magic = frame::MAGIC.to_le_bytes();
    let hostile: Vec<(&str, Vec<u8>)> = vec![
        ("truncated header", vec![magic[0], magic[1], frame::VERSION]),
        (
            "bad magic",
            vec![0xDE, 0xAD, frame::VERSION, frame::FT_HELLO, 0, 0, 0, 0],
        ),
        (
            "wrong version",
            vec![magic[0], magic[1], 99, frame::FT_HELLO, 0, 0, 0, 0],
        ),
        (
            "oversized length",
            vec![
                magic[0],
                magic[1],
                frame::VERSION,
                frame::FT_SUBMIT,
                0xFF,
                0xFF,
                0xFF,
                0xFF,
            ],
        ),
        ("server-only frame type from a client", {
            // a 32 MiB "STEP" aimed at the server: rejected from the
            // header alone, allocation-free (wrong direction)
            let mut b = vec![magic[0], magic[1], frame::VERSION, frame::FT_STEP];
            b.extend_from_slice(&(32u32 << 20).to_le_bytes());
            b
        }),
        ("submit length over the per-type cap", {
            let mut b = vec![magic[0], magic[1], frame::VERSION, frame::FT_SUBMIT];
            b.extend_from_slice(&(1u32 << 20).to_le_bytes());
            b
        }),
        ("mid-stream garbage", {
            let mut b = Vec::new();
            let mut hello = Vec::new();
            frame::encode(&Frame::Hello, &mut hello);
            b.extend_from_slice(&hello);
            b.extend_from_slice(&[0x5A; 64]); // garbage after a valid HELLO
            b
        }),
        ("truncated payload then close", {
            let mut b = Vec::new();
            let mut lease = Vec::new();
            frame::encode(
                &Frame::Lease {
                    req: 1,
                    task: Task::PointNav,
                    n_envs: 1,
                },
                &mut lease,
            );
            let mut hello = Vec::new();
            frame::encode(&Frame::Hello, &mut hello);
            b.extend_from_slice(&hello);
            b.extend_from_slice(&lease[..lease.len() - 3]); // cut mid-payload
            b
        }),
    ];
    let before = wire.conn_stats().len();
    for (what, bytes) in &hostile {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(bytes).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        // drain whatever courtesy frames the server sends until EOF —
        // the point is that the server hangs up without panicking
        while frame::read_frame(&mut s).is_ok() {}
        drop(s);
        // the co-tenant's shard is untouched by the hostile connection
        let v = tenant.step(&acts).unwrap();
        assert!(
            v.rewards.iter().all(|r| r.is_finite()),
            "co-tenant wobbled after {what}"
        );
    }
    wait_until("hostile conns to close", || {
        wire.conn_stats().iter().skip(before).all(|c| c.closed)
    });
    let conns = wire.conn_stats();
    assert_eq!(conns.len(), before + hostile.len());
    let flagged = conns.iter().skip(before).filter(|c| c.bad_frames > 0).count();
    assert_eq!(flagged, hostile.len(), "every hostile conn counted a bad frame");
    assert_eq!(srv.stats()[0].bad_submits, 0, "no lease, no submits");
    assert_eq!(srv.stats()[0].leased, n, "tenant lease untouched");
}

/// Well-formed frames with hostile *content*: bad slot indices are
/// skipped and counted (never panicking the driver), an all-bad submit
/// earns an error frame instead of a hung wait, and unknown session ids
/// are reported without killing the connection.
#[test]
fn bad_slot_indices_are_counted_not_fatal() {
    let n = 4;
    let pool = Arc::new(WorkerPool::new(2));
    let policy = StragglerPolicy::Deadline {
        ticks: 2,
        fill: FillAction::NoOp,
    };
    let srv = server(n, policy, &pool);
    let wire = WireServer::listen("127.0.0.1:0", Arc::clone(&srv)).unwrap();
    // in-process co-tenant on half the shard
    let mut tenant = srv.connect(Task::PointNav, 2).unwrap();
    let acts = vec![ACTION_FORWARD; 2];

    // hand-rolled wire client so we control the exact slot indices
    let mut s = TcpStream::connect(wire.local_addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    frame::write_frame(&mut s, &Frame::Hello).unwrap();
    match frame::read_frame(&mut s).unwrap() {
        Frame::Welcome { shards } => assert_eq!(shards, 1),
        other => panic!("want WELCOME, got {other:?}"),
    }
    frame::write_frame(
        &mut s,
        &Frame::Lease {
            req: 1,
            task: Task::PointNav,
            n_envs: 2,
        },
    )
    .unwrap();
    let (session, slots) = match frame::read_frame(&mut s).unwrap() {
        Frame::Grant { session, slots, .. } => (session, slots),
        other => panic!("want GRANT, got {other:?}"),
    };
    assert_eq!(slots, vec![2, 3], "co-tenant holds 0,1");
    match frame::read_frame(&mut s).unwrap() {
        Frame::Step { step, .. } => assert_eq!(step, 0, "initial observation"),
        other => panic!("want initial STEP, got {other:?}"),
    }

    // one valid pair + one insane index: the insane one is skipped and
    // counted, the valid one steps (deadline fills the rest)
    frame::write_frame(
        &mut s,
        &Frame::Submit {
            session,
            pairs: vec![(slots[0], ACTION_FORWARD), (u32::MAX, ACTION_FORWARD)],
        },
    )
    .unwrap();
    let tv = tenant.step(&acts).unwrap();
    assert!(tv.step >= 1);
    match frame::read_frame(&mut s).unwrap() {
        Frame::Step { step, .. } => assert!(step >= 1),
        other => panic!("want STEP, got {other:?}"),
    }
    assert_eq!(srv.stats()[0].bad_submits, 1);

    // an all-bad submit must not hang the session in an unprovokable
    // wait: the server answers with ERR_SUBMIT and keeps the session
    frame::write_frame(
        &mut s,
        &Frame::Submit {
            session,
            pairs: vec![(999_999, 1), (0, 1)], // slot 0 is the tenant's!
        },
    )
    .unwrap();
    match frame::read_frame(&mut s).unwrap() {
        Frame::Error { re, code, .. } => {
            assert_eq!(re, session);
            assert_eq!(code, ERR_SUBMIT);
        }
        other => panic!("want ERROR, got {other:?}"),
    }
    assert_eq!(srv.stats()[0].bad_submits, 3, "foreign slot counted too");

    // unknown session ids are reported without killing the connection
    frame::write_frame(
        &mut s,
        &Frame::Submit {
            session: 0xDEAD,
            pairs: vec![(0, 1)],
        },
    )
    .unwrap();
    match frame::read_frame(&mut s).unwrap() {
        Frame::Error { re, code, .. } => {
            assert_eq!(re, 0xDEAD);
            assert_eq!(code, ERR_SESSION);
        }
        other => panic!("want ERROR, got {other:?}"),
    }

    // the session (and the shard) are still healthy after all of it
    frame::write_frame(
        &mut s,
        &Frame::Submit {
            session,
            pairs: vec![(slots[0], ACTION_FORWARD), (slots[1], ACTION_FORWARD)],
        },
    )
    .unwrap();
    let tv = tenant.step(&acts).unwrap();
    assert!(tv.rewards.iter().all(|r| r.is_finite()));
    match frame::read_frame(&mut s).unwrap() {
        Frame::Step { .. } => {}
        other => panic!("want STEP, got {other:?}"),
    }
    assert_eq!(srv.stats()[0].leased, n, "all leases intact");
}

/// Backpressure: a client that submits but never drains its socket
/// overflows the bounded per-connection outbox and is disconnected by
/// the slow-reader policy; its lease is released for re-use.
#[test]
fn slow_reader_is_disconnected_and_lease_released() {
    let n = 1;
    let pool = Arc::new(WorkerPool::new(2));
    let s = scene();
    let spec = ShardSpec::with_scenes(
        EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(32)).seed(SEED),
        vec![Arc::clone(&s)],
    );
    let srv = Arc::new(SimServer::start(vec![spec], Arc::clone(&pool)).unwrap());
    // huge inbox so this test isolates the *outbox* (slow-reader) bound;
    // the inbox (flood) bound gets its own test below
    let wire = WireServer::listen_with(
        "127.0.0.1:0",
        Arc::clone(&srv),
        WireConfig {
            outbox_frames: 1,
            inbox_submits: 1 << 20,
            ..WireConfig::default()
        },
    )
    .unwrap();

    let mut sock = TcpStream::connect(wire.local_addr()).unwrap();
    sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    frame::write_frame(&mut sock, &Frame::Hello).unwrap();
    assert!(matches!(
        frame::read_frame(&mut sock).unwrap(),
        Frame::Welcome { .. }
    ));
    frame::write_frame(
        &mut sock,
        &Frame::Lease {
            req: 1,
            task: Task::PointNav,
            n_envs: n as u32,
        },
    )
    .unwrap();
    let session = match frame::read_frame(&mut sock).unwrap() {
        Frame::Grant { session, .. } => session,
        other => panic!("want GRANT, got {other:?}"),
    };
    wait_until("lease to register", || srv.stats()[0].leased == n);

    // flood submits without ever reading a step view: the kernel socket
    // buffers fill, the writer blocks, the 1-frame outbox overflows,
    // and the slow-reader policy hangs up
    let mut submit = Vec::new();
    frame::encode(
        &Frame::Submit {
            session,
            pairs: vec![(0, ACTION_FORWARD)],
        },
        &mut submit,
    );
    // The flood exits early the moment the slow-reader policy fires;
    // the bound only caps a pathological run (scaled down under TSan).
    for _ in 0..scaled(200_000) {
        if sock.write_all(&submit).is_err() {
            break; // server already hung up on us
        }
        let stats = wire.conn_stats();
        if stats[0].dropped_slow {
            break;
        }
    }
    wait_until("slow-reader disconnect", || wire.conn_stats()[0].dropped_slow);
    wait_until("conn to close", || wire.conn_stats()[0].closed);
    // the dead connection's lease is released; a fresh client can lease
    wait_until("lease release", || srv.stats()[0].leased == 0);
    let client = RemoteClient::connect(&wire.local_addr().to_string()).unwrap();
    let mut fresh = client.open_session(Task::PointNav, n).unwrap();
    let fwd = vec![ACTION_FORWARD; n];
    let v = fresh.step(&fwd).unwrap();
    assert!(v.rewards.iter().all(|r| r.is_finite()));
}

/// Backpressure, inbound direction: a client pipelining submits faster
/// than the shard steps overflows the bounded per-session inbox and has
/// the excess *shed* with a typed `ERR_RETRY_AFTER` frame (carrying a
/// `retry_after_ms=` hint) — the connection and the lease survive, so a
/// well-behaved client backs off and continues instead of losing its
/// slots to one burst.
#[test]
fn submit_flood_is_shed_with_retry_after() {
    let pool = Arc::new(WorkerPool::new(2));
    let srv = server(2, StragglerPolicy::Wait, &pool);
    let wire = WireServer::listen_with(
        "127.0.0.1:0",
        Arc::clone(&srv),
        WireConfig {
            outbox_frames: 256,
            inbox_submits: 4,
            ..WireConfig::default()
        },
    )
    .unwrap();

    let mut sock = TcpStream::connect(wire.local_addr()).unwrap();
    sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    frame::write_frame(&mut sock, &Frame::Hello).unwrap();
    assert!(matches!(
        frame::read_frame(&mut sock).unwrap(),
        Frame::Welcome { .. }
    ));
    frame::write_frame(
        &mut sock,
        &Frame::Lease {
            req: 1,
            task: Task::PointNav,
            n_envs: 1,
        },
    )
    .unwrap();
    let session = match frame::read_frame(&mut sock).unwrap() {
        Frame::Grant { session, .. } => session,
        other => panic!("want GRANT, got {other:?}"),
    };
    // drain the seed STEP that follows every GRANT, so the burst
    // accounting below is exact
    match frame::read_frame(&mut sock).unwrap() {
        Frame::Step { session: s, .. } => assert_eq!(s, session),
        other => panic!("want seed STEP, got {other:?}"),
    }
    // The sole tenant's submit provokes one coalesced step each, but a
    // burst of 64 arrives far faster than the shard can step, so the
    // 4-deep inbox overflows and the excess sheds. Every submit is
    // answered — a STEP if accepted, ERR_RETRY_AFTER if shed — so
    // reading exactly 64 frames accounts for the whole burst.
    const BURST: usize = 64;
    let mut submit = Vec::new();
    frame::encode(
        &Frame::Submit {
            session,
            pairs: vec![(0, ACTION_FORWARD)],
        },
        &mut submit,
    );
    for _ in 0..BURST {
        sock.write_all(&submit).unwrap();
    }
    let (mut steps, mut sheds) = (0usize, 0usize);
    for _ in 0..BURST {
        match frame::read_frame(&mut sock).unwrap() {
            Frame::Step { session: s, .. } => {
                assert_eq!(s, session);
                steps += 1;
            }
            Frame::Error { re, code, msg } => {
                assert_eq!(re, session, "shed error targets the session stream");
                assert_eq!(code, ERR_RETRY_AFTER);
                assert!(
                    frame::retry_after_ms(&msg).is_some(),
                    "shed frame must carry a retry_after_ms hint: {msg:?}"
                );
                sheds += 1;
            }
            other => panic!("want STEP or ERR_RETRY_AFTER, got {other:?}"),
        }
    }
    assert_eq!(steps + sheds, BURST);
    assert!(sheds > 0, "a 64-burst into a 4-deep inbox must shed");
    // shed, not disconnected: connection open, lease intact, and the
    // session keeps stepping at a polite pace
    assert!(!wire.conn_stats()[0].closed, "flood must not disconnect");
    assert_eq!(srv.stats()[0].leased, 1, "lease survives the shed");
    sock.write_all(&submit).unwrap();
    match frame::read_frame(&mut sock).unwrap() {
        Frame::Step { session: s, .. } => assert_eq!(s, session),
        other => panic!("want STEP after backing off, got {other:?}"),
    }
    drop(sock);
    wait_until("lease release on disconnect", || srv.stats()[0].leased == 0);
    // the shard is healthy: a fresh client leases and steps
    let client = RemoteClient::connect(&wire.local_addr().to_string()).unwrap();
    let mut fresh = client.open_session(Task::PointNav, 2).unwrap();
    let fwd = vec![ACTION_FORWARD; 2];
    let v = fresh.step(&fwd).unwrap();
    assert!(v.rewards.iter().all(|r| r.is_finite()));
}
