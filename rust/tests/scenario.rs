//! Integration tests for the scenario engine (`bps::scenario`).
//!
//! The acceptance gates: procgen is bitwise deterministic (same
//! `(id, seed, Complexity)` → identical `.bsc` bytes); dataset splits
//! stay disjoint with stable ordering; a warm procgen prefetch queue
//! makes `rotate_scenes` non-blocking (zero feed stalls); and a
//! curriculum-driven `EnvBatch` run advances ≥ 2 difficulty stages
//! *bitwise-reproducibly* across two runs under a fixed seed — in both
//! the synchronous and pipelined stepping modes. When AOT artifacts are
//! present, `bps train --scenario` (via the coordinator) must be equally
//! reproducible end to end.

use std::sync::Arc;

use bps::env::{EnvBatch, EnvBatchConfig};
use bps::render::{RenderConfig, SceneRotation};
use bps::scenario::{sensor_policy, Curriculum, ScenarioSpec, ScenarioStream};
use bps::scene::procgen::{generate, Complexity};
use bps::sim::{BatchSim, SimConfig, SimOutputs, ACTION_LEFT};
use bps::util::pool::WorkerPool;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("bps_scenario_test").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn easy_spec() -> ScenarioSpec {
    ScenarioSpec::parse(
        "name=curr task=pointnav stages=3 tris=400..2k extent=6..9 \
         clutter=0..2 mats=1..3 tex=16 min-geo=1 max-steps=100",
    )
    .unwrap()
}

/// Same `(id, seed, Complexity)` must produce bitwise-identical scene
/// assets — geometry, materials, textures, navmesh — verified on the
/// serialized `.bsc` bytes, the strongest equality the format offers.
#[test]
fn procgen_bitwise_deterministic() {
    let dir = tmpdir("bitwise");
    for (seed, cx) in [
        (7u64, Complexity::test()),
        (7u64, Complexity::thor_like()),
        (1234u64, Complexity::test()),
    ] {
        let a = generate("det", seed, cx);
        let b = generate("det", seed, cx);
        let pa = dir.join("a.bsc");
        let pb = dir.join("b.bsc");
        a.save(&pa).unwrap();
        b.save(&pb).unwrap();
        let ba = std::fs::read(&pa).unwrap();
        let bb = std::fs::read(&pb).unwrap();
        assert_eq!(ba, bb, "seed {seed}: regeneration changed the bytes");
        // a different seed must change them
        let c = generate("det", seed ^ 1, cx);
        c.save(&pb).unwrap();
        assert_ne!(ba, std::fs::read(&pb).unwrap());
    }
}

/// Dataset split integrity: train/val/test are disjoint id sets, every
/// id resolves to a file, and reopening preserves the exact ordering.
#[test]
fn dataset_splits_disjoint_and_stable() {
    let dir = tmpdir("splits");
    let ds = bps::scene::generate_dataset(&dir, 4, 2, 2, Complexity::test(), 33).unwrap();
    let all: Vec<&String> = ds
        .train
        .iter()
        .chain(ds.val.iter())
        .chain(ds.test.iter())
        .collect();
    assert_eq!(all.len(), 8);
    let unique: std::collections::BTreeSet<&&String> = all.iter().collect();
    assert_eq!(unique.len(), all.len(), "split ids must be disjoint");
    for id in &all {
        assert!(ds.scene_path(id).exists(), "{id} missing on disk");
    }
    // reopen: identical membership *and* ordering
    let re = bps::scene::Dataset::open(&dir).unwrap();
    assert_eq!(re.train, ds.train);
    assert_eq!(re.val, ds.val);
    assert_eq!(re.test, ds.test);
    assert_eq!(re.split("train").unwrap(), &ds.train[..]);
}

/// The prefetch-queue guarantee: with a warm queue, a pinned rotation
/// never synchronously generates — its blocking take pops a finished
/// scene (zero stalls), and the swapped-in scenes follow the
/// deterministic request order.
#[test]
fn warm_prefetch_keeps_rotation_non_blocking() {
    let pool = Arc::new(WorkerPool::new(2));
    let stream = ScenarioStream::new(easy_spec(), 5, 3, false, Arc::clone(&pool));
    let mut rot = SceneRotation::streaming(stream, 2).unwrap();
    let mut sim = BatchSim::new(
        SimConfig {
            max_steps: 2,
            ..SimConfig::pointnav()
        },
        rot.assign(4),
        11,
    );
    let mut swapped = Vec::new();
    for _ in 0..6 {
        // deterministic warmth: never rotate against a half-filled queue
        rot.wait_feed_warm();
        rot.rotate_pinned(&mut sim);
        swapped.push(rot.active[(rot.rotations as usize + 1) % 2].id.clone());
    }
    assert_eq!(rot.rotations, 6);
    assert_eq!(rot.feed_stalls(), 0, "warm takes must not wait on procgen");
    // scene ids continue the request sequence started by the initial K
    let ids: Vec<String> = (0..6).map(|i| format!("curr_s0_{:05}", i + 2)).collect();
    assert_eq!(swapped, ids);
    // and the queued swaps actually reach the sim at episode resets
    let pool0 = WorkerPool::new(0);
    let mut out = SimOutputs::with_capacity(4);
    sim.step_batch(&pool0, &[ACTION_LEFT; 4], &mut out);
    sim.step_batch(&pool0, &[ACTION_LEFT; 4], &mut out);
    assert!(out.dones.iter().all(|&d| d));
    assert!(sim.env(0).scene.id.starts_with("curr_s0_"));
}

/// Everything observable from one curriculum run, for bitwise A/B.
#[derive(PartialEq, Debug)]
struct RunTrace {
    rewards: Vec<f32>,
    advances: Vec<(usize, u32)>,
    obs: Vec<f32>,
    rotations: u64,
}

/// One curriculum-driven run over the public `EnvBatch` seam: scripted
/// GPS+compass policy, streaming procgen scenes, pinned rotation.
fn curriculum_run(overlap: bool, steps: usize) -> RunTrace {
    let spec = easy_spec();
    let n = 8;
    let pool = Arc::new(WorkerPool::new(2));
    let stream = ScenarioStream::new(spec.clone(), 21, 2, false, Arc::clone(&pool));
    let rot = SceneRotation::streaming(stream, 2).unwrap();
    let mut env: EnvBatch = EnvBatchConfig::new(spec.task, RenderConfig::depth(16))
        .sim(spec.sim_config())
        .seed(0xCAFE)
        .overlap(overlap)
        .pin_rotation(4)
        .build_with_rotation(rot, n, pool)
        .unwrap();
    // lenient advance rule: the scripted policy only has to land *some*
    // successes per window; the machinery under test is the scheduling
    let mut cur = Curriculum::new(spec.stages, 8, 0.05);
    let mut actions = vec![0u8; n];
    let mut rewards = Vec::with_capacity(steps);
    let mut advances = Vec::new();
    for t in 0..steps {
        sensor_policy(env.view().goal, 0.15, t, &mut actions);
        let v = env.step(&actions).unwrap();
        rewards.push(v.rewards.iter().sum());
        cur.observe(v.dones, v.successes, v.spl);
        if let Some(stage) = cur.advance_if_ready() {
            env.set_stage(stage).unwrap();
            advances.push((t, stage));
        }
        env.rotate_scenes().unwrap();
    }
    let obs = env.view().obs.to_vec();
    RunTrace {
        rewards,
        advances,
        obs,
        rotations: env.rotations(),
    }
}

/// The tentpole acceptance gate: under a fixed seed the curriculum
/// deterministically advances >= 2 stages, and the entire run — rewards,
/// advance schedule, final observations, rotation count — is bitwise
/// reproducible across two runs *and* across sync vs pipelined stepping.
#[test]
fn curriculum_advances_two_stages_bitwise_reproducibly() {
    let steps = 900;
    let a = curriculum_run(false, steps);
    let b = curriculum_run(false, steps);
    assert_eq!(a, b, "two identical runs diverged");
    assert!(
        a.advances.len() >= 2,
        "curriculum advanced only {} stage(s): {:?}",
        a.advances.len(),
        a.advances
    );
    assert_eq!(a.advances.last().unwrap().1, 2, "must reach the hardest stage");

    // pipelined stepping replays the identical run (set_stage and rotate
    // execute in request order on the driver thread); the rotation count
    // is read while the driver may still be draining, so compare the
    // deterministic fields
    let c = curriculum_run(true, steps);
    assert_eq!(a.rewards, c.rewards, "pipelined rewards diverged");
    assert_eq!(a.advances, c.advances, "pipelined advance schedule diverged");
    assert_eq!(a.obs, c.obs, "pipelined observations diverged");
}

/// Full-stack gate (needs `make artifacts`): two scenario training runs
/// through the coordinator — `bps train --scenario …` — must produce
/// bitwise-identical parameters and stage schedules.
#[test]
fn train_scenario_reproducible_when_artifacts_present() {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    if !root.join("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mk = || bps::config::Config {
        variant: "test".into(),
        artifacts_dir: root.join("artifacts"),
        scenario: Some(
            "name=trainspec task=pointnav stages=2 tris=400..1500 extent=6..8 \
             clutter=0..1 tex=16 max-steps=64"
                .into(),
        ),
        num_envs: 4,
        rollout_len: 4,
        num_minibatches: 2,
        k_scenes: 2,
        prefetch_scenes: 2,
        curriculum_window: 4,
        curriculum_threshold: 0.25,
        rotate_every: Some(2),
        total_frames: 64,
        seed: 5,
        threads: 2,
        ..Default::default()
    };
    let mut a = bps::coordinator::Coordinator::new(mk()).unwrap();
    let mut b = bps::coordinator::Coordinator::new(mk()).unwrap();
    for _ in 0..4 {
        a.train_iteration().unwrap();
        b.train_iteration().unwrap();
    }
    assert_eq!(a.params.flat, b.params.flat, "scenario training diverged");
    assert_eq!(a.stages(), b.stages(), "curriculum schedules diverged");
}

/// Heterogeneous scenario check: a goal-free task spec runs through the
/// same machinery (zero goal sensor, scripted policy never stops).
#[test]
fn goal_free_scenario_runs() {
    let spec = ScenarioSpec::parse(
        "name=sweep task=explore stages=2 tris=400..1200 extent=6..8 \
         clutter=0..1 tex=16 max-steps=50",
    )
    .unwrap();
    let n = 4;
    let pool = Arc::new(WorkerPool::new(2));
    let stream = ScenarioStream::new(spec.clone(), 3, 2, false, Arc::clone(&pool));
    let rot = SceneRotation::streaming(stream, 2).unwrap();
    let mut env = EnvBatchConfig::new(spec.task, RenderConfig::depth(16))
        .sim(spec.sim_config())
        .seed(1)
        .pin_rotation(4)
        .build_with_rotation(rot, n, pool)
        .unwrap();
    assert!(env.view().goal.iter().all(|&g| g == 0.0));
    let mut actions = vec![0u8; n];
    let mut episodes = 0u32;
    for t in 0..120 {
        sensor_policy(env.view().goal, 0.15, t, &mut actions);
        let v = env.step(&actions).unwrap();
        episodes += v.dones.iter().filter(|&&d| d).count() as u32;
        env.rotate_scenes().unwrap();
    }
    // max-steps=50 guarantees episode turnover for the goal-free script
    assert!(episodes >= n as u32 * 2, "only {episodes} episodes");
}
