//! Integration tests for the in-server policy tenant layer
//! (`bps::serve::tenant` + the `LEASE_POLICY`/`GOAL`/`TRAJ` wire frames).
//!
//! Acceptance gates: a greedy policy tenant driven by the server over
//! loopback TCP must stream the *bitwise identical* trajectory a client
//! would compute itself with `Policy::step_greedy` on a same-seeded
//! direct `EnvBatch` (same manifest, same init seed); two concurrent
//! tenants on one shard must share exactly one coalesced `Exec::run`
//! per tick; hostile `GOAL`/`LEASE_POLICY` traffic must error cleanly
//! without killing co-tenants; idle connections must be reaped and
//! release their leases.
//!
//! The policy-execution tests are gated on `artifacts/manifest.json`
//! exactly like the coordinator's end-to-end tests (run `make
//! artifacts` first); the hostile-traffic and idle-reap tests run
//! everywhere.

use std::io::Write;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use bps::env::{EnvBatch, EnvBatchConfig};
use bps::policy::Policy;
use bps::render::RenderConfig;
use bps::runtime::{Manifest, ParamStore, Runtime};
use bps::scene::procgen::{generate, Complexity};
use bps::scene::SceneAsset;
use bps::serve::wire::frame::{self, Frame, ERR_LEASE, ERR_SESSION, ERR_SUBMIT};
use bps::serve::{
    ActionMode, FillAction, PolicyVault, RemoteClient, ShardSpec, SimServer, StragglerPolicy,
    WireConfig, WireServer,
};
use bps::sim::{Task, ACTION_FORWARD};
use bps::util::pool::WorkerPool;

/// Env seed shared by the server shard and the direct replica.
const SEED: u64 = 0x7E_4A47;
/// Policy-init seed shared by the vault and the client-side replica.
const PSEED: u64 = 40;

fn artifacts() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !d.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts` first)");
        return None;
    }
    Some(d)
}

fn scene() -> Arc<SceneAsset> {
    Arc::new(generate("tenant_eqv", 71, Complexity::test()))
}

/// The `test` artifact variant sees 32x32x1 observations and exports
/// `infer_n4` only, so tenant shards are 4 slots of depth-32 renders.
fn tenant_cfg() -> EnvBatchConfig {
    EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(32)).seed(SEED)
}

fn direct_batch(n: usize, pool: &Arc<WorkerPool>) -> EnvBatch {
    let s = scene();
    tenant_cfg()
        .overlap(false)
        .build_with_scenes((0..n).map(|_| Arc::clone(&s)).collect(), Arc::clone(pool))
        .unwrap()
}

/// A server whose vault inits every variant from `PSEED` (no
/// checkpoint) — the same parameters the client-side replica derives.
fn tenant_server(n: usize, artifacts: &Path, pool: &Arc<WorkerPool>) -> Arc<SimServer> {
    let s = scene();
    let spec = ShardSpec::with_scenes(tenant_cfg(), (0..n).map(|_| Arc::clone(&s)).collect())
        .straggler(StragglerPolicy::Wait);
    let vault = PolicyVault::open(artifacts, None, PSEED).unwrap();
    Arc::new(SimServer::with_vault(vec![spec], Arc::clone(pool), None, Some(vault)).unwrap())
}

/// A vault-less server (env leases only) for the no-artifact tests.
fn plain_server(n: usize, policy: StragglerPolicy, pool: &Arc<WorkerPool>) -> Arc<SimServer> {
    let s = scene();
    let spec = ShardSpec::with_scenes(
        EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(16)).seed(SEED),
        (0..n).map(|_| Arc::clone(&s)).collect(),
    )
    .straggler(policy);
    Arc::new(SimServer::start(vec![spec], Arc::clone(pool)).unwrap())
}

/// Poll until `cond` holds (10s cap) so thread hand-off races can't
/// flake the assertions.
fn wait_until(what: &str, cond: impl Fn() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// A greedy remote agent leasing the whole shard must stream exactly
/// the trajectory the client would compute itself: same actions, same
/// observations, bit for bit, starting from the initial snapshot. The
/// client-side replica runs `Policy::step_greedy` on a same-seeded
/// direct `EnvBatch` with params initialized from the vault's seed.
#[test]
fn tenant_traj_bitwise_equals_client_side_policy_loop() {
    let Some(artifacts) = artifacts() else { return };
    let n = 4;
    let pool = Arc::new(WorkerPool::new(2));
    let mut direct = direct_batch(n, &pool);
    let srv = tenant_server(n, &artifacts, &pool);
    let wire = WireServer::listen("127.0.0.1:0", Arc::clone(&srv)).unwrap();
    let client = RemoteClient::connect(&wire.local_addr().to_string()).unwrap();
    let mut agent = client
        .open_agent(Task::PointNav, n, "test", true, 0)
        .unwrap();
    assert_eq!(agent.num_envs(), n);
    assert_eq!(agent.obs_floats(), direct.obs_floats());
    assert_eq!(agent.slots(), (0..n).collect::<Vec<_>>().as_slice());

    // Client-side replica of the server's engine: same manifest, same
    // width, same init seed => same flat params, same recurrent zeros.
    let rt = Runtime::cpu().unwrap();
    let man = Manifest::load(&artifacts).unwrap();
    let variant = man.variant("test").unwrap().clone();
    let init = rt
        .load(&man.artifact_path(&variant, "init").unwrap())
        .unwrap();
    let params = ParamStore::init(&init, variant.num_params, PSEED as i32)
        .unwrap()
        .flat;
    let mut policy = Policy::new(&rt, &man, &variant, n, 0).unwrap();

    // The initial snapshot crossed the wire bit-for-bit.
    let (step0, iv) = agent.initial();
    assert_eq!(step0, 0);
    assert_eq!(iv.obs, direct.view().obs);
    assert_eq!(iv.goal, direct.view().goal);

    const STEPS: u32 = 12;
    agent.set_goal(STEPS).unwrap();
    for t in 0..STEPS as usize {
        let expect = policy
            .step_greedy(&params, direct.view().obs, direct.view().goal)
            .unwrap();
        let dv = direct.step(&expect).unwrap();
        let (obs, goal, rewards, dones, successes, spl, scores) = (
            dv.obs.to_vec(),
            dv.goal.to_vec(),
            dv.rewards.to_vec(),
            dv.dones.to_vec(),
            dv.successes.to_vec(),
            dv.spl.to_vec(),
            dv.scores.to_vec(),
        );
        policy.reset_done(&dones);
        let tr = agent.next_traj().unwrap().expect("goal ended early");
        assert_eq!(tr.step, (t + 1) as u64, "shard step counter");
        assert_eq!(tr.actions, expect, "actions diverged at step {t}");
        assert_eq!(tr.view.obs, obs, "obs diverged at step {t}");
        assert_eq!(tr.view.goal, goal, "goal diverged at step {t}");
        assert_eq!(tr.view.rewards, rewards, "rewards diverged at step {t}");
        assert_eq!(tr.view.dones, dones, "dones diverged at step {t}");
        assert_eq!(tr.view.successes, successes, "successes diverged at step {t}");
        assert_eq!(tr.view.spl, spl, "spl diverged at step {t}");
        assert_eq!(tr.view.scores, scores, "scores diverged at step {t}");
    }
    assert_eq!(agent.steps(), STEPS as u64);

    let st = &srv.stats()[0];
    assert_eq!(st.steps, STEPS as u64);
    assert_eq!(st.bad_submits, 0);
    let ten = st.tenant.as_ref().expect("tenant stats present");
    assert_eq!(ten.infer_runs, STEPS as u64, "one forward per tick");
    assert_eq!(ten.infer_batch_size, n, "inference at full shard width");
    assert_eq!(ten.agent_steps, STEPS as u64 * n as u64);

    agent.detach().unwrap();
    wait_until("lease release", || srv.stats()[0].leased == 0);
    // The pump decrements its session counter after acking the detach.
    wait_until("session close", || wire.conn_stats()[0].sessions_open == 0);
    let conns = wire.conn_stats();
    assert_eq!(conns[0].bad_frames, 0);
    assert_eq!(conns[0].sessions_opened, 1);
}

/// Two concurrent tenants (one greedy, one sampling) on one shard:
/// every tick runs exactly ONE coalesced `Exec::run` for both — that
/// is the whole point of the inference coalescer — and each tenant
/// streams its own slots' rows of the shared forward.
#[test]
fn two_tenants_share_one_coalesced_forward_per_tick() {
    let Some(artifacts) = artifacts() else { return };
    let pool = Arc::new(WorkerPool::new(2));
    let srv = tenant_server(4, &artifacts, &pool);
    let mut a = srv.connect_with_policy(Task::PointNav, 2, "test").unwrap();
    let mut b = srv
        .connect_with_policy_mode(Task::PointNav, 2, "test", ActionMode::Sample { seed: 11 })
        .unwrap();
    assert_eq!(a.slots(), &[0, 1]);
    assert_eq!(b.slots(), &[2, 3]);
    assert_eq!(a.initial().obs.len(), 2 * a.obs_floats());

    // Both goals posted before draining: under the Wait policy the
    // first tick fires only once every registered tenant is active.
    const GOAL: u32 = 10;
    a.set_goal(GOAL).unwrap();
    b.set_goal(GOAL).unwrap();
    // Drain both streams concurrently — the trajectory queue is
    // shorter than the goal, so a sequential drain would stall the
    // driver on the undrained co-tenant.
    std::thread::scope(|s| {
        for sess in [&mut a, &mut b] {
            s.spawn(move || {
                for t in 0..GOAL as u64 {
                    let ts = sess.next_step().unwrap().expect("stream ended early");
                    assert_eq!(ts.step, t + 1);
                    assert_eq!(ts.actions.len(), 2);
                    assert!(ts.rewards.iter().all(|r| r.is_finite()));
                }
            });
        }
    });
    assert_eq!(a.steps(), GOAL as u64);
    assert_eq!(b.steps(), GOAL as u64);

    // Counters publish after the tick's trajectory sends — poll.
    wait_until("tick counters", || {
        srv.stats()[0]
            .tenant
            .as_ref()
            .is_some_and(|t| t.infer_runs == GOAL as u64)
    });
    let st = &srv.stats()[0];
    assert_eq!(st.steps, GOAL as u64, "ticks are shard steps, 1:1");
    assert_eq!(st.bad_submits, 0);
    let ten = st.tenant.as_ref().unwrap();
    assert_eq!(ten.tenants, 2);
    assert_eq!(
        ten.infer_runs,
        GOAL as u64,
        "one Exec::run per tick regardless of tenant count"
    );
    assert_eq!(ten.infer_batch_size, 4);
    assert_eq!(ten.agent_steps, 2 * 2 * GOAL as u64);
    a.detach();
    b.detach();
    wait_until("lease release", || srv.stats()[0].leased == 0);
}

/// Hostile `GOAL`/`LEASE_POLICY` content on a well-formed connection
/// earns error frames without killing it; malformed tenant frames kill
/// the connection like any other wire garbage. Runs vault-less, so it
/// also pins the no-artifact behavior: `LEASE_POLICY` is declined with
/// a diagnosable error, never a panic.
#[test]
fn hostile_goal_and_lease_policy_frames_error_cleanly() {
    let pool = Arc::new(WorkerPool::new(2));
    let srv = plain_server(4, StragglerPolicy::Wait, &pool);
    let wire = WireServer::listen("127.0.0.1:0", Arc::clone(&srv)).unwrap();
    let addr = wire.local_addr();

    // --- One connection surviving a gauntlet of content errors. ---
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    frame::write_frame(&mut s, &Frame::Hello).unwrap();
    match frame::read_frame(&mut s).unwrap() {
        Frame::Welcome { .. } => {}
        other => panic!("want WELCOME, got {other:?}"),
    }
    // GOAL for a session that never existed.
    frame::write_frame(&mut s, &Frame::Goal { session: 0xDEAD, steps: 4 }).unwrap();
    match frame::read_frame(&mut s).unwrap() {
        Frame::Error { code, .. } => assert_eq!(code, ERR_SESSION),
        other => panic!("want ERROR, got {other:?}"),
    }
    // A plain env lease, then a GOAL aimed at it: wrong session kind.
    frame::write_frame(
        &mut s,
        &Frame::Lease { req: 7, task: Task::PointNav, n_envs: 4 },
    )
    .unwrap();
    let (session, slots) = match frame::read_frame(&mut s).unwrap() {
        Frame::Grant { session, slots, .. } => (session, slots),
        other => panic!("want GRANT, got {other:?}"),
    };
    match frame::read_frame(&mut s).unwrap() {
        Frame::Step { step, .. } => assert_eq!(step, 0, "initial observation"),
        other => panic!("want initial STEP, got {other:?}"),
    }
    frame::write_frame(&mut s, &Frame::Goal { session, steps: 4 }).unwrap();
    match frame::read_frame(&mut s).unwrap() {
        Frame::Error { code, msg, .. } => {
            assert_eq!(code, ERR_SUBMIT);
            assert!(msg.contains("plain env session"), "got: {msg}");
        }
        other => panic!("want ERROR, got {other:?}"),
    }
    // LEASE_POLICY on a vault-less server: declined, diagnosably.
    frame::write_frame(
        &mut s,
        &Frame::LeasePolicy {
            req: 8,
            task: Task::PointNav,
            n_envs: 2,
            greedy: true,
            seed: 0,
            variant: "test".into(),
        },
    )
    .unwrap();
    match frame::read_frame(&mut s).unwrap() {
        Frame::Error { code, msg, .. } => {
            assert_eq!(code, ERR_LEASE);
            assert!(msg.contains("no policy artifacts"), "got: {msg}");
        }
        other => panic!("want ERROR, got {other:?}"),
    }
    // After all that, the connection still serves its env session.
    frame::write_frame(
        &mut s,
        &Frame::Submit {
            session,
            pairs: slots.iter().map(|&sl| (sl, ACTION_FORWARD)).collect(),
        },
    )
    .unwrap();
    match frame::read_frame(&mut s).unwrap() {
        Frame::Step { step, .. } => assert_eq!(step, 1),
        other => panic!("want STEP, got {other:?}"),
    }
    frame::write_frame(&mut s, &Frame::Detach { session }).unwrap();
    match frame::read_frame(&mut s).unwrap() {
        Frame::Detached { .. } => {}
        other => panic!("want DETACHED, got {other:?}"),
    }
    drop(s);

    // --- Malformed tenant frames: connection-fatal, counted. ---
    let magic = frame::MAGIC.to_le_bytes();
    let raw = |ftype: u8, payload: &[u8]| -> Vec<u8> {
        let mut b = vec![magic[0], magic[1], frame::VERSION, ftype];
        b.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        b.extend_from_slice(payload);
        b
    };
    let mut hello = Vec::new();
    frame::encode(&Frame::Hello, &mut hello);
    let hostile: Vec<(&str, Vec<u8>)> = vec![
        ("truncated GOAL payload", {
            let mut b = hello.clone();
            b.extend_from_slice(&raw(frame::FT_GOAL, &[0u8; 8])); // needs 12
            b
        }),
        ("GOAL length over the per-type cap", {
            let mut b = hello.clone();
            let mut h = vec![magic[0], magic[1], frame::VERSION, frame::FT_GOAL];
            h.extend_from_slice(&64u32.to_le_bytes());
            b.extend_from_slice(&h);
            b
        }),
        ("LEASE_POLICY with a lying variant length", {
            // header says 28 payload bytes, vlen field claims 300
            let mut p = Vec::new();
            p.extend_from_slice(&8u64.to_le_bytes()); // req
            p.push(0); // task
            p.extend_from_slice(&2u32.to_le_bytes()); // n_envs
            p.push(1); // greedy
            p.extend_from_slice(&0u64.to_le_bytes()); // seed
            p.extend_from_slice(&300u32.to_le_bytes()); // vlen (lie)
            p.extend_from_slice(b"ab");
            let mut b = hello.clone();
            b.extend_from_slice(&raw(frame::FT_LEASE_POLICY, &p));
            b
        }),
        ("LEASE_POLICY length over the per-type cap", {
            // 26 + 300 > the 26 + MAX_VARIANT_NAME cap: header-level kill
            let mut b = hello.clone();
            let mut h = vec![magic[0], magic[1], frame::VERSION, frame::FT_LEASE_POLICY];
            h.extend_from_slice(&((26 + 300) as u32).to_le_bytes());
            b.extend_from_slice(&h);
            b
        }),
        ("TRAJ from a client (server-only direction)", {
            let mut b = hello.clone();
            let mut h = vec![magic[0], magic[1], frame::VERSION, frame::FT_TRAJ];
            h.extend_from_slice(&64u32.to_le_bytes());
            b.extend_from_slice(&h);
            b
        }),
    ];
    let before = wire.conn_stats().len();
    for (_what, bytes) in &hostile {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(bytes).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        // drain courtesy frames until the server hangs up
        while frame::read_frame(&mut s).is_ok() {}
        drop(s);
    }
    wait_until("hostile conns to close", || {
        wire.conn_stats().iter().skip(before).all(|c| c.closed)
    });
    let conns = wire.conn_stats();
    assert_eq!(conns.len(), before + hostile.len());
    let flagged = conns.iter().skip(before).filter(|c| c.bad_frames > 0).count();
    assert_eq!(flagged, hostile.len(), "every hostile conn counted a bad frame");
    assert_eq!(srv.stats()[0].bad_submits, 0);
    assert_eq!(srv.stats()[0].leased, 0, "nothing leaked a lease");
}

/// `open_agent` against a vault-less server fails with the diagnosable
/// no-artifacts error on both the in-process and remote paths, without
/// leaking slots or poisoning the connection for env leases.
#[test]
fn policy_lease_without_artifacts_fails_cleanly() {
    let pool = Arc::new(WorkerPool::new(2));
    let srv = plain_server(4, StragglerPolicy::Wait, &pool);
    assert!(!srv.has_vault());
    let err = srv
        .connect_with_policy(Task::PointNav, 2, "test")
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("no policy artifacts"),
        "got: {err:#}"
    );
    let wire = WireServer::listen("127.0.0.1:0", Arc::clone(&srv)).unwrap();
    let client = RemoteClient::connect(&wire.local_addr().to_string()).unwrap();
    let err = client
        .open_agent(Task::PointNav, 2, "test", true, 0)
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("no policy artifacts"),
        "got: {err:#}"
    );
    assert_eq!(srv.stats()[0].leased, 0, "failed lease released its slots");
    // The same connection still serves plain env leases.
    let mut sess = client.open_session(Task::PointNav, 4).unwrap();
    sess.step(&vec![ACTION_FORWARD; 4]).unwrap();
    sess.detach().unwrap();
}

/// With `idle_timeout_ticks` set, a silent connection holding a lease
/// is reaped — flagged in `conn_stats`, closed, lease released — while
/// an actively stepping connection sails past the timeout untouched.
#[test]
fn idle_connections_are_reaped_and_release_leases() {
    let pool = Arc::new(WorkerPool::new(2));
    // Deadline policy: the busy session's steps never wait on the idle
    // co-tenant, so its wire stays active the whole test.
    let srv = plain_server(
        4,
        StragglerPolicy::Deadline { ticks: 5, fill: FillAction::NoOp },
        &pool,
    );
    let cfg = WireConfig {
        idle_timeout_ticks: Some(400), // ticks are milliseconds
        ..WireConfig::default()
    };
    let wire = WireServer::listen_with("127.0.0.1:0", Arc::clone(&srv), cfg).unwrap();
    let addr = wire.local_addr().to_string();

    let idle_client = RemoteClient::connect(&addr).unwrap();
    let _idle_sess = idle_client.open_session(Task::PointNav, 2).unwrap();
    let busy_client = RemoteClient::connect(&addr).unwrap();
    let mut busy = busy_client.open_session(Task::PointNav, 2).unwrap();
    assert_eq!(srv.stats()[0].leased, 4);

    // Step continuously for 3x the timeout: the idle conn goes quiet
    // and gets reaped mid-loop, the busy conn's traffic keeps it alive.
    let acts = vec![ACTION_FORWARD; 2];
    let deadline = Instant::now() + Duration::from_millis(1200);
    while Instant::now() < deadline {
        busy.step(&acts).unwrap();
    }
    wait_until("idle conn reaped", || {
        wire.conn_stats().iter().any(|c| c.reaped && c.closed)
    });
    wait_until("idle lease released", || srv.stats()[0].leased == 2);
    assert_eq!(
        wire.conn_stats().iter().filter(|c| c.reaped).count(),
        1,
        "only the silent connection was reaped"
    );
    // The survivor is still fully functional.
    busy.step(&acts).unwrap();
    busy.detach().unwrap();
}
