//! Fixture corpus for `bps lint` (DESIGN.md §0.13): one seeded violation
//! and one clean sample per rule, the `--json` schema pin, allow-directive
//! scoping, and a meta-check that the repository's own tree lints clean —
//! the same invariant the CI `lint` job enforces deny-by-default.

use std::path::Path;

use bps::lint::{lint_protocol, lint_str, lint_tree, Diag, LintReport};
use bps::util::json::Json;

fn rules(diags: &[Diag]) -> Vec<&'static str> {
    diags.iter().map(|d| d.rule).collect()
}

// -- L001: unsafe needs SAFETY -----------------------------------------------

#[test]
fn l001_seeded_unsafe_without_safety() {
    let src = "pub fn read(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    let d = lint_str("rust/src/x.rs", src);
    assert_eq!(rules(&d), ["L001"]);
    assert_eq!(d[0].line, 2);
    assert!(d[0].msg.contains("SAFETY"), "{}", d[0].msg);
}

#[test]
fn l001_clean_justified_unsafe() {
    let src = "pub fn read(p: *const u8) -> u8 {\n    \
               // SAFETY: caller keeps p valid for the call\n    \
               unsafe { *p }\n}\n";
    assert!(lint_str("rust/src/x.rs", src).is_empty());
}

// -- L002: control-flow Relaxed needs a note ---------------------------------

#[test]
fn l002_seeded_relaxed_in_branch() {
    let src = "fn f(a: &AtomicUsize) -> bool {\n    \
               if a.load(Ordering::Relaxed) > 0 {\n        \
               return true;\n    }\n    false\n}\n";
    let d = lint_str("rust/src/x.rs", src);
    assert_eq!(rules(&d), ["L002"]);
    assert_eq!(d[0].line, 2);
}

#[test]
fn l002_clean_noted_branch_and_plain_counter() {
    let noted = "fn f(a: &AtomicUsize) -> bool {\n    \
                 // relaxed: advisory peek; the Acquire reload decides\n    \
                 if a.load(Ordering::Relaxed) > 0 {\n        \
                 return true;\n    }\n    false\n}\n";
    assert!(lint_str("rust/src/x.rs", noted).is_empty());
    // A counter bump outside control flow never needs a note.
    let counter = "fn bump(a: &AtomicUsize) {\n    \
                   a.fetch_add(1, Ordering::Relaxed);\n}\n";
    assert!(lint_str("rust/src/x.rs", counter).is_empty());
}

// -- L003: serve lock discipline ---------------------------------------------

#[test]
fn l003_seeded_raw_state_lock_in_serve() {
    let src = "impl S {\n    fn touch(&self) {\n        \
               let g = self.state.lock().unwrap();\n        g.step();\n    }\n}\n";
    let d = lint_str("rust/src/serve/x.rs", src);
    assert_eq!(rules(&d), ["L003"]);
    assert_eq!(d[0].line, 3);
    // The same code outside serve/ is not this rule's business.
    assert!(lint_str("rust/src/sim/x.rs", src).is_empty());
}

#[test]
fn l003_seeded_lock_order_inversion() {
    let src = "fn stats(&self) {\n    \
               let t = lock_tenants(&self.tenants);\n    \
               let s = lock_state(&self.state);\n    use_both(&t, &s);\n}\n";
    let d = lint_str("rust/src/serve/x.rs", src);
    assert_eq!(rules(&d), ["L003"]);
    assert_eq!(d[0].line, 3);
}

#[test]
fn l003_clean_helpers_in_canonical_order() {
    let src = "fn stats(&self) {\n    \
               let s = lock_state(&self.state);\n    \
               let t = lock_tenants(&self.tenants);\n    use_both(&s, &t);\n}\n";
    assert!(lint_str("rust/src/serve/x.rs", src).is_empty());
}

// -- L004: thread hygiene ----------------------------------------------------

#[test]
fn l004_seeded_bare_spawn_and_unnamed_builder() {
    let bare = "fn start() {\n    \
                std::thread::spawn(move || loop {\n        tick();\n    });\n}\n";
    let d = lint_str("rust/src/obs/x.rs", bare);
    assert_eq!(rules(&d), ["L004"]);
    // Outside serve/obs/scenario the rule does not apply.
    assert!(lint_str("rust/src/sim/x.rs", bare).is_empty());

    let unnamed = "fn start(w: &Watchdog) {\n    \
                   let hb = w.heartbeat(\"pump\");\n    \
                   std::thread::Builder::new()\n        \
                   .spawn(move || loop {\n            hb.beat();\n        })\n        \
                   .unwrap();\n}\n";
    let d = lint_str("rust/src/serve/x.rs", unnamed);
    assert_eq!(rules(&d), ["L004"]);
    assert!(d[0].msg.contains(".name("), "{}", d[0].msg);
}

#[test]
fn l004_clean_named_spawn_with_heartbeat() {
    let src = "fn start(w: &Watchdog) {\n    \
               let hb = w.heartbeat(\"pump\");\n    \
               std::thread::Builder::new()\n        \
               .name(\"pump\".into())\n        \
               .spawn(move || loop {\n            hb.beat();\n        })\n        \
               .unwrap();\n}\n";
    assert!(lint_str("rust/src/serve/x.rs", src).is_empty());
}

// -- L005: protocol drift ----------------------------------------------------

const FRAME_FIXTURE: &str = "\
pub const FT_HELLO: u8 = 1;
pub const FT_STEP: u8 = 2;
pub const ERR_PROTOCOL: u16 = 1;
pub const ERR_LEASE: u16 = 2;
pub fn payload_cap(ftype: u8) -> usize {
    match ftype {
        FT_HELLO => 0,
        FT_STEP => 64,
        _ => 0,
    }
}
";

const DESIGN_FIXTURE: &str = "\
| `HELLO` | c->s | - |
| `STEP`  | s->c | step view |
Errors: ERR_PROTOCOL closes the connection, ERR_LEASE declines a lease.
";

#[test]
fn l005_clean_when_wire_and_design_agree() {
    assert!(lint_protocol(FRAME_FIXTURE, DESIGN_FIXTURE).is_empty());
}

#[test]
fn l005_seeded_drift_variants() {
    // A frame type with no DESIGN.md row.
    let design = DESIGN_FIXTURE.replace("| `STEP`  | s->c | step view |\n", "");
    let d = lint_protocol(FRAME_FIXTURE, &design);
    assert_eq!(rules(&d), ["L005"]);
    assert!(d[0].msg.contains("FT_STEP"), "{}", d[0].msg);

    // A reused wire value.
    let frame = FRAME_FIXTURE.replace("ERR_LEASE: u16 = 2", "ERR_LEASE: u16 = 1");
    let d = lint_protocol(&frame, DESIGN_FIXTURE);
    assert_eq!(rules(&d), ["L005"]);
    assert!(d[0].msg.contains("ERR_LEASE"), "{}", d[0].msg);

    // A frame type missing its payload_cap arm.
    let frame = FRAME_FIXTURE.replace("        FT_STEP => 64,\n", "");
    let d = lint_protocol(&frame, DESIGN_FIXTURE);
    assert_eq!(rules(&d), ["L005"]);
    assert!(d[0].msg.contains("payload_cap"), "{}", d[0].msg);

    // An error code DESIGN.md never mentions. ERR_LEASE must not match
    // a hypothetical ERR_LEASE_FOO — the check is word-boundary exact.
    let design = DESIGN_FIXTURE.replace("ERR_LEASE", "ERR_LEASE_FOO");
    let d = lint_protocol(FRAME_FIXTURE, &design);
    assert_eq!(rules(&d), ["L005"]);
    assert!(d[0].msg.contains("ERR_LEASE"), "{}", d[0].msg);
}

// -- L000 + allow-directive scoping ------------------------------------------

#[test]
fn l000_seeded_bad_directives() {
    let d = lint_str("rust/src/x.rs", "// bps-lint: allow(L001)\n");
    assert_eq!(rules(&d), ["L000"]);
    assert!(d[0].msg.contains("reason"), "{}", d[0].msg);

    let d = lint_str("rust/src/x.rs", "// bps-lint: allow(L999, nope)\n");
    assert_eq!(rules(&d), ["L000"]);
    assert!(d[0].msg.contains("unknown rule"), "{}", d[0].msg);

    let d = lint_str("rust/src/x.rs", "// bps-lint: allow(\n");
    assert_eq!(rules(&d), ["L000"]);
    assert!(d[0].msg.contains("malformed"), "{}", d[0].msg);
}

#[test]
fn allow_trailing_covers_one_statement_only() {
    let src = "fn f(p: *const u8) -> u8 {\n    \
               unsafe { *p } // bps-lint: allow(L001, fixture)\n}\n\
               fn g(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    let d = lint_str("rust/src/x.rs", src);
    assert_eq!(rules(&d), ["L001"]);
    assert_eq!(d[0].line, 5, "only the un-allowed unsafe is reported");
}

#[test]
fn allow_comment_line_covers_rest_of_file() {
    let src = "// bps-lint: allow(L001, fixture file)\n\
               fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n\
               fn g(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    assert!(lint_str("rust/src/x.rs", src).is_empty());
}

#[test]
fn doc_comment_mention_is_not_a_directive() {
    // Prose about the syntax must neither arm an allow nor trip L000.
    let src = "/// see bps-lint: allow(L001, example) in DESIGN.md\n\
               fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    let d = lint_str("rust/src/x.rs", src);
    assert_eq!(rules(&d), ["L001"], "the unsafe is still reported");
}

// -- --json schema ------------------------------------------------------------

#[test]
fn json_report_schema_is_stable() {
    let rep = LintReport {
        diags: vec![Diag {
            rule: "L001",
            file: "rust/src/x.rs".to_string(),
            line: 7,
            msg: "`unsafe` without a `// SAFETY:` justification".to_string(),
        }],
        files_scanned: 3,
    };
    let parsed = Json::parse(&rep.to_json().to_string()).unwrap();
    assert_eq!(parsed.req("version").unwrap().as_f64().unwrap(), 1.0);
    assert!(matches!(parsed.req("clean").unwrap(), Json::Bool(false)));
    assert_eq!(parsed.req("files_scanned").unwrap().as_usize().unwrap(), 3);
    let v = parsed.req("violations").unwrap().as_arr().unwrap();
    assert_eq!(v.len(), 1);
    assert_eq!(v[0].req("rule").unwrap().as_str().unwrap(), "L001");
    assert_eq!(v[0].req("file").unwrap().as_str().unwrap(), "rust/src/x.rs");
    assert_eq!(v[0].req("line").unwrap().as_usize().unwrap(), 7);
    assert!(v[0].req("msg").unwrap().as_str().unwrap().contains("SAFETY"));

    let empty = LintReport { diags: vec![], files_scanned: 72 };
    let parsed = Json::parse(&empty.to_json().to_string()).unwrap();
    assert!(matches!(parsed.req("clean").unwrap(), Json::Bool(true)));
    assert!(parsed.req("violations").unwrap().as_arr().unwrap().is_empty());
}

// -- the tree itself ----------------------------------------------------------

#[test]
fn repository_tree_lints_clean() {
    // CARGO_MANIFEST_DIR is <repo>/rust for this crate; the repo root is
    // one level up. Deny-by-default: any new violation fails this test
    // (and the CI lint job) until fixed or explicitly allowed.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap();
    let rep = lint_tree(root).expect("lint_tree");
    assert!(
        rep.files_scanned > 40,
        "expected to scan the whole tree, got {} files",
        rep.files_scanned
    );
    assert!(rep.clean(), "repository must lint clean:\n{}", rep.render_text());
}
