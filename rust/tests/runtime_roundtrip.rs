//! Integration: python AOT artifacts -> rust PJRT load/compile/execute.
//! Requires `make artifacts` (test preset). These are the core correctness
//! checks of the L3<->L2 boundary.

use std::path::PathBuf;

use bps::runtime::{lit_f32, lit_i32, lit_scalar_f32, to_f32, Manifest, ParamStore, Runtime};

fn artifacts() -> Option<Manifest> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Manifest::load(&dir).ok()
}

#[test]
fn init_infer_grad_update_roundtrip() {
    let Some(man) = artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let v = man.variant("test").unwrap();
    let rt = Runtime::cpu().unwrap();

    // init: deterministic in the seed
    let init = rt.load(&man.artifact_path(v, "init").unwrap()).unwrap();
    let ps = ParamStore::init(&init, v.num_params, 7).unwrap();
    let ps2 = ParamStore::init(&init, v.num_params, 7).unwrap();
    let ps3 = ParamStore::init(&init, v.num_params, 8).unwrap();
    assert_eq!(ps.flat, ps2.flat);
    assert_ne!(ps.flat, ps3.flat);
    assert!(ps.flat.iter().all(|x| x.is_finite()));

    // infer: shapes + finiteness + hidden-state evolution
    let n = 4usize;
    let infer = rt
        .load(&man.artifact_path(v, "infer_n4").unwrap())
        .unwrap();
    let res = v.res;
    let obs = vec![0.5f32; n * res * res * v.in_ch];
    let goal = vec![0.1f32; n * 3];
    let h = vec![0.0f32; n * v.hidden];
    let c = vec![0.0f32; n * v.hidden];
    let out = infer
        .run(&[
            lit_f32(&ps.flat, &[v.num_params as i64]).unwrap(),
            lit_f32(&obs, &[n as i64, res as i64, res as i64, v.in_ch as i64]).unwrap(),
            lit_f32(&goal, &[n as i64, 3]).unwrap(),
            lit_f32(&h, &[n as i64, v.hidden as i64]).unwrap(),
            lit_f32(&c, &[n as i64, v.hidden as i64]).unwrap(),
        ])
        .unwrap();
    assert_eq!(out.len(), 4);
    let logits = to_f32(&out[0]).unwrap();
    let value = to_f32(&out[1]).unwrap();
    let h2 = to_f32(&out[2]).unwrap();
    assert_eq!(logits.len(), n * v.num_actions);
    assert_eq!(value.len(), n);
    assert_eq!(h2.len(), n * v.hidden);
    assert!(logits.iter().all(|x| x.is_finite()));
    assert!(h2.iter().any(|&x| x.abs() > 0.0), "hidden state unchanged");
    // identical rows for identical inputs (batch determinism)
    assert_eq!(logits[0..4], logits[4..8]);

    // grad: finite grads of the right size; loss aux has 4 entries
    let (b, l) = (2usize, 4usize);
    let grad = rt
        .load(&man.artifact_path(v, "grad_b2l4").unwrap())
        .unwrap();
    let obs_bl = vec![0.5f32; b * l * res * res * v.in_ch];
    let goal_bl = vec![0.1f32; b * l * 3];
    let h0 = vec![0.0f32; b * v.hidden];
    let actions = vec![1i32; b * l];
    let logp_old = vec![-1.3863f32; b * l]; // ln(1/4)
    let ret = vec![0.5f32; b * l];
    let adv = vec![0.3f32; b * l];
    let notdone = vec![1.0f32; b * l];
    let gout = grad
        .run(&[
            lit_f32(&ps.flat, &[v.num_params as i64]).unwrap(),
            lit_f32(
                &obs_bl,
                &[b as i64, l as i64, res as i64, res as i64, v.in_ch as i64],
            )
            .unwrap(),
            lit_f32(&goal_bl, &[b as i64, l as i64, 3]).unwrap(),
            lit_f32(&h0, &[b as i64, v.hidden as i64]).unwrap(),
            lit_f32(&h0, &[b as i64, v.hidden as i64]).unwrap(),
            lit_i32(&actions, &[b as i64, l as i64]).unwrap(),
            lit_f32(&logp_old, &[b as i64, l as i64]).unwrap(),
            lit_f32(&ret, &[b as i64, l as i64]).unwrap(),
            lit_f32(&adv, &[b as i64, l as i64]).unwrap(),
            lit_f32(&notdone, &[b as i64, l as i64]).unwrap(),
        ])
        .unwrap();
    assert_eq!(gout.len(), 2);
    let grads = to_f32(&gout[0]).unwrap();
    let losses = to_f32(&gout[1]).unwrap();
    assert_eq!(grads.len(), v.num_params);
    assert_eq!(losses.len(), 4);
    assert!(grads.iter().all(|x| x.is_finite()));
    let gnorm: f32 = grads.iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(gnorm > 0.0 && gnorm <= 1.0 + 1e-3, "clipped grad norm {gnorm}");
    // entropy of a near-uniform init policy ~ ln(4)
    assert!(losses[2] > 0.9 * (4.0f32).ln(), "entropy {}", losses[2]);

    // update: params move, step increments, lamb != adam
    for algo in ["update_lamb", "update_adam"] {
        let upd = rt.load(&man.artifact_path(v, algo).unwrap()).unwrap();
        let uout = upd
            .run(&[
                lit_f32(&ps.flat, &[v.num_params as i64]).unwrap(),
                lit_f32(&ps.m, &[v.num_params as i64]).unwrap(),
                lit_f32(&ps.v, &[v.num_params as i64]).unwrap(),
                lit_scalar_f32(0.0),
                lit_f32(&grads, &[v.num_params as i64]).unwrap(),
                lit_scalar_f32(2.5e-4),
            ])
            .unwrap();
        assert_eq!(uout.len(), 4);
        let new_p = to_f32(&uout[0]).unwrap();
        let step = to_f32(&uout[3]).unwrap();
        assert_eq!(step[0], 1.0);
        let delta: f32 = new_p
            .iter()
            .zip(&ps.flat)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(delta > 0.0, "{algo} did not change params");
        assert!(new_p.iter().all(|x| x.is_finite()));
    }
}
