//! Integration tests for the multi-client serving layer (`bps::serve`).
//!
//! The acceptance gates: a single session driving a whole shard through
//! `SimServer` must be *bitwise identical* to driving the same-seeded
//! `EnvBatch` directly; two clients interleaving partial submissions on
//! one shard must jointly reproduce the direct full-batch step; and
//! detach / re-lease must not disturb co-tenants.

use std::sync::Arc;

use bps::env::{EnvBatch, EnvBatchConfig};
use bps::render::RenderConfig;
use bps::scene::procgen::{generate, Complexity};
use bps::scene::SceneAsset;
use bps::serve::{FillAction, ShardSpec, SimServer, StragglerPolicy};
use bps::sim::{Task, ACTION_FORWARD, NUM_ACTIONS};
use bps::util::pool::WorkerPool;

const SEED: u64 = 0xD0_5EED;

fn scene() -> Arc<SceneAsset> {
    Arc::new(generate("serve_eqv", 91, Complexity::test()))
}

fn env_cfg() -> EnvBatchConfig {
    EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(20)).seed(SEED)
}

fn direct_batch(n: usize, pool: &Arc<WorkerPool>) -> EnvBatch {
    let s = scene();
    env_cfg()
        .overlap(false)
        .build_with_scenes((0..n).map(|_| Arc::clone(&s)).collect(), Arc::clone(pool))
        .unwrap()
}

fn server(n: usize, policy: StragglerPolicy, pool: &Arc<WorkerPool>) -> SimServer {
    let s = scene();
    let spec = ShardSpec::with_scenes(env_cfg(), (0..n).map(|_| Arc::clone(&s)).collect())
        .straggler(policy);
    SimServer::start(vec![spec], Arc::clone(pool)).unwrap()
}

fn actions_at(t: usize, n: usize) -> Vec<u8> {
    (0..n).map(|i| ((5 * t + 3 * i) % NUM_ACTIONS) as u8).collect()
}

/// One session leasing the whole shard: served tensors must be bitwise
/// equal to direct `EnvBatch` stepping at every step.
#[test]
fn single_session_bitwise_equals_direct() {
    let n = 8;
    let pool = Arc::new(WorkerPool::new(2));
    let mut direct = direct_batch(n, &pool);
    let srv = server(n, StragglerPolicy::Wait, &pool);
    let mut session = srv.connect(Task::PointNav, n).unwrap();
    assert_eq!(session.num_envs(), n);
    assert_eq!(session.slots(), (0..n).collect::<Vec<_>>().as_slice());

    // initial observations (step 0) already match
    assert_eq!(session.view().step, 0);
    assert_eq!(session.view().obs, direct.view().obs);
    assert_eq!(session.view().goal, direct.view().goal);

    for t in 0..40 {
        let actions = actions_at(t, n);
        let dv = direct.step(&actions).unwrap();
        let (obs, goal, rewards, dones, successes, spl, scores) = (
            dv.obs.to_vec(),
            dv.goal.to_vec(),
            dv.rewards.to_vec(),
            dv.dones.to_vec(),
            dv.successes.to_vec(),
            dv.spl.to_vec(),
            dv.scores.to_vec(),
        );
        let sv = session.step(&actions).unwrap();
        assert_eq!(sv.step, (t + 1) as u64, "shard step counter");
        assert_eq!(obs, sv.obs, "obs diverged at step {t}");
        assert_eq!(goal, sv.goal, "goal diverged at step {t}");
        assert_eq!(rewards, sv.rewards, "rewards diverged at step {t}");
        assert_eq!(dones, sv.dones, "dones diverged at step {t}");
        assert_eq!(successes, sv.successes, "successes diverged at step {t}");
        assert_eq!(spl, sv.spl, "spl diverged at step {t}");
        assert_eq!(scores, sv.scores, "scores diverged at step {t}");
    }
    let stats = srv.stats();
    assert_eq!(stats.len(), 1);
    assert_eq!(stats[0].steps, 40);
    assert_eq!(stats[0].leased, n);
    assert!((stats[0].occupancy() - 1.0).abs() < 1e-6);
    assert_eq!(stats[0].straggler_fills, 0);
    assert_eq!(stats[0].bad_submits, 0);
    assert!(stats[0].latency_p95 >= stats[0].latency_p50);
    let (p50, p95) = session.latency();
    assert!(p50 > 0.0 && p95 >= p50);
}

/// Two clients on one shard, submitting partial batches in alternating
/// order: their joint results must equal the direct full-batch step.
#[test]
fn two_clients_interleave_and_match_direct() {
    let n = 8;
    let half = n / 2;
    let pool = Arc::new(WorkerPool::new(2));
    let mut direct = direct_batch(n, &pool);
    let srv = server(n, StragglerPolicy::Wait, &pool);
    let mut a = srv.connect(Task::PointNav, half).unwrap();
    let mut b = srv.connect(Task::PointNav, half).unwrap();
    assert_eq!(a.slots(), &[0, 1, 2, 3]);
    assert_eq!(b.slots(), &[4, 5, 6, 7]);
    let of = a.obs_floats();

    for t in 0..30 {
        let actions = actions_at(t, n);
        let dv = direct.step(&actions).unwrap();
        let (d_obs, d_rewards, d_dones) =
            (dv.obs.to_vec(), dv.rewards.to_vec(), dv.dones.to_vec());
        // alternate submission order; the step only fires once both land
        let (va, vb) = if t % 2 == 0 {
            let ta = a.submit(&actions[..half]).unwrap();
            let tb = b.submit(&actions[half..]).unwrap();
            let vb = tb.wait().unwrap();
            let va = ta.wait().unwrap();
            (va, vb)
        } else {
            let tb = b.submit(&actions[half..]).unwrap();
            let ta = a.submit(&actions[..half]).unwrap();
            let va = ta.wait().unwrap();
            let vb = tb.wait().unwrap();
            (va, vb)
        };
        assert_eq!(va.step, vb.step, "both clients see the same batch step");
        assert_eq!(va.obs, &d_obs[..half * of], "client A obs at step {t}");
        assert_eq!(vb.obs, &d_obs[half * of..], "client B obs at step {t}");
        assert_eq!(va.rewards, &d_rewards[..half]);
        assert_eq!(vb.rewards, &d_rewards[half..]);
        assert_eq!(va.dones, &d_dones[..half]);
        assert_eq!(vb.dones, &d_dones[half..]);
    }
}

/// Detach frees slots without disturbing the co-tenant; freed slots are
/// re-leased to a new session which then steps normally.
#[test]
fn detach_and_re_lease() {
    let n = 6;
    let pool = Arc::new(WorkerPool::new(2));
    let srv = server(n, StragglerPolicy::Wait, &pool);
    let mut a = srv.connect(Task::PointNav, 3).unwrap();
    let mut b = srv.connect(Task::PointNav, 3).unwrap();
    // shard is full now
    assert!(srv.connect(Task::PointNav, 1).is_err());

    let acts = vec![ACTION_FORWARD; 3];
    let ta = a.submit(&acts).unwrap();
    let tb = b.submit(&acts).unwrap();
    assert_eq!(ta.wait().unwrap().step, 1);
    assert_eq!(tb.wait().unwrap().step, 1);

    // A detaches; B keeps stepping alone (freed slots run on the filler)
    a.detach();
    assert_eq!(srv.stats()[0].leased, 3);
    for t in 0..5 {
        let v = b.step(&acts).unwrap();
        assert_eq!(v.step, (t + 2) as u64);
        assert!(v.rewards.iter().all(|r| r.is_finite()));
    }

    // A's old slots are re-leased to a new session, lowest-first
    let mut c = srv.connect(Task::PointNav, 3).unwrap();
    assert_eq!(c.slots(), &[0, 1, 2]);
    assert_eq!(srv.stats()[0].leased, 6);
    // both tenants step together again
    let tc = c.submit(&acts).unwrap();
    let tb = b.submit(&acts).unwrap();
    let vc = tc.wait().unwrap();
    let vb = tb.wait().unwrap();
    assert_eq!(vc.step, vb.step);
    // a detached session refuses further submits
    assert!(a.submit(&acts).is_err());
}

/// With a deadline policy, one client's submissions keep the shard
/// stepping even when the co-tenant goes silent.
#[test]
fn straggler_deadline_unblocks_half_occupied_shard() {
    let n = 4;
    let pool = Arc::new(WorkerPool::new(2));
    let policy = StragglerPolicy::Deadline {
        ticks: 2,
        fill: FillAction::Repeat,
    };
    let srv = server(n, policy, &pool);
    let mut active = srv.connect(Task::PointNav, 2).unwrap();
    let _silent = srv.connect(Task::PointNav, 2).unwrap();

    let acts = vec![ACTION_FORWARD; 2];
    for t in 0..4 {
        let v = active.step(&acts).unwrap();
        assert_eq!(v.step, (t + 1) as u64, "deadline must fire each step");
    }
    let stats = srv.stats();
    assert_eq!(stats[0].steps, 4);
    assert!(
        stats[0].straggler_fills >= 8,
        "silent tenant's 2 slots filled on all 4 steps (got {})",
        stats[0].straggler_fills
    );
}

/// Session/connect misuse is rejected cleanly.
#[test]
fn api_misuse_rejected() {
    let pool = Arc::new(WorkerPool::new(0));
    let srv = server(2, StragglerPolicy::Wait, &pool);
    assert!(srv.connect(Task::PointNav, 0).is_err(), "zero-env lease");
    assert!(srv.connect(Task::Flee, 1).is_err(), "no shard for task");
    assert!(srv.connect(Task::PointNav, 3).is_err(), "lease > shard");
    let mut s = srv.connect(Task::PointNav, 2).unwrap();
    assert!(s.submit(&[ACTION_FORWARD]).is_err(), "wrong action count");
    // a failed oversized submit must not poison the session
    let v = s.step(&[ACTION_FORWARD, ACTION_FORWARD]).unwrap();
    assert_eq!(v.step, 1);
}

/// Admission control: with a memory budget that fits only one shard's
/// resident assets, leases that would activate a second shard are
/// rejected until the first is vacated.
#[test]
fn admission_control_enforces_memory_budget() {
    let n = 4;
    let pool = Arc::new(WorkerPool::new(2));
    let s = scene();
    let one_shard = s.footprint_bytes(false) * n;
    let specs: Vec<ShardSpec> = (0..2)
        .map(|i| {
            let cfg = env_cfg().seed(SEED + i as u64);
            ShardSpec::with_scenes(cfg, (0..n).map(|_| Arc::clone(&s)).collect())
        })
        .collect();
    // budget: one shard resident, not two
    let srv = SimServer::with_budget(specs, Arc::clone(&pool), Some(one_shard + 1)).unwrap();
    for st in srv.stats() {
        assert_eq!(st.resident_bytes, one_shard);
    }

    // first lease activates shard 0 and fits the budget
    let mut a = srv.connect(Task::PointNav, n).unwrap();
    // shard 0 is full; shard 1 has room but activating it would go over
    let err = match srv.connect(Task::PointNav, 1) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("lease admitted over the memory budget"),
    };
    assert!(err.contains("budget"), "expected a budget rejection: {err}");

    // sessions on the active shard keep working
    let acts = vec![ACTION_FORWARD; n];
    let v = a.step(&acts).unwrap();
    assert_eq!(v.step, 1);

    // vacating shard 0 frees the budget; the next lease is admitted
    a.detach();
    let b = srv.connect(Task::PointNav, 1).unwrap();
    assert_eq!(b.num_envs(), 1);

    // without a budget, both shards admit freely
    let s2 = scene();
    let specs: Vec<ShardSpec> = (0..2)
        .map(|i| {
            let cfg = env_cfg().seed(SEED + i as u64);
            ShardSpec::with_scenes(cfg, (0..n).map(|_| Arc::clone(&s2)).collect())
        })
        .collect();
    let open = SimServer::start(specs, pool).unwrap();
    let _c = open.connect(Task::PointNav, n).unwrap();
    let _d = open.connect(Task::PointNav, n).unwrap();
}

/// Served shards stream scenes like training shards: the shard driver
/// drives `rotate_scenes` on its own cadence, gated on the shard's
/// rotation (scenario) assignment, and the swaps show up in the stats.
#[test]
fn shard_driver_streams_scene_rotation() {
    use bps::render::SceneRotation;

    let dir = std::env::temp_dir().join("bps_serve_rot");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let ds = bps::scene::generate_dataset(&dir, 4, 0, 0, Complexity::test(), 61).unwrap();
    let n = 4;
    let pool = Arc::new(WorkerPool::new(2));
    let rot = SceneRotation::new(ds.clone(), ds.train.clone(), 2, false).unwrap();
    // pin_rotation(1): every driver-side rotate call performs one
    // blocking swap, so the rotation count is deterministic in steps
    let spec = ShardSpec::with_rotation(env_cfg().pin_rotation(1), rot, n).rotate_every(2);
    let srv = SimServer::start(vec![spec], Arc::clone(&pool)).unwrap();

    let mut session = srv.connect(Task::PointNav, n).unwrap();
    let acts = vec![ACTION_FORWARD; n];
    let steps = 10u64;
    for _ in 0..steps {
        let v = session.step(&acts).unwrap();
        assert!(v.rewards.iter().all(|r| r.is_finite()));
    }
    assert_eq!(srv.stats()[0].steps, steps);
    // the driver rotates *after* publishing a step, so give the final
    // swap a moment to land before asserting the exact count
    let want = steps / 2;
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while srv.stats()[0].rotations < want && std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let got = srv.stats()[0].rotations;
    assert_eq!(got, want, "driver must rotate every 2 steps (got {got})");

    // fixed-scene shards never rotate
    let fixed = server(n, StragglerPolicy::Wait, &pool);
    let mut fs = fixed.connect(Task::PointNav, n).unwrap();
    for _ in 0..4 {
        fs.step(&acts).unwrap();
    }
    assert_eq!(fixed.stats()[0].rotations, 0);
}

/// Multi-threaded smoke: M client threads drive one server concurrently
/// (sessions are Send); every client sees every one of its steps.
#[test]
fn threaded_clients_serve_concurrently() {
    let clients = 3;
    let epc = 2;
    let steps = 25;
    let pool = Arc::new(WorkerPool::new(2));
    let srv = server(clients * epc, StragglerPolicy::Wait, &pool);
    // connect on the main thread so every lease exists before any client
    // submits (with Wait coalescing, a lone early tenant would otherwise
    // race a private batch step in before the others join)
    let sessions: Vec<_> = (0..clients)
        .map(|_| srv.connect(Task::PointNav, epc).unwrap())
        .collect();
    let totals: Vec<u64> = std::thread::scope(|sc| {
        let handles: Vec<_> = sessions
            .into_iter()
            .enumerate()
            .map(|(c, mut session)| {
                sc.spawn(move || {
                    let mut last = 0;
                    for t in 0..steps {
                        let actions: Vec<u8> =
                            (0..epc).map(|j| (1 + (t + c + j) % 3) as u8).collect();
                        let v = session.step(&actions).unwrap();
                        assert!(v.step > last, "steps advance monotonically");
                        last = v.step;
                        assert!(v.rewards.iter().all(|r| r.is_finite()));
                    }
                    last
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // all clients share the shard, so they all end on the same step count
    assert!(totals.iter().all(|&s| s == steps as u64));
    assert_eq!(srv.stats()[0].steps, steps as u64);
}
