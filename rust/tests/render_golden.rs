//! Raster-refactor guard rails (ISSUE 4): heterogeneous batches must be
//! bitwise-identical across pipeline modes and dispatch orders, repeated
//! megaframes must be deterministic, and per-sensor golden-image checksums
//! pin the raster output against silent drift.
//!
//! The golden file (`tests/goldens/render_golden.json`) bootstraps on
//! first run: when missing it is written from the current output and the
//! test passes; once committed, any change to the rendered bits fails.

use std::path::PathBuf;
use std::sync::Arc;

use bps::geom::vec::v2;
use bps::render::{BatchRenderer, PipelineMode, RenderConfig, RenderItem, Sensor};
use bps::scene::procgen::{generate, Complexity};
use bps::scene::SceneAsset;
use bps::util::json::{obj, s, Json};
use bps::util::pool::WorkerPool;
use bps::util::rng::Rng;

/// FNV-1a over the f32 bit patterns — stable, order-sensitive.
fn checksum(data: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for x in data {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// One heavy env + seven light ones: the straggler shape that cost-aware
/// (LPT) dispatch targets.
fn hetero_items(frame: u32) -> Vec<RenderItem> {
    let heavy: Arc<SceneAsset> = Arc::new(generate(
        "golden_heavy",
        9,
        Complexity {
            extent: 8.0,
            clutter_per_room: 4,
            detail: 8,
            ..Complexity::test()
        },
    ));
    let light = Arc::new(generate("golden_light", 11, Complexity::test()));
    let mut rng = Rng::new(13);
    (0..8)
        .map(|i| {
            let scene = if i == 0 { &heavy } else { &light };
            RenderItem {
                scene: Arc::clone(scene),
                pos: scene.navmesh.random_point(&mut rng).unwrap(),
                heading: rng.range_f32(0.0, std::f32::consts::TAU) + frame as f32 * 0.37,
            }
        })
        .collect()
}

fn render(renderer: &BatchRenderer, pool: &WorkerPool, items: &[RenderItem]) -> Vec<f32> {
    let mut obs = vec![0.0f32; items.len() * renderer.cfg.obs_floats()];
    renderer.render_batch(pool, items, &mut obs);
    obs
}

#[test]
fn hetero_batch_bitwise_across_modes_and_frames() {
    let pool = WorkerPool::new(3);
    let mut cfg = RenderConfig::depth(32);
    cfg.mode = PipelineMode::Fused;
    let fused = BatchRenderer::new(cfg, 8);
    cfg.mode = PipelineMode::Pipelined;
    let pipelined = BatchRenderer::new(cfg, 8);
    // frame 0 runs in env order; later frames run LPT (heavy env first in
    // both renderers) — every frame must still match bitwise
    for frame in 0..3 {
        let items = hetero_items(frame);
        let of = render(&fused, &pool, &items);
        let op = render(&pipelined, &pool, &items);
        assert_eq!(of, op, "fused vs pipelined diverged at frame {frame}");
    }
}

#[test]
fn dispatch_order_does_not_change_output() {
    let pool = WorkerPool::new(3);
    let cfg = RenderConfig::depth(24); // Pipelined default
    let frame_a = hetero_items(0);
    let frame_b = hetero_items(5);
    // renderer 1 sees frame_b cold (identity dispatch order)
    let r1 = BatchRenderer::new(cfg, 8);
    let cold = render(&r1, &pool, &frame_b);
    // renderer 2 renders frame_a first, so its LPT order for frame_b is
    // driven by recorded costs — a different dispatch order
    let r2 = BatchRenderer::new(cfg, 8);
    let _ = render(&r2, &pool, &frame_a);
    let warm = render(&r2, &pool, &frame_b);
    assert_eq!(cold, warm, "dispatch order leaked into the image");
}

#[test]
fn repeated_megaframes_deterministic() {
    let pool = WorkerPool::new(3);
    let cfg = RenderConfig::rgb(24);
    let items = hetero_items(2);
    let r1 = BatchRenderer::new(cfg, 8);
    let r2 = BatchRenderer::new(cfg, 8);
    for round in 0..3 {
        let a = render(&r1, &pool, &items);
        let b = render(&r2, &pool, &items);
        assert_eq!(a, b, "round {round} not run-to-run deterministic");
        assert_eq!(checksum(&a), checksum(&b));
    }
}

#[test]
fn golden_image_checksums_per_sensor() {
    let pool = WorkerPool::new(2);
    let scene = Arc::new(generate("golden", 7, Complexity::test()));
    // fixed literal poses: decoupled from RNG/navmesh changes
    let poses = [
        (v2(3.0, 3.0), 0.0f32),
        (v2(1.5, 2.0), 1.3),
        (v2(4.2, 4.5), 2.7),
        (v2(2.5, 4.0), 4.2),
    ];
    let items: Vec<RenderItem> = poses
        .iter()
        .map(|&(pos, heading)| RenderItem {
            scene: Arc::clone(&scene),
            pos,
            heading,
        })
        .collect();
    let mut hashes = Vec::new();
    for (sensor, name) in [(Sensor::Depth, "depth"), (Sensor::Rgb, "rgb")] {
        let cfg = RenderConfig {
            res: 32,
            sensor,
            scale: 1,
            mode: PipelineMode::Fused,
        };
        let renderer = BatchRenderer::new(cfg, items.len());
        let obs = render(&renderer, &pool, &items);
        assert!(obs.iter().all(|v| v.is_finite()));
        // in-process determinism regardless of the golden file
        let again = render(&renderer, &pool, &items);
        assert_eq!(obs, again, "{name} render not deterministic");
        hashes.push((name, format!("{:016x}", checksum(&obs))));
    }
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/goldens/render_golden.json");
    match std::fs::read_to_string(&path) {
        Ok(text) => {
            let golden = Json::parse(&text).expect("golden file parses");
            for (name, hash) in &hashes {
                let want = golden
                    .req(name)
                    .and_then(|v| v.as_str().map(str::to_string))
                    .unwrap_or_else(|e| panic!("golden key {name}: {e}"));
                assert_eq!(
                    *hash, want,
                    "{name} image checksum drifted from the pinned golden \
                     ({path:?}); if the raster change is intentional, delete \
                     the file and re-run to re-bless"
                );
            }
        }
        // only a *missing* file may bootstrap; any other read failure (perms,
        // truncation, …) must not silently re-bless
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            assert!(
                std::env::var("BPS_GOLDEN_STRICT").map(|v| v != "1").unwrap_or(true),
                "golden file {path:?} missing and BPS_GOLDEN_STRICT=1 — \
                 generate and commit it (run this test once without strict mode)"
            );
            std::fs::create_dir_all(path.parent().unwrap()).expect("goldens dir");
            let record = obj(hashes.iter().map(|(n, h)| (*n, s(h))).collect());
            std::fs::write(&path, record.to_string() + "\n").expect("write golden");
            eprintln!(
                "WARNING: bootstrapped golden checksums at {path:?} — the guard \
                 is inert until this file is committed (set BPS_GOLDEN_STRICT=1 \
                 to fail instead)"
            );
        }
        Err(e) => panic!("golden file {path:?} unreadable: {e}"),
    }
}
