//! Chaos tests for the fault-tolerance layer (DESIGN.md §0.12): shard
//! panic quarantine + restart, session park/resume across injected
//! connection drops, and the typed overload/failure error frames.
//!
//! The centerpiece is the chaos loopback run: T steps driven through
//! `bps serve`'s wire layer with k injected connection kills and one
//! shard panic+restart mid-stream must deliver an observation sequence
//! *bitwise identical* to an undisturbed run — fault tolerance is not
//! allowed to perturb the simulation stream, only to delay it.

use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use bps::env::EnvBatchConfig;
use bps::render::RenderConfig;
use bps::scene::procgen::{generate, Complexity};
use bps::scene::SceneAsset;
use bps::serve::wire::frame::{self, Frame, ERR_SESSION, ERR_SHARD_DOWN};
use bps::serve::{
    FaultSpec, Injector, RemoteClient, ResumeCfg, ShardSpec, SimServer, StragglerPolicy,
    WireConfig, WireServer,
};
use bps::sim::{Task, NUM_ACTIONS};
use bps::util::pool::WorkerPool;

const SEED: u64 = 0xC4A05;

fn scene() -> Arc<SceneAsset> {
    Arc::new(generate("serve_chaos", 29, Complexity::test()))
}

fn env_cfg() -> EnvBatchConfig {
    EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(16)).seed(SEED)
}

/// `shards` identical shards of `n` slots each — identical specs, so a
/// session's stream depends only on its actions, never on which shard
/// hosted it (the chaos run and the baseline may place differently).
fn server(shards: usize, n: usize, pool: &Arc<WorkerPool>) -> Arc<SimServer> {
    let s = scene();
    let specs = (0..shards)
        .map(|_| {
            ShardSpec::with_scenes(env_cfg(), (0..n).map(|_| Arc::clone(&s)).collect())
                .straggler(StragglerPolicy::Wait)
        })
        .collect();
    Arc::new(SimServer::start(specs, Arc::clone(pool)).unwrap())
}

fn actions_at(t: usize, n: usize) -> Vec<u8> {
    (0..n).map(|i| ((5 * t + 3 * i) % NUM_ACTIONS) as u8).collect()
}

fn wait_until(what: &str, cond: impl Fn() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Divide an iteration count by `BPS_TEST_SCALE` (the CI TSan job sets
/// it — every memory access is instrumented there, so native counts
/// would run for hours). Unset or 1 means full native counts.
fn scaled(n: usize) -> usize {
    match std::env::var("BPS_TEST_SCALE") {
        Ok(v) => (n / v.parse::<usize>().unwrap_or(1).max(1)).max(1),
        Err(_) => n,
    }
}

/// One step's delivered arrays, recorded for bitwise comparison.
#[derive(PartialEq, Debug)]
struct Recorded {
    step: u64,
    obs: Vec<f32>,
    goal: Vec<f32>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
    successes: Vec<bool>,
    spl: Vec<f32>,
    scores: Vec<f32>,
}

/// Deep-copy a borrowed step view so it outlives the session.
fn record(v: bps::serve::SessionView<'_>) -> Recorded {
    Recorded {
        step: v.step,
        obs: v.obs.to_vec(),
        goal: v.goal.to_vec(),
        rewards: v.rewards.to_vec(),
        dones: v.dones.to_vec(),
        successes: v.successes.to_vec(),
        spl: v.spl.to_vec(),
        scores: v.scores.to_vec(),
    }
}

/// The chaos loopback drill (ISSUE §0.12 acceptance): T steps with k
/// injected connection kills plus one shard panic + restart mid-stream.
/// The session rides `conn_drop:every=9` — every ninth outbound frame
/// cuts the connection — while a co-tenant on the second shard absorbs
/// a driver panic and an in-place restart. The delivered stream must be
/// bitwise identical to an undisturbed baseline, every lease and park
/// slot must return to zero, and `serve.resume.ok` must equal the
/// number of kills.
#[test]
fn chaos_resume_stream_is_bitwise_identical() {
    const N: usize = 2; // slots per shard == envs per session
    // 12 is the floor: the drill needs enough steps for the mid-stream
    // panic plus at least one every=9 connection kill on each side.
    let t_steps = scaled(30).max(12);
    let pool = Arc::new(WorkerPool::new(2));

    // Undisturbed baseline: same spec, no faults, plain client.
    let baseline: Vec<Recorded> = {
        let srv = server(1, N, &pool);
        let wire = WireServer::listen("127.0.0.1:0", Arc::clone(&srv)).unwrap();
        let client = RemoteClient::connect(&wire.local_addr().to_string()).unwrap();
        let mut session = client.open_session(Task::PointNav, N).unwrap();
        let mut rec = Vec::with_capacity(t_steps + 1);
        rec.push(record(session.view()));
        for t in 0..t_steps {
            let r = record(session.step(&actions_at(t, N)).unwrap());
            rec.push(r);
        }
        session.detach().unwrap();
        rec
    };

    // Chaos run: two shards (remote session lands on shard 0 first-fit,
    // the in-process co-tenant fills shard 1), deterministic conn kills,
    // parking armed, resume-capable client.
    let srv = server(2, N, &pool);
    let inj = Arc::new(Injector::new(FaultSpec::parse("conn_drop:every=9").unwrap()));
    srv.arm_faults(Arc::clone(&inj)).unwrap();
    let wire = WireServer::listen_with(
        "127.0.0.1:0",
        Arc::clone(&srv),
        WireConfig {
            park_ttl_ticks: Some(60_000),
            fault: Some(Arc::clone(&inj)),
            ..WireConfig::default()
        },
    )
    .unwrap();
    let client = RemoteClient::connect_with_resume(
        &wire.local_addr().to_string(),
        ResumeCfg {
            max_retries: 10,
            base_ms: 40,
            cap_ms: 200,
            seed: 3,
        },
    )
    .unwrap();
    let mut session = client.open_session(Task::PointNav, N).unwrap();
    let mut cotenant = Some(srv.connect(Task::PointNav, N).unwrap());
    assert_eq!(srv.stats()[0].leased, N, "remote session fills shard 0");
    assert_eq!(srv.stats()[1].leased, N, "co-tenant fills shard 1");

    let mut delivered = Vec::with_capacity(t_steps + 1);
    delivered.push(record(session.view()));
    let mut panicked = false;
    for t in 0..t_steps {
        // Mid-stream, panic the co-tenant's shard driver and restart it
        // in place; the remote session's shard must never notice.
        if t == t_steps / 2 {
            inj.arm_panic(1);
            let err = cotenant
                .as_mut()
                .unwrap()
                .step(&actions_at(t, N))
                .expect_err("armed panic must fail the co-tenant step");
            let msg = format!("{err:#}");
            assert!(
                msg.contains("quarantined") || msg.contains("panic"),
                "co-tenant error names the quarantine: {msg}"
            );
            wait_until("shard 1 quarantine", || srv.shard_quarantined(1));
            cotenant = None; // release the dead session before the rebuild
            srv.restart_shard(1).unwrap();
            assert!(!srv.shard_quarantined(1));
            cotenant = Some(srv.connect(Task::PointNav, N).unwrap());
            cotenant.as_mut().unwrap().step(&actions_at(t, N)).unwrap();
            panicked = true;
        } else if t % 5 == 0 {
            cotenant.as_mut().unwrap().step(&actions_at(t, N)).unwrap();
        }
        let r = record(session.step(&actions_at(t, N)).unwrap());
        delivered.push(r);
    }
    assert!(panicked);
    session.detach().unwrap();

    // Bitwise identity, step by step, starting from the seed view.
    assert_eq!(delivered.len(), baseline.len());
    for (t, (got, want)) in delivered.iter().zip(&baseline).enumerate() {
        assert_eq!(got, want, "stream diverged at delivered step {t}");
    }

    // The run actually exercised the fault plane: several kills, each
    // reclaimed by exactly one successful resume, client and server in
    // agreement about the count.
    let k = inj.fired_drops.load(Ordering::Relaxed);
    let want_kills = (t_steps / 10).max(1) as u64;
    assert!(
        k >= want_kills,
        "conn_drop:every=9 over {t_steps} steps must kill >= {want_kills}, got {k}"
    );
    assert_eq!(inj.fired_panics.load(Ordering::Relaxed), 1);
    let (resumes, backoff_ms) = client.resume_stats();
    assert_eq!(resumes, k, "every kill resumed exactly once");
    assert!(backoff_ms > 0, "resume waited out at least one backoff");
    let snap = srv.registry().snapshot();
    assert_eq!(snap.counter("serve.resume.ok", &[]), Some(k));
    assert_eq!(snap.counter("serve.resume.fail", &[]), Some(0));
    assert_eq!(snap.counter("serve.park.parked", &[]), Some(k));
    assert_eq!(snap.counter("serve.park.expired", &[]), Some(0));

    // Everything returns to zero: leases, park slots, open sessions.
    drop(cotenant);
    wait_until("leases to drain", || {
        srv.stats().iter().all(|s| s.leased == 0)
    });
    assert_eq!(snap.gauge("serve.park.open", &[]), Some(0.0));
    wait_until("wire sessions to close", || session_open_total(&wire) == 0);
}

fn session_open_total(wire: &WireServer) -> usize {
    wire.conn_stats().iter().map(|c| c.sessions_open).sum()
}

/// A quarantined shard answers in-flight submits with the typed
/// `ERR_SHARD_DOWN` frame carrying a `retry_after_ms=` hint — never a
/// silent close — and an in-place restart brings the shard back for
/// fresh leases.
#[test]
fn shard_panic_yields_typed_error_and_restart_recovers() {
    let n = 2;
    let pool = Arc::new(WorkerPool::new(2));
    let srv = server(1, n, &pool);
    let inj = Arc::new(Injector::new(FaultSpec::default()));
    srv.arm_faults(Arc::clone(&inj)).unwrap();
    let wire = WireServer::listen("127.0.0.1:0", Arc::clone(&srv)).unwrap();
    let client = RemoteClient::connect(&wire.local_addr().to_string()).unwrap();
    let mut session = client.open_session(Task::PointNav, n).unwrap();
    session.step(&actions_at(0, n)).unwrap();

    inj.arm_panic(0);
    let err = session
        .step(&actions_at(1, n))
        .expect_err("step into an armed panic must fail");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("retry_after_ms="),
        "ERR_SHARD_DOWN carries a retry-after hint: {msg}"
    );
    wait_until("quarantine", || srv.shard_quarantined(0));
    // the failed session's lease released (pump exit: Failed → detach)
    wait_until("lease release", || srv.stats()[0].leased == 0);

    // leasing while quarantined is a diagnosable decline, not a hang
    let decline = client
        .open_session(Task::PointNav, n)
        .expect_err("quarantined shard must decline leases");
    assert!(
        format!("{decline:#}").contains("quarantined"),
        "decline names the quarantine: {decline:#}"
    );

    srv.restart_shard(0).unwrap();
    assert!(!srv.shard_quarantined(0));
    let mut fresh = client.open_session(Task::PointNav, n).unwrap();
    let v = fresh.step(&actions_at(0, n)).unwrap();
    assert!(v.rewards.iter().all(|r| r.is_finite()));
}

/// Protocol-level resume: a parked session is reclaimed only by the
/// exact grant token; a stale token is refused (the park entry
/// survives for the rightful owner), and the reclaim replays nothing
/// when the client is already current.
#[test]
fn resume_validates_token_and_skips_replay_when_current() {
    let n = 1;
    let pool = Arc::new(WorkerPool::new(2));
    let srv = server(1, n, &pool);
    let wire = WireServer::listen_with(
        "127.0.0.1:0",
        Arc::clone(&srv),
        WireConfig {
            park_ttl_ticks: Some(60_000),
            ..WireConfig::default()
        },
    )
    .unwrap();
    let addr = wire.local_addr();

    let mut sock = TcpStream::connect(addr).unwrap();
    sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    frame::write_frame(&mut sock, &Frame::Hello).unwrap();
    assert!(matches!(frame::read_frame(&mut sock).unwrap(), Frame::Welcome { .. }));
    frame::write_frame(
        &mut sock,
        &Frame::Lease {
            req: 1,
            task: Task::PointNav,
            n_envs: n as u32,
        },
    )
    .unwrap();
    let (session, token) = match frame::read_frame(&mut sock).unwrap() {
        Frame::Grant { session, token, .. } => (session, token),
        other => panic!("want GRANT, got {other:?}"),
    };
    // the seed step view: applied=1 server-side, delivered=1 here
    match frame::read_frame(&mut sock).unwrap() {
        Frame::Step { session: s, step, .. } => {
            assert_eq!(s, session);
            assert_eq!(step, 0);
        }
        other => panic!("want seed STEP, got {other:?}"),
    }
    drop(sock); // connection dies; the session parks, lease held
    wait_until("park", || {
        srv.registry().snapshot().gauge("serve.park.open", &[]) == Some(1.0)
    });
    assert_eq!(srv.stats()[0].leased, n, "parked lease is held");

    let mut sock = TcpStream::connect(addr).unwrap();
    sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    frame::write_frame(&mut sock, &Frame::Hello).unwrap();
    assert!(matches!(frame::read_frame(&mut sock).unwrap(), Frame::Welcome { .. }));
    // wrong token: refused, entry kept for the rightful owner
    frame::write_frame(
        &mut sock,
        &Frame::Resume {
            req: 7,
            session,
            token: token ^ 1,
            delivered: 1,
        },
    )
    .unwrap();
    match frame::read_frame(&mut sock).unwrap() {
        Frame::Error { re, code, msg } => {
            assert_eq!(re, 7);
            assert_eq!(code, ERR_SESSION);
            assert!(msg.contains("token"), "refusal names the token: {msg:?}");
        }
        other => panic!("want ERR_SESSION, got {other:?}"),
    }
    assert_eq!(
        srv.registry().snapshot().gauge("serve.park.open", &[]),
        Some(1.0),
        "refused resume must not consume the park entry"
    );
    // right token, already current: RESUMED with applied=1, no replay,
    // and the session steps on
    frame::write_frame(
        &mut sock,
        &Frame::Resume {
            req: 8,
            session,
            token,
            delivered: 1,
        },
    )
    .unwrap();
    match frame::read_frame(&mut sock).unwrap() {
        Frame::Resumed { req, session: s, applied } => {
            assert_eq!(req, 8);
            assert_eq!(s, session);
            assert_eq!(applied, 1);
        }
        other => panic!("want RESUMED, got {other:?}"),
    }
    frame::write_frame(
        &mut sock,
        &Frame::Submit {
            session,
            pairs: vec![(0, 1)],
        },
    )
    .unwrap();
    match frame::read_frame(&mut sock).unwrap() {
        Frame::Step { session: s, step, .. } => {
            assert_eq!(s, session);
            assert_eq!(step, 1, "resumed session continues the shard stream");
        }
        other => panic!("want STEP, got {other:?}"),
    }
    let snap = srv.registry().snapshot();
    assert_eq!(snap.counter("serve.resume.ok", &[]), Some(1));
    assert_eq!(snap.counter("serve.resume.fail", &[]), Some(1));
    assert_eq!(snap.gauge("serve.park.open", &[]), Some(0.0));
}

/// A parked session whose owner never returns expires at the TTL and
/// releases its lease — parking holds capacity for seconds, not
/// forever.
#[test]
fn parked_session_expires_at_ttl_and_releases_lease() {
    let n = 2;
    let pool = Arc::new(WorkerPool::new(2));
    let srv = server(1, n, &pool);
    let wire = WireServer::listen_with(
        "127.0.0.1:0",
        Arc::clone(&srv),
        WireConfig {
            park_ttl_ticks: Some(300), // ticks are milliseconds
            ..WireConfig::default()
        },
    )
    .unwrap();
    {
        // raw socket so the disconnect is abrupt — no courtesy DETACH
        let mut sock = TcpStream::connect(wire.local_addr()).unwrap();
        sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        frame::write_frame(&mut sock, &Frame::Hello).unwrap();
        assert!(matches!(frame::read_frame(&mut sock).unwrap(), Frame::Welcome { .. }));
        frame::write_frame(
            &mut sock,
            &Frame::Lease {
                req: 1,
                task: Task::PointNav,
                n_envs: n as u32,
            },
        )
        .unwrap();
        assert!(matches!(frame::read_frame(&mut sock).unwrap(), Frame::Grant { .. }));
        wait_until("lease", || srv.stats()[0].leased == n);
        // socket dropped here without detaching
    }
    // parked first — the lease survives the disconnect...
    wait_until("park", || {
        srv.registry().snapshot().counter("serve.park.parked", &[]) == Some(1)
    });
    // ...then the TTL reaps it and the slots come back
    wait_until("park expiry", || {
        srv.registry().snapshot().counter("serve.park.expired", &[]) == Some(1)
    });
    wait_until("lease release", || srv.stats()[0].leased == 0);
    assert_eq!(
        srv.registry().snapshot().gauge("serve.park.open", &[]),
        Some(0.0)
    );
    // an expired session cannot be resumed; the refusal is typed
    let mut sock = TcpStream::connect(wire.local_addr()).unwrap();
    sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    frame::write_frame(&mut sock, &Frame::Hello).unwrap();
    assert!(matches!(frame::read_frame(&mut sock).unwrap(), Frame::Welcome { .. }));
    frame::write_frame(
        &mut sock,
        &Frame::Resume {
            req: 3,
            session: 1,
            token: 0,
            delivered: 1,
        },
    )
    .unwrap();
    match frame::read_frame(&mut sock).unwrap() {
        Frame::Error { re, code, msg } => {
            assert_eq!(re, 3);
            assert_eq!(code, ERR_SESSION);
            assert!(msg.contains("expired") || msg.contains("unknown"), "{msg:?}");
        }
        other => panic!("want ERR_SESSION, got {other:?}"),
    }
}
