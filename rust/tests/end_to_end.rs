//! End-to-end integration: full training iterations through the real stack
//! (procgen dataset → batch sim → batch render → PJRT inference → GAE →
//! PPO grad → Lamb update), on the tiny `test` artifact variant.

use std::path::PathBuf;

use bps::config::{Config, SimArch};
use bps::coordinator::Coordinator;

fn test_config(name: &str) -> Option<Config> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    if !root.join("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    // tiny dataset generated on demand (cached across tests)
    let ds_dir = std::env::temp_dir().join("bps_e2e_dataset");
    if !ds_dir.join("splits.json").exists() {
        std::fs::create_dir_all(&ds_dir).unwrap();
        bps::scene::generate_dataset(
            &ds_dir,
            3,
            1,
            1,
            bps::scene::Complexity::test(),
            123,
        )
        .unwrap();
    }
    let cfg = Config {
        variant: "test".into(),
        artifacts_dir: root.join("artifacts"),
        dataset_dir: ds_dir,
        complexity: "test".into(),
        num_envs: 4,
        rollout_len: 4,
        num_minibatches: 2,
        k_scenes: 2,
        shards: 1,
        total_frames: 64,
        seed: 9,
        threads: 2,
        out_dir: std::env::temp_dir().join(format!("bps_e2e_{name}")),
        ..Config::default()
    };
    cfg.validate().unwrap();
    Some(cfg)
}

#[test]
fn bps_training_iterations_run_and_update_params() {
    let Some(cfg) = test_config("bps") else { return };
    let mut coord = Coordinator::new(cfg).unwrap();
    let p0 = coord.params.flat.clone();
    for _ in 0..3 {
        let it = coord.train_iteration().unwrap();
        assert_eq!(it.frames, 16);
        assert!(it.losses.entropy > 0.0 && it.losses.entropy <= (4.0f32).ln() + 1e-4);
        assert!(it.losses.value.is_finite());
    }
    assert_eq!(coord.frames(), 48);
    // params changed and remained finite
    let p1 = &coord.params.flat;
    assert!(p1.iter().all(|x| x.is_finite()));
    let delta: f32 = p1.iter().zip(&p0).map(|(a, b)| (a - b).abs()).sum();
    assert!(delta > 0.0);
    // optimizer stepped: 3 iters * 1 epoch * 2 minibatches
    assert_eq!(coord.params.step, 6.0);
    // profiler recorded every phase
    for phase in ["inference", "sim", "render", "learn"] {
        assert!(coord.prof.count(phase) > 0, "missing phase {phase}");
    }
}

#[test]
fn workers_arch_runs() {
    let Some(mut cfg) = test_config("workers") else { return };
    cfg.arch = SimArch::Workers;
    let mut coord = Coordinator::new(cfg).unwrap();
    let it = coord.train_iteration().unwrap();
    assert_eq!(it.frames, 16);
}

#[test]
fn multi_shard_ddppo_matches_frame_accounting() {
    let Some(mut cfg) = test_config("shards") else { return };
    cfg.shards = 2;
    let mut coord = Coordinator::new(cfg).unwrap();
    let it = coord.train_iteration().unwrap();
    assert_eq!(it.frames, 32); // 2 shards x 4 envs x 4 steps
    assert!(coord.params.step > 0.0);
}

#[test]
fn evaluation_completes_episodes() {
    let Some(cfg) = test_config("eval") else { return };
    let mut coord = Coordinator::new(cfg).unwrap();
    let (spl, success, _score) = coord.evaluate("val", 8).unwrap();
    assert!((0.0..=1.0).contains(&spl));
    assert!((0.0..=1.0).contains(&success));
}

#[test]
fn checkpoint_roundtrip_through_coordinator() {
    let Some(cfg) = test_config("ckpt") else { return };
    let mut coord = Coordinator::new(cfg).unwrap();
    coord.train_iteration().unwrap();
    let path = std::env::temp_dir().join("bps_e2e_ckpt.bin");
    coord.params.save(&path).unwrap();
    let loaded = bps::runtime::ParamStore::load(&path).unwrap();
    assert_eq!(loaded.flat, coord.params.flat);
    assert_eq!(loaded.step, coord.params.step);
}

#[test]
fn adam_optimizer_variant_runs() {
    let Some(mut cfg) = test_config("adam") else { return };
    cfg.optimizer = "adam".into();
    let mut coord = Coordinator::new(cfg).unwrap();
    let it = coord.train_iteration().unwrap();
    assert!(it.losses.value.is_finite());
}
