//! PJRT runtime: load HLO-text artifacts, compile once, execute on the hot
//! path. Python never runs at request time — the Rust binary is fully
//! self-contained after `make artifacts` (DESIGN.md §2).
//!
//! HLO *text* is the interchange format: jax >= 0.5 emits HloModuleProto
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::path::Path;

use anyhow::{Context, Result};

/// Shared PJRT client (CPU).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact. Compilation happens once; the
    /// returned executable is reused for every call on the hot path.
    pub fn load(&self, path: &Path) -> Result<Exec> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {path:?}"))?;
        Ok(Exec { exe })
    }
}

/// One compiled executable.
pub struct Exec {
    exe: xla::PjRtLoadedExecutable,
}

impl Exec {
    /// Execute with literal inputs; returns the decomposed output tuple
    /// (aot.py lowers everything with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

// -- literal construction helpers -------------------------------------------

/// f32 literal with shape `dims` from a flat slice.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    debug_assert_eq!(
        data.len() as i64,
        dims.iter().product::<i64>(),
        "shape/volume mismatch"
    );
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// i32 literal with shape `dims`.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// f32 scalar literal (shape `[]`).
pub fn lit_scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// i32 scalar literal.
pub fn lit_scalar_i32(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Copy a literal out to an f32 vec.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
