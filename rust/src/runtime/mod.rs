//! PJRT runtime: manifest loading, HLO-text compilation, execution, and the
//! flat parameter store. The only module that touches the `xla` crate.

pub mod client;
pub mod manifest;
pub mod params;

pub use client::{lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32, to_f32, Exec, Runtime};
pub use manifest::{Manifest, Variant};
pub use params::ParamStore;
