//! `artifacts/manifest.json` parsing: the contract between `python/compile/
//! aot.py` and the Rust runtime (DESIGN.md §2). Describes every AOT
//! variant: model geometry, flat-parameter layout, and artifact filenames.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One tensor in the flat parameter vector.
#[derive(Clone, Debug)]
pub struct LayoutEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl LayoutEntry {
    pub fn size(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT-compiled model variant.
#[derive(Clone, Debug)]
pub struct Variant {
    pub name: String,
    pub encoder: String,
    pub res: usize,
    pub in_ch: usize,
    pub hidden: usize,
    pub num_actions: usize,
    pub goal_dim: usize,
    pub num_params: usize,
    pub infer_ns: Vec<usize>,
    pub grad_bls: Vec<(usize, usize)>,
    pub files: BTreeMap<String, String>,
    pub layout: Vec<LayoutEntry>,
}

impl Variant {
    /// Artifact filename for a kind like `"infer_n64"` / `"update_lamb"`.
    pub fn file(&self, kind: &str) -> Result<&str> {
        self.files
            .get(kind)
            .map(String::as_str)
            .ok_or_else(|| {
                anyhow!(
                    "variant {:?} has no artifact {kind:?} (have: {:?}); \
                     re-run `make artifacts` with the right preset",
                    self.name,
                    self.files.keys().collect::<Vec<_>>()
                )
            })
    }

    /// Largest exported inference batch `<= n`, used to pick an executable
    /// when the requested env count has no exact artifact.
    pub fn best_infer_n(&self, n: usize) -> Option<usize> {
        self.infer_ns
            .iter()
            .copied()
            .filter(|&k| k <= n)
            .max()
            .or_else(|| self.infer_ns.iter().copied().min())
    }

    pub fn obs_floats(&self, n: usize) -> usize {
        n * self.res * self.res * self.in_ch
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: BTreeMap<String, Variant>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&text)?;
        let version = root.req("version")?.as_usize()?;
        if version != 1 {
            bail!("manifest version {version} unsupported (expected 1)");
        }
        let mut variants = BTreeMap::new();
        for (name, v) in root.req("variants")?.as_obj()? {
            let files = v
                .req("files")?
                .as_obj()?
                .iter()
                .map(|(k, f)| Ok((k.clone(), f.as_str()?.to_string())))
                .collect::<Result<BTreeMap<_, _>>>()?;
            let layout = v
                .req("layout")?
                .as_arr()?
                .iter()
                .map(|e| {
                    Ok(LayoutEntry {
                        name: e.req("name")?.as_str()?.to_string(),
                        offset: e.req("offset")?.as_usize()?,
                        shape: e
                            .req("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<Vec<_>>>()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let grad_bls = v
                .req("grad_bls")?
                .as_arr()?
                .iter()
                .map(|bl| {
                    let bl = bl.as_arr()?;
                    Ok((bl[0].as_usize()?, bl[1].as_usize()?))
                })
                .collect::<Result<Vec<_>>>()?;
            variants.insert(
                name.clone(),
                Variant {
                    name: name.clone(),
                    encoder: v.req("encoder")?.as_str()?.to_string(),
                    res: v.req("res")?.as_usize()?,
                    in_ch: v.req("in_ch")?.as_usize()?,
                    hidden: v.req("hidden")?.as_usize()?,
                    num_actions: v.req("num_actions")?.as_usize()?,
                    goal_dim: v.req("goal_dim")?.as_usize()?,
                    num_params: v.req("num_params")?.as_usize()?,
                    infer_ns: v
                        .req("infer_ns")?
                        .as_arr()?
                        .iter()
                        .map(|x| x.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                    grad_bls,
                    files,
                    layout,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            variants,
        })
    }

    pub fn variant(&self, name: &str) -> Result<&Variant> {
        self.variants.get(name).ok_or_else(|| {
            anyhow!(
                "variant {name:?} not in manifest (have: {:?}); \
                 run: cd python && python -m compile.aot --out-dir ../artifacts --presets {name}",
                self.variants.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn artifact_path(&self, variant: &Variant, kind: &str) -> Result<PathBuf> {
        Ok(self.dir.join(variant.file(kind)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Manifest checks against the real exported artifacts when present
    /// (integration tests cover execution; this validates parsing).
    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn parse_real_manifest_if_present() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts dir (run `make artifacts`)");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let v = m.variant("test").unwrap();
        assert_eq!(v.res, 32);
        assert_eq!(v.in_ch, 1);
        assert_eq!(v.num_actions, 4);
        assert!(v.num_params > 10_000);
        // layout is contiguous and sums to num_params
        let mut off = 0;
        for e in &v.layout {
            assert_eq!(e.offset, off, "{}", e.name);
            off += e.size();
        }
        assert_eq!(off, v.num_params);
        assert!(m.artifact_path(v, "init").unwrap().exists());
        assert!(v.file("nonexistent").is_err());
    }

    #[test]
    fn best_infer_n_picks_fit() {
        let v = Variant {
            name: "x".into(),
            encoder: "se9".into(),
            res: 64,
            in_ch: 1,
            hidden: 256,
            num_actions: 4,
            goal_dim: 3,
            num_params: 1,
            infer_ns: vec![4, 64, 256],
            grad_bls: vec![],
            files: BTreeMap::new(),
            layout: vec![],
        };
        assert_eq!(v.best_infer_n(300), Some(256));
        assert_eq!(v.best_infer_n(64), Some(64));
        assert_eq!(v.best_infer_n(65), Some(64));
        assert_eq!(v.best_infer_n(2), Some(4)); // smallest available
    }
}
