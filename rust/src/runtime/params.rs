//! Flat parameter + optimizer-state store with binary checkpointing.
//!
//! Everything the learner owns lives in four flat buffers (params, m, v,
//! step) — the contract that lets the Rust side checkpoint, average
//! gradients across DD-PPO shards, and call the update artifact without
//! knowing anything about the network (DESIGN.md §2).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::client::{lit_scalar_i32, to_f32, Exec};

/// Parameter vector + Adam/Lamb moments + step counter.
pub struct ParamStore {
    pub flat: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: f32,
}

impl ParamStore {
    /// Initialize by running the `init` artifact (Fixup init in JAX).
    pub fn init(init_exec: &Exec, num_params: usize, seed: i32) -> Result<ParamStore> {
        let out = init_exec.run(&[lit_scalar_i32(seed)])?;
        let flat = to_f32(&out[0])?;
        if flat.len() != num_params {
            bail!(
                "init artifact returned {} params, manifest says {num_params}",
                flat.len()
            );
        }
        Ok(ParamStore {
            flat,
            m: vec![0.0; num_params],
            v: vec![0.0; num_params],
            step: 0.0,
        })
    }

    pub fn num_params(&self) -> usize {
        self.flat.len()
    }

    /// Save a checkpoint (params + optimizer state).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
        );
        w.write_all(b"BPSCKPT1")?;
        w.write_all(&(self.flat.len() as u64).to_le_bytes())?;
        w.write_all(&self.step.to_le_bytes())?;
        for buf in [&self.flat, &self.m, &self.v] {
            // SAFETY: a `[f32]` reinterpreted as bytes — same allocation,
            // same length in bytes (len * 4), f32 has no padding or
            // invalid bit patterns, and the shared borrow of `buf` keeps
            // the storage alive for the duration of `bytes`.
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(buf.as_ptr() as *const u8, buf.len() * 4)
            };
            w.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ParamStore> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != b"BPSCKPT1" {
            bail!("{path:?}: not a BPS checkpoint");
        }
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        let step = f32::from_le_bytes(b4);
        let mut read_vec = |n: usize| -> Result<Vec<f32>> {
            let mut v = vec![0.0f32; n];
            // SAFETY: the byte view aliases `v`'s own storage exclusively
            // (fresh `&mut`), covers exactly its n * 4 bytes, and any bit
            // pattern read into it is a valid f32 — little-endian on-disk
            // layout matches the in-memory layout written by `save`.
            let bytes: &mut [u8] = unsafe {
                std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut u8, n * 4)
            };
            r.read_exact(bytes)?;
            Ok(v)
        };
        Ok(ParamStore {
            flat: read_vec(n)?,
            m: read_vec(n)?,
            v: read_vec(n)?,
            step,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip() {
        let ps = ParamStore {
            flat: (0..100).map(|i| i as f32 * 0.5).collect(),
            m: vec![0.25; 100],
            v: vec![0.125; 100],
            step: 42.0,
        };
        let dir = std::env::temp_dir().join("bps_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        ps.save(&path).unwrap();
        let back = ParamStore::load(&path).unwrap();
        assert_eq!(back.flat, ps.flat);
        assert_eq!(back.m, ps.m);
        assert_eq!(back.v, ps.v);
        assert_eq!(back.step, 42.0);
    }

    #[test]
    fn rejects_wrong_magic() {
        let dir = std::env::temp_dir().join("bps_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPT").unwrap();
        assert!(ParamStore::load(&path).is_err());
    }
}
