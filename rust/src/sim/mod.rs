//! Batch environment simulator (paper §3.1): episodes and tasks
//! (PointGoalNav, Flee, Explore), rewards/SPL/success accounting, the
//! GPS+compass sensor, and the dynamically scheduled batch stepper.

pub mod batch;
pub mod episode;

pub use batch::{
    BatchSim, SimConfig, SimOutputs, ACTION_FORWARD, ACTION_LEFT, ACTION_RIGHT,
    ACTION_STOP, NUM_ACTIONS,
};
pub use episode::{sample_episode, Episode, Task};
