//! The CPU batch simulator (paper §3.1).
//!
//! Executes geodesic-distance and navmesh computations for a large batch of
//! environments in parallel: the batch contains significantly more
//! environments than CPU cores and work is dynamically scheduled onto the
//! worker pool; results land in a designated per-environment slot of a
//! results buffer, handed to the renderer as one batched request.
//!
//! Per-episode Dijkstra distance fields make the per-step geodesic query
//! O(1); the flood itself (the expensive part) runs inside the dynamically
//! scheduled per-env reset, which is exactly the variable-cost workload the
//! paper's scheduling design targets.

use std::cell::UnsafeCell;
use std::sync::Arc;

use crate::geom::vec::{v2, Vec2};
use crate::navmesh::DistField;
use crate::scene::SceneAsset;
use crate::util::pool::WorkerPool;
use crate::util::rng::Rng;

use super::episode::{sample_episode, Episode, Task};

/// Simulator parameters (paper Appendix B: Habitat PointGoalNav actions).
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    pub task: Task,
    pub forward_step: f32,
    pub turn_rad: f32,
    pub max_steps: u32,
    pub success_dist: f32,
    pub slack_reward: f32,
    pub success_reward: f32,
    /// Explore task: edge length of visitation cells (meters).
    pub explore_cell: f32,
    /// Episode difficulty floor: minimum start→goal geodesic distance in
    /// meters (PointNav). Scenario specs raise it per difficulty stage;
    /// the sampler relaxes it when a scene cannot host it.
    pub min_geodesic: f32,
}

impl SimConfig {
    pub fn pointnav() -> SimConfig {
        SimConfig {
            task: Task::PointNav,
            forward_step: 0.25,
            turn_rad: 10.0f32.to_radians(),
            max_steps: 500,
            success_dist: 0.2,
            slack_reward: -0.01,
            success_reward: 2.5,
            explore_cell: 0.5,
            min_geodesic: 1.0,
        }
    }

    pub fn for_task(task: Task) -> SimConfig {
        SimConfig {
            task,
            ..SimConfig::pointnav()
        }
    }
}

/// Discrete action space (paper Appendix B).
pub const ACTION_STOP: u8 = 0;
pub const ACTION_FORWARD: u8 = 1;
pub const ACTION_LEFT: u8 = 2;
pub const ACTION_RIGHT: u8 = 3;
pub const NUM_ACTIONS: usize = 4;

/// Per-environment simulation state.
pub struct EnvState {
    pub scene: Arc<SceneAsset>,
    pub episode: Episode,
    pub pos: Vec2,
    pub heading: f32,
    pub steps: u32,
    pub path_len: f32,
    prev_dist: f32,
    dist_field: Option<DistField>,
    visited: Vec<bool>,
    visited_count: u32,
    visited_w: usize,
    rng: Rng,
    /// Set by the coordinator when the asset streamer has a new scene for
    /// this env; swapped in on the next episode reset (paper §3.2).
    pending_scene: Option<Arc<SceneAsset>>,
}

/// Per-step outputs, struct-of-arrays (the batched results buffer).
#[derive(Clone, Debug, Default)]
pub struct SimOutputs {
    pub rewards: Vec<f32>,
    pub dones: Vec<bool>,
    pub successes: Vec<bool>,
    /// SPL for episodes that ended this step (0 when not done / failed).
    pub spl: Vec<f32>,
    /// Task score for episodes that ended (flee: meters; explore: cells).
    pub scores: Vec<f32>,
    /// GPS+compass sensor: [dist/10, cos, sin] per env.
    pub goal_sensor: Vec<f32>,
}

impl SimOutputs {
    pub fn with_capacity(n: usize) -> SimOutputs {
        SimOutputs {
            rewards: vec![0.0; n],
            dones: vec![false; n],
            successes: vec![false; n],
            spl: vec![0.0; n],
            scores: vec![0.0; n],
            goal_sensor: vec![0.0; n * 3],
        }
    }
}

/// Interior-mutability wrapper: `parallel_for` touches disjoint indices.
struct EnvSlots(Vec<UnsafeCell<EnvState>>);

// SAFETY: each index is accessed by exactly one worker per batch step.
unsafe impl Sync for EnvSlots {}

/// The batch simulator: N environments stepped as one request.
pub struct BatchSim {
    pub cfg: SimConfig,
    envs: EnvSlots,
}

impl BatchSim {
    /// Build N environments over the given scene assignment (env -> asset).
    pub fn new(cfg: SimConfig, scenes: Vec<Arc<SceneAsset>>, seed: u64) -> BatchSim {
        let mut root = Rng::new(seed);
        let envs = scenes
            .into_iter()
            .enumerate()
            .map(|(i, scene)| {
                let mut rng = root.split(i as u64);
                let mut env = EnvState {
                    scene,
                    episode: Episode {
                        start: v2(0.0, 0.0),
                        start_heading: 0.0,
                        goal: v2(0.0, 0.0),
                        geodesic_dist: 0.0,
                    },
                    pos: v2(0.0, 0.0),
                    heading: 0.0,
                    steps: 0,
                    path_len: 0.0,
                    prev_dist: 0.0,
                    dist_field: None,
                    visited: Vec::new(),
                    visited_count: 0,
                    visited_w: 0,
                    rng: rng.split(0xE0),
                    pending_scene: None,
                };
                reset_env(&cfg, &mut env);
                UnsafeCell::new(env)
            })
            .collect();
        BatchSim {
            cfg,
            envs: EnvSlots(envs),
        }
    }

    pub fn num_envs(&self) -> usize {
        self.envs.0.len()
    }

    /// Queue a scene swap for env `i` (applied at its next episode reset) —
    /// the simulator half of the renderer's asset rotation (paper §3.2).
    pub fn queue_scene(&mut self, i: usize, scene: Arc<SceneAsset>) {
        // SAFETY: `&mut self` gives exclusive access to every env cell —
        // no `step_batch` (also `&mut self`) can be running concurrently.
        unsafe { (*self.envs.0[i].get()).pending_scene = Some(scene) };
    }

    pub fn env(&self, i: usize) -> &EnvState {
        // SAFETY: all mutation goes through `&mut self` methods
        // (`step_batch`, `queue_scene`), so under this `&self` borrow no
        // writer can exist; used by tests/metrics between steps.
        unsafe { &*self.envs.0[i].get() }
    }

    /// Current camera poses (pos, heading) for the renderer.
    pub fn poses(&self) -> Vec<(Vec2, f32)> {
        (0..self.num_envs())
            .map(|i| {
                let e = self.env(i);
                (e.pos, e.heading)
            })
            .collect()
    }

    /// Scene reference per env (renderer needs the asset, not the id).
    pub fn scene_of(&self, i: usize) -> Arc<SceneAsset> {
        Arc::clone(&self.env(i).scene)
    }

    /// Step the whole batch: `actions[i]` for env `i`, results into `out`.
    /// Dynamically scheduled over `pool` (paper §3.1). Episodes that end
    /// auto-reset; `dones[i]` marks the boundary for the rollout buffer.
    pub fn step_batch(&mut self, pool: &WorkerPool, actions: &[u8], out: &mut SimOutputs) {
        let n = self.num_envs();
        assert_eq!(actions.len(), n);
        assert_eq!(out.rewards.len(), n);
        let cfg = self.cfg;
        let envs = &self.envs;
        let outs = OutSlots {
            rewards: out.rewards.as_mut_ptr() as usize,
            dones: out.dones.as_mut_ptr() as usize,
            successes: out.successes.as_mut_ptr() as usize,
            spl: out.spl.as_mut_ptr() as usize,
            scores: out.scores.as_mut_ptr() as usize,
            goal: out.goal_sensor.as_mut_ptr() as usize,
        };
        pool.parallel_for(n, 8, |i| {
            // SAFETY: index-disjoint writes (one env per slot).
            let env = unsafe { &mut *envs.0[i].get() };
            let (reward, done, success, spl, score) = step_env(&cfg, env, actions[i]);
            // SAFETY: same index-disjointness as the env cell above —
            // worker i writes only offset i (and the i*3 goal triple) of
            // each output buffer, whose `&mut` borrows outlive this
            // `parallel_for` (the pool joins before `step_batch` returns).
            unsafe {
                *(outs.rewards as *mut f32).add(i) = reward;
                *(outs.dones as *mut bool).add(i) = done;
                *(outs.successes as *mut bool).add(i) = success;
                *(outs.spl as *mut f32).add(i) = spl;
                *(outs.scores as *mut f32).add(i) = score;
                let g = (outs.goal as *mut f32).add(i * 3);
                let sensor = goal_sensor(&cfg, env);
                *g = sensor[0];
                *g.add(1) = sensor[1];
                *g.add(2) = sensor[2];
            }
        });
    }

    /// Fill the goal sensor for the *current* state (used for the very
    /// first observation of a rollout, before any action).
    pub fn fill_goal_sensor(&self, out: &mut [f32]) {
        for i in 0..self.num_envs() {
            let s = goal_sensor(&self.cfg, self.env(i));
            out[i * 3..i * 3 + 3].copy_from_slice(&s);
        }
    }
}

#[derive(Clone, Copy)]
struct OutSlots {
    rewards: usize,
    dones: usize,
    successes: usize,
    spl: usize,
    scores: usize,
    goal: usize,
}

/// GPS+compass: geodesic-free relative goal vector in the agent frame
/// (paper Appendix B), distance scaled by 1/10 for network conditioning.
fn goal_sensor(cfg: &SimConfig, env: &EnvState) -> [f32; 3] {
    match cfg.task {
        Task::PointNav => {
            let rel = env.episode.goal - env.pos;
            let dist = rel.length();
            let angle = rel.y.atan2(rel.x) - env.heading;
            [dist / 10.0, angle.cos(), angle.sin()]
        }
        // Flee/Explore agents get no goal: zero sensor (same policy arch).
        Task::Flee | Task::Explore => [0.0, 0.0, 0.0],
    }
}

fn current_dist(env: &EnvState) -> f32 {
    match &env.dist_field {
        Some(f) => env.scene.navmesh.field_dist(f, env.pos),
        None => 0.0,
    }
}

fn reset_env(cfg: &SimConfig, env: &mut EnvState) {
    if let Some(next) = env.pending_scene.take() {
        env.scene = next;
    }
    let nav = &env.scene.navmesh;
    let episode = sample_episode(nav, &mut env.rng, cfg.task, cfg.min_geodesic)
        .expect("scene has no valid episodes (navmesh too small)");
    // Dijkstra flood once per episode: PointNav floods from the goal
    // (reward shaping + success), Flee floods from the start (score).
    let field_src = match cfg.task {
        Task::PointNav => episode.goal,
        Task::Flee | Task::Explore => episode.start,
    };
    env.dist_field = nav.dist_field(field_src);
    env.pos = episode.start;
    env.heading = episode.start_heading;
    env.steps = 0;
    env.path_len = 0.0;
    env.episode = episode;
    env.prev_dist = current_dist(env);
    if cfg.task == Task::Explore {
        let w = ((nav.w as f32 * nav.cell) / cfg.explore_cell).ceil() as usize;
        let h = ((nav.h as f32 * nav.cell) / cfg.explore_cell).ceil() as usize;
        env.visited = vec![false; w.max(1) * h.max(1)];
        env.visited_w = w.max(1);
        env.visited_count = 0;
        mark_visited(cfg, env);
    }
}

fn mark_visited(cfg: &SimConfig, env: &mut EnvState) -> u32 {
    let nav = &env.scene.navmesh;
    let x = (((env.pos.x - nav.origin.x) / cfg.explore_cell) as usize).min(env.visited_w - 1);
    let y = ((env.pos.y - nav.origin.y) / cfg.explore_cell) as usize;
    let idx = y * env.visited_w + x;
    if idx < env.visited.len() && !env.visited[idx] {
        env.visited[idx] = true;
        env.visited_count += 1;
        1
    } else {
        0
    }
}

/// Advance one environment by one action. Returns
/// `(reward, done, success, spl, score)` and auto-resets on episode end.
fn step_env(cfg: &SimConfig, env: &mut EnvState, action: u8) -> (f32, bool, bool, f32, f32) {
    env.steps += 1;
    let mut done = false;
    let mut success = false;
    let mut reward = cfg.slack_reward;

    match action {
        ACTION_FORWARD => {
            let dir = v2(env.heading.cos(), env.heading.sin());
            let before = env.pos;
            env.pos = env
                .scene
                .navmesh
                .move_agent(env.pos, dir * cfg.forward_step);
            env.path_len += (env.pos - before).length();
        }
        ACTION_LEFT => env.heading += cfg.turn_rad,
        ACTION_RIGHT => env.heading -= cfg.turn_rad,
        ACTION_STOP => {
            if cfg.task == Task::PointNav {
                done = true;
                // success requires calling stop within the radius (§B)
                success = (env.episode.goal - env.pos).length() <= cfg.success_dist;
            }
        }
        _ => {}
    }

    let new_dist = current_dist(env);
    match cfg.task {
        Task::PointNav => {
            // dense shaping: progress along the geodesic to the goal
            reward += env.prev_dist - new_dist;
            if success {
                reward += cfg.success_reward;
            }
        }
        Task::Flee => {
            reward += new_dist - env.prev_dist;
        }
        Task::Explore => {
            reward += 0.25 * mark_visited(cfg, env) as f32;
        }
    }
    env.prev_dist = new_dist;

    if env.steps >= cfg.max_steps {
        done = true;
    }

    let (mut spl, mut score) = (0.0, 0.0);
    if done {
        match cfg.task {
            Task::PointNav => {
                if success {
                    let short = env.episode.geodesic_dist;
                    spl = short / short.max(env.path_len).max(1e-6);
                }
                score = if success { 1.0 } else { 0.0 };
            }
            Task::Flee => score = new_dist,
            Task::Explore => score = env.visited_count as f32,
        }
        reset_env(cfg, env);
    }
    (reward, done, success, spl, score)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::procgen::{generate, Complexity};
    use crate::util::prop;

    fn scene() -> Arc<SceneAsset> {
        Arc::new(generate("sim", 31, Complexity::test()))
    }

    fn sim_n(n: usize, task: Task) -> BatchSim {
        let s = scene();
        BatchSim::new(
            SimConfig::for_task(task),
            (0..n).map(|_| Arc::clone(&s)).collect(),
            7,
        )
    }

    #[test]
    fn forward_moves_turn_rotates() {
        let mut sim = sim_n(1, Task::PointNav);
        let pool = WorkerPool::new(0);
        let mut out = SimOutputs::with_capacity(1);
        let p0 = sim.env(0).pos;
        let h0 = sim.env(0).heading;
        sim.step_batch(&pool, &[ACTION_FORWARD], &mut out);
        let moved = (sim.env(0).pos - p0).length();
        assert!(moved <= 0.25 + 1e-5);
        sim.step_batch(&pool, &[ACTION_LEFT], &mut out);
        assert!((sim.env(0).heading - h0 - 10f32.to_radians()).abs() < 1e-5);
    }

    #[test]
    fn stop_far_from_goal_fails() {
        let mut sim = sim_n(4, Task::PointNav);
        let pool = WorkerPool::new(2);
        let mut out = SimOutputs::with_capacity(4);
        // episodes start >= 1m from goal, so immediate stop must fail
        sim.step_batch(&pool, &[ACTION_STOP; 4], &mut out);
        for i in 0..4 {
            assert!(out.dones[i]);
            assert!(!out.successes[i]);
            assert_eq!(out.spl[i], 0.0);
        }
    }

    #[test]
    fn reaching_goal_and_stopping_succeeds() {
        let mut sim = sim_n(1, Task::PointNav);
        let pool = WorkerPool::new(0);
        let mut out = SimOutputs::with_capacity(1);
        // drive the agent greedily along the goal direction via teleport-
        // free actions: pick turn/forward by the goal sensor each step.
        let mut reward_sum = 0.0;
        for _ in 0..2000 {
            let e = sim.env(0);
            let rel = e.episode.goal - e.pos;
            if rel.length() <= 0.15 {
                sim.step_batch(&pool, &[ACTION_STOP], &mut out);
                reward_sum += out.rewards[0];
                assert!(out.dones[0]);
                assert!(out.successes[0], "stop at dist {}", rel.length());
                assert!(out.spl[0] > 0.0 && out.spl[0] <= 1.0 + 1e-5);
                assert!(reward_sum > 1.0, "shaped+success reward {reward_sum}");
                return;
            }
            let angle = rel.y.atan2(rel.x);
            let mut diff = angle - e.heading;
            while diff > std::f32::consts::PI {
                diff -= std::f32::consts::TAU;
            }
            while diff < -std::f32::consts::PI {
                diff += std::f32::consts::TAU;
            }
            let act = if diff.abs() > 0.12 {
                if diff > 0.0 {
                    ACTION_LEFT
                } else {
                    ACTION_RIGHT
                }
            } else {
                ACTION_FORWARD
            };
            sim.step_batch(&pool, &[act], &mut out);
            reward_sum += out.rewards[0];
            if out.dones[0] {
                // greedy can wall-follow into timeout in twisty scenes;
                // accept only successful terminations here
                assert!(out.successes[0] || sim.env(0).steps == 0);
                return;
            }
        }
        panic!("never reached goal");
    }

    #[test]
    fn max_steps_terminates() {
        let mut sim = sim_n(2, Task::PointNav);
        sim.cfg.max_steps = 5;
        let pool = WorkerPool::new(0);
        let mut out = SimOutputs::with_capacity(2);
        for step in 0..5 {
            sim.step_batch(&pool, &[ACTION_LEFT, ACTION_RIGHT], &mut out);
            assert_eq!(out.dones[0], step == 4);
        }
        // auto-reset happened
        assert_eq!(sim.env(0).steps, 0);
    }

    #[test]
    fn flee_rewards_distance_gain() {
        let mut sim = sim_n(1, Task::Flee);
        let pool = WorkerPool::new(0);
        let mut out = SimOutputs::with_capacity(1);
        let mut total = 0.0;
        for _ in 0..50 {
            sim.step_batch(&pool, &[ACTION_FORWARD], &mut out);
            total += out.rewards[0];
        }
        // walking away from start yields positive shaped reward overall
        let dist_now = sim
            .env(0)
            .scene
            .navmesh
            .geodesic(sim.env(0).episode.start, sim.env(0).pos)
            .unwrap_or(0.0);
        assert!(
            (total - (dist_now + 50.0 * sim.cfg.slack_reward)).abs() < 0.5,
            "total {total} vs dist {dist_now}"
        );
    }

    #[test]
    fn explore_counts_new_cells() {
        let mut sim = sim_n(1, Task::Explore);
        let pool = WorkerPool::new(0);
        let mut out = SimOutputs::with_capacity(1);
        let before = sim.env(0).visited_count;
        assert!(before >= 1); // start cell marked
        for _ in 0..40 {
            sim.step_batch(&pool, &[ACTION_FORWARD], &mut out);
        }
        assert!(sim.env(0).visited_count > before);
    }

    #[test]
    fn goal_sensor_points_at_goal() {
        let sim = sim_n(1, Task::PointNav);
        let mut buf = vec![0.0f32; 3];
        sim.fill_goal_sensor(&mut buf);
        let e = sim.env(0);
        let rel = e.episode.goal - e.pos;
        assert!((buf[0] * 10.0 - rel.length()).abs() < 1e-4);
        // cos^2 + sin^2 == 1
        assert!((buf[1] * buf[1] + buf[2] * buf[2] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn batch_step_parallel_matches_serial_property() {
        prop::check("sim_parallel_deterministic", 5, |rng| {
            let s = scene();
            let n = 16;
            let seed = rng.next_u64();
            let mk = || {
                BatchSim::new(
                    SimConfig::pointnav(),
                    (0..n).map(|_| Arc::clone(&s)).collect(),
                    seed,
                )
            };
            let mut a = mk();
            let mut b = mk();
            let pool0 = WorkerPool::new(0);
            let pool4 = WorkerPool::new(4);
            let mut oa = SimOutputs::with_capacity(n);
            let mut ob = SimOutputs::with_capacity(n);
            for step in 0..30 {
                let actions: Vec<u8> =
                    (0..n).map(|i| ((step + i) % 4) as u8).collect();
                a.step_batch(&pool0, &actions, &mut oa);
                b.step_batch(&pool4, &actions, &mut ob);
                assert_eq!(oa.rewards, ob.rewards);
                assert_eq!(oa.dones, ob.dones);
                assert_eq!(oa.goal_sensor, ob.goal_sensor);
            }
        });
    }

    #[test]
    fn agent_never_leaves_navmesh_property() {
        prop::check("sim_agent_on_navmesh", 10, |rng| {
            let s = scene();
            let mut sim = BatchSim::new(
                SimConfig::pointnav(),
                vec![Arc::clone(&s)],
                rng.next_u64(),
            );
            let pool = WorkerPool::new(0);
            let mut out = SimOutputs::with_capacity(1);
            for _ in 0..100 {
                let act = (rng.below(3) + 1) as u8; // forward/left/right
                sim.step_batch(&pool, &[act], &mut out);
                assert!(s.navmesh.is_walkable(sim.env(0).pos));
            }
        });
    }

    #[test]
    fn scene_swap_applies_on_reset() {
        let s1 = scene();
        let s2 = Arc::new(generate("sim2", 99, Complexity::test()));
        let mut sim = BatchSim::new(SimConfig::pointnav(), vec![Arc::clone(&s1)], 3);
        sim.cfg.max_steps = 2;
        sim.queue_scene(0, Arc::clone(&s2));
        assert_eq!(sim.env(0).scene.id, "sim");
        let pool = WorkerPool::new(0);
        let mut out = SimOutputs::with_capacity(1);
        sim.step_batch(&pool, &[ACTION_LEFT], &mut out);
        sim.step_batch(&pool, &[ACTION_LEFT], &mut out);
        assert!(out.dones[0]);
        assert_eq!(sim.env(0).scene.id, "sim2");
    }
}
