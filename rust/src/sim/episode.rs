//! Episode specification and sampling for the three tasks the paper's
//! system supports: PointGoalNav (§4.1) plus Flee and Explore (Appendix A.1).

use crate::geom::vec::Vec2;
use crate::navmesh::GridNav;
use crate::util::rng::Rng;

/// Which task the agents are being trained for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Navigate to a point given relative to the start (GPS+compass).
    PointNav,
    /// Find the farthest valid location from the start point.
    Flee,
    /// Visit as much of the navigable area as possible.
    Explore,
}

impl Task {
    pub fn parse(s: &str) -> Option<Task> {
        match s {
            "pointnav" => Some(Task::PointNav),
            "flee" => Some(Task::Flee),
            "explore" => Some(Task::Explore),
            _ => None,
        }
    }

    /// The lowercase CLI/spec name — the exact inverse of
    /// [`parse`](Task::parse), so printed specs re-parse.
    pub fn name(&self) -> &'static str {
        match self {
            Task::PointNav => "pointnav",
            Task::Flee => "flee",
            Task::Explore => "explore",
        }
    }
}

/// One episode: start pose, goal, and the shortest-path length (for reward
/// shaping and SPL).
#[derive(Clone, Debug)]
pub struct Episode {
    pub start: Vec2,
    pub start_heading: f32,
    pub goal: Vec2,
    pub geodesic_dist: f32,
}

/// Episode difficulty filter, Habitat-style: geodesic distance within
/// bounds, and (when possible) a non-trivial geodesic/euclidean ratio so
/// straight-line policies do not solve everything. `min_geodesic` is the
/// episode-difficulty floor (meters); scenario specs raise it to demand
/// longer paths (`SimConfig::min_geodesic`). Scenes whose navmesh cannot
/// host it degrade gracefully: after half the attempts the floor relaxes
/// toward the baseline so generation never livelocks on a small layout.
pub fn sample_episode(
    nav: &GridNav,
    rng: &mut Rng,
    task: Task,
    min_geodesic: f32,
) -> Option<Episode> {
    let base_min = 1.0f32;
    for attempt in 0..64 {
        // relax a too-ambitious difficulty floor once half the attempts
        // have failed, bottoming out at the baseline
        let min_d = if attempt < 32 {
            min_geodesic.max(base_min)
        } else {
            base_min
        };
        let start = nav.random_point(rng)?;
        let heading = rng.range_f32(0.0, std::f32::consts::TAU);
        match task {
            Task::PointNav => {
                let goal = nav.random_point(rng)?;
                let euclid = (goal - start).length();
                if euclid < base_min {
                    continue;
                }
                let Some(geo) = nav.geodesic(start, goal) else {
                    continue;
                };
                if !geo.is_finite() || geo < min_d {
                    continue;
                }
                // prefer non-straight-line episodes early in the attempts
                if attempt < 32 && geo / euclid.max(1e-6) < 1.05 {
                    continue;
                }
                return Some(Episode {
                    start,
                    start_heading: heading,
                    goal,
                    geodesic_dist: geo,
                });
            }
            Task::Flee | Task::Explore => {
                // goal is unused; keep start as the reference point
                return Some(Episode {
                    start,
                    start_heading: heading,
                    goal: start,
                    geodesic_dist: 0.0,
                });
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::procgen::{generate, Complexity};

    #[test]
    fn pointnav_episode_valid() {
        let scene = generate("e", 21, Complexity::test());
        let mut rng = Rng::new(0);
        for _ in 0..20 {
            let ep = sample_episode(&scene.navmesh, &mut rng, Task::PointNav, 1.0).unwrap();
            assert!(scene.navmesh.is_walkable(ep.start));
            assert!(scene.navmesh.is_walkable(ep.goal));
            assert!(ep.geodesic_dist >= 1.0);
            assert!(ep.geodesic_dist.is_finite());
            // geodesic >= euclidean (up to grid snap)
            let euclid = (ep.goal - ep.start).length();
            assert!(ep.geodesic_dist >= euclid - 0.4);
        }
    }

    #[test]
    fn flee_episode_goal_is_start() {
        let scene = generate("f", 22, Complexity::test());
        let mut rng = Rng::new(0);
        let ep = sample_episode(&scene.navmesh, &mut rng, Task::Flee, 1.0).unwrap();
        assert_eq!(ep.goal, ep.start);
    }

    #[test]
    fn min_geodesic_raises_difficulty() {
        let scene = generate("g", 23, Complexity::test());
        let mut rng = Rng::new(4);
        let mut raised = 0usize;
        for _ in 0..20 {
            let ep = sample_episode(&scene.navmesh, &mut rng, Task::PointNav, 3.0).unwrap();
            if ep.geodesic_dist >= 3.0 {
                raised += 1;
            }
        }
        // the floor may relax on a small navmesh, but most episodes honor it
        assert!(raised >= 15, "only {raised}/20 episodes above the floor");
    }

    #[test]
    fn unreachable_floor_relaxes_instead_of_failing() {
        // a 6m test scene cannot host a 50m geodesic; sampling must still
        // succeed by relaxing toward the baseline
        let scene = generate("r", 24, Complexity::test());
        let mut rng = Rng::new(9);
        let ep = sample_episode(&scene.navmesh, &mut rng, Task::PointNav, 50.0);
        assert!(ep.is_some(), "sampler livelocked on an unreachable floor");
    }

    #[test]
    fn task_parse() {
        assert_eq!(Task::parse("pointnav"), Some(Task::PointNav));
        assert_eq!(Task::parse("flee"), Some(Task::Flee));
        assert_eq!(Task::parse("explore"), Some(Task::Explore));
        assert_eq!(Task::parse("x"), None);
        // name() is the exact inverse of parse()
        for t in [Task::PointNav, Task::Flee, Task::Explore] {
            assert_eq!(Task::parse(t.name()), Some(t));
        }
    }
}
