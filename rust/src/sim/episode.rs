//! Episode specification and sampling for the three tasks the paper's
//! system supports: PointGoalNav (§4.1) plus Flee and Explore (Appendix A.1).

use crate::geom::vec::Vec2;
use crate::navmesh::GridNav;
use crate::util::rng::Rng;

/// Which task the agents are being trained for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Navigate to a point given relative to the start (GPS+compass).
    PointNav,
    /// Find the farthest valid location from the start point.
    Flee,
    /// Visit as much of the navigable area as possible.
    Explore,
}

impl Task {
    pub fn parse(s: &str) -> Option<Task> {
        match s {
            "pointnav" => Some(Task::PointNav),
            "flee" => Some(Task::Flee),
            "explore" => Some(Task::Explore),
            _ => None,
        }
    }
}

/// One episode: start pose, goal, and the shortest-path length (for reward
/// shaping and SPL).
#[derive(Clone, Debug)]
pub struct Episode {
    pub start: Vec2,
    pub start_heading: f32,
    pub goal: Vec2,
    pub geodesic_dist: f32,
}

/// Episode difficulty filter, Habitat-style: geodesic distance within
/// bounds, and (when possible) a non-trivial geodesic/euclidean ratio so
/// straight-line policies do not solve everything.
pub fn sample_episode(nav: &GridNav, rng: &mut Rng, task: Task) -> Option<Episode> {
    let min_d = 1.0f32;
    for attempt in 0..64 {
        let start = nav.random_point(rng)?;
        let heading = rng.range_f32(0.0, std::f32::consts::TAU);
        match task {
            Task::PointNav => {
                let goal = nav.random_point(rng)?;
                let euclid = (goal - start).length();
                if euclid < min_d {
                    continue;
                }
                let Some(geo) = nav.geodesic(start, goal) else {
                    continue;
                };
                if !geo.is_finite() || geo < min_d {
                    continue;
                }
                // prefer non-straight-line episodes early in the attempts
                if attempt < 32 && geo / euclid.max(1e-6) < 1.05 {
                    continue;
                }
                return Some(Episode {
                    start,
                    start_heading: heading,
                    goal,
                    geodesic_dist: geo,
                });
            }
            Task::Flee | Task::Explore => {
                // goal is unused; keep start as the reference point
                return Some(Episode {
                    start,
                    start_heading: heading,
                    goal: start,
                    geodesic_dist: 0.0,
                });
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::procgen::{generate, Complexity};

    #[test]
    fn pointnav_episode_valid() {
        let scene = generate("e", 21, Complexity::test());
        let mut rng = Rng::new(0);
        for _ in 0..20 {
            let ep = sample_episode(&scene.navmesh, &mut rng, Task::PointNav).unwrap();
            assert!(scene.navmesh.is_walkable(ep.start));
            assert!(scene.navmesh.is_walkable(ep.goal));
            assert!(ep.geodesic_dist >= 1.0);
            assert!(ep.geodesic_dist.is_finite());
            // geodesic >= euclidean (up to grid snap)
            let euclid = (ep.goal - ep.start).length();
            assert!(ep.geodesic_dist >= euclid - 0.4);
        }
    }

    #[test]
    fn flee_episode_goal_is_start() {
        let scene = generate("f", 22, Complexity::test());
        let mut rng = Rng::new(0);
        let ep = sample_episode(&scene.navmesh, &mut rng, Task::Flee).unwrap();
        assert_eq!(ep.goal, ep.start);
    }

    #[test]
    fn task_parse() {
        assert_eq!(Task::parse("pointnav"), Some(Task::PointNav));
        assert_eq!(Task::parse("flee"), Some(Task::Flee));
        assert_eq!(Task::parse("explore"), Some(Task::Explore));
        assert_eq!(Task::parse("x"), None);
    }
}
