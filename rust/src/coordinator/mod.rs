//! The end-to-end RL coordinator: rollout generation (batch sim → batch
//! render → batched inference), GAE, PPO training through the AOT
//! artifacts, DD-PPO multi-shard gradient averaging, scene rotation, and
//! evaluation. This is the paper's Fig. 2 loop.
//!
//! Two simulation architectures are selectable (Table 1):
//! `SimArch::Bps` shares K ≪ N scene assets across the batch and uses the
//! pipelined batch renderer; `SimArch::Workers` reproduces the prior-art
//! design — every environment owns a *private* copy of its scene asset
//! (deep-cloned, so memory pressure is real) and renders fused per-env,
//! which is what caps its env count at a given memory budget.

use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{Config, SimArch};
use crate::metrics::EpisodeStats;
use crate::optim::{scale_lr, Losses, LrSchedule, Trainer};
use crate::policy::Policy;
use crate::render::{BatchRenderer, RenderConfig, RenderItem, SceneRotation, Sensor};
use crate::rollout::Rollout;
use crate::runtime::{Exec, Manifest, ParamStore, Runtime, Variant};
use crate::scene::{Dataset, SceneAsset};
use crate::sim::{BatchSim, SimConfig, SimOutputs};
use crate::util::pool::WorkerPool;
use crate::util::timer::{FpsMeter, Profiler};

/// One DD-PPO shard ("GPU"): envs + renderer + policy state + rollout.
pub struct Shard {
    pub sim: BatchSim,
    pub renderer: BatchRenderer,
    pub rotation: Option<SceneRotation>,
    pub policy: Policy,
    pub rollout: Rollout,
    pub obs: Vec<f32>,
    pub goal: Vec<f32>,
    pub sim_out: SimOutputs,
    pub last_dones: Vec<bool>,
}

/// Per-iteration summary.
#[derive(Clone, Copy, Debug)]
pub struct IterStats {
    pub frames: u64,
    pub losses: Losses,
}

/// The training coordinator.
pub struct Coordinator {
    pub cfg: Config,
    pub variant: Variant,
    pub pool: WorkerPool,
    pub shards: Vec<Shard>,
    pub params: ParamStore,
    pub trainer: Trainer,
    pub prof: Profiler,
    pub stats: EpisodeStats,
    pub fps: FpsMeter,
    rt: Runtime,
    man: Manifest,
}

impl Coordinator {
    pub fn new(cfg: Config) -> Result<Coordinator> {
        cfg.validate()?;
        let man = Manifest::load(&cfg.artifacts_dir)?;
        let variant = man.variant(&cfg.variant)?.clone();
        let (b, l) = cfg.grad_bl();
        let grad_kind = format!("grad_b{b}l{l}");
        if variant.file(&grad_kind).is_err() {
            bail!(
                "variant {:?} lacks {grad_kind} (exported: {:?}); adjust \
                 --envs/--minibatches/--rollout-len or extend the preset",
                variant.name,
                variant.grad_bls
            );
        }
        let rt = Runtime::cpu()?;
        let init = rt.load(&man.artifact_path(&variant, "init")?)?;
        let params = ParamStore::init(&init, variant.num_params, cfg.seed as i32)?;
        let infer = Rc::new(rt.load(
            &man.artifact_path(&variant, &format!("infer_n{}", cfg.num_envs))?,
        )?);
        let grad = rt.load(&man.artifact_path(&variant, &grad_kind)?)?;
        let upd_kind = format!("update_{}", cfg.optimizer);
        let update = rt.load(&man.artifact_path(&variant, &upd_kind)?)?;

        let frames_per_iter = (cfg.num_envs * cfg.rollout_len * cfg.shards) as u64;
        let total_iters = (cfg.total_frames / frames_per_iter.max(1)).max(1);
        // LR scaling: sqrt(B/256), disabled for Adam (diverges — paper A.3).
        let scaled = if cfg.lr_scaling && cfg.optimizer == "lamb" {
            scale_lr(cfg.base_lr, cfg.train_batch() * cfg.shards, 256)
        } else {
            cfg.base_lr
        };
        let trainer = Trainer::new(
            grad,
            update,
            variant.num_params,
            cfg.num_minibatches,
            cfg.ppo_epochs,
            LrSchedule {
                base: cfg.base_lr,
                scaled,
                decay_iters: total_iters / 2,
            },
            cfg.gamma,
            cfg.gae_lambda,
            cfg.normalize_adv,
        );

        let threads = if cfg.threads == 0 {
            WorkerPool::default_size()
        } else {
            cfg.threads
        };
        let pool = WorkerPool::new(threads);

        let dataset = Dataset::open(&cfg.dataset_dir).with_context(|| {
            format!(
                "open dataset {:?} — generate with `bps gen-dataset --dir {}`",
                cfg.dataset_dir,
                cfg.dataset_dir.display()
            )
        })?;

        let mut shards = Vec::with_capacity(cfg.shards);
        for s in 0..cfg.shards {
            shards.push(build_shard(
                &cfg,
                &variant,
                Rc::clone(&infer),
                &dataset,
                s,
            )?);
        }
        check_memory_budget(&cfg, &shards)?;

        let stats = EpisodeStats::new(cfg.num_envs * cfg.shards, 256);
        Ok(Coordinator {
            cfg,
            variant,
            pool,
            shards,
            params,
            trainer,
            prof: Profiler::new(),
            stats,
            fps: FpsMeter::start(),
            rt,
            man,
        })
    }

    /// Collect one rollout on every shard, then run the PPO update with
    /// cross-shard gradient averaging. Returns frames processed.
    pub fn train_iteration(&mut self) -> Result<IterStats> {
        let l = self.cfg.rollout_len;
        for si in 0..self.shards.len() {
            {
                let shard = &mut self.shards[si];
                shard
                    .rollout
                    .begin(&shard.policy.h, &shard.policy.c, &shard.last_dones);
            }
            for t in 0..l {
                let shard = &mut self.shards[si];
                let step = {
                    let _s = self.prof.span("inference");
                    shard
                        .policy
                        .step(&self.params.flat, &shard.obs, &shard.goal)?
                };
                shard.rollout.record_step(
                    t,
                    &shard.obs,
                    &shard.goal,
                    &step.actions,
                    &step.logp,
                    &step.values,
                );
                {
                    let _s = self.prof.span("sim");
                    shard
                        .sim
                        .step_batch(&self.pool, &step.actions, &mut shard.sim_out);
                }
                shard
                    .rollout
                    .record_outcome(t, &shard.sim_out.rewards, &shard.sim_out.dones);
                self.stats.update(
                    &shard.sim_out.rewards,
                    &shard.sim_out.dones,
                    &shard.sim_out.successes,
                    &shard.sim_out.spl,
                    &shard.sim_out.scores,
                );
                shard.policy.reset_done(&shard.sim_out.dones);
                shard.last_dones.copy_from_slice(&shard.sim_out.dones);
                shard.goal.copy_from_slice(&shard.sim_out.goal_sensor);
                {
                    let _s = self.prof.span("render");
                    render_current(shard, &self.pool);
                }
            }
            // bootstrap + scene rotation
            let shard = &mut self.shards[si];
            shard.rollout.bootstrap = {
                let _s = self.prof.span("inference");
                shard
                    .policy
                    .values_only(&self.params.flat, &shard.obs, &shard.goal)?
            };
            if let Some(rot) = shard.rotation.as_mut() {
                rot.rotate(&mut shard.sim);
            }
        }
        // learning (DD-PPO gradient averaging across shards inside)
        let losses = {
            let _s = self.prof.span("learn");
            let mut rollouts: Vec<&mut Rollout> =
                self.shards.iter_mut().map(|s| &mut s.rollout).collect();
            self.trainer.train_refs(&mut self.params, &mut rollouts)?
        };
        let frames = (self.cfg.num_envs * l * self.shards.len()) as u64;
        self.fps.add_frames(frames);
        Ok(IterStats { frames, losses })
    }

    /// Paper-methodology FPS: frames / wall-time over rollout + training.
    pub fn fps(&self) -> f64 {
        self.fps.fps()
    }

    pub fn frames(&self) -> u64 {
        self.fps.frames()
    }

    /// Greedy evaluation on a dataset split. Returns (SPL, success, score)
    /// means over `episodes` completed episodes.
    pub fn evaluate(&mut self, split: &str, episodes: usize) -> Result<(f32, f32, f32)> {
        let dataset = Dataset::open(&self.cfg.dataset_dir)?;
        let ids = dataset.split(split)?.to_vec();
        if ids.is_empty() {
            bail!("split {split:?} is empty");
        }
        let n = self.cfg.num_envs;
        let with_tex = self.variant.in_ch == 3;
        let scenes: Vec<Arc<SceneAsset>> = (0..n)
            .map(|i| {
                dataset
                    .load_scene(&ids[i % ids.len()], with_tex)
                    .map(Arc::new)
            })
            .collect::<Result<_>>()?;
        let mut sim = BatchSim::new(
            SimConfig::for_task(self.cfg.task),
            scenes,
            self.cfg.seed ^ 0xEA51,
        );
        let rcfg = render_cfg(&self.cfg, &self.variant);
        let renderer = BatchRenderer::new(rcfg, n);
        let mut policy = Policy::with_exec(
            Rc::new(self.rt.load(&self.man.artifact_path(
                &self.variant,
                &format!("infer_n{n}"),
            )?)?),
            &self.variant,
            n,
            self.cfg.seed ^ 0x5EED,
        );
        let mut obs = vec![0.0f32; n * rcfg.obs_floats()];
        let mut goal = vec![0.0f32; n * 3];
        let mut out = SimOutputs::with_capacity(n);
        sim.fill_goal_sensor(&mut goal);
        render_sim(&sim, &renderer, &self.pool, &mut obs);
        let (mut spl_sum, mut succ_sum, mut score_sum, mut count) =
            (0.0f32, 0.0f32, 0.0f32, 0usize);
        let max_steps = episodes * 600 / n + 600;
        for _ in 0..max_steps {
            let actions = policy.step_greedy(&self.params.flat, &obs, &goal)?;
            sim.step_batch(&self.pool, &actions, &mut out);
            policy.reset_done(&out.dones);
            goal.copy_from_slice(&out.goal_sensor);
            render_sim(&sim, &renderer, &self.pool, &mut obs);
            for i in 0..n {
                if out.dones[i] {
                    count += 1;
                    spl_sum += out.spl[i];
                    succ_sum += if out.successes[i] { 1.0 } else { 0.0 };
                    score_sum += out.scores[i];
                }
            }
            if count >= episodes {
                break;
            }
        }
        let c = count.max(1) as f32;
        Ok((spl_sum / c, succ_sum / c, score_sum / c))
    }
}

/// Build one shard (scene assignment differs per arch — see module docs).
fn build_shard(
    cfg: &Config,
    variant: &Variant,
    infer: Rc<Exec>,
    dataset: &Dataset,
    shard_idx: usize,
) -> Result<Shard> {
    let n = cfg.num_envs;
    let with_tex = variant.in_ch == 3;
    // rotate the train split so shards see different scenes
    let mut ids = dataset.train.clone();
    if ids.is_empty() {
        bail!("dataset has no train scenes");
    }
    let shift = (shard_idx * cfg.k_scenes) % ids.len();
    ids.rotate_left(shift);

    let (scenes, rotation): (Vec<Arc<SceneAsset>>, Option<SceneRotation>) = match cfg.arch {
        SimArch::Bps => {
            let rot = SceneRotation::new(dataset.clone(), ids, cfg.k_scenes, with_tex)?;
            (rot.assign(n), Some(rot))
        }
        SimArch::Workers => {
            // No sharing: every env deep-loads its own copy (real memory).
            let mut scenes = Vec::with_capacity(n);
            for i in 0..n {
                let base = dataset.load_scene(&ids[i % ids.len()], with_tex)?;
                scenes.push(Arc::new(base));
            }
            (scenes, None)
        }
    };

    let sim = BatchSim::new(
        SimConfig::for_task(cfg.task),
        scenes,
        cfg.seed.wrapping_add(shard_idx as u64 * 7919),
    );
    let rcfg = render_cfg(cfg, variant);
    let renderer = BatchRenderer::new(rcfg, n);
    let policy = Policy::with_exec(
        infer,
        variant,
        n,
        cfg.seed.wrapping_add(0xAC + shard_idx as u64),
    );
    let rollout = Rollout::new(n, cfg.rollout_len, rcfg.obs_floats(), variant.hidden);
    let mut shard = Shard {
        sim,
        renderer,
        rotation,
        policy,
        rollout,
        obs: vec![0.0; n * rcfg.obs_floats()],
        goal: vec![0.0; n * 3],
        sim_out: SimOutputs::with_capacity(n),
        last_dones: vec![true; n], // first obs of each env starts an episode
    };
    shard.sim.fill_goal_sensor(&mut shard.goal);
    // initial observations (rendered once; subsequent renders follow steps)
    let pool = WorkerPool::new(0);
    render_current(&mut shard, &pool);
    Ok(shard)
}

fn render_cfg(cfg: &Config, variant: &Variant) -> RenderConfig {
    RenderConfig {
        res: variant.res,
        sensor: if variant.in_ch == 3 {
            Sensor::Rgb
        } else {
            Sensor::Depth
        },
        scale: cfg.render_scale.max(1),
        mode: match cfg.arch {
            SimArch::Bps => cfg.pipeline,
            // workers render fused per env (no staged batch pipeline)
            SimArch::Workers => crate::render::PipelineMode::Fused,
        },
    }
}

fn render_current(shard: &mut Shard, pool: &WorkerPool) {
    let items: Vec<RenderItem> = (0..shard.sim.num_envs())
        .map(|i| {
            let (pos, heading) = {
                let e = shard.sim.env(i);
                (e.pos, e.heading)
            };
            RenderItem {
                scene: shard.sim.scene_of(i),
                pos,
                heading,
            }
        })
        .collect();
    shard.renderer.render_batch(pool, &items, &mut shard.obs);
}

/// Render a sim's current poses (shared by eval and benches).
pub fn render_sim(sim: &BatchSim, renderer: &BatchRenderer, pool: &WorkerPool, obs: &mut [f32]) {
    let items: Vec<RenderItem> = (0..sim.num_envs())
        .map(|i| {
            let e = sim.env(i);
            RenderItem {
                scene: sim.scene_of(i),
                pos: e.pos,
                heading: e.heading,
            }
        })
        .collect();
    renderer.render_batch(pool, &items, obs);
}

/// Resident-memory check against the simulated accelerator budget.
fn check_memory_budget(cfg: &Config, shards: &[Shard]) -> Result<()> {
    let with_tex = matches!(shards[0].renderer.cfg.sensor, Sensor::Rgb);
    let mut bytes = 0usize;
    for shard in shards {
        match cfg.arch {
            SimArch::Bps => {
                if let Some(rot) = &shard.rotation {
                    bytes += rot.resident_bytes(with_tex);
                }
            }
            SimArch::Workers => {
                for i in 0..shard.sim.num_envs() {
                    bytes += shard.sim.scene_of(i).footprint_bytes(with_tex);
                }
            }
        }
    }
    let budget = cfg.memory_budget_mb * 1024 * 1024;
    if bytes > budget {
        bail!(
            "resident scene assets need {} MB but the memory budget is {} MB \
             (arch {:?}): lower --envs (workers) or --k-scenes (bps), or raise \
             --memory-mb",
            bytes / (1024 * 1024),
            cfg.memory_budget_mb,
            cfg.arch
        );
    }
    Ok(())
}

/// Asset bytes resident under an arch (used by benches to derive the
/// memory-capped env counts the paper reports).
pub fn resident_bytes_for(
    arch: SimArch,
    asset: &SceneAsset,
    with_tex: bool,
    n: usize,
    k: usize,
) -> usize {
    match arch {
        SimArch::Bps => asset.footprint_bytes(with_tex) * k.min(n.max(1)),
        SimArch::Workers => asset.footprint_bytes(with_tex) * n,
    }
}
