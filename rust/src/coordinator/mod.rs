//! The end-to-end RL coordinator: rollout generation driven through the
//! batched environment API (`EnvBatch` request/response stepping), GAE,
//! PPO training through the AOT artifacts, DD-PPO multi-shard gradient
//! averaging, and evaluation. This is the paper's Fig. 2 loop.
//!
//! The coordinator is a pure *client* of [`crate::env`]: each shard owns
//! an `EnvBatch` (which encapsulates the batch simulator, batch renderer,
//! and scene rotation) plus the policy and rollout storage. In the default
//! pipelined mode the `EnvBatch` overlaps simulation+rendering of step
//! t+1 with the coordinator's bookkeeping on step t (`--overlap false`
//! selects the synchronous path, which is bitwise-identical).
//!
//! Two simulation architectures are selectable (Table 1):
//! `SimArch::Bps` shares K ≪ N scene assets across the batch and uses the
//! pipelined batch renderer; `SimArch::Workers` reproduces the prior-art
//! design — every environment owns a *private* copy of its scene asset
//! (deep-loaded, so memory pressure is real) and renders fused per-env,
//! which is what caps its env count at a given memory budget.
//!
//! Shards may run heterogeneous tasks (`--tasks pointnav,flee,explore`
//! assigns tasks round-robin): every shard is an independent `EnvBatch`,
//! so a PointNav shard and a Flee shard share nothing but the worker pool
//! and the policy parameters.

use std::rc::Rc;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::config::{Config, SimArch};
use crate::env::{EnvBatch, EnvBatchConfig};
use crate::metrics::EpisodeStats;
use crate::obs::{EventLog, Registry, TraceSink};
use crate::optim::{scale_lr, Losses, LrSchedule, Trainer};
use crate::policy::Policy;
use crate::render::{RenderConfig, SceneRotation, Sensor};
use crate::rollout::Rollout;
use crate::runtime::{Exec, Manifest, ParamStore, Runtime, Variant};
use crate::scenario::{Curriculum, ScenarioSpec, ScenarioStream};
use crate::scene::{Dataset, SceneAsset};
use crate::util::pool::WorkerPool;
use crate::util::timer::{FpsMeter, Profiler};

/// One DD-PPO shard ("GPU"): a batched environment plus policy state and
/// rollout storage. Internals are private — everything below the policy
/// goes through the `EnvBatch` API.
struct Shard {
    env: EnvBatch,
    policy: Policy,
    rollout: Rollout,
    last_dones: Vec<bool>,
    /// Scenario runs only: the per-shard difficulty scheduler. Stage
    /// changes flow through the public seam (`EnvBatch::set_stage`).
    curriculum: Option<Curriculum>,
}

/// Per-iteration summary.
#[derive(Clone, Copy, Debug)]
pub struct IterStats {
    pub frames: u64,
    pub losses: Losses,
}

/// The training coordinator.
pub struct Coordinator {
    pub cfg: Config,
    pub params: ParamStore,
    pub prof: Profiler,
    pub stats: EpisodeStats,
    pub fps: FpsMeter,
    /// Lifecycle event sink (curriculum stage advances). Disarmed by
    /// default — `bps train --event-log FILE` arms it.
    pub events: Arc<EventLog>,
    /// Metrics registry scraped by `bps train --metrics-addr`.
    pub registry: Arc<Registry>,
    /// Megaframe trace sink, armed by `bps train --trace-out`.
    pub trace: Arc<TraceSink>,
    variant: Variant,
    pool: Arc<WorkerPool>,
    shards: Vec<Shard>,
    trainer: Trainer,
    rt: Runtime,
    man: Manifest,
    /// Resolved `--scenario` spec (evaluation generates val scenes from
    /// it instead of reading a dataset split).
    scenario: Option<ScenarioSpec>,
    /// Compiled `infer_n{n}` executable, cached per env count so repeated
    /// `evaluate` calls don't reload + recompile the artifact.
    eval_infer: Option<(usize, Rc<Exec>)>,
}

impl Coordinator {
    pub fn new(cfg: Config) -> Result<Coordinator> {
        cfg.validate()?;
        let man = Manifest::load(&cfg.artifacts_dir)?;
        let variant = man.variant(&cfg.variant)?.clone();
        let (b, l) = cfg.grad_bl();
        let grad_kind = format!("grad_b{b}l{l}");
        if variant.file(&grad_kind).is_err() {
            bail!(
                "variant {:?} lacks {grad_kind} (exported: {:?}); adjust \
                 --envs/--minibatches/--rollout-len or extend the preset",
                variant.name,
                variant.grad_bls
            );
        }
        let rt = Runtime::cpu()?;
        let init = rt.load(&man.artifact_path(&variant, "init")?)?;
        let params = ParamStore::init(&init, variant.num_params, cfg.seed as i32)?;
        let infer = Rc::new(rt.load(
            &man.artifact_path(&variant, &format!("infer_n{}", cfg.num_envs))?,
        )?);
        let grad = rt.load(&man.artifact_path(&variant, &grad_kind)?)?;
        let upd_kind = format!("update_{}", cfg.optimizer);
        let update = rt.load(&man.artifact_path(&variant, &upd_kind)?)?;

        let frames_per_iter = (cfg.num_envs * cfg.rollout_len * cfg.shards) as u64;
        let total_iters = (cfg.total_frames / frames_per_iter.max(1)).max(1);
        // LR scaling: sqrt(B/256), disabled for Adam (diverges — paper A.3).
        let scaled = if cfg.lr_scaling && cfg.optimizer == "lamb" {
            scale_lr(cfg.base_lr, cfg.train_batch() * cfg.shards, 256)
        } else {
            cfg.base_lr
        };
        let trainer = Trainer::new(
            grad,
            update,
            variant.num_params,
            cfg.num_minibatches,
            cfg.ppo_epochs,
            LrSchedule {
                base: cfg.base_lr,
                scaled,
                decay_iters: total_iters / 2,
            },
            cfg.gamma,
            cfg.gae_lambda,
            cfg.normalize_adv,
        );

        let threads = if cfg.threads == 0 {
            WorkerPool::default_size()
        } else {
            cfg.threads
        };
        let pool = Arc::new(WorkerPool::new(threads));

        // Scenario runs synthesize scenes on demand; dataset runs stream
        // pre-generated assets from disk. Exactly one source is active.
        let scenario = cfg
            .scenario
            .as_ref()
            .map(|arg| ScenarioSpec::resolve(arg, &cfg.scenario_dir))
            .transpose()?;
        let dataset = if scenario.is_some() {
            None
        } else {
            Some(Dataset::open(&cfg.dataset_dir).with_context(|| {
                format!(
                    "open dataset {:?} — generate with `bps gen-dataset --dir {}`",
                    cfg.dataset_dir,
                    cfg.dataset_dir.display()
                )
            })?)
        };

        let mut shards = Vec::with_capacity(cfg.shards);
        for s in 0..cfg.shards {
            shards.push(build_shard(
                &cfg,
                &variant,
                Rc::clone(&infer),
                dataset.as_ref(),
                scenario.as_ref(),
                s,
                Arc::clone(&pool),
            )?);
        }
        check_memory_budget(&cfg, &shards)?;

        let stats = EpisodeStats::new(cfg.num_envs * cfg.shards, 256);
        // The training infer exec serves eval too whenever the env counts
        // match (they do by default), so seed the cache with it.
        let eval_infer = Some((cfg.num_envs, infer));
        Ok(Coordinator {
            cfg,
            params,
            prof: Profiler::new(),
            stats,
            fps: FpsMeter::start(),
            events: Arc::new(EventLog::disabled()),
            registry: Registry::new(),
            trace: Arc::new(TraceSink::new(crate::obs::DEFAULT_TRACE_SPANS)),
            variant,
            pool,
            shards,
            trainer,
            rt,
            man,
            scenario,
            eval_infer,
        })
    }

    /// Collect one rollout on every shard, then run the PPO update with
    /// cross-shard gradient averaging. Returns frames processed.
    ///
    /// Per step the shard runs the paper's pipelined request cycle:
    /// inference on the front buffer (step t) → `submit` the sampled
    /// actions (sim+render of t+1 starts on the driver) → record step t
    /// into the rollout *while the step executes* → `wait` and consume
    /// the outcomes.
    pub fn train_iteration(&mut self) -> Result<IterStats> {
        let l = self.cfg.rollout_len;
        for (si, shard) in self.shards.iter_mut().enumerate() {
            shard
                .rollout
                .begin(&shard.policy.h, &shard.policy.c, &shard.last_dones);
            for t in 0..l {
                let step = {
                    let _s = self.prof.span("inference");
                    let v = shard.env.view();
                    shard.policy.step(&self.params.flat, v.obs, v.goal)?
                };
                let handle = shard.env.submit(&step.actions)?;
                {
                    // overlapped with sim+render of this step
                    let v = handle.current();
                    shard.rollout.record_step(
                        t,
                        v.obs,
                        v.goal,
                        &step.actions,
                        &step.logp,
                        &step.values,
                    );
                }
                let v = handle.wait()?;
                shard.rollout.record_outcome(t, v.rewards, v.dones);
                self.stats
                    .update(v.rewards, v.dones, v.successes, v.spl, v.scores);
                if let Some(cur) = shard.curriculum.as_mut() {
                    cur.observe(v.dones, v.successes, v.spl);
                }
                shard.policy.reset_done(v.dones);
                shard.last_dones.copy_from_slice(v.dones);
            }
            // bootstrap values + scene rotation
            shard.rollout.bootstrap = {
                let _s = self.prof.span("inference");
                let v = shard.env.view();
                shard.policy.values_only(&self.params.flat, v.obs, v.goal)?
            };
            // curriculum: advance the difficulty stage before rotating so
            // the rotation's next prefetches request the new stage
            if let Some(cur) = shard.curriculum.as_mut() {
                if let Some(stage) = cur.advance_if_ready() {
                    shard.env.set_stage(stage)?;
                    self.events.emit(
                        "curriculum.stage_advance",
                        &[
                            ("shard", crate::util::json::Json::Num(si as f64)),
                            ("stage", crate::util::json::Json::Num(stage as f64)),
                            (
                                "episodes",
                                crate::util::json::Json::Num(cur.episodes() as f64),
                            ),
                        ],
                    );
                }
            }
            shard.env.rotate_scenes()?;
            let (sim_d, render_d) = shard.env.drain_timings();
            self.prof.add("sim", sim_d);
            self.prof.add("render", render_d);
            // renderer stage breakdown (transform/cull/raster/resolve) —
            // worker-summed wall time, so stages can exceed "render"
            let rs = shard.env.take_render_stats();
            self.prof
                .add("render.transform", Duration::from_nanos(rs.transform_ns));
            self.prof.add("render.cull", Duration::from_nanos(rs.cull_ns));
            self.prof
                .add("render.raster", Duration::from_nanos(rs.raster_ns));
            self.prof
                .add("render.resolve", Duration::from_nanos(rs.resolve_ns));
        }
        // learning (DD-PPO gradient averaging across shards inside)
        let losses = {
            let _s = self.prof.span("learn");
            let mut rollouts: Vec<&mut Rollout> =
                self.shards.iter_mut().map(|s| &mut s.rollout).collect();
            self.trainer.train_refs(&mut self.params, &mut rollouts)?
        };
        let frames = (self.cfg.num_envs * l * self.shards.len()) as u64;
        self.fps.add_frames(frames);
        Ok(IterStats { frames, losses })
    }

    /// Per-shard curriculum stage (0 for shards without a curriculum).
    pub fn stages(&self) -> Vec<u32> {
        self.shards
            .iter()
            .map(|s| s.curriculum.as_ref().map_or(0, Curriculum::stage))
            .collect()
    }

    /// Paper-methodology FPS: frames / wall-time over rollout + training.
    pub fn fps(&self) -> f64 {
        self.fps.fps()
    }

    pub fn frames(&self) -> u64 {
        self.fps.frames()
    }

    /// Greedy evaluation on a dataset split. Returns (SPL, success, score)
    /// means over `episodes` completed episodes. The eval environments are
    /// a fresh `EnvBatch` over the split's scenes; the inference
    /// executable is cached per env count across calls.
    ///
    /// Heterogeneous-task runs (`--tasks`) evaluate the first listed
    /// task (shard 0's); to evaluate a different one, list it first.
    pub fn evaluate(&mut self, split: &str, episodes: usize) -> Result<(f32, f32, f32)> {
        let n = self.cfg.num_envs;
        let with_tex = self.variant.in_ch == 3;
        // Scenario runs: "val" = unseen layouts from the spec's hardest
        // stage, drawn from a seed stream disjoint from training's.
        // Dataset runs: load the split's scenes as before.
        let (task, sim, scenes): (_, _, Vec<Arc<SceneAsset>>) = match &self.scenario {
            Some(spec) => {
                // Synthesize through the same DR pipeline as training
                // (complexity + lighting proxy + texture stripping), in
                // parallel on the shared pool — serial procgen of n heavy
                // scenes would stall every periodic eval.
                let hardest = spec.stages.saturating_sub(1);
                let base_seed = self.cfg.seed;
                let slots: Vec<std::sync::Mutex<Option<SceneAsset>>> =
                    (0..n).map(|_| std::sync::Mutex::new(None)).collect();
                self.pool.parallel_for(n, 1, |i| {
                    let seed = base_seed ^ 0xEA51_0000 ^ (i as u64).wrapping_mul(7919);
                    let id = format!("{}_{split}_{i:03}", spec.name);
                    let scene =
                        crate::scenario::synthesize_scene(spec, hardest, &id, seed, with_tex);
                    *slots[i].lock().unwrap() = Some(scene);
                });
                let scenes = slots
                    .into_iter()
                    .map(|s| Arc::new(s.into_inner().unwrap().expect("eval scene synthesized")))
                    .collect();
                (spec.task, spec.sim_config(), scenes)
            }
            None => {
                let dataset = Dataset::open(&self.cfg.dataset_dir)?;
                let ids = dataset.split(split)?.to_vec();
                if ids.is_empty() {
                    bail!("split {split:?} is empty");
                }
                let scenes = (0..n)
                    .map(|i| {
                        dataset
                            .load_scene(&ids[i % ids.len()], with_tex)
                            .map(Arc::new)
                    })
                    .collect::<Result<_>>()?;
                let task = self.cfg.task_of_shard(0);
                (task, crate::sim::SimConfig::for_task(task), scenes)
            }
        };
        let rcfg = render_cfg(&self.cfg, &self.variant);
        // Eval consumes every step immediately (submit + wait back to
        // back, no bookkeeping in between), so the synchronous path is
        // strictly cheaper and bitwise-identical — no driver thread.
        let mut env = EnvBatchConfig::new(task, rcfg)
            .sim(sim)
            .seed(self.cfg.seed ^ 0xEA51)
            .overlap(false)
            .build_with_scenes(scenes, Arc::clone(&self.pool))?;
        let infer = self.eval_exec(n)?;
        let mut policy = Policy::with_exec(infer, &self.variant, n, self.cfg.seed ^ 0x5EED);
        let (mut spl_sum, mut succ_sum, mut score_sum, mut count) =
            (0.0f32, 0.0f32, 0.0f32, 0usize);
        let max_steps = episodes * 600 / n + 600;
        for _ in 0..max_steps {
            let actions = {
                let v = env.view();
                policy.step_greedy(&self.params.flat, v.obs, v.goal)?
            };
            let v = env.step(&actions)?;
            policy.reset_done(v.dones);
            for i in 0..n {
                if v.dones[i] {
                    count += 1;
                    spl_sum += v.spl[i];
                    succ_sum += if v.successes[i] { 1.0 } else { 0.0 };
                    score_sum += v.scores[i];
                }
            }
            if count >= episodes {
                break;
            }
        }
        let c = count.max(1) as f32;
        Ok((spl_sum / c, succ_sum / c, score_sum / c))
    }

    /// Cached per-env-count `infer_n{n}` executable for evaluation.
    fn eval_exec(&mut self, n: usize) -> Result<Rc<Exec>> {
        if let Some((cached_n, exec)) = self.eval_infer.as_ref() {
            if *cached_n == n {
                return Ok(Rc::clone(exec));
            }
        }
        let exec = Rc::new(self.rt.load(
            &self.man.artifact_path(&self.variant, &format!("infer_n{n}"))?,
        )?);
        self.eval_infer = Some((n, Rc::clone(&exec)));
        Ok(exec)
    }
}

/// Build one shard (scene assignment differs per arch — see module docs).
/// Exactly one of `dataset` / `scenario` is `Some`: scenario shards run
/// the streaming procgen pipeline behind the scene rotation plus a
/// success-driven curriculum; dataset shards stream `.bsc` assets.
fn build_shard(
    cfg: &Config,
    variant: &Variant,
    infer: Rc<Exec>,
    dataset: Option<&Dataset>,
    scenario: Option<&ScenarioSpec>,
    shard_idx: usize,
    pool: Arc<WorkerPool>,
) -> Result<Shard> {
    let n = cfg.num_envs;
    let with_tex = variant.in_ch == 3;
    let rcfg = render_cfg(cfg, variant);
    let task = match scenario {
        Some(spec) => spec.task,
        None => cfg.task_of_shard(shard_idx),
    };
    let mut ecfg = EnvBatchConfig::new(task, rcfg)
        .seed(cfg.seed.wrapping_add(shard_idx as u64 * 7919))
        .overlap(cfg.overlap);
    if let Some(every) = cfg.rotate_every {
        ecfg = ecfg.pin_rotation(every);
    }

    let mut curriculum = None;
    let env = if let Some(spec) = scenario {
        // Scenario engine: the spec defines the task, episode constraints
        // and the streaming scene supply; shards get disjoint seed
        // streams so they synthesize different worlds.
        ecfg = ecfg.sim(spec.sim_config());
        let stream_seed = cfg.seed.wrapping_add(0x5CE2A0 + shard_idx as u64 * 104_729);
        let stream = ScenarioStream::new(
            spec.clone(),
            stream_seed,
            cfg.prefetch_scenes,
            with_tex,
            Arc::clone(&pool),
        );
        let rot = SceneRotation::streaming(stream, cfg.k_scenes)?;
        curriculum = Some(Curriculum::new(
            spec.stages,
            cfg.curriculum_window,
            cfg.curriculum_threshold,
        ));
        ecfg.build_with_rotation(rot, n, pool)?
    } else {
        let dataset = dataset.expect("dataset or scenario");
        // rotate the train split so shards see different scenes
        let mut ids = dataset.train.clone();
        if ids.is_empty() {
            bail!("dataset has no train scenes");
        }
        let shift = (shard_idx * cfg.k_scenes) % ids.len();
        ids.rotate_left(shift);
        match cfg.arch {
            SimArch::Bps => {
                let rot = SceneRotation::new(dataset.clone(), ids, cfg.k_scenes, with_tex)?;
                ecfg.build_with_rotation(rot, n, pool)?
            }
            SimArch::Workers => {
                // No sharing: every env deep-loads its own copy (real memory).
                let mut scenes = Vec::with_capacity(n);
                for i in 0..n {
                    let base = dataset.load_scene(&ids[i % ids.len()], with_tex)?;
                    scenes.push(Arc::new(base));
                }
                ecfg.build_with_scenes(scenes, pool)?
            }
        }
    };

    let policy = Policy::with_exec(
        infer,
        variant,
        n,
        cfg.seed.wrapping_add(0xAC + shard_idx as u64),
    );
    let rollout = Rollout::new(n, cfg.rollout_len, rcfg.obs_floats(), variant.hidden);
    Ok(Shard {
        env,
        policy,
        rollout,
        last_dones: vec![true; n], // first obs of each env starts an episode
        curriculum,
    })
}

fn render_cfg(cfg: &Config, variant: &Variant) -> RenderConfig {
    RenderConfig {
        res: variant.res,
        sensor: if variant.in_ch == 3 {
            Sensor::Rgb
        } else {
            Sensor::Depth
        },
        scale: cfg.render_scale.max(1),
        mode: match cfg.arch {
            SimArch::Bps => cfg.pipeline,
            // workers render fused per env (no staged batch pipeline)
            SimArch::Workers => crate::render::PipelineMode::Fused,
        },
    }
}

/// Resident-memory check against the simulated accelerator budget. Every
/// shard's `EnvBatch` reports its resident asset footprint (rotation slots
/// for BPS, per-env copies for Workers).
fn check_memory_budget(cfg: &Config, shards: &[Shard]) -> Result<()> {
    let bytes: usize = shards.iter().map(|s| s.env.resident_bytes()).sum();
    let budget = cfg.memory_budget_mb * 1024 * 1024;
    if bytes > budget {
        bail!(
            "resident scene assets need {} MB but the memory budget is {} MB \
             (arch {:?}): lower --envs (workers) or --k-scenes (bps), or raise \
             --memory-mb",
            bytes / (1024 * 1024),
            cfg.memory_budget_mb,
            cfg.arch
        );
    }
    Ok(())
}

/// Asset bytes resident under an arch (used by benches to derive the
/// memory-capped env counts the paper reports).
pub fn resident_bytes_for(
    arch: SimArch,
    asset: &SceneAsset,
    with_tex: bool,
    n: usize,
    k: usize,
) -> usize {
    match arch {
        SimArch::Bps => asset.footprint_bytes(with_tex) * k.min(n.max(1)),
        SimArch::Workers => asset.footprint_bytes(with_tex) * n,
    }
}
