//! On-disk scene datasets with train/val/test splits (replaces the
//! Gibson-2plus / Matterport3D / AI2-THOR datasets; DESIGN.md §1).
//!
//! `generate_dataset` writes `.bsc` assets plus a `splits.json`; `Dataset`
//! indexes them so the renderer's asset streamer can load scenes by name
//! during training, and evaluation can iterate the val/test splits.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

use super::asset::SceneAsset;
use super::procgen::{generate, Complexity};

/// Index over a generated dataset directory.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dir: PathBuf,
    pub train: Vec<String>,
    pub val: Vec<String>,
    pub test: Vec<String>,
}

/// Generate `n_train`/`n_val`/`n_test` scenes into `dir`.
pub fn generate_dataset(
    dir: &Path,
    n_train: usize,
    n_val: usize,
    n_test: usize,
    cx: Complexity,
    seed: u64,
) -> Result<Dataset> {
    std::fs::create_dir_all(dir).with_context(|| format!("create {dir:?}"))?;
    let mut splits = [Vec::new(), Vec::new(), Vec::new()];
    let names = ["train", "val", "test"];
    let counts = [n_train, n_val, n_test];
    let mut scene_index = 0u64;
    for (s, &count) in counts.iter().enumerate() {
        for k in 0..count {
            let id = format!("{}_{k:03}", names[s]);
            // disjoint seeds per scene — val/test scenes are unseen layouts
            let scene = generate(&id, seed.wrapping_add(1000 + scene_index), cx);
            scene.save(&dir.join(format!("{id}.bsc")))?;
            splits[s].push(id);
            scene_index += 1;
        }
    }
    let ds = Dataset {
        dir: dir.to_path_buf(),
        train: splits[0].clone(),
        val: splits[1].clone(),
        test: splits[2].clone(),
    };
    ds.save_splits()?;
    Ok(ds)
}

impl Dataset {
    pub fn open(dir: &Path) -> Result<Dataset> {
        let text = std::fs::read_to_string(dir.join("splits.json"))
            .with_context(|| format!("read {dir:?}/splits.json"))?;
        let v = Json::parse(&text)?;
        let read = |key: &str| -> Result<Vec<String>> {
            v.req(key)?
                .as_arr()?
                .iter()
                .map(|x| Ok(x.as_str()?.to_string()))
                .collect()
        };
        Ok(Dataset {
            dir: dir.to_path_buf(),
            train: read("train")?,
            val: read("val")?,
            test: read("test")?,
        })
    }

    fn save_splits(&self) -> Result<()> {
        let arr = |v: &[String]| Json::Arr(v.iter().map(|s| json::s(s)).collect());
        let doc = json::obj(vec![
            ("train", arr(&self.train)),
            ("val", arr(&self.val)),
            ("test", arr(&self.test)),
        ]);
        std::fs::write(self.dir.join("splits.json"), doc.to_string())?;
        Ok(())
    }

    pub fn scene_path(&self, id: &str) -> PathBuf {
        self.dir.join(format!("{id}.bsc"))
    }

    pub fn load_scene(&self, id: &str, with_textures: bool) -> Result<SceneAsset> {
        SceneAsset::load(&self.scene_path(id), with_textures)
    }

    pub fn split(&self, name: &str) -> Result<&[String]> {
        match name {
            "train" => Ok(&self.train),
            "val" => Ok(&self.val),
            "test" => Ok(&self.test),
            _ => bail!("unknown split {name:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("bps_ds_test").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn generate_open_load() {
        let dir = tmpdir("basic");
        let ds = generate_dataset(&dir, 3, 1, 1, Complexity::test(), 5).unwrap();
        assert_eq!(ds.train.len(), 3);
        let re = Dataset::open(&dir).unwrap();
        assert_eq!(re.train, ds.train);
        assert_eq!(re.val, vec!["val_000".to_string()]);
        let scene = re.load_scene("train_001", false).unwrap();
        assert_eq!(scene.id, "train_001");
        assert!(scene.textures.is_empty());
        let scene_tex = re.load_scene("train_001", true).unwrap();
        assert!(!scene_tex.textures.is_empty());
    }

    #[test]
    fn scenes_differ_across_split() {
        let dir = tmpdir("differ");
        let ds = generate_dataset(&dir, 2, 1, 0, Complexity::test(), 9).unwrap();
        let a = ds.load_scene("train_000", false).unwrap();
        let b = ds.load_scene("train_001", false).unwrap();
        let v = ds.load_scene("val_000", false).unwrap();
        assert_ne!(a.mesh.num_tris(), 0);
        // layouts differ (seeds disjoint)
        assert!(
            a.navmesh.walkable != b.navmesh.walkable
                || a.mesh.positions.len() != b.mesh.positions.len()
        );
        assert!(v.navmesh.walkable != a.navmesh.walkable);
    }

    #[test]
    fn unknown_split_rejected() {
        let dir = tmpdir("split");
        let ds = generate_dataset(&dir, 1, 0, 0, Complexity::test(), 1).unwrap();
        assert!(ds.split("train").is_ok());
        assert!(ds.split("dev").is_err());
    }
}
