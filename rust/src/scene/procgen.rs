//! Procedural indoor scene generator (replaces the Gibson / Matterport3D /
//! AI2-THOR scan datasets — DESIGN.md §1).
//!
//! BSP floor-plan: a rectangular apartment is recursively split into rooms;
//! internal walls carry doorway gaps; each room gets box/cylinder clutter.
//! The `detail` knob subdivides surfaces so triangle counts can be pushed to
//! Gibson-scale (100K+ tris) or kept AI2-THOR-small (paper Appendix A.1),
//! stressing the same rasterization-bound regime the paper measures.

use crate::geom::vec::{v2, v3};
use crate::navmesh::GridNav;
use crate::util::rng::Rng;

use super::asset::SceneAsset;
use super::mesh::{Material, Mesh, Texture, NO_TEX};

/// Scene complexity preset.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Complexity {
    /// World extent in meters (square apartment).
    pub extent: f32,
    /// Minimum room size before BSP splitting stops.
    pub min_room: f32,
    /// Clutter objects per room.
    pub clutter_per_room: usize,
    /// Surface subdivision factor (triangle-count knob).
    pub detail: usize,
    /// Procedural texture resolution (RGB payload size knob).
    pub tex_res: usize,
    /// Number of procedural textures.
    pub tex_count: usize,
}

impl Complexity {
    /// Gibson-like: large scans, heavy geometry, big texture payloads.
    pub fn gibson_like() -> Complexity {
        Complexity {
            extent: 16.0,
            min_room: 3.5,
            clutter_per_room: 6,
            detail: 12,
            tex_res: 256,
            tex_count: 8,
        }
    }

    /// AI2-THOR-like: single-home scale, light geometry (paper A.1).
    pub fn thor_like() -> Complexity {
        Complexity {
            extent: 9.0,
            min_room: 3.0,
            clutter_per_room: 3,
            detail: 4,
            tex_res: 128,
            tex_count: 4,
        }
    }

    /// Tiny scenes for unit tests.
    pub fn test() -> Complexity {
        Complexity {
            extent: 6.0,
            min_room: 2.5,
            clutter_per_room: 1,
            detail: 2,
            tex_res: 32,
            tex_count: 2,
        }
    }
}

const WALL_H: f32 = 2.5;
const WALL_T: f32 = 0.10;
const DOOR_W: f32 = 1.0;
const AGENT_RADIUS: f32 = 0.18;
const NAV_CELL: f32 = 0.1;

#[derive(Clone, Copy, Debug)]
struct Rect {
    x0: f32,
    z0: f32,
    x1: f32,
    z1: f32,
}

impl Rect {
    fn w(&self) -> f32 {
        self.x1 - self.x0
    }

    fn d(&self) -> f32 {
        self.z1 - self.z0
    }
}

/// An internal wall segment with a doorway gap, on a BSP split line.
#[derive(Clone, Copy, Debug)]
struct Wall {
    vertical: bool, // true: wall along z at x=pos; false: along x at z=pos
    pos: f32,
    lo: f32,
    hi: f32,
    door_lo: f32,
    door_hi: f32,
}

/// 2D obstacle footprint for navmesh carving.
#[derive(Clone, Copy, Debug)]
struct Obstacle {
    x0: f32,
    z0: f32,
    x1: f32,
    z1: f32,
}

/// Generate a complete scene asset (mesh + materials + textures + navmesh).
pub fn generate(id: &str, seed: u64, cx: Complexity) -> SceneAsset {
    let mut rng = Rng::new(seed);
    let world = Rect {
        x0: 0.0,
        z0: 0.0,
        x1: cx.extent,
        z1: cx.extent,
    };

    // ---- BSP rooms + internal walls -------------------------------------
    let mut rooms = Vec::new();
    let mut walls = Vec::new();
    bsp_split(world, cx.min_room, &mut rng, &mut rooms, &mut walls);

    // ---- materials + textures -------------------------------------------
    let mut textures = Vec::new();
    for t in 0..cx.tex_count {
        textures.push(make_texture(&mut rng, cx.tex_res, t));
    }
    let mut materials = vec![
        Material { albedo: [0.55, 0.5, 0.45], tex: 0 % cx.tex_count as u32 }, // floor
        Material { albedo: [0.85, 0.85, 0.8], tex: 1 % cx.tex_count as u32 }, // walls
        Material { albedo: [0.3, 0.3, 0.35], tex: NO_TEX },                   // ceiling trim
    ];

    // ---- geometry ---------------------------------------------------------
    let mut mesh = Mesh::default();
    // floor (one subdivided quad across the apartment)
    mesh.add_quad(
        v3(world.x0, 0.0, world.z0),
        v3(world.w(), 0.0, 0.0),
        v3(0.0, 0.0, world.d()),
        0,
        (cx.detail * 8).max(4),
        cx.extent / 2.0,
    );

    let mut obstacles: Vec<Obstacle> = Vec::new();

    // perimeter walls
    let peri = [
        Wall { vertical: true, pos: world.x0, lo: world.z0, hi: world.z1, door_lo: 0.0, door_hi: 0.0 },
        Wall { vertical: true, pos: world.x1, lo: world.z0, hi: world.z1, door_lo: 0.0, door_hi: 0.0 },
        Wall { vertical: false, pos: world.z0, lo: world.x0, hi: world.x1, door_lo: 0.0, door_hi: 0.0 },
        Wall { vertical: false, pos: world.z1, lo: world.x0, hi: world.x1, door_lo: 0.0, door_hi: 0.0 },
    ];
    for w in peri.iter().chain(walls.iter()) {
        emit_wall(&mut mesh, w, cx.detail, &mut obstacles);
    }

    // clutter
    for room in &rooms {
        for _ in 0..cx.clutter_per_room {
            if room.w() < 2.0 || room.d() < 2.0 {
                continue;
            }
            let margin = 0.6;
            let px = rng.range_f32(room.x0 + margin, room.x1 - margin);
            let pz = rng.range_f32(room.z0 + margin, room.z1 - margin);
            let size = rng.range_f32(0.25, 0.6);
            let height = rng.range_f32(0.4, 1.4);
            let mat = materials.len() as u32;
            materials.push(Material {
                albedo: [
                    rng.range_f32(0.2, 0.9),
                    rng.range_f32(0.2, 0.9),
                    rng.range_f32(0.2, 0.9),
                ],
                tex: if rng.chance(0.5) {
                    rng.range_usize(0, cx.tex_count) as u32
                } else {
                    NO_TEX
                },
            });
            if rng.chance(0.5) {
                mesh.add_box(
                    v3(px - size, 0.0, pz - size),
                    v3(px + size, height, pz + size),
                    mat,
                    cx.detail.max(1),
                );
                obstacles.push(Obstacle {
                    x0: px - size,
                    z0: pz - size,
                    x1: px + size,
                    z1: pz + size,
                });
            } else {
                mesh.add_cylinder(
                    v3(px, 0.0, pz),
                    size,
                    height,
                    (cx.detail * 8).max(6),
                    mat,
                );
                obstacles.push(Obstacle {
                    x0: px - size,
                    z0: pz - size,
                    x1: px + size,
                    z1: pz + size,
                });
            }
        }
    }

    // ---- navmesh ----------------------------------------------------------
    let navmesh = build_navmesh(world, &obstacles);

    SceneAsset {
        id: id.to_string(),
        mesh,
        materials,
        textures,
        navmesh,
    }
}

fn bsp_split(r: Rect, min_room: f32, rng: &mut Rng, rooms: &mut Vec<Rect>, walls: &mut Vec<Wall>) {
    let splittable_x = r.w() > 2.0 * min_room;
    let splittable_z = r.d() > 2.0 * min_room;
    if !splittable_x && !splittable_z {
        rooms.push(r);
        return;
    }
    let split_x = if splittable_x && splittable_z {
        r.w() > r.d()
    } else {
        splittable_x
    };
    if split_x {
        let s = rng.range_f32(r.x0 + min_room, r.x1 - min_room);
        let door = rng.range_f32(r.z0 + 0.4, r.z1 - 0.4 - DOOR_W);
        walls.push(Wall {
            vertical: true,
            pos: s,
            lo: r.z0,
            hi: r.z1,
            door_lo: door,
            door_hi: door + DOOR_W,
        });
        bsp_split(Rect { x1: s, ..r }, min_room, rng, rooms, walls);
        bsp_split(Rect { x0: s, ..r }, min_room, rng, rooms, walls);
    } else {
        let s = rng.range_f32(r.z0 + min_room, r.z1 - min_room);
        let door = rng.range_f32(r.x0 + 0.4, r.x1 - 0.4 - DOOR_W);
        walls.push(Wall {
            vertical: false,
            pos: s,
            lo: r.x0,
            hi: r.x1,
            door_lo: door,
            door_hi: door + DOOR_W,
        });
        bsp_split(Rect { z1: s, ..r }, min_room, rng, rooms, walls);
        bsp_split(Rect { z0: s, ..r }, min_room, rng, rooms, walls);
    }
}

/// Emit wall geometry (splitting around the doorway) + obstacle footprints.
fn emit_wall(mesh: &mut Mesh, w: &Wall, detail: usize, obstacles: &mut Vec<Obstacle>) {
    let mut spans = Vec::new();
    if w.door_hi > w.door_lo {
        if w.door_lo > w.lo {
            spans.push((w.lo, w.door_lo));
        }
        if w.hi > w.door_hi {
            spans.push((w.door_hi, w.hi));
        }
    } else {
        spans.push((w.lo, w.hi));
    }
    for (lo, hi) in spans {
        if hi - lo < 1e-3 {
            continue;
        }
        let t = WALL_T * 0.5;
        let (min, max) = if w.vertical {
            (v3(w.pos - t, 0.0, lo), v3(w.pos + t, WALL_H, hi))
        } else {
            (v3(lo, 0.0, w.pos - t), v3(hi, WALL_H, w.pos + t))
        };
        mesh.add_box(min, max, 1, detail.max(1));
        obstacles.push(Obstacle {
            x0: min.x,
            z0: min.z,
            x1: max.x,
            z1: max.z,
        });
    }
}

fn build_navmesh(world: Rect, obstacles: &[Obstacle]) -> GridNav {
    let w = (world.w() / NAV_CELL).ceil() as usize;
    let h = (world.d() / NAV_CELL).ceil() as usize;
    let mut nav = GridNav::new(v2(world.x0, world.z0), NAV_CELL, w, h);
    let margin = AGENT_RADIUS;
    for y in 0..h {
        for x in 0..w {
            let c = nav.cell_center(x, y);
            // stay off the world boundary by the agent radius
            let mut ok = c.x > world.x0 + margin
                && c.x < world.x1 - margin
                && c.y > world.z0 + margin
                && c.y < world.z1 - margin;
            if ok {
                for ob in obstacles {
                    if c.x > ob.x0 - margin
                        && c.x < ob.x1 + margin
                        && c.y > ob.z0 - margin
                        && c.y < ob.z1 + margin
                    {
                        ok = false;
                        break;
                    }
                }
            }
            let i = nav.idx(x, y);
            nav.walkable[i] = ok;
        }
    }
    // Keep only the largest connected component: clutter can fully block a
    // doorway, and episodes must always be sampled from mutually reachable
    // space (Habitat does the same when baking navmeshes).
    retain_largest_component(&mut nav);
    nav
}

fn retain_largest_component(nav: &mut GridNav) {
    let n = nav.w * nav.h;
    let mut comp = vec![u32::MAX; n];
    let mut sizes: Vec<usize> = Vec::new();
    let mut stack = Vec::new();
    for start in 0..n {
        if !nav.walkable[start] || comp[start] != u32::MAX {
            continue;
        }
        let cid = sizes.len() as u32;
        let mut size = 0usize;
        stack.push(start);
        comp[start] = cid;
        while let Some(i) = stack.pop() {
            size += 1;
            let (x, y) = (i % nav.w, i / nav.w);
            for (dx, dy) in [(1i32, 0i32), (-1, 0), (0, 1), (0, -1)] {
                let nx = x as i32 + dx;
                let ny = y as i32 + dy;
                if nx < 0 || ny < 0 || nx as usize >= nav.w || ny as usize >= nav.h {
                    continue;
                }
                let j = ny as usize * nav.w + nx as usize;
                if nav.walkable[j] && comp[j] == u32::MAX {
                    comp[j] = cid;
                    stack.push(j);
                }
            }
        }
        sizes.push(size);
    }
    if let Some((best, _)) = sizes.iter().enumerate().max_by_key(|(_, &s)| s) {
        for i in 0..n {
            nav.walkable[i] = comp[i] == best as u32;
        }
    }
}

/// Procedural texture: checker / stripes / value-noise variants.
fn make_texture(rng: &mut Rng, res: usize, kind: usize) -> Texture {
    let mut rgb = vec![0u8; res * res * 3];
    let c1 = [
        rng.range_f32(0.3, 1.0),
        rng.range_f32(0.3, 1.0),
        rng.range_f32(0.3, 1.0),
    ];
    let c2 = [c1[0] * 0.5, c1[1] * 0.5, c1[2] * 0.5];
    let scale = rng.range_usize(4, 16);
    for y in 0..res {
        for x in 0..res {
            let f = match kind % 3 {
                0 => ((x * scale / res) + (y * scale / res)) % 2 == 0,
                1 => (x * scale / res) % 2 == 0,
                _ => {
                    // hash noise
                    let n = (x as u64)
                        .wrapping_mul(0x9E3779B97F4A7C15)
                        .wrapping_add((y as u64).wrapping_mul(0xD1B54A32D192ED03));
                    (n >> 32) & 1 == 0
                }
            };
            let c = if f { c1 } else { c2 };
            let i = (y * res + x) * 3;
            rgb[i] = (c[0] * 255.0) as u8;
            rgb[i + 1] = (c[1] * 255.0) as u8;
            rgb[i + 2] = (c[2] * 255.0) as u8;
        }
    }
    Texture { w: res, h: res, rgb }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_connected_navmesh() {
        let scene = generate("t0", 42, Complexity::test());
        let nav = &scene.navmesh;
        assert!(nav.num_walkable() > 100, "walkable {}", nav.num_walkable());
        // all rooms must be mutually reachable (doors carved): sample pairs
        let mut rng = Rng::new(0);
        for _ in 0..10 {
            let a = nav.random_point(&mut rng).unwrap();
            let b = nav.random_point(&mut rng).unwrap();
            assert!(
                nav.geodesic(a, b).is_some(),
                "disconnected navmesh: {a:?} -> {b:?}"
            );
        }
    }

    #[test]
    fn deterministic_generation() {
        let a = generate("x", 7, Complexity::test());
        let b = generate("x", 7, Complexity::test());
        assert_eq!(a.mesh.num_tris(), b.mesh.num_tris());
        assert_eq!(a.mesh.positions.len(), b.mesh.positions.len());
        assert_eq!(a.navmesh.walkable, b.navmesh.walkable);
        let c = generate("x", 8, Complexity::test());
        assert!(a.navmesh.walkable != c.navmesh.walkable);
    }

    #[test]
    fn complexity_scales_triangles() {
        let small = generate("s", 3, Complexity::test());
        let big = generate("b", 3, Complexity::gibson_like());
        assert!(
            big.mesh.num_tris() > 10 * small.mesh.num_tris(),
            "{} vs {}",
            big.mesh.num_tris(),
            small.mesh.num_tris()
        );
        assert!(big.texture_bytes() > small.texture_bytes());
    }

    #[test]
    fn gibson_like_triangle_count_scale() {
        let s = generate("g", 1, Complexity::gibson_like());
        // order 100K triangles — the regime where rasterization is
        // triangle-bound (paper §3.2 pipelined culling motivation)
        assert!(s.mesh.num_tris() > 50_000, "tris {}", s.mesh.num_tris());
    }

    #[test]
    fn clutter_not_walkable() {
        let scene = generate("c", 11, Complexity::test());
        // cell centers inside obstacle footprints must be blocked; verify by
        // sampling random walkable points and checking none are inside
        // clutter chunks' xz AABBs (with margin slack).
        let nav = &scene.navmesh;
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let p = nav.random_point(&mut rng).unwrap();
            assert!(nav.is_walkable(p));
        }
    }

    #[test]
    fn walls_have_positive_height_and_chunks() {
        let scene = generate("w", 5, Complexity::test());
        let bb = scene.mesh.aabb();
        assert!((bb.max.y - WALL_H).abs() < 0.5);
        assert!(scene.mesh.chunks.len() > 5);
    }
}
