//! Scene substrate: meshes/materials/textures, the procedural indoor scene
//! generator standing in for Gibson/Matterport3D/AI2-THOR scans, binary
//! asset serialization, and on-disk datasets with train/val/test splits.

pub mod asset;
pub mod dataset;
pub mod mesh;
pub mod procgen;

pub use asset::SceneAsset;
pub use dataset::{generate_dataset, Dataset};
pub use mesh::{Chunk, Material, Mesh, Texture};
pub use procgen::Complexity;
