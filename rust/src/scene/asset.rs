//! Scene asset container + versioned binary serialization (`.bsc`).
//!
//! Assets are generated once (`bps gen-dataset`) and streamed from disk by
//! the renderer's background loader during training (paper §3.2). Loading
//! supports `with_textures = false` so Depth agents skip the texture
//! payload — the exact memory asymmetry the paper exploits (§4.1/§4.2).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::geom::vec::{v2, v3};
use crate::geom::Aabb;
use crate::navmesh::GridNav;

use super::mesh::{Chunk, Material, Mesh, Texture};

/// A fully loaded scene: geometry, materials, textures, navmesh.
#[derive(Clone, Debug)]
pub struct SceneAsset {
    pub id: String,
    pub mesh: Mesh,
    pub materials: Vec<Material>,
    pub textures: Vec<Texture>,
    pub navmesh: GridNav,
}

impl SceneAsset {
    pub fn geometry_bytes(&self) -> usize {
        self.mesh.geometry_bytes() + self.materials.len() * 16
    }

    pub fn texture_bytes(&self) -> usize {
        self.textures.iter().map(Texture::bytes).sum()
    }

    /// Total in-memory footprint for GPU-memory budgeting (DESIGN.md §1).
    pub fn footprint_bytes(&self, with_textures: bool) -> usize {
        self.geometry_bytes() + if with_textures { self.texture_bytes() } else { 0 }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = Vec::with_capacity(1 << 20);
        w.extend_from_slice(MAGIC);
        put_str(&mut w, &self.id);
        // mesh
        put_u32(&mut w, self.mesh.positions.len() as u32);
        for p in &self.mesh.positions {
            put_f32(&mut w, p.x);
            put_f32(&mut w, p.y);
            put_f32(&mut w, p.z);
        }
        for uv in &self.mesh.uvs {
            put_f32(&mut w, uv.x);
            put_f32(&mut w, uv.y);
        }
        put_u32(&mut w, self.mesh.indices.len() as u32);
        for &i in &self.mesh.indices {
            put_u32(&mut w, i);
        }
        for &m in &self.mesh.tri_material {
            put_u32(&mut w, m);
        }
        put_u32(&mut w, self.mesh.chunks.len() as u32);
        for c in &self.mesh.chunks {
            for v in [c.aabb.min, c.aabb.max] {
                put_f32(&mut w, v.x);
                put_f32(&mut w, v.y);
                put_f32(&mut w, v.z);
            }
            put_u32(&mut w, c.tri_start);
            put_u32(&mut w, c.tri_count);
        }
        // materials
        put_u32(&mut w, self.materials.len() as u32);
        for m in &self.materials {
            for c in m.albedo {
                put_f32(&mut w, c);
            }
            put_u32(&mut w, m.tex);
        }
        // navmesh
        put_f32(&mut w, self.navmesh.origin.x);
        put_f32(&mut w, self.navmesh.origin.y);
        put_f32(&mut w, self.navmesh.cell);
        put_u32(&mut w, self.navmesh.w as u32);
        put_u32(&mut w, self.navmesh.h as u32);
        let bits = pack_bits(&self.navmesh.walkable);
        put_u32(&mut w, bits.len() as u32);
        w.extend_from_slice(&bits);
        // textures (trailing section so depth-only loads can stop early)
        put_u32(&mut w, self.textures.len() as u32);
        for t in &self.textures {
            put_u32(&mut w, t.w as u32);
            put_u32(&mut w, t.h as u32);
            w.extend_from_slice(&t.rgb);
        }
        std::fs::File::create(path)
            .with_context(|| format!("create {path:?}"))?
            .write_all(&w)?;
        Ok(())
    }

    /// Load an asset; `with_textures = false` skips the texture payload
    /// (Depth agents — paper §4.1 "minor modification to not load textures").
    pub fn load(path: &Path, with_textures: bool) -> Result<SceneAsset> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {path:?}"))?
            .read_to_end(&mut bytes)?;
        let mut r = Reader { b: &bytes, pos: 0 };
        let magic = r.take(MAGIC.len())?;
        if magic != MAGIC {
            bail!("{path:?}: bad magic (not a .bsc scene asset)");
        }
        let id = r.str()?;
        let nv = r.u32()? as usize;
        let mut mesh = Mesh::default();
        mesh.positions.reserve(nv);
        for _ in 0..nv {
            mesh.positions.push(v3(r.f32()?, r.f32()?, r.f32()?));
        }
        mesh.uvs.reserve(nv);
        for _ in 0..nv {
            mesh.uvs.push(v2(r.f32()?, r.f32()?));
        }
        let ni = r.u32()? as usize;
        mesh.indices.reserve(ni);
        for _ in 0..ni {
            mesh.indices.push(r.u32()?);
        }
        let ntri = ni / 3;
        mesh.tri_material.reserve(ntri);
        for _ in 0..ntri {
            mesh.tri_material.push(r.u32()?);
        }
        let nc = r.u32()? as usize;
        mesh.chunks.reserve(nc);
        for _ in 0..nc {
            let min = v3(r.f32()?, r.f32()?, r.f32()?);
            let max = v3(r.f32()?, r.f32()?, r.f32()?);
            mesh.chunks.push(Chunk {
                aabb: Aabb { min, max },
                tri_start: r.u32()?,
                tri_count: r.u32()?,
            });
        }
        let nm = r.u32()? as usize;
        let mut materials = Vec::with_capacity(nm);
        for _ in 0..nm {
            materials.push(Material {
                albedo: [r.f32()?, r.f32()?, r.f32()?],
                tex: r.u32()?,
            });
        }
        let origin = v2(r.f32()?, r.f32()?);
        let cell = r.f32()?;
        let w = r.u32()? as usize;
        let h = r.u32()? as usize;
        let nbits = r.u32()? as usize;
        let bits = r.take(nbits)?;
        let mut navmesh = GridNav::new(origin, cell, w, h);
        navmesh.walkable = unpack_bits(bits, w * h);
        // derived data: chunk vertex ranges (the renderer's transform-cache
        // granule) are not serialized
        mesh.rebuild_chunk_vert_ranges();
        let mut textures = Vec::new();
        if with_textures {
            let nt = r.u32()? as usize;
            for _ in 0..nt {
                let tw = r.u32()? as usize;
                let th = r.u32()? as usize;
                let rgb = r.take(tw * th * 3)?.to_vec();
                textures.push(Texture { w: tw, h: th, rgb });
            }
        }
        Ok(SceneAsset {
            id,
            mesh,
            materials,
            textures,
            navmesh,
        })
    }
}

const MAGIC: &[u8] = b"BSC1";

fn put_u32(w: &mut Vec<u8>, x: u32) {
    w.extend_from_slice(&x.to_le_bytes());
}

fn put_f32(w: &mut Vec<u8>, x: f32) {
    w.extend_from_slice(&x.to_le_bytes());
}

fn put_str(w: &mut Vec<u8>, s: &str) {
    put_u32(w, s.len() as u32);
    w.extend_from_slice(s.as_bytes());
}

fn pack_bits(bools: &[bool]) -> Vec<u8> {
    let mut out = vec![0u8; (bools.len() + 7) / 8];
    for (i, &b) in bools.iter().enumerate() {
        if b {
            out[i / 8] |= 1 << (i % 8);
        }
    }
    out
}

fn unpack_bits(bytes: &[u8], n: usize) -> Vec<bool> {
    (0..n).map(|i| bytes[i / 8] & (1 << (i % 8)) != 0).collect()
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("truncated asset file at byte {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn f32(&mut self) -> Result<f32> {
        let s = self.take(4)?;
        Ok(f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8_lossy(self.take(n)?).into_owned())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::procgen::{generate, Complexity};

    #[test]
    fn save_load_roundtrip() {
        let scene = generate("rt", 13, Complexity::test());
        let dir = std::env::temp_dir().join("bps_asset_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.bsc");
        scene.save(&path).unwrap();
        let back = SceneAsset::load(&path, true).unwrap();
        assert_eq!(back.id, "rt");
        assert_eq!(back.mesh.positions.len(), scene.mesh.positions.len());
        assert_eq!(back.mesh.indices, scene.mesh.indices);
        assert_eq!(back.mesh.tri_material, scene.mesh.tri_material);
        assert_eq!(back.materials.len(), scene.materials.len());
        assert_eq!(back.textures.len(), scene.textures.len());
        assert_eq!(back.textures[0].rgb, scene.textures[0].rgb);
        assert_eq!(back.navmesh.walkable, scene.navmesh.walkable);
        assert_eq!(back.mesh.chunks.len(), scene.mesh.chunks.len());
    }

    #[test]
    fn depth_load_skips_textures() {
        let scene = generate("dt", 14, Complexity::test());
        let dir = std::env::temp_dir().join("bps_asset_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dt.bsc");
        scene.save(&path).unwrap();
        let depth = SceneAsset::load(&path, false).unwrap();
        assert!(depth.textures.is_empty());
        assert!(depth.footprint_bytes(false) < scene.footprint_bytes(true));
        // geometry intact
        assert_eq!(depth.mesh.num_tris(), scene.mesh.num_tris());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("bps_asset_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bsc");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(SceneAsset::load(&path, true).is_err());
    }

    #[test]
    fn bit_packing_roundtrip() {
        let bools: Vec<bool> = (0..37).map(|i| i % 3 == 0).collect();
        let packed = pack_bits(&bools);
        assert_eq!(unpack_bits(&packed, 37), bools);
    }
}
