//! Triangle meshes, materials, and procedural textures.
//!
//! Meshes are split into *chunks* (contiguous triangle ranges with an AABB)
//! at build time — the culling granule of the batch renderer (paper §3.2).
//! Textures are only materialized for RGB agents; Depth agents skip the
//! texture payload entirely, reproducing the paper's memory asymmetry
//! between Depth and RGB training (§4.2).

use crate::geom::{Aabb, Vec2, Vec3};
use crate::geom::vec::{v2, v3};

/// Point-sampled RGB texture (procedurally generated; see `procgen`).
#[derive(Clone, Debug, PartialEq)]
pub struct Texture {
    pub w: usize,
    pub h: usize,
    pub rgb: Vec<u8>, // w * h * 3
}

impl Texture {
    /// Point sample with wrap addressing; returns linear [0,1] rgb.
    #[inline]
    pub fn sample(&self, u: f32, v: f32) -> [f32; 3] {
        let x = ((u.rem_euclid(1.0)) * self.w as f32) as usize % self.w;
        let y = ((v.rem_euclid(1.0)) * self.h as f32) as usize % self.h;
        let i = (y * self.w + x) * 3;
        [
            self.rgb[i] as f32 / 255.0,
            self.rgb[i + 1] as f32 / 255.0,
            self.rgb[i + 2] as f32 / 255.0,
        ]
    }

    pub fn bytes(&self) -> usize {
        self.rgb.len()
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Material {
    pub albedo: [f32; 3],
    /// Texture index, or u32::MAX for untextured.
    pub tex: u32,
}

pub const NO_TEX: u32 = u32::MAX;

/// Contiguous triangle range with a bounding box (culling granule).
#[derive(Clone, Copy, Debug)]
pub struct Chunk {
    pub aabb: Aabb,
    pub tri_start: u32,
    pub tri_count: u32,
}

/// Indexed triangle mesh with per-triangle materials.
#[derive(Clone, Debug, Default)]
pub struct Mesh {
    pub positions: Vec<Vec3>,
    pub uvs: Vec<Vec2>,
    pub indices: Vec<u32>,      // 3 per triangle
    pub tri_material: Vec<u32>, // 1 per triangle
    pub chunks: Vec<Chunk>,
    /// Per-chunk vertex index range `[start, end)` — the renderer's
    /// transform-cache granule. Maintained by `close_chunk`, rebuilt after
    /// deserialization; derived data, never serialized.
    chunk_verts: Vec<(u32, u32)>,
}

impl Mesh {
    pub fn num_tris(&self) -> usize {
        self.indices.len() / 3
    }

    pub fn geometry_bytes(&self) -> usize {
        self.positions.len() * 12
            + self.uvs.len() * 8
            + self.indices.len() * 4
            + self.tri_material.len() * 4
            + self.chunks.len() * 32
    }

    pub fn aabb(&self) -> Aabb {
        Aabb::from_points(self.positions.iter().copied())
    }

    /// Close the current open triangle range into a chunk.
    fn close_chunk(&mut self, tri_start: usize) {
        let tri_count = self.num_tris() - tri_start;
        if tri_count == 0 {
            return;
        }
        let mut aabb = Aabb::EMPTY;
        let (mut v_lo, mut v_hi) = (u32::MAX, 0u32);
        for t in tri_start..tri_start + tri_count {
            for k in 0..3 {
                let vi = self.indices[t * 3 + k];
                v_lo = v_lo.min(vi);
                v_hi = v_hi.max(vi);
                aabb.grow(self.positions[vi as usize]);
            }
        }
        self.chunks.push(Chunk {
            aabb,
            tri_start: tri_start as u32,
            tri_count: tri_count as u32,
        });
        self.chunk_verts.push((v_lo, v_hi + 1));
    }

    /// Vertex index range `[start, end)` referenced by chunk `ci`. Uses the
    /// range recorded at build time; falls back to an index scan for meshes
    /// whose chunks were assembled by hand.
    pub fn chunk_vert_range(&self, ci: usize) -> (usize, usize) {
        if let Some(&(s, e)) = self.chunk_verts.get(ci) {
            return (s as usize, e as usize);
        }
        self.scan_vert_range(&self.chunks[ci])
    }

    fn scan_vert_range(&self, c: &Chunk) -> (usize, usize) {
        let (mut lo, mut hi) = (u32::MAX, 0u32);
        for t in c.tri_start..c.tri_start + c.tri_count {
            for k in 0..3 {
                let vi = self.indices[t as usize * 3 + k];
                lo = lo.min(vi);
                hi = hi.max(vi);
            }
        }
        if lo == u32::MAX {
            (0, 0)
        } else {
            (lo as usize, hi as usize + 1)
        }
    }

    /// Recompute every chunk's vertex range (after deserialization, where
    /// chunks arrive without their build-time ranges).
    pub fn rebuild_chunk_vert_ranges(&mut self) {
        let ranges: Vec<(u32, u32)> = self
            .chunks
            .iter()
            .map(|c| {
                let (s, e) = self.scan_vert_range(c);
                (s as u32, e as u32)
            })
            .collect();
        self.chunk_verts = ranges;
    }

    fn push_vert(&mut self, p: Vec3, uv: Vec2) -> u32 {
        self.positions.push(p);
        self.uvs.push(uv);
        (self.positions.len() - 1) as u32
    }

    fn push_tri(&mut self, a: u32, b: u32, c: u32, mat: u32) {
        self.indices.extend_from_slice(&[a, b, c]);
        self.tri_material.push(mat);
    }

    /// Add a subdivided quad (two triangles per cell). `subdiv >= 1` splits
    /// the quad into `subdiv^2` cells — the triangle-count knob that lets
    /// procgen hit Gibson-like geometric complexity (paper: up to 600K tris).
    pub fn add_quad(
        &mut self,
        origin: Vec3,
        edge_u: Vec3,
        edge_v: Vec3,
        mat: u32,
        subdiv: usize,
        uv_scale: f32,
    ) {
        let start = self.num_tris();
        let s = subdiv.max(1);
        let inv = 1.0 / s as f32;
        // vertex grid
        let mut grid = Vec::with_capacity((s + 1) * (s + 1));
        for j in 0..=s {
            for i in 0..=s {
                let fu = i as f32 * inv;
                let fv = j as f32 * inv;
                let p = origin + edge_u * fu + edge_v * fv;
                grid.push(self.push_vert(p, v2(fu * uv_scale, fv * uv_scale)));
            }
        }
        for j in 0..s {
            for i in 0..s {
                let a = grid[j * (s + 1) + i];
                let b = grid[j * (s + 1) + i + 1];
                let c = grid[(j + 1) * (s + 1) + i + 1];
                let d = grid[(j + 1) * (s + 1) + i];
                self.push_tri(a, b, c, mat);
                self.push_tri(a, c, d, mat);
            }
        }
        self.close_chunk(start);
    }

    /// Axis-aligned box from `min` to `max`, each face subdivided.
    pub fn add_box(&mut self, min: Vec3, max: Vec3, mat: u32, subdiv: usize) {
        let d = max - min;
        let uvs = 1.0f32;
        // -y (bottom), +y (top)
        self.add_quad(min, v3(d.x, 0.0, 0.0), v3(0.0, 0.0, d.z), mat, subdiv, uvs);
        self.add_quad(
            v3(min.x, max.y, min.z),
            v3(0.0, 0.0, d.z),
            v3(d.x, 0.0, 0.0),
            mat,
            subdiv,
            uvs,
        );
        // -z, +z
        self.add_quad(min, v3(0.0, d.y, 0.0), v3(d.x, 0.0, 0.0), mat, subdiv, uvs);
        self.add_quad(
            v3(min.x, min.y, max.z),
            v3(d.x, 0.0, 0.0),
            v3(0.0, d.y, 0.0),
            mat,
            subdiv,
            uvs,
        );
        // -x, +x
        self.add_quad(min, v3(0.0, 0.0, d.z), v3(0.0, d.y, 0.0), mat, subdiv, uvs);
        self.add_quad(
            v3(max.x, min.y, min.z),
            v3(0.0, d.y, 0.0),
            v3(0.0, 0.0, d.z),
            mat,
            subdiv,
            uvs,
        );
    }

    /// Vertical cylinder (clutter objects): `segments` sides + fan caps.
    pub fn add_cylinder(
        &mut self,
        center: Vec3,
        radius: f32,
        height: f32,
        segments: usize,
        mat: u32,
    ) {
        let start = self.num_tris();
        let seg = segments.max(3);
        let mut bottom = Vec::with_capacity(seg);
        let mut top = Vec::with_capacity(seg);
        for k in 0..seg {
            let a = k as f32 / seg as f32 * std::f32::consts::TAU;
            let (s, c) = a.sin_cos();
            let p = v3(center.x + radius * c, center.y, center.z + radius * s);
            bottom.push(self.push_vert(p, v2(k as f32 / seg as f32, 0.0)));
            top.push(self.push_vert(
                v3(p.x, center.y + height, p.z),
                v2(k as f32 / seg as f32, 1.0),
            ));
        }
        for k in 0..seg {
            let k2 = (k + 1) % seg;
            self.push_tri(bottom[k], bottom[k2], top[k2], mat);
            self.push_tri(bottom[k], top[k2], top[k], mat);
        }
        // caps (fan around the first rim vertex)
        for k in 1..seg - 1 {
            self.push_tri(top[0], top[k], top[k + 1], mat);
            self.push_tri(bottom[0], bottom[k + 1], bottom[k], mat);
        }
        self.close_chunk(start);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quad_subdivision_counts() {
        let mut m = Mesh::default();
        m.add_quad(Vec3::ZERO, v3(1.0, 0.0, 0.0), v3(0.0, 0.0, 1.0), 0, 4, 1.0);
        assert_eq!(m.num_tris(), 32); // 4*4 cells * 2
        assert_eq!(m.positions.len(), 25);
        assert_eq!(m.chunks.len(), 1);
        assert_eq!(m.tri_material.len(), m.num_tris());
    }

    #[test]
    fn box_chunk_aabbs_cover_box() {
        let mut m = Mesh::default();
        m.add_box(v3(1.0, 0.0, 2.0), v3(2.0, 1.0, 3.0), 0, 2);
        assert_eq!(m.chunks.len(), 6);
        let total = m.aabb();
        assert_eq!(total.min, v3(1.0, 0.0, 2.0));
        assert_eq!(total.max, v3(2.0, 1.0, 3.0));
        assert_eq!(m.num_tris(), 6 * 8);
    }

    #[test]
    fn cylinder_closed_tri_count() {
        let mut m = Mesh::default();
        m.add_cylinder(Vec3::ZERO, 0.5, 1.0, 8, 1);
        // 8 sides * 2 + 2 caps * 6
        assert_eq!(m.num_tris(), 16 + 12);
        assert!(m.chunks.len() == 1);
        let b = m.aabb();
        assert!((b.max.y - 1.0).abs() < 1e-6);
        assert!((b.max.x - 0.5).abs() < 1e-6);
    }

    #[test]
    fn chunks_partition_triangles() {
        let mut m = Mesh::default();
        m.add_box(Vec3::ZERO, v3(1.0, 1.0, 1.0), 0, 1);
        m.add_cylinder(v3(3.0, 0.0, 0.0), 0.3, 1.0, 6, 1);
        let mut covered = vec![false; m.num_tris()];
        for c in &m.chunks {
            for t in c.tri_start..c.tri_start + c.tri_count {
                assert!(!covered[t as usize], "overlap at {t}");
                covered[t as usize] = true;
            }
        }
        assert!(covered.iter().all(|&b| b));
    }

    #[test]
    fn chunk_vert_ranges_cover_indices() {
        let mut m = Mesh::default();
        m.add_box(Vec3::ZERO, v3(1.0, 1.0, 1.0), 0, 2);
        m.add_cylinder(v3(3.0, 0.0, 0.0), 0.3, 1.0, 6, 1);
        assert_eq!(m.chunk_verts.len(), m.chunks.len());
        for (ci, c) in m.chunks.iter().enumerate() {
            let (lo, hi) = m.chunk_vert_range(ci);
            assert!(lo < hi);
            for t in c.tri_start..c.tri_start + c.tri_count {
                for k in 0..3 {
                    let vi = m.indices[t as usize * 3 + k] as usize;
                    assert!((lo..hi).contains(&vi), "chunk {ci} vert {vi} outside [{lo},{hi})");
                }
            }
        }
        // rebuild (the deserialization path) must agree with build-time ranges
        let built = m.chunk_verts.clone();
        m.rebuild_chunk_vert_ranges();
        assert_eq!(m.chunk_verts, built);
    }

    #[test]
    fn chunk_vert_range_fallback_scans() {
        let mut m = Mesh::default();
        m.add_box(Vec3::ZERO, v3(1.0, 1.0, 1.0), 0, 1);
        let built = m.chunk_vert_range(0);
        m.chunk_verts.clear(); // hand-assembled mesh: no recorded ranges
        assert_eq!(m.chunk_vert_range(0), built);
    }

    #[test]
    fn texture_sample_wraps() {
        let t = Texture {
            w: 2,
            h: 2,
            rgb: vec![255, 0, 0, 0, 255, 0, 0, 0, 255, 255, 255, 255],
        };
        assert_eq!(t.sample(0.0, 0.0), [1.0, 0.0, 0.0]);
        assert_eq!(t.sample(1.0, 1.0), t.sample(0.0, 0.0)); // wrap
        assert_eq!(t.sample(-0.25, 0.0), t.sample(0.75, 0.0));
    }

    #[test]
    fn geometry_bytes_positive() {
        let mut m = Mesh::default();
        m.add_box(Vec3::ZERO, v3(1.0, 1.0, 1.0), 0, 1);
        assert!(m.geometry_bytes() > 0);
    }
}
