//! Deterministic PRNG (xoshiro256**) used everywhere randomness is needed
//! on the Rust side: episode sampling, procedural scene generation, action
//! sampling. Self-contained (no `rand` crate in the offline vendor set) and
//! splittable so per-environment streams are independent and reproducible.

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via splitmix64 (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (e.g. one per environment).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). Lemire's unbiased method.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from a categorical distribution given by `logits`
    /// (softmax sampling — used for action selection during rollouts).
    /// Returns `(index, log_prob_of_index)`.
    pub fn categorical(&mut self, logits: &[f32]) -> (usize, f32) {
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for &l in logits {
            sum += (l - max).exp();
        }
        let log_z = sum.ln() + max;
        let u = self.f32() * sum;
        let mut acc = 0.0f32;
        let mut idx = logits.len() - 1;
        for (i, &l) in logits.iter().enumerate() {
            acc += (l - max).exp();
            if u < acc {
                idx = i;
                break;
            }
        }
        (idx, logits[idx] - log_z)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.gaussian() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_matches_softmax() {
        let logits = [1.0f32, 0.0, -1.0, 2.0];
        let max = 2.0f32;
        let z: f32 = logits.iter().map(|l| (l - max).exp()).sum();
        let probs: Vec<f32> = logits.iter().map(|l| (l - max).exp() / z).collect();
        let mut r = Rng::new(5);
        let mut counts = [0usize; 4];
        let trials = 200_000;
        for _ in 0..trials {
            let (i, lp) = r.categorical(&logits);
            counts[i] += 1;
            assert!((lp - probs[i].ln()).abs() < 1e-5);
        }
        for (i, &c) in counts.iter().enumerate() {
            let freq = c as f32 / trials as f32;
            assert!((freq - probs[i]).abs() < 0.01, "{i}: {freq} vs {}", probs[i]);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let xs: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
