//! Per-component timing for the paper's runtime breakdowns (Fig. 5,
//! Table A2: µs/frame spent in Simulation+Rendering / Inference / Learning).
//!
//! A `Profiler` accumulates named durations; `breakdown(frames)` converts to
//! µs-per-frame rows identical in shape to the paper's tables.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Accumulates wall-time per named component. Cheap enough for per-step use.
#[derive(Default)]
pub struct Profiler {
    acc: Mutex<BTreeMap<&'static str, (Duration, u64)>>,
}

/// RAII guard: adds elapsed time to its component when dropped.
pub struct Span<'a> {
    prof: &'a Profiler,
    name: &'static str,
    start: Instant,
}

impl Profiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start timing `name`; stops when the returned guard drops.
    pub fn span(&self, name: &'static str) -> Span<'_> {
        Span {
            prof: self,
            name,
            start: Instant::now(),
        }
    }

    /// Add an externally measured duration.
    pub fn add(&self, name: &'static str, d: Duration) {
        let mut acc = self.acc.lock().unwrap();
        let e = acc.entry(name).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Total accumulated time for one component.
    pub fn total(&self, name: &'static str) -> Duration {
        self.acc
            .lock()
            .unwrap()
            .get(name)
            .map(|e| e.0)
            .unwrap_or(Duration::ZERO)
    }

    pub fn count(&self, name: &'static str) -> u64 {
        self.acc.lock().unwrap().get(name).map(|e| e.1).unwrap_or(0)
    }

    /// µs per frame for every component, given the number of frames
    /// (samples of experience) processed — the paper's breakdown unit.
    pub fn breakdown(&self, frames: u64) -> Vec<(String, f64)> {
        let acc = self.acc.lock().unwrap();
        acc.iter()
            .map(|(k, (d, _))| {
                (k.to_string(), d.as_secs_f64() * 1e6 / frames.max(1) as f64)
            })
            .collect()
    }

    pub fn reset(&self) {
        self.acc.lock().unwrap().clear();
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        self.prof.add(self.name, self.start.elapsed());
    }
}

/// Frames-per-second meter using the paper's methodology (§4.1): samples of
/// experience processed divided by wall time of rollout + training.
pub struct FpsMeter {
    start: Instant,
    frames: u64,
}

impl FpsMeter {
    pub fn start() -> Self {
        FpsMeter {
            start: Instant::now(),
            frames: 0,
        }
    }

    pub fn add_frames(&mut self, n: u64) {
        self.frames += n;
    }

    pub fn frames(&self) -> u64 {
        self.frames
    }

    pub fn fps(&self) -> f64 {
        self.frames as f64 / self.start.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_accumulates() {
        let p = Profiler::new();
        for _ in 0..3 {
            let _s = p.span("sim");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(p.count("sim"), 3);
        assert!(p.total("sim") >= Duration::from_millis(6));
    }

    #[test]
    fn breakdown_per_frame() {
        let p = Profiler::new();
        p.add("render", Duration::from_micros(1000));
        p.add("infer", Duration::from_micros(3000));
        let rows = p.breakdown(100);
        let map: std::collections::BTreeMap<_, _> = rows.into_iter().collect();
        assert!((map["render"] - 10.0).abs() < 1e-9);
        assert!((map["infer"] - 30.0).abs() < 1e-9);
    }

    #[test]
    fn fps_meter_counts() {
        let mut m = FpsMeter::start();
        m.add_frames(500);
        m.add_frames(500);
        assert_eq!(m.frames(), 1000);
        assert!(m.fps() > 0.0);
    }

    #[test]
    fn zero_frames_no_panic() {
        let p = Profiler::new();
        p.add("x", Duration::from_micros(5));
        let _ = p.breakdown(0);
    }
}
