//! Persistent worker pool with dynamic scheduling (paper §3.1).
//!
//! The batch simulator operates on "significantly more environments than
//! available CPU cores and dynamically schedules work onto cores using a
//! pool of worker threads". This module is that pool: N persistent threads,
//! a broadcast "current task" slot, and an atomic grab-next-chunk index so
//! fast environments do not wait for slow ones (the workload-imbalance
//! problem that motivates the design).
//!
//! `parallel_for` borrows its closure (no `'static` bound) — the pool
//! guarantees every worker has finished with the closure before returning,
//! which is what makes the internal pointer-erasure sound. The erasure
//! itself is a plain raw-pointer cast ([`erase`]); the only `unsafe` is
//! the dereference inside [`task::Task::run`], whose liveness argument is
//! spelled out at the deref site.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use task::Task;

/// Erase the caller-stack lifetime from a borrowed task closure.
///
/// A plain coercion cannot turn `&'a (dyn Fn(usize) + Sync + 'a)` into
/// `*const (dyn Fn(usize) + Sync)` because the unadorned trait-object
/// pointer type implies a `'static` bound. Raw-pointer `as` casts,
/// however, may change only the lifetime bound of a trait object (the
/// vtable and principal trait are identical), so the two-step cast below
/// is the documented, transmute-free spelling of the same erasure. The
/// cast itself is safe; all obligations attach to the later dereference.
///
/// Contract for the single caller (`parallel_for`): the returned pointer
/// must not be dereferenced after `'a` ends. `Task::run` documents how
/// the completion protocol enforces that.
fn erase<'a>(f: &'a (dyn Fn(usize) + Sync + 'a)) -> *const (dyn Fn(usize) + Sync) {
    f as *const (dyn Fn(usize) + Sync + 'a) as *const (dyn Fn(usize) + Sync)
}

/// Private home of [`Task`]: keeps the erased pointer and the completion
/// protocol's fields inaccessible outside this block, so every use goes
/// through `new`/`run`/`wait_done` and the liveness argument below stays
/// local to one screen of code.
mod task {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Condvar, Mutex};

    pub(super) struct Task {
        /// Type-erased `&dyn Fn(usize)` (see [`super::erase`]) valid until
        /// [`wait_done`](Task::wait_done) returns.
        func: *const (dyn Fn(usize) + Sync),
        /// Next unclaimed index (chunk grab cursor).
        next: AtomicUsize,
        end: usize,
        grain: usize,
        /// Indices fully executed; reaching `end` flips `done`.
        completed: AtomicUsize,
        done: Mutex<bool>,
        done_cv: Condvar,
    }

    // SAFETY: the raw `func` pointer is the only non-Send field; it is
    // produced from a `&(dyn Fn + Sync)` that outlives the task (the
    // submitting thread blocks in `wait_done` until every worker is out
    // of `run`), so sending the Task to worker threads cannot outlive
    // the pointee.
    unsafe impl Send for Task {}
    // SAFETY: sharing `&Task` across threads shares `*const dyn Fn` and
    // atomics/locks. The pointee is `Sync` (bound on the erased type),
    // so concurrent `&`-calls through `func` are permitted.
    unsafe impl Sync for Task {}

    impl Task {
        /// Wrap an erased closure for one `parallel_for` batch.
        ///
        /// Contract: `func` must stay dereferenceable until `wait_done`
        /// returns (the submitter must not drop the closure earlier).
        pub(super) fn new(func: *const (dyn Fn(usize) + Sync), end: usize, grain: usize) -> Task {
            Task {
                func,
                next: AtomicUsize::new(0),
                end,
                grain,
                completed: AtomicUsize::new(0),
                done: Mutex::new(false),
                done_cv: Condvar::new(),
            }
        }

        /// Claim and execute chunks until the index range is exhausted.
        pub(super) fn run(&self) {
            loop {
                let start = self.next.fetch_add(self.grain, Ordering::Relaxed);
                if start >= self.end {
                    break;
                }
                let stop = (start + self.grain).min(self.end);
                // SAFETY: the pointee is still alive *here*. `wait_done`
                // cannot return (so the borrowed closure cannot drop)
                // before `completed` reaches `end`, and this chunk's
                // indices have not been counted into `completed` yet —
                // claiming a ticket below `end` therefore pins the
                // closure until the `fetch_add` below. Workers that
                // arrive after completion observe `start >= end` and
                // break above without ever touching `func`. The pointee
                // is `Sync`, so concurrent `&`-calls are allowed.
                let f = unsafe { &*self.func };
                for i in start..stop {
                    f(i);
                }
                let prev = self.completed.fetch_add(stop - start, Ordering::AcqRel);
                if prev + (stop - start) == self.end {
                    *self.done.lock().unwrap() = true;
                    self.done_cv.notify_all();
                }
            }
        }

        /// Block until every index has fully executed (i.e. every worker
        /// has returned from the closure). This is the fence that makes
        /// the lifetime erasure sound.
        pub(super) fn wait_done(&self) {
            let mut done = self.done.lock().unwrap();
            while !*done {
                done = self.done_cv.wait(done).unwrap();
            }
        }
    }
}

struct Shared {
    slot: Mutex<(u64, Option<Arc<Task>>)>,
    cv: Condvar,
    shutdown: AtomicUsize,
}

/// Persistent dynamic-scheduling thread pool.
pub struct WorkerPool {
    shared: Arc<Shared>,
    threads: Vec<JoinHandle<()>>,
    serialize: Mutex<()>,
    n_workers: usize,
}

impl WorkerPool {
    /// `n_threads` worker threads (0 = caller-only execution, still correct).
    pub fn new(n_threads: usize) -> Self {
        let shared = Arc::new(Shared {
            slot: Mutex::new((0, None)),
            cv: Condvar::new(),
            shutdown: AtomicUsize::new(0),
        });
        let threads = (0..n_threads)
            .map(|_| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&sh))
            })
            .collect();
        WorkerPool {
            shared,
            threads,
            serialize: Mutex::new(()),
            n_workers: n_threads,
        }
    }

    /// Pool sized for the current machine (leaves one core for the OS).
    pub fn default_size() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get().saturating_sub(1).max(1))
            .unwrap_or(4)
    }

    pub fn num_workers(&self) -> usize {
        self.n_workers
    }

    /// Run `f(i)` for every `i in 0..n`, dynamically scheduled in chunks of
    /// `grain`. Blocks until every call has returned. The caller thread
    /// participates, so progress is guaranteed even with 0 workers.
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, n: usize, grain: usize, f: F) {
        if n == 0 {
            return;
        }
        let grain = grain.max(1);
        // One batch at a time: the slot is a broadcast of the current task.
        let _guard = self.serialize.lock().unwrap();
        let fref: &(dyn Fn(usize) + Sync) = &f;
        // Lifetime-erasing cast (no unsafe): `task.wait_done()` below keeps
        // `f` alive until every worker has left the closure.
        let task = Arc::new(Task::new(erase(fref), n, grain));
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.0 += 1;
            slot.1 = Some(Arc::clone(&task));
            self.shared.cv.notify_all();
        }
        // The caller helps until the index range is exhausted...
        task.run();
        // ...then waits for stragglers still inside `f`.
        task.wait_done();
        // Clear the slot so idle workers stop re-checking a finished task.
        let mut slot = self.shared.slot.lock().unwrap();
        slot.1 = None;
    }

    /// Two-stage pipelined loop on the persistent workers (paper §3.2's
    /// overlapped cull→raster, generalized): `stage1` runs exactly once per
    /// index — tickets are claimed from an atomic cursor and *published* in
    /// index order through a lock-free readiness counter — and `stage2(k)`
    /// runs once `stage1(0..=k)` have all been published. A worker whose
    /// stage-2 item is not ready yet helps drain the stage-1 ticket queue
    /// instead of blocking, so the two stages overlap with no extra
    /// threads, channels, or locks. Blocks until every `stage2` returned;
    /// the caller thread participates, so 0 workers still completes.
    pub fn staged_for<F1, F2>(&self, n: usize, stage1: F1, stage2: F2)
    where
        F1: Fn(usize) + Sync,
        F2: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let cursor = AtomicUsize::new(0); // next stage-1 ticket
        let ready = AtomicUsize::new(0); // published stage-1 prefix length
        self.parallel_for(n, 1, |k| {
            while ready.load(Ordering::Acquire) <= k {
                // relaxed: an advisory peek only — a stale read merely
                // takes one extra trip through the ticket fetch_add (whose
                // bound is re-checked); correctness rests on the Acquire
                // reads of `ready`, never on this load. Once every ticket
                // is claimed this waits without hammering the cursor cache
                // line with RMWs (the cullers still need it).
                if cursor.load(Ordering::Relaxed) >= n {
                    std::hint::spin_loop();
                    continue;
                }
                let t = cursor.fetch_add(1, Ordering::Relaxed);
                if t < n {
                    stage1(t);
                    // publish in ticket order so `ready` stays a prefix
                    while ready.load(Ordering::Acquire) != t {
                        std::hint::spin_loop();
                    }
                    ready.store(t + 1, Ordering::Release);
                } else {
                    std::hint::spin_loop();
                }
            }
            stage2(k);
        });
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(1, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn worker_loop(sh: &Shared) {
    let mut seen_gen = 0u64;
    loop {
        let task = {
            let mut slot = sh.slot.lock().unwrap();
            loop {
                if sh.shutdown.load(Ordering::SeqCst) == 1 {
                    return;
                }
                if slot.0 != seen_gen {
                    if let Some(t) = slot.1.clone() {
                        seen_gen = slot.0;
                        break t;
                    }
                    seen_gen = slot.0;
                }
                slot = sh.cv.wait(slot).unwrap();
            }
        };
        task.run();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    /// Miri runs these tests too (CI `miri` job) at a fraction of the
    /// index space — enough to exercise multi-chunk, multi-worker
    /// interleavings without minutes of interpreted spinning.
    fn sized(native: usize, miri: usize) -> usize {
        if cfg!(miri) {
            miri
        } else {
            native
        }
    }

    #[test]
    fn all_indices_visited_exactly_once() {
        let pool = WorkerPool::new(4);
        let n = sized(10_000, 128);
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(n, 7, |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn zero_workers_still_completes() {
        let pool = WorkerPool::new(0);
        let sum = AtomicU64::new(0);
        pool.parallel_for(100, 1, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // wall-clock assertion; meaningless interpreted
    fn imbalanced_work_dynamic_schedule() {
        // A few very slow items must not serialize the rest: with dynamic
        // scheduling total wall time ~= slow item, not sum of all.
        let pool = WorkerPool::new(4);
        let start = std::time::Instant::now();
        pool.parallel_for(64, 1, |i| {
            if i % 16 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        });
        let elapsed = start.elapsed();
        assert!(elapsed.as_millis() < 60, "took {elapsed:?}");
    }

    #[test]
    fn reusable_across_batches() {
        let pool = WorkerPool::new(3);
        for round in 0..sized(50, 8) {
            let sum = AtomicU64::new(0);
            pool.parallel_for(round + 1, 4, |i| {
                sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
            });
            let n = (round + 1) as u64;
            assert_eq!(sum.load(Ordering::Relaxed), n * (n + 1) / 2);
        }
    }

    #[test]
    fn empty_batch_is_noop() {
        let pool = WorkerPool::new(2);
        pool.parallel_for(0, 8, |_| panic!("must not run"));
    }

    #[test]
    fn staged_for_runs_each_stage_once_in_order() {
        use std::sync::atomic::AtomicBool;
        let pool = WorkerPool::new(4);
        let n = sized(500, 24);
        let s1: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
        let s1_count: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let s2_count: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.staged_for(
            n,
            |t| {
                s1_count[t].fetch_add(1, Ordering::Relaxed);
                s1[t].store(true, Ordering::Release);
            },
            |k| {
                // contract: stage1 of every index <= k has been published
                for flag in &s1[..=k] {
                    assert!(flag.load(Ordering::Acquire), "stage2({k}) before stage1");
                }
                s2_count[k].fetch_add(1, Ordering::Relaxed);
            },
        );
        for i in 0..n {
            assert_eq!(s1_count[i].load(Ordering::Relaxed), 1, "stage1 {i}");
            assert_eq!(s2_count[i].load(Ordering::Relaxed), 1, "stage2 {i}");
        }
    }

    #[test]
    fn staged_for_zero_workers_and_empty() {
        let pool = WorkerPool::new(0);
        pool.staged_for(0, |_| panic!("stage1"), |_| panic!("stage2"));
        let sum = AtomicU64::new(0);
        pool.staged_for(
            64,
            |t| {
                sum.fetch_add(t as u64, Ordering::Relaxed);
            },
            |k| {
                sum.fetch_add(k as u64 * 1000, Ordering::Relaxed);
            },
        );
        let base = (0..64u64).sum::<u64>();
        assert_eq!(sum.load(Ordering::Relaxed), base + base * 1000);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // wall-clock assertion; meaningless interpreted
    fn staged_for_imbalanced_stage2_overlaps() {
        // stage1 is cheap; a slow stage-2 item must not serialize the rest
        let pool = WorkerPool::new(4);
        let start = std::time::Instant::now();
        pool.staged_for(
            32,
            |_| {},
            |k| {
                if k == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(25));
                }
            },
        );
        assert!(start.elapsed().as_millis() < 80, "took {:?}", start.elapsed());
    }

    #[test]
    fn borrows_local_state() {
        let pool = WorkerPool::new(2);
        let data: Vec<u64> = (0..sized(1000, 200) as u64).collect();
        let sum = AtomicU64::new(0);
        let expect: u64 = data.iter().sum();
        pool.parallel_for(data.len(), 16, |i| {
            sum.fetch_add(data[i], Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), expect);
    }
}
