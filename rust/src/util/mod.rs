//! Shared infrastructure: PRNG, JSON/TOML parsing, argv parsing, the
//! dynamic-scheduling worker pool (paper §3.1), timers, and the
//! property-test driver. Everything is dependency-free (offline build).

pub mod args;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod timer;
pub mod toml;

pub use json::Json;
pub use pool::WorkerPool;
pub use rng::Rng;
pub use timer::{FpsMeter, Profiler};
