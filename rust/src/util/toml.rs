//! Minimal TOML-subset parser for the config system (no `toml` crate in the
//! offline vendor set). Supports: `[section]` headers, `key = value` with
//! string / bool / integer / float / homogeneous-array values, `#` comments
//! and blank lines. This covers every config file the launcher accepts.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum TomlVal {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
    Arr(Vec<TomlVal>),
}

impl TomlVal {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlVal::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlVal::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            TomlVal::Int(i) => Ok(*i),
            _ => bail!("expected integer, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        if i < 0 {
            bail!("expected non-negative integer, got {i}");
        }
        Ok(i as usize)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlVal::Float(f) => Ok(*f),
            TomlVal::Int(i) => Ok(*i as f64),
            _ => bail!("expected float, got {self:?}"),
        }
    }
}

/// section -> key -> value. Top-level keys live in section `""`.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlVal>>;

pub fn parse(text: &str) -> Result<TomlDoc> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected `key = value`", lineno + 1);
        };
        let key = line[..eq].trim();
        let val = line[eq + 1..].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let parsed = parse_value(val)
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        doc.get_mut(&section).unwrap().insert(key.to_string(), parsed);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<TomlVal> {
    if v.is_empty() {
        bail!("empty value");
    }
    if let Some(body) = v.strip_prefix('"') {
        let Some(body) = body.strip_suffix('"') else {
            bail!("unterminated string");
        };
        return Ok(TomlVal::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if v == "true" {
        return Ok(TomlVal::Bool(true));
    }
    if v == "false" {
        return Ok(TomlVal::Bool(false));
    }
    if let Some(body) = v.strip_prefix('[') {
        let Some(body) = body.strip_suffix(']') else {
            bail!("unterminated array");
        };
        let mut out = Vec::new();
        for item in split_top_level(body) {
            let item = item.trim();
            if !item.is_empty() {
                out.push(parse_value(item)?);
            }
        }
        return Ok(TomlVal::Arr(out));
    }
    if !v.contains('.') && !v.contains('e') && !v.contains('E') {
        if let Ok(i) = v.replace('_', "").parse::<i64>() {
            return Ok(TomlVal::Int(i));
        }
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(TomlVal::Float(f));
    }
    bail!("cannot parse value {v:?}")
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_document() {
        let doc = parse(
            r#"
# top comment
seed = 42
name = "run-a"   # trailing comment

[sim]
num_envs = 256
forward_step = 0.25
tasks = ["pointnav", "flee"]
verbose = true
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["seed"], TomlVal::Int(42));
        assert_eq!(doc[""]["name"].as_str().unwrap(), "run-a");
        assert_eq!(doc["sim"]["num_envs"].as_usize().unwrap(), 256);
        assert!((doc["sim"]["forward_step"].as_f64().unwrap() - 0.25).abs() < 1e-12);
        assert_eq!(
            doc["sim"]["tasks"],
            TomlVal::Arr(vec![
                TomlVal::Str("pointnav".into()),
                TomlVal::Str("flee".into())
            ])
        );
        assert!(doc["sim"]["verbose"].as_bool().unwrap());
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = parse("k = \"a#b\"").unwrap();
        assert_eq!(doc[""]["k"].as_str().unwrap(), "a#b");
    }

    #[test]
    fn int_vs_float() {
        let doc = parse("a = 3\nb = 3.0\nc = 1e-4").unwrap();
        assert_eq!(doc[""]["a"], TomlVal::Int(3));
        assert_eq!(doc[""]["b"], TomlVal::Float(3.0));
        assert!((doc[""]["c"].as_f64().unwrap() - 1e-4).abs() < 1e-18);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[oops").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("k = \"unterminated").is_err());
        assert!(parse("k = [1, 2").is_err());
    }

    #[test]
    fn int_conversion_bounds() {
        let doc = parse("neg = -1").unwrap();
        assert!(doc[""]["neg"].as_usize().is_err());
        assert_eq!(doc[""]["neg"].as_i64().unwrap(), -1);
    }
}
