//! Minimal JSON parser + writer (no serde_json in the offline vendor set).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and
//! the metrics JSONL output: objects, arrays, strings (with escapes),
//! numbers, booleans, null. Not streaming; fine for MB-scale manifests.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    // -- writer --------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders for metrics output.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.bytes[self.pos] as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| anyhow!("{e}"))?,
                                16,
                            )?;
                            self.pos += 4;
                            // Surrogate pairs: only BMP needed for manifests.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        c => bail!("bad escape {:?}", c as char),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at pos-1.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        bail!("truncated utf-8");
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|e| anyhow!("{e}"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number {text:?} at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("a").unwrap().as_arr().unwrap()[2]
                .req("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"layout":[{"name":"s0.w","offset":0,"shape":[3,3,16,8]}],"num_params":124633,"ok":true,"pi":3.5,"s":"a\"b\\c"}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse("\"héllo → ∞\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → ∞");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Json::parse("{\"n\": 1.5}").unwrap();
        assert!(v.req("n").unwrap().as_usize().is_err());
        assert!(v.req("missing").is_err());
        assert!(v.req("n").unwrap().as_str().is_err());
    }
}
