//! Tiny argv parser for the CLI (no `clap` in the offline vendor set).
//!
//! Grammar: `bps <subcommand> [operand ...] [--key value | --key=value |
//! --flag] ...`. Positional operands after the subcommand (e.g. the
//! address in `bps connect 127.0.0.1:7447`) are consumed in order via
//! `operand()`. Typed getters consume recognized options; `finish()`
//! errors on leftovers so typos are caught instead of silently ignored.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    operands: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.opts.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                out.operands.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    /// Consume the next positional operand (in argv order).
    pub fn operand(&mut self) -> Option<String> {
        if self.operands.is_empty() {
            None
        } else {
            Some(self.operands.remove(0))
        }
    }

    /// Consume a string option.
    pub fn opt(&mut self, name: &str) -> Option<String> {
        self.opts.remove(name)
    }

    pub fn opt_or(&mut self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or_else(|| default.to_string())
    }

    pub fn req(&mut self, name: &str) -> Result<String> {
        self.opt(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    pub fn usize_or(&mut self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}: invalid integer {v:?}: {e}")),
        }
    }

    pub fn u64_or(&mut self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}: invalid integer {v:?}: {e}")),
        }
    }

    pub fn f64_or(&mut self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}: invalid float {v:?}: {e}")),
        }
    }

    /// Consume a boolean flag (`--verbose`; explicit `--verbose=true` /
    /// `=false` also accepted). A flag followed by a bare token parses
    /// as `--flag value` — when that happens the captured value was
    /// almost certainly a positional operand (`bps serve --once ADDR`),
    /// so it is an error here rather than a silently swallowed address.
    pub fn flag(&mut self, name: &str) -> Result<bool> {
        if let Some(pos) = self.flags.iter().position(|f| f == name) {
            self.flags.remove(pos);
            return Ok(true);
        }
        match self.opt(name).as_deref() {
            None => Ok(false),
            Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(v) => bail!(
                "--{name} is a flag and takes no value (got {v:?}); \
                 put positional arguments before flags, or write --{name}=true"
            ),
        }
    }

    /// Error if any positional operand was not consumed — subcommands
    /// that take no operands call this (via `main`) so a stray
    /// positional is rejected like it was before operands existed.
    pub fn ensure_no_operands(&self) -> Result<()> {
        if let Some(o) = self.operands.first() {
            bail!("unexpected positional argument {o:?}");
        }
        Ok(())
    }

    /// Error if any option/flag/operand was not consumed (catches typos).
    pub fn finish(self) -> Result<()> {
        if let Some(k) = self.opts.keys().next() {
            bail!("unknown option --{k}");
        }
        if let Some(f) = self.flags.first() {
            bail!("unknown flag --{f}");
        }
        if let Some(o) = self.operands.first() {
            bail!("unexpected positional argument {o:?}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let mut a = Args::parse(&argv("train --preset depth64 --iters=10 --verbose")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.opt("preset").as_deref(), Some("depth64"));
        assert_eq!(a.usize_or("iters", 0).unwrap(), 10);
        assert!(a.flag("verbose").unwrap());
        a.finish().unwrap();
    }

    #[test]
    fn leftover_option_is_error() {
        let a = Args::parse(&argv("train --oops 1")).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn missing_required() {
        let mut a = Args::parse(&argv("eval")).unwrap();
        assert!(a.req("checkpoint").is_err());
    }

    #[test]
    fn defaults_apply() {
        let mut a = Args::parse(&argv("bench")).unwrap();
        assert_eq!(a.usize_or("envs", 64).unwrap(), 64);
        assert!((a.f64_or("lr", 2.5e-4).unwrap() - 2.5e-4).abs() < 1e-12);
    }

    #[test]
    fn operands_consumed_in_order_or_rejected() {
        let mut a = Args::parse(&argv("connect 127.0.0.1:7447 --envs 4")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("connect"));
        assert_eq!(a.operand().as_deref(), Some("127.0.0.1:7447"));
        assert!(a.operand().is_none());
        assert_eq!(a.usize_or("envs", 0).unwrap(), 4);
        a.finish().unwrap();
        // an unconsumed operand is caught by ensure_no_operands/finish
        let a = Args::parse(&argv("a b")).unwrap();
        assert!(a.ensure_no_operands().is_err());
        assert!(a.finish().is_err());
    }

    #[test]
    fn flag_that_swallowed_an_operand_is_an_error() {
        // `--once 0.0.0.0:9000` parses as a key/value pair; flag() must
        // surface the mistake instead of silently dropping the address
        let mut a = Args::parse(&argv("serve --once 0.0.0.0:9000")).unwrap();
        let err = a.flag("once").unwrap_err().to_string();
        assert!(err.contains("takes no value"), "got: {err}");
        // explicit boolean values stay accepted
        let mut a = Args::parse(&argv("serve --once=true --list=false")).unwrap();
        assert!(a.flag("once").unwrap());
        assert!(!a.flag("list").unwrap());
        assert!(!a.flag("absent").unwrap());
    }
}
