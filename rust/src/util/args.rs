//! Tiny argv parser for the CLI (no `clap` in the offline vendor set).
//!
//! Grammar: `bps <subcommand> [--key value | --key=value | --flag] ...`.
//! Typed getters consume recognized options; `finish()` errors on leftovers
//! so typos are caught instead of silently ignored.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.opts.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                bail!("unexpected positional argument {a:?}");
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    /// Consume a string option.
    pub fn opt(&mut self, name: &str) -> Option<String> {
        self.opts.remove(name)
    }

    pub fn opt_or(&mut self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or_else(|| default.to_string())
    }

    pub fn req(&mut self, name: &str) -> Result<String> {
        self.opt(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    pub fn usize_or(&mut self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}: invalid integer {v:?}: {e}")),
        }
    }

    pub fn u64_or(&mut self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}: invalid integer {v:?}: {e}")),
        }
    }

    pub fn f64_or(&mut self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}: invalid float {v:?}: {e}")),
        }
    }

    /// Consume a boolean flag (`--verbose`).
    pub fn flag(&mut self, name: &str) -> bool {
        if let Some(pos) = self.flags.iter().position(|f| f == name) {
            self.flags.remove(pos);
            true
        } else {
            false
        }
    }

    /// Error if any option/flag was not consumed (catches typos).
    pub fn finish(self) -> Result<()> {
        if let Some(k) = self.opts.keys().next() {
            bail!("unknown option --{k}");
        }
        if let Some(f) = self.flags.first() {
            bail!("unknown flag --{f}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let mut a = Args::parse(&argv("train --preset depth64 --iters=10 --verbose")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.opt("preset").as_deref(), Some("depth64"));
        assert_eq!(a.usize_or("iters", 0).unwrap(), 10);
        assert!(a.flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn leftover_option_is_error() {
        let a = Args::parse(&argv("train --oops 1")).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn missing_required() {
        let mut a = Args::parse(&argv("eval")).unwrap();
        assert!(a.req("checkpoint").is_err());
    }

    #[test]
    fn defaults_apply() {
        let mut a = Args::parse(&argv("bench")).unwrap();
        assert_eq!(a.usize_or("envs", 64).unwrap(), 64);
        assert!((a.f64_or("lr", 2.5e-4).unwrap() - 2.5e-4).abs() < 1e-12);
    }

    #[test]
    fn double_positional_rejected() {
        assert!(Args::parse(&argv("a b")).is_err());
    }
}
