//! Hand-rolled property-testing driver (no `proptest` in the offline vendor
//! set). `check` runs a property against many seeded random cases and, on
//! failure, reports the seed so the case can be replayed deterministically:
//!
//! ```ignore
//! prop::check("astar_symmetric", 200, |rng| {
//!     let g = random_navmesh(rng);
//!     /* ... assertions ... */
//! });
//! ```

use super::rng::Rng;

/// Run `property` against `cases` deterministic random cases. Panics with
/// the failing case's seed on assertion failure.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    property: F,
) {
    // Base seed is fixed so CI is reproducible; override with PROP_SEED.
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xB5_u64);
    for case in 0..cases {
        let seed = base
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            property(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}):\n{msg}\n\
                 replay with PROP_SEED={base} and case index {case}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        // count via a cell-free trick: check is Fn, so use an atomic
        let counter = std::sync::atomic::AtomicU64::new(0);
        check("trivial", 50, |rng| {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
            counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        count += counter.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property \"always_fails\" failed")]
    fn failing_property_reports_seed() {
        check("always_fails", 3, |_| panic!("boom"));
    }

    #[test]
    fn deterministic_across_runs() {
        let a = std::sync::Mutex::new(Vec::new());
        check("collect", 5, |rng| a.lock().unwrap().push(rng.next_u64()));
        let b = std::sync::Mutex::new(Vec::new());
        check("collect", 5, |rng| b.lock().unwrap().push(rng.next_u64()));
        assert_eq!(*a.lock().unwrap(), *b.lock().unwrap());
    }
}
