//! `EnvBatchConfig`: the builder for [`EnvBatch`](super::EnvBatch).
//!
//! Two scene sources cover every workload in the repo:
//! - [`build_with_scenes`](EnvBatchConfig::build_with_scenes): an explicit
//!   env → scene assignment (eval, Workers arch, tests, benches);
//! - [`build_with_rotation`](EnvBatchConfig::build_with_rotation): the
//!   K-slot [`SceneRotation`] with background asset streaming (BPS arch).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::render::{RenderConfig, SceneRotation};
use crate::scene::SceneAsset;
use crate::sim::{SimConfig, Task};
use crate::util::pool::WorkerPool;

use super::batch::EnvBatch;

/// Everything needed to stand up one batched environment.
#[derive(Clone, Copy, Debug)]
pub struct EnvBatchConfig {
    /// Simulator parameters (task, step sizes, episode limits).
    pub sim: SimConfig,
    /// Renderer parameters (resolution, sensor, supersampling, pipeline).
    pub render: RenderConfig,
    /// Master seed for episode sampling across the batch.
    pub seed: u64,
    /// Double-buffered pipelined stepping: when true (default) a driver
    /// thread overlaps simulation+rendering of step t+1 with the caller's
    /// consumption of step t. When false, steps execute inline on the
    /// caller thread. Output is bitwise-identical either way.
    pub overlap: bool,
    /// Pin the scene-rotation schedule to call counts: `Some(k)` makes
    /// every k-th [`EnvBatch::rotate_scenes`](super::EnvBatch::rotate_scenes)
    /// call perform exactly one *blocking* slot swap (waiting for the
    /// prefetched asset), all other calls a no-op. `None` (default) keeps
    /// the non-blocking poll, whose swap iteration depends on load
    /// latency. Pinning makes pipelined-vs-sync A/B runs exactly
    /// reproducible with prefetch active.
    pub rotate_every: Option<u64>,
}

impl EnvBatchConfig {
    /// Start a config for `task` with the given render settings.
    pub fn new(task: Task, render: RenderConfig) -> EnvBatchConfig {
        EnvBatchConfig {
            sim: SimConfig::for_task(task),
            render,
            seed: 0,
            overlap: true,
            rotate_every: None,
        }
    }

    /// Override the full simulator config (custom step sizes / limits).
    pub fn sim(mut self, sim: SimConfig) -> EnvBatchConfig {
        self.sim = sim;
        self
    }

    /// Set the batch seed.
    pub fn seed(mut self, seed: u64) -> EnvBatchConfig {
        self.seed = seed;
        self
    }

    /// Enable/disable the pipelined double-buffered driver.
    pub fn overlap(mut self, overlap: bool) -> EnvBatchConfig {
        self.overlap = overlap;
        self
    }

    /// Pin the rotation schedule: every `every`-th `rotate_scenes` call
    /// performs one blocking slot swap (see the `rotate_every` field).
    pub fn pin_rotation(mut self, every: u64) -> EnvBatchConfig {
        self.rotate_every = Some(every.max(1));
        self
    }

    /// Build over an explicit env → scene assignment (no rotation).
    pub fn build_with_scenes(
        self,
        scenes: Vec<Arc<SceneAsset>>,
        pool: Arc<WorkerPool>,
    ) -> Result<EnvBatch> {
        if scenes.is_empty() {
            bail!("EnvBatch needs at least one environment");
        }
        EnvBatch::build(self, scenes, None, pool)
    }

    /// Build `n` environments over a K-slot scene rotation; the rotation's
    /// background streamer keeps swapping fresh scenes in at episode
    /// resets (drive it with [`EnvBatch::rotate_scenes`]).
    pub fn build_with_rotation(
        self,
        rotation: SceneRotation,
        n: usize,
        pool: Arc<WorkerPool>,
    ) -> Result<EnvBatch> {
        if n == 0 {
            bail!("EnvBatch needs at least one environment");
        }
        let scenes = rotation.assign(n);
        EnvBatch::build(self, scenes, Some(rotation), pool)
    }
}
