//! [`EnvBatch`]: the request/response batched environment server.
//!
//! One `EnvBatch` owns N environments (the `BatchSim`), their renderer
//! (`BatchRenderer`), and optionally the K-slot `SceneRotation`. Clients
//! never touch those internals; they drive the batch through
//! [`submit`](EnvBatch::submit) / [`StepHandle::wait`] and read results as
//! borrowed SoA slices via [`StepView`].
//!
//! ## Double buffering
//!
//! Two `StepBuffers` (observation megaframe + `SimOutputs`) rotate between
//! the caller and the step executor. In pipelined mode the executor is a
//! dedicated driver thread: `submit` *moves* the back buffer and the action
//! vector to it over a channel, the driver runs sim → render on the shared
//! `WorkerPool`, and `wait` moves the filled buffer back and swaps it in as
//! the new front. The caller keeps full read access to the front buffer
//! (via [`StepHandle::current`]) for the whole in-flight window — that is
//! the paper's overlap of inference/bookkeeping on step *t* with
//! simulation+rendering of step *t+1* (Fig. 2). Because ownership moves,
//! no `unsafe` is needed at this layer.
//!
//! Determinism: the sim's per-env RNG streams and the renderer are
//! independent of worker count and scheduling, so pipelined and
//! synchronous stepping produce bitwise-identical tensors for the same
//! seed, action sequence, and scene-rotation schedule (asserted in
//! `rust/tests/env_batch.rs`). An active rotation prefetch swaps scenes
//! at wall-clock-dependent iterations in either mode unless the schedule
//! is pinned to call counts via [`EnvBatchConfig::pin_rotation`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::obs::Heartbeat;
use crate::render::batch::RenderCounters;
use crate::render::{BatchRenderer, RenderItem, RenderStats, SceneRotation, Sensor};
use crate::scene::SceneAsset;
use crate::sim::{BatchSim, SimOutputs, Task};
use crate::util::pool::WorkerPool;

use super::config::EnvBatchConfig;

/// One rotating buffer: the observation megaframe plus the SoA outputs.
struct StepBuffers {
    obs: Vec<f32>,
    out: SimOutputs,
}

impl StepBuffers {
    fn new(n: usize, obs_floats: usize) -> StepBuffers {
        StepBuffers {
            obs: vec![0.0; n * obs_floats],
            out: SimOutputs::with_capacity(n),
        }
    }
}

/// Wall-time spent in sim / render, accumulated by the executor and
/// drained by the client (feeds the paper's runtime-breakdown profiling).
#[derive(Default)]
struct StepTimings {
    sim_ns: AtomicU64,
    render_ns: AtomicU64,
}

impl StepTimings {
    fn add(&self, sim: Duration, render: Duration) {
        self.sim_ns
            .fetch_add(sim.as_nanos() as u64, Ordering::Relaxed);
        self.render_ns
            .fetch_add(render.as_nanos() as u64, Ordering::Relaxed);
    }

    fn drain(&self) -> (Duration, Duration) {
        (
            Duration::from_nanos(self.sim_ns.swap(0, Ordering::Relaxed)),
            Duration::from_nanos(self.render_ns.swap(0, Ordering::Relaxed)),
        )
    }
}

/// The simulation world: everything the step executor owns. Lives on the
/// driver thread in pipelined mode, inline in synchronous mode.
struct EnvWorld {
    sim: BatchSim,
    renderer: BatchRenderer,
    rotation: Option<SceneRotation>,
    pool: Arc<WorkerPool>,
    timings: Arc<StepTimings>,
    /// Completed rotation swaps, mirrored for the client (and the serve
    /// layer's shard stats) to read without reaching into the world.
    rotations: Arc<AtomicU64>,
    /// Scenario-feed stalls (blocking takes that found the prefetch
    /// queue cold), mirrored the same way.
    feed_stalls: Arc<AtomicU64>,
}

impl EnvWorld {
    /// Execute one batch step: simulate, then render the new poses.
    fn exec_step(&mut self, actions: &[u8], buf: &mut StepBuffers) {
        let t0 = Instant::now();
        self.sim.step_batch(&self.pool, actions, &mut buf.out);
        let t1 = Instant::now();
        self.render(&mut buf.obs);
        self.timings.add(t1 - t0, t1.elapsed());
    }

    /// Render the sim's current poses into the observation megaframe.
    fn render(&self, obs: &mut [f32]) {
        let items: Vec<RenderItem> = (0..self.sim.num_envs())
            .map(|i| {
                let (pos, heading) = {
                    let e = self.sim.env(i);
                    (e.pos, e.heading)
                };
                RenderItem {
                    scene: self.sim.scene_of(i),
                    pos,
                    heading,
                }
            })
            .collect();
        self.renderer.render_batch(&self.pool, &items, obs);
    }

    /// First observation of the run: goal sensor + rendered megaframe.
    /// Not accumulated into the step timings — it happens at build time,
    /// outside the profiled rollout loop.
    fn render_initial(&mut self, buf: &mut StepBuffers) {
        self.sim.fill_goal_sensor(&mut buf.out.goal_sensor);
        self.render(&mut buf.obs);
    }

    fn rotate(&mut self, pinned: bool) {
        if let Some(rot) = self.rotation.as_mut() {
            if pinned {
                rot.rotate_pinned(&mut self.sim);
            } else {
                rot.rotate(&mut self.sim);
            }
            self.rotations.store(rot.rotations, Ordering::Relaxed);
            self.feed_stalls.store(rot.feed_stalls(), Ordering::Relaxed);
        }
    }

    /// Forward a curriculum stage change to the rotation's scene feed
    /// (no-op without a rotation or with a dataset-backed one).
    fn set_stage(&mut self, stage: u32) {
        if let Some(rot) = self.rotation.as_mut() {
            rot.set_stage(stage);
        }
    }
}

/// Requests the client sends to the step executor, in order.
enum Request {
    Step { actions: Vec<u8>, buf: StepBuffers },
    Rotate { pinned: bool },
    SetStage(u32),
}

/// Completed step: the filled buffer plus the recycled action vector.
type Response = (StepBuffers, Vec<u8>);

enum Mode {
    /// Steps execute inline on the caller thread.
    Sync(Box<EnvWorld>),
    /// Steps execute on a dedicated driver thread (double-buffered).
    Pipelined {
        req_tx: Option<Sender<Request>>,
        resp_rx: Receiver<Response>,
        driver: Option<JoinHandle<()>>,
    },
}

fn driver_loop(mut world: EnvWorld, req_rx: Receiver<Request>, resp_tx: Sender<Response>) {
    while let Ok(req) = req_rx.recv() {
        match req {
            Request::Step { actions, mut buf } => {
                world.exec_step(&actions, &mut buf);
                if resp_tx.send((buf, actions)).is_err() {
                    return; // client dropped mid-step; shut down
                }
            }
            Request::Rotate { pinned } => world.rotate(pinned),
            Request::SetStage(stage) => world.set_stage(stage),
        }
    }
}

/// The batched environment server (see module docs).
pub struct EnvBatch {
    n: usize,
    obs_floats: usize,
    task: Task,
    mode: Mode,
    /// Step-t results the client reads from (always owned here).
    front: StepBuffers,
    /// The buffer the next submit will hand to the executor.
    spare: Option<StepBuffers>,
    /// Sync mode: the executed-but-not-consumed step result.
    ready: Option<StepBuffers>,
    /// Recycled action vector (avoids a per-step allocation).
    actions_scratch: Option<Vec<u8>>,
    inflight: bool,
    timings: Arc<StepTimings>,
    /// Renderer work/stage counters, shared with the `BatchRenderer` that
    /// lives on the driver thread in pipelined mode.
    render_counters: Arc<RenderCounters>,
    rotations: Arc<AtomicU64>,
    feed_stalls: Arc<AtomicU64>,
    resident_bytes: usize,
    /// `Some(k)`: pinned rotation schedule — every k-th `rotate_scenes`
    /// call performs one blocking swap (`EnvBatchConfig::pin_rotation`).
    rotate_every: Option<u64>,
    rotate_calls: u64,
    /// A scenario feed's generator-thread heartbeat, captured at build
    /// time (the rotation itself moves onto the driver thread) so the
    /// serve layer can adopt it into its watchdog.
    procgen_hb: Option<Heartbeat>,
}

impl EnvBatch {
    /// Assemble sim + renderer + rotation, render the initial observation,
    /// and start the driver thread when `cfg.overlap` is set. Called via
    /// the [`EnvBatchConfig`] builders.
    pub(super) fn build(
        cfg: EnvBatchConfig,
        scenes: Vec<Arc<SceneAsset>>,
        rotation: Option<SceneRotation>,
        pool: Arc<WorkerPool>,
    ) -> Result<EnvBatch> {
        let n = scenes.len();
        let obs_floats = cfg.render.obs_floats();
        let with_tex = cfg.render.sensor == Sensor::Rgb;
        let resident_bytes = match &rotation {
            Some(rot) => rot.resident_bytes(with_tex),
            // No sharing bookkeeping: count every env's asset (Workers-arch
            // semantics, where each env loads a private copy).
            None => scenes.iter().map(|s| s.footprint_bytes(with_tex)).sum(),
        };
        let task = cfg.sim.task;
        let sim = BatchSim::new(cfg.sim, scenes, cfg.seed);
        let renderer = BatchRenderer::new(cfg.render, n);
        let render_counters = renderer.counters();
        let timings = Arc::new(StepTimings::default());
        let rotations = Arc::new(AtomicU64::new(0));
        let feed_stalls = Arc::new(AtomicU64::new(0));
        let procgen_hb = rotation.as_ref().and_then(|r| r.procgen_heartbeat());
        let mut world = EnvWorld {
            sim,
            renderer,
            rotation,
            pool,
            timings: Arc::clone(&timings),
            rotations: Arc::clone(&rotations),
            feed_stalls: Arc::clone(&feed_stalls),
        };
        let mut front = StepBuffers::new(n, obs_floats);
        world.render_initial(&mut front);
        let mode = if cfg.overlap {
            let (req_tx, req_rx) = channel();
            let (resp_tx, resp_rx) = channel();
            let driver = std::thread::Builder::new()
                .name("env-batch-driver".into())
                .spawn(move || driver_loop(world, req_rx, resp_tx))
                .map_err(|e| anyhow!("spawn env driver thread: {e}"))?;
            Mode::Pipelined {
                req_tx: Some(req_tx),
                resp_rx,
                driver: Some(driver),
            }
        } else {
            Mode::Sync(Box::new(world))
        };
        Ok(EnvBatch {
            n,
            obs_floats,
            task,
            mode,
            front,
            spare: Some(StepBuffers::new(n, obs_floats)),
            ready: None,
            actions_scratch: Some(Vec::with_capacity(n)),
            inflight: false,
            timings,
            render_counters,
            rotations,
            feed_stalls,
            resident_bytes,
            rotate_every: cfg.rotate_every,
            rotate_calls: 0,
            procgen_hb,
        })
    }

    pub fn num_envs(&self) -> usize {
        self.n
    }

    /// Floats per environment observation tile.
    pub fn obs_floats(&self) -> usize {
        self.obs_floats
    }

    pub fn task(&self) -> Task {
        self.task
    }

    /// True when steps run on the pipelined driver thread.
    pub fn is_pipelined(&self) -> bool {
        matches!(self.mode, Mode::Pipelined { .. })
    }

    /// Resident scene-asset footprint (the "GPU memory" budget input),
    /// computed at build time.
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    /// The current front buffer: observations + outcomes of the last
    /// completed step (or the initial observation before any submit).
    pub fn view(&self) -> StepView<'_> {
        StepView {
            obs: &self.front.obs,
            goal: &self.front.out.goal_sensor,
            rewards: &self.front.out.rewards,
            dones: &self.front.out.dones,
            successes: &self.front.out.successes,
            spl: &self.front.out.spl,
            scores: &self.front.out.scores,
        }
    }

    /// Submit a batch of actions (`actions[i]` steps env `i`). In
    /// pipelined mode this returns immediately while sim+render run on the
    /// driver thread; consume the result through the returned handle. If a
    /// previous step is still unconsumed (its handle was dropped), it is
    /// drained first so the request order stays deterministic.
    pub fn submit(&mut self, actions: &[u8]) -> Result<StepHandle<'_>> {
        // validate before draining so a rejected submit is side-effect-free
        if actions.len() != self.n {
            bail!(
                "submit: {} actions for {} environments",
                actions.len(),
                self.n
            );
        }
        if self.inflight {
            self.finish_step()?;
        }
        let mut act = self.actions_scratch.take().unwrap_or_default();
        act.clear();
        act.extend_from_slice(actions);
        let mut buf = self.spare.take().expect("spare step buffer");
        match &mut self.mode {
            Mode::Sync(world) => {
                world.exec_step(&act, &mut buf);
                self.ready = Some(buf);
                self.actions_scratch = Some(act);
            }
            Mode::Pipelined { req_tx, .. } => {
                let sent = req_tx
                    .as_ref()
                    .expect("driver channel open")
                    .send(Request::Step { actions: act, buf });
                if let Err(std::sync::mpsc::SendError(req)) = sent {
                    // recover the buffers so the batch stays usable
                    if let Request::Step { actions, buf } = req {
                        self.actions_scratch = Some(actions);
                        self.spare = Some(buf);
                    }
                    bail!("env driver thread terminated");
                }
            }
        }
        self.inflight = true;
        Ok(StepHandle { batch: self })
    }

    /// Convenience: submit and immediately wait (no overlap window).
    pub fn step(&mut self, actions: &[u8]) -> Result<StepView<'_>> {
        self.submit(actions)?.wait()
    }

    /// Apply pending scene-rotation swaps (BPS asset streaming, §3.2).
    /// Executed in request order after any in-flight step; a no-op when
    /// the batch was built without a rotation. With a pinned schedule
    /// (`EnvBatchConfig::pin_rotation(k)`) every k-th call performs one
    /// blocking swap and the rest do nothing, so the swap iterations are
    /// a pure function of the call count — reproducible across A/B runs.
    pub fn rotate_scenes(&mut self) -> Result<()> {
        let pinned = match self.rotate_every {
            Some(every) => {
                self.rotate_calls += 1;
                if self.rotate_calls % every != 0 {
                    return Ok(());
                }
                true
            }
            None => false,
        };
        match &mut self.mode {
            Mode::Sync(world) => {
                world.rotate(pinned);
                Ok(())
            }
            Mode::Pipelined { req_tx, .. } => req_tx
                .as_ref()
                .expect("driver channel open")
                .send(Request::Rotate { pinned })
                .map_err(|_| anyhow!("env driver thread terminated")),
        }
    }

    /// Forward a curriculum stage change to the scene rotation's feed
    /// (the scenario engine's seam — see `bps::scenario::Curriculum`).
    /// Executed in request order with steps and rotations, so the stage a
    /// given rotation sees is a pure function of the call sequence in
    /// both the pipelined and synchronous modes. No-op for batches built
    /// without a rotation or over a dataset feed.
    pub fn set_stage(&mut self, stage: u32) -> Result<()> {
        match &mut self.mode {
            Mode::Sync(world) => {
                world.set_stage(stage);
                Ok(())
            }
            Mode::Pipelined { req_tx, .. } => req_tx
                .as_ref()
                .expect("driver channel open")
                .send(Request::SetStage(stage))
                .map_err(|_| anyhow!("env driver thread terminated")),
        }
    }

    /// Completed scene-rotation swaps so far. In pipelined mode this
    /// reflects rotations the driver has already executed.
    pub fn rotations(&self) -> u64 {
        self.rotations.load(Ordering::Relaxed)
    }

    /// Scenario-feed stalls so far: rotation swaps that had to wait on
    /// scene synthesis because the prefetch queue was cold. Stays 0 when
    /// generation keeps up with rotation (the non-blocking guarantee
    /// asserted in `rust/tests/scenario.rs`); always 0 for dataset feeds
    /// and fixed scene assignments.
    pub fn feed_stalls(&self) -> u64 {
        self.feed_stalls.load(Ordering::Relaxed)
    }

    /// Shared rotation counter (the serve layer reads it for shard stats
    /// after the batch moves onto its driver thread).
    pub(crate) fn rotations_counter(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.rotations)
    }

    /// The scenario feed's generator-thread heartbeat, if this batch is
    /// backed by streaming procgen (`None` for dataset feeds and static
    /// scenes) — serve-layer watchdog plumbing.
    pub(crate) fn procgen_heartbeat(&self) -> Option<Heartbeat> {
        self.procgen_hb.clone()
    }

    /// Shared feed-stall counter: the serve layer attaches it to the obs
    /// registry (`scenario.feed_stalls{shard}`) so scrapes read the very
    /// cell [`feed_stalls`](Self::feed_stalls) reads.
    pub(crate) fn feed_stalls_counter(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.feed_stalls)
    }

    /// Drain accumulated (simulation, rendering) wall time since the last
    /// drain. In pipelined mode this reflects completed steps only.
    pub fn drain_timings(&self) -> (Duration, Duration) {
        self.timings.drain()
    }

    /// Drain the renderer's per-stage statistics (reset-on-read): triangle
    /// and chunk counts plus transform/cull/raster/resolve wall time since
    /// the last take — the Table A2 renderer breakdown. In pipelined mode
    /// this reflects steps the driver has completed.
    pub fn take_render_stats(&self) -> RenderStats {
        self.render_counters.take()
    }

    /// Receive the in-flight step and rotate it in as the new front.
    fn finish_step(&mut self) -> Result<()> {
        debug_assert!(self.inflight, "finish_step without an in-flight step");
        let buf = match &mut self.mode {
            Mode::Sync(_) => self.ready.take().expect("sync step result"),
            Mode::Pipelined { resp_rx, .. } => {
                let (buf, act) = resp_rx
                    .recv()
                    .map_err(|_| anyhow!("env driver thread terminated"))?;
                self.actions_scratch = Some(act);
                buf
            }
        };
        let old_front = std::mem::replace(&mut self.front, buf);
        self.spare = Some(old_front);
        self.inflight = false;
        Ok(())
    }
}

impl Drop for EnvBatch {
    fn drop(&mut self) {
        if let Mode::Pipelined { req_tx, driver, .. } = &mut self.mode {
            drop(req_tx.take()); // close the request channel
            if let Some(h) = driver.take() {
                let _ = h.join();
            }
        }
    }
}

/// An in-flight batch step. While it lives, sim+render of the submitted
/// step may still be executing; [`current`](StepHandle::current) exposes
/// the *previous* step's front buffer for overlapped bookkeeping, and
/// [`wait`](StepHandle::wait) blocks until the new step is ready.
pub struct StepHandle<'a> {
    batch: &'a mut EnvBatch,
}

impl<'a> StepHandle<'a> {
    /// The front buffer (step *t*) — valid while step *t+1* executes.
    pub fn current(&self) -> StepView<'_> {
        self.batch.view()
    }

    /// Block until the submitted step completes and view its results.
    pub fn wait(self) -> Result<StepView<'a>> {
        let batch = self.batch;
        batch.finish_step()?;
        Ok(batch.view())
    }
}

/// Borrowed SoA results of one batch step: the observation megaframe
/// (`[N, res, res, C]` f32), the GPS+compass goal sensor (`[N, 3]`), and
/// the per-env outcome arrays (rewards / dones / successes / SPL / task
/// scores — the "infos" of the step).
#[derive(Clone, Copy)]
pub struct StepView<'a> {
    pub obs: &'a [f32],
    pub goal: &'a [f32],
    pub rewards: &'a [f32],
    pub dones: &'a [bool],
    pub successes: &'a [bool],
    pub spl: &'a [f32],
    pub scores: &'a [f32],
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::render::RenderConfig;
    use crate::scene::procgen::{generate, Complexity};
    use crate::sim::{ACTION_FORWARD, ACTION_LEFT};

    fn batch(n: usize, overlap: bool) -> EnvBatch {
        let scene = Arc::new(generate("envb", 41, Complexity::test()));
        EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(16))
            .seed(11)
            .overlap(overlap)
            .build_with_scenes(
                (0..n).map(|_| Arc::clone(&scene)).collect(),
                Arc::new(WorkerPool::new(2)),
            )
            .unwrap()
    }

    #[test]
    fn initial_view_is_rendered_and_goal_filled() {
        let env = batch(3, false);
        let v = env.view();
        assert_eq!(v.obs.len(), 3 * env.obs_floats());
        assert_eq!(v.goal.len(), 9);
        // depth tiles are normalized to [0, 1] and goal dist is positive
        assert!(v.obs.iter().all(|d| (0.0..=1.0).contains(d)));
        assert!(v.goal[0] > 0.0);
        assert!(!v.dones.iter().any(|&d| d));
    }

    #[test]
    fn submit_wait_cycle_advances_state() {
        for overlap in [false, true] {
            let mut env = batch(2, overlap);
            assert_eq!(env.is_pipelined(), overlap);
            let obs0 = env.view().obs.to_vec();
            let v = env.step(&[ACTION_FORWARD, ACTION_LEFT]).unwrap();
            assert_eq!(v.rewards.len(), 2);
            assert_ne!(v.obs, &obs0[..], "observation did not advance");
            let (sim_d, render_d) = env.drain_timings();
            assert!(sim_d > Duration::ZERO && render_d > Duration::ZERO);
        }
    }

    #[test]
    fn overlap_window_keeps_front_readable() {
        let mut env = batch(2, true);
        let before = env.view().obs.to_vec();
        let handle = env.submit(&[ACTION_FORWARD, ACTION_FORWARD]).unwrap();
        // while step t+1 is in flight, the front buffer still serves step t
        assert_eq!(handle.current().obs, &before[..]);
        let v = handle.wait().unwrap();
        assert_ne!(v.obs, &before[..]);
    }

    #[test]
    fn dropped_handle_is_drained_on_next_submit() {
        let mut env = batch(1, true);
        let _ = env.submit(&[ACTION_FORWARD]).unwrap(); // dropped unconsumed
        let v = env.step(&[ACTION_FORWARD]).unwrap();
        assert_eq!(v.rewards.len(), 1);
    }

    #[test]
    fn render_stats_drain_through_env() {
        for overlap in [false, true] {
            let mut env = batch(2, overlap);
            let _ = env.step(&[ACTION_FORWARD, ACTION_FORWARD]).unwrap();
            // initial render + one completed step have been counted
            let rs = env.take_render_stats();
            assert!(rs.tris_rasterized > 0, "overlap={overlap}");
            assert!(rs.stage_ns_total() > 0, "overlap={overlap}");
            // reset-on-read: nothing ran since the take
            let rs2 = env.take_render_stats();
            assert_eq!(rs2.tris_rasterized, 0, "overlap={overlap}");
        }
    }

    #[test]
    fn wrong_action_count_rejected() {
        let mut env = batch(2, false);
        assert!(env.submit(&[ACTION_FORWARD]).is_err());
    }
}
