//! First-class batched environment API (paper §3, Fig. 2).
//!
//! The paper's core systems contribution is an *API shape*: a simulator
//! that "accepts and executes large batches of requests simultaneously".
//! This module is that surface. A client builds an [`EnvBatch`] from an
//! [`EnvBatchConfig`], then drives it with a request/response step cycle:
//!
//! ```ignore
//! let mut env = EnvBatchConfig::new(Task::PointNav, RenderConfig::depth(64))
//!     .seed(7)
//!     .build_with_scenes(scenes, pool)?;
//! loop {
//!     let actions = policy(env.view());          // inference on step t
//!     let handle = env.submit(&actions)?;        // sim+render of t+1 starts
//!     record(handle.current());                  // overlapped bookkeeping
//!     let view = handle.wait()?;                 // step t+1 observations
//! }
//! ```
//!
//! [`EnvBatch`] owns the `BatchSim` + `BatchRenderer` + `SceneRotation`
//! triple and internally **double-buffers**: in the default pipelined mode
//! a driver thread executes simulation + rendering of step *t+1* on the
//! worker pool while the caller is still consuming step *t* from the front
//! buffer (the paper's pipelined-overlap design, Fig. 2). Buffers are
//! *moved* between the caller and the driver through channels, so the
//! overlap requires no shared mutable state. The synchronous mode
//! (`overlap(false)`) executes steps inline on the caller thread and is
//! bitwise-identical in output for the same seed, action stream, and
//! scene-rotation schedule — see `rust/tests/env_batch.rs`.
//!
//! The RL `Coordinator` and the eval loop are pure clients of this API;
//! heterogeneous workloads (PointNav / Flee / Explore per shard) are
//! expressed as independently configured `EnvBatch` instances sharing one
//! `WorkerPool`.

pub mod batch;
pub mod config;

pub use batch::{EnvBatch, StepHandle, StepView};
pub use config::EnvBatchConfig;
