//! The config system: one typed `Config` drives the launcher, the training
//! coordinator, the eval loop, and every bench. Loadable from a TOML file,
//! overridable from the CLI, with presets that mirror the paper's Table A5
//! system configurations.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::render::PipelineMode;
use crate::scene::Complexity;
use crate::sim::Task;
use crate::util::args::Args;
use crate::util::toml;

/// Simulation architecture under test (Table 1 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimArch {
    /// BPS: batch simulation + batch renderer + asset sharing (the paper).
    Bps,
    /// WIJMANS20/++-style: per-environment private simulator+renderer
    /// instances, no asset sharing (memory-capped env count).
    Workers,
}

impl SimArch {
    pub fn parse(s: &str) -> Option<SimArch> {
        match s {
            "bps" => Some(SimArch::Bps),
            "workers" => Some(SimArch::Workers),
            _ => None,
        }
    }
}

/// Everything needed to run training / eval / benches.
#[derive(Clone, Debug)]
pub struct Config {
    // artifacts / model
    pub variant: String,
    pub artifacts_dir: PathBuf,
    // dataset
    pub dataset_dir: PathBuf,
    pub complexity: String, // "gibson" | "thor" | "test"
    // scenario engine (replaces the on-disk dataset when set)
    /// `--scenario`: an inline spec string (contains `=`) or the name of
    /// a `.scenario` file in `scenario_dir`. When set, every shard runs
    /// the scenario engine's streaming procgen instead of a pre-generated
    /// dataset, and a success-driven curriculum advances the spec's
    /// difficulty stages (`bps::scenario`).
    pub scenario: Option<String>,
    /// `--scenario-dir`: the `.scenario` registry directory.
    pub scenario_dir: PathBuf,
    /// `--prefetch`: scenario prefetch-queue depth (scenes generated
    /// ahead of demand per shard).
    pub prefetch_scenes: usize,
    /// `--curriculum-window`: episodes of evidence per difficulty stage.
    pub curriculum_window: usize,
    /// `--curriculum-threshold`: windowed success rate that advances the
    /// curriculum to the next stage.
    pub curriculum_threshold: f32,
    // architecture
    pub arch: SimArch,
    pub pipeline: PipelineMode,
    // batch geometry (paper Table A5)
    pub num_envs: usize,
    pub rollout_len: usize,
    pub num_minibatches: usize,
    pub ppo_epochs: usize,
    pub shards: usize,
    pub k_scenes: usize,
    // sim
    pub task: Task,
    /// Optional per-shard task override: shard `s` runs
    /// `tasks[s % tasks.len()]`. Empty = every shard runs `task`.
    /// Every shard is an independent `EnvBatch`, so heterogeneous
    /// workloads (e.g. `--tasks pointnav,flee`) train one policy across
    /// tasks.
    pub tasks: Vec<Task>,
    /// Double-buffered pipelined env stepping (paper Fig. 2 overlap).
    /// `--overlap false` selects the synchronous path; given the same
    /// scene-rotation schedule the two produce bitwise-identical
    /// rollouts (see rust/tests/env_batch.rs). Active rotation prefetch
    /// swaps scenes at wall-clock-dependent iterations in *both* modes;
    /// for exact A/B runs either set `--rotate-every` (below) or pin
    /// `k_scenes` to the train-split size.
    pub overlap: bool,
    /// `--rotate-every K` pins the scene-rotation schedule to iteration
    /// counts: every K-th training iteration performs exactly one
    /// blocking slot swap instead of polling the prefetch, making runs
    /// reproducible with prefetch active. `None` (0 on the CLI) keeps
    /// the non-blocking wall-clock behavior.
    pub rotate_every: Option<u64>,
    // optimization (paper Table A4)
    pub optimizer: String, // "lamb" | "adam"
    pub base_lr: f32,
    pub lr_scaling: bool,
    pub gamma: f32,
    pub gae_lambda: f32,
    pub normalize_adv: bool,
    // run control
    pub total_frames: u64,
    pub seed: u64,
    pub threads: usize,
    pub out_dir: PathBuf,
    pub render_scale: usize,
    /// Simulated accelerator memory budget in MB ("GPU memory"): caps the
    /// resident asset set for BPS and the env count for Workers.
    pub memory_budget_mb: usize,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            variant: "depth64".into(),
            artifacts_dir: "artifacts".into(),
            dataset_dir: "datasets/gibson_like".into(),
            complexity: "gibson".into(),
            scenario: None,
            scenario_dir: "scenarios".into(),
            prefetch_scenes: 2,
            curriculum_window: 64,
            curriculum_threshold: 0.8,
            arch: SimArch::Bps,
            pipeline: PipelineMode::Pipelined,
            num_envs: 64,
            rollout_len: 32,
            num_minibatches: 2,
            ppo_epochs: 1,
            shards: 1,
            k_scenes: 4,
            task: Task::PointNav,
            tasks: Vec::new(),
            overlap: true,
            rotate_every: None,
            optimizer: "lamb".into(),
            base_lr: 2.5e-4,
            lr_scaling: true,
            gamma: 0.99,
            gae_lambda: 0.95,
            normalize_adv: true,
            total_frames: 500_000,
            seed: 1,
            threads: 0, // 0 = auto
            out_dir: "runs/default".into(),
            render_scale: 1,
            memory_budget_mb: 2048,
        }
    }
}

impl Config {
    /// Per-shard training batch (frames per gradient step): N*L / minibatches.
    pub fn train_batch(&self) -> usize {
        self.num_envs * self.rollout_len / self.num_minibatches
    }

    /// Aggregate batch across shards (the paper's N in Table 2 / Fig. 4).
    pub fn aggregate_envs(&self) -> usize {
        self.num_envs * self.shards
    }

    /// Task assigned to shard `s` (round-robin over `tasks`, falling back
    /// to the homogeneous `task`).
    pub fn task_of_shard(&self, s: usize) -> Task {
        if self.tasks.is_empty() {
            self.task
        } else {
            self.tasks[s % self.tasks.len()]
        }
    }

    pub fn complexity_preset(&self) -> Result<Complexity> {
        Ok(match self.complexity.as_str() {
            "gibson" => Complexity::gibson_like(),
            "thor" => Complexity::thor_like(),
            "test" => Complexity::test(),
            other => bail!("unknown complexity {other:?} (gibson|thor|test)"),
        })
    }

    /// Grad-artifact minibatch geometry implied by this config.
    pub fn grad_bl(&self) -> (usize, usize) {
        (self.num_envs / self.num_minibatches, self.rollout_len)
    }

    /// Load from TOML, then apply CLI overrides.
    pub fn load(path: Option<&Path>, args: &mut Args) -> Result<Config> {
        let mut cfg = Config::default();
        if let Some(p) = path {
            cfg.apply_toml(p)?;
        }
        cfg.apply_args(args)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn apply_toml(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)?;
        let doc = toml::parse(&text)?;
        let all = doc.values().flat_map(|m| m.iter());
        for (k, v) in all {
            self.set(k, &toml_to_string(v))?;
        }
        Ok(())
    }

    pub fn apply_args(&mut self, args: &mut Args) -> Result<()> {
        for key in [
            "variant", "artifacts-dir", "dataset", "complexity", "arch", "pipeline",
            "envs", "rollout-len", "minibatches", "ppo-epochs", "shards", "k-scenes",
            "task", "tasks", "overlap", "rotate-every", "optimizer", "lr", "lr-scaling",
            "gamma", "gae-lambda",
            "normalize-adv", "frames", "seed", "threads", "out", "render-scale",
            "memory-mb", "scenario", "scenario-dir", "prefetch", "curriculum-window",
            "curriculum-threshold",
        ] {
            if let Some(v) = args.opt(key) {
                self.set(&key.replace('-', "_"), &v)?;
            }
        }
        Ok(())
    }

    fn set(&mut self, key: &str, v: &str) -> Result<()> {
        match key {
            "variant" => self.variant = v.into(),
            "artifacts_dir" => self.artifacts_dir = v.into(),
            "dataset" | "dataset_dir" => self.dataset_dir = v.into(),
            "complexity" => self.complexity = v.into(),
            "scenario" => {
                self.scenario = if v.is_empty() { None } else { Some(v.into()) }
            }
            "scenario_dir" => self.scenario_dir = v.into(),
            "prefetch" | "prefetch_scenes" => self.prefetch_scenes = v.parse()?,
            "curriculum_window" => self.curriculum_window = v.parse()?,
            "curriculum_threshold" => self.curriculum_threshold = v.parse()?,
            "arch" => {
                self.arch = SimArch::parse(v)
                    .ok_or_else(|| anyhow::anyhow!("bad arch {v:?} (bps|workers)"))?
            }
            "pipeline" => {
                self.pipeline = match v {
                    "fused" => PipelineMode::Fused,
                    "pipelined" => PipelineMode::Pipelined,
                    _ => bail!("bad pipeline {v:?} (fused|pipelined)"),
                }
            }
            "envs" | "num_envs" => self.num_envs = v.parse()?,
            "rollout_len" => self.rollout_len = v.parse()?,
            "minibatches" | "num_minibatches" => self.num_minibatches = v.parse()?,
            "ppo_epochs" => self.ppo_epochs = v.parse()?,
            "shards" => self.shards = v.parse()?,
            "k_scenes" => self.k_scenes = v.parse()?,
            "task" => {
                self.task = Task::parse(v)
                    .ok_or_else(|| anyhow::anyhow!("bad task {v:?}"))?
            }
            "tasks" => {
                self.tasks = v
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|s| {
                        Task::parse(s.trim())
                            .ok_or_else(|| anyhow::anyhow!("bad task {s:?} in --tasks"))
                    })
                    .collect::<Result<Vec<_>>>()?
            }
            "overlap" => self.overlap = v.parse()?,
            "rotate_every" => {
                self.rotate_every = match v.parse::<u64>()? {
                    0 => None,
                    k => Some(k),
                }
            }
            "optimizer" => self.optimizer = v.into(),
            "lr" | "base_lr" => self.base_lr = v.parse()?,
            "lr_scaling" => self.lr_scaling = v.parse()?,
            "gamma" => self.gamma = v.parse()?,
            "gae_lambda" => self.gae_lambda = v.parse()?,
            "normalize_adv" => self.normalize_adv = v.parse()?,
            "frames" | "total_frames" => self.total_frames = v.parse()?,
            "seed" => self.seed = v.parse()?,
            "threads" => self.threads = v.parse()?,
            "out" | "out_dir" => self.out_dir = v.into(),
            "render_scale" => self.render_scale = v.parse()?,
            "memory_mb" | "memory_budget_mb" => self.memory_budget_mb = v.parse()?,
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.num_envs == 0 || self.rollout_len == 0 {
            bail!("num_envs and rollout_len must be positive");
        }
        if self.num_envs % self.num_minibatches != 0 {
            bail!(
                "num_envs ({}) must divide evenly into {} minibatches",
                self.num_envs,
                self.num_minibatches
            );
        }
        if !matches!(self.optimizer.as_str(), "lamb" | "adam") {
            bail!("optimizer must be lamb or adam");
        }
        if self.num_envs > self.k_scenes * crate::render::MAX_N_TO_K {
            bail!(
                "num_envs {} violates the N:K<=32 sharing cap with k_scenes {}",
                self.num_envs,
                self.k_scenes
            );
        }
        if self.scenario.is_some() {
            if self.arch != SimArch::Bps {
                bail!("--scenario requires --arch bps (scene rotation is the scenario seam)");
            }
            if self.prefetch_scenes == 0 {
                bail!("--prefetch must be positive");
            }
            if self.curriculum_window == 0 {
                bail!("--curriculum-window must be positive");
            }
            if !(self.curriculum_threshold > 0.0 && self.curriculum_threshold <= 1.0) {
                bail!(
                    "--curriculum-threshold {} must be in (0, 1]",
                    self.curriculum_threshold
                );
            }
        }
        Ok(())
    }
}

fn toml_to_string(v: &toml::TomlVal) -> String {
    match v {
        toml::TomlVal::Str(s) => s.clone(),
        toml::TomlVal::Bool(b) => b.to_string(),
        toml::TomlVal::Int(i) => i.to_string(),
        toml::TomlVal::Float(f) => f.to_string(),
        toml::TomlVal::Arr(_) => String::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn cli_overrides() {
        let argv: Vec<String> = "train --envs 128 --arch workers --lr 1e-3 --task flee"
            .split_whitespace()
            .map(str::to_string)
            .collect();
        let mut args = Args::parse(&argv).unwrap();
        let cfg = Config::load(None, &mut args).unwrap();
        assert_eq!(cfg.num_envs, 128);
        assert_eq!(cfg.arch, SimArch::Workers);
        assert!((cfg.base_lr - 1e-3).abs() < 1e-9);
        assert_eq!(cfg.task, Task::Flee);
    }

    #[test]
    fn toml_file_applies() {
        let dir = std::env::temp_dir().join("bps_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.toml");
        std::fs::write(
            &p,
            "num_envs = 32\nrollout_len = 16\n[optim]\noptimizer = \"adam\"\nbase_lr = 1e-4\n",
        )
        .unwrap();
        let mut cfg = Config::default();
        cfg.apply_toml(&p).unwrap();
        assert_eq!(cfg.num_envs, 32);
        assert_eq!(cfg.rollout_len, 16);
        assert_eq!(cfg.optimizer, "adam");
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut cfg = Config::default();
        cfg.num_envs = 33; // not divisible by 2 minibatches
        assert!(cfg.validate().is_err());
        let mut cfg = Config::default();
        cfg.num_envs = 256;
        cfg.k_scenes = 4; // 256 > 4*32
        assert!(cfg.validate().is_err());
        let mut cfg = Config::default();
        cfg.optimizer = "sgd".into();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn hetero_tasks_and_overlap() {
        let argv: Vec<String> = "train --tasks pointnav,flee,explore --overlap false --shards 6"
            .split_whitespace()
            .map(str::to_string)
            .collect();
        let mut args = Args::parse(&argv).unwrap();
        let cfg = Config::load(None, &mut args).unwrap();
        assert!(!cfg.overlap);
        assert_eq!(cfg.task_of_shard(0), Task::PointNav);
        assert_eq!(cfg.task_of_shard(1), Task::Flee);
        assert_eq!(cfg.task_of_shard(2), Task::Explore);
        assert_eq!(cfg.task_of_shard(3), Task::PointNav); // round-robin
        // homogeneous fallback
        let base = Config::default();
        assert_eq!(base.task_of_shard(5), base.task);
        // bad task rejected
        let mut cfg = Config::default();
        assert!(cfg.set("tasks", "pointnav,swim").is_err());
    }

    #[test]
    fn scenario_keys_parse_and_validate() {
        let argv: Vec<String> = [
            "train",
            "--scenario",
            "name=maze task=pointnav tris=10k..40k stages=3",
            "--scenario-dir",
            "specs",
            "--prefetch",
            "3",
            "--curriculum-window",
            "32",
            "--curriculum-threshold",
            "0.7",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let mut args = Args::parse(&argv).unwrap();
        let cfg = Config::load(None, &mut args).unwrap();
        assert_eq!(
            cfg.scenario.as_deref(),
            Some("name=maze task=pointnav tris=10k..40k stages=3")
        );
        assert_eq!(cfg.scenario_dir, PathBuf::from("specs"));
        assert_eq!(cfg.prefetch_scenes, 3);
        assert_eq!(cfg.curriculum_window, 32);
        assert!((cfg.curriculum_threshold - 0.7).abs() < 1e-6);
        // scenario runs require the BPS arch and sane curriculum knobs
        let mut bad = Config {
            scenario: Some("task=pointnav".into()),
            arch: SimArch::Workers,
            ..Config::default()
        };
        assert!(bad.validate().is_err());
        bad.arch = SimArch::Bps;
        bad.curriculum_threshold = 1.5;
        assert!(bad.validate().is_err());
        bad.curriculum_threshold = 0.8;
        bad.validate().unwrap();
    }

    #[test]
    fn rotate_every_parses_with_zero_meaning_off() {
        let argv: Vec<String> = "train --rotate-every 3"
            .split_whitespace()
            .map(str::to_string)
            .collect();
        let mut args = Args::parse(&argv).unwrap();
        let cfg = Config::load(None, &mut args).unwrap();
        assert_eq!(cfg.rotate_every, Some(3));
        let mut cfg = Config::default();
        cfg.set("rotate_every", "0").unwrap();
        assert_eq!(cfg.rotate_every, None);
    }

    #[test]
    fn batch_geometry() {
        let cfg = Config::default();
        assert_eq!(cfg.train_batch(), 64 * 32 / 2);
        assert_eq!(cfg.grad_bl(), (32, 32));
    }
}
