//! Policy optimization driver (paper §3.4): learning-rate scaling
//! (sqrt(B/256), no warmup, cosine decay from the scaled LR back to base
//! over the first half of training — Appendix B), DD-PPO-style multi-shard
//! gradient averaging, and the Lamb/Adam update artifacts.

use anyhow::Result;

use crate::rollout::Rollout;
use crate::runtime::{lit_f32, lit_i32, lit_scalar_f32, to_f32, Exec, ParamStore};

/// Which optimizer artifact to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Optimizer {
    /// Lamb (paper §3.4) — the default.
    Lamb,
    /// Plain AdamW (the Fig. A3 ablation; LR scaling is disabled for Adam
    /// because scaled LRs diverge, per the paper).
    Adam,
}

/// Scaled learning rate: `base * sqrt(B / B_base)` (paper §3.4).
pub fn scale_lr(base: f32, train_batch: usize, b_base: usize) -> f32 {
    base * ((train_batch as f32 / b_base as f32).sqrt())
}

/// Cosine decay from the scaled LR back to base over the first
/// `decay_iters` iterations, then constant base (Appendix B).
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub base: f32,
    pub scaled: f32,
    pub decay_iters: u64,
}

impl LrSchedule {
    pub fn lr(&self, iter: u64) -> f32 {
        if self.decay_iters == 0 || iter >= self.decay_iters {
            return self.base;
        }
        let t = iter as f32 / self.decay_iters as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
        self.base + (self.scaled - self.base) * cos
    }
}

/// PPO trainer bound to the `grad` + `update_*` executables.
pub struct Trainer {
    grad: Exec,
    update: Exec,
    pub num_params: usize,
    pub mb_count: usize,
    pub epochs: usize,
    pub schedule: LrSchedule,
    pub gamma: f32,
    pub gae_lambda: f32,
    pub normalize_adv: bool,
    pub iter: u64,
}

/// Loss diagnostics averaged over the iteration's updates.
#[derive(Clone, Copy, Debug, Default)]
pub struct Losses {
    pub policy: f32,
    pub value: f32,
    pub entropy: f32,
    pub approx_kl: f32,
    pub lr: f32,
}

impl Trainer {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        grad: Exec,
        update: Exec,
        num_params: usize,
        mb_count: usize,
        epochs: usize,
        schedule: LrSchedule,
        gamma: f32,
        gae_lambda: f32,
        normalize_adv: bool,
    ) -> Trainer {
        Trainer {
            grad,
            update,
            num_params,
            mb_count,
            epochs,
            schedule,
            gamma,
            gae_lambda,
            normalize_adv,
            iter: 0,
        }
    }

    /// Gradient for one minibatch of one shard.
    fn grad_minibatch(
        &self,
        params: &[f32],
        ro: &Rollout,
        env_lo: usize,
        env_hi: usize,
    ) -> Result<(Vec<f32>, [f32; 4])> {
        let mb = ro.minibatch(env_lo, env_hi);
        let (b, l) = (mb.b as i64, mb.l as i64);
        // obs dims recovered from the rollout geometry
        let (r, c) = obs_dims(ro.obs_f);
        let out = self.grad.run(&[
            lit_f32(params, &[self.num_params as i64])?,
            lit_f32(&mb.obs, &[b, l, r as i64, r as i64, c as i64])?,
            lit_f32(&mb.goal, &[b, l, 3])?,
            lit_f32(&mb.h0, &[b, ro.hidden as i64])?,
            lit_f32(&mb.c0, &[b, ro.hidden as i64])?,
            lit_i32(&mb.actions, &[b, l])?,
            lit_f32(&mb.logp, &[b, l])?,
            lit_f32(&mb.returns, &[b, l])?,
            lit_f32(&mb.adv, &[b, l])?,
            lit_f32(&mb.notdone, &[b, l])?,
        ])?;
        let grads = to_f32(&out[0])?;
        let losses = to_f32(&out[1])?;
        Ok((grads, [losses[0], losses[1], losses[2], losses[3]]))
    }

    /// One PPO training phase over the shards' rollouts — the DD-PPO
    /// dataflow: per minibatch, every shard computes its gradient, the
    /// coordinator averages (the all-reduce), and one update is applied.
    pub fn train(&mut self, params: &mut ParamStore, shards: &mut [Rollout]) -> Result<Losses> {
        let mut refs: Vec<&mut Rollout> = shards.iter_mut().collect();
        self.train_refs(params, &mut refs)
    }

    /// Same as [`Trainer::train`] over mutable references (shard rollouts
    /// live inside `Shard` structs in the coordinator).
    pub fn train_refs(
        &mut self,
        params: &mut ParamStore,
        shards: &mut [&mut Rollout],
    ) -> Result<Losses> {
        for ro in shards.iter_mut() {
            ro.compute_gae(self.gamma, self.gae_lambda, self.normalize_adv);
        }
        let lr = self.schedule.lr(self.iter);
        let n = shards[0].n;
        let per_mb = n / self.mb_count;
        let mut avg = Losses {
            lr,
            ..Default::default()
        };
        let mut updates = 0u32;
        for _epoch in 0..self.epochs {
            for mb in 0..self.mb_count {
                let lo = mb * per_mb;
                let hi = if mb == self.mb_count - 1 { n } else { lo + per_mb };
                // shard gradients -> average (the all-reduce)
                let mut acc = vec![0.0f32; self.num_params];
                for ro in shards.iter() {
                    let (g, l) = self.grad_minibatch(&params.flat, ro, lo, hi)?;
                    for (a, b) in acc.iter_mut().zip(&g) {
                        *a += b;
                    }
                    avg.policy += l[0];
                    avg.value += l[1];
                    avg.entropy += l[2];
                    avg.approx_kl += l[3];
                    updates += 1;
                }
                let inv = 1.0 / shards.len() as f32;
                for a in &mut acc {
                    *a *= inv;
                }
                self.apply(params, &acc, lr)?;
            }
        }
        self.iter += 1;
        let inv = 1.0 / updates.max(1) as f32;
        avg.policy *= inv;
        avg.value *= inv;
        avg.entropy *= inv;
        avg.approx_kl *= inv;
        Ok(avg)
    }

    /// Run the optimizer update artifact in place.
    pub fn apply(&self, params: &mut ParamStore, grads: &[f32], lr: f32) -> Result<()> {
        let p = self.num_params as i64;
        let out = self.update.run(&[
            lit_f32(&params.flat, &[p])?,
            lit_f32(&params.m, &[p])?,
            lit_f32(&params.v, &[p])?,
            lit_scalar_f32(params.step),
            lit_f32(grads, &[p])?,
            lit_scalar_f32(lr),
        ])?;
        params.flat = to_f32(&out[0])?;
        params.m = to_f32(&out[1])?;
        params.v = to_f32(&out[2])?;
        params.step = to_f32(&out[3])?[0];
        Ok(())
    }
}

/// obs_f = res*res*c with c in {1, 3}: recover (res, c). Resolutions are
/// powers of two in this system, so the factorization is unambiguous.
fn obs_dims(obs_f: usize) -> (usize, usize) {
    for c in [1usize, 3] {
        if obs_f % c == 0 {
            let rr = obs_f / c;
            let r = (rr as f64).sqrt() as usize;
            if r * r == rr && (c == 3 || r.is_power_of_two()) {
                return (r, c);
            }
        }
    }
    // prefer rgb when both fit (res divisible by 3 never is a square here)
    panic!("cannot infer obs dims from {obs_f}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_scaling_sqrt() {
        assert!((scale_lr(2.5e-4, 256, 256) - 2.5e-4).abs() < 1e-9);
        assert!((scale_lr(2.5e-4, 1024, 256) - 5.0e-4).abs() < 1e-9);
    }

    #[test]
    fn schedule_decays_scaled_to_base() {
        let s = LrSchedule {
            base: 1e-4,
            scaled: 4e-4,
            decay_iters: 100,
        };
        assert!((s.lr(0) - 4e-4).abs() < 1e-9);
        assert!((s.lr(100) - 1e-4).abs() < 1e-9);
        assert!((s.lr(1_000) - 1e-4).abs() < 1e-9);
        let mid = s.lr(50);
        assert!(mid < 4e-4 && mid > 1e-4);
        // monotone non-increasing
        let mut prev = f32::INFINITY;
        for i in 0..=100 {
            let lr = s.lr(i);
            assert!(lr <= prev + 1e-9);
            prev = lr;
        }
    }

    #[test]
    fn obs_dims_inference() {
        assert_eq!(obs_dims(64 * 64), (64, 1));
        assert_eq!(obs_dims(64 * 64 * 3), (64, 3));
        assert_eq!(obs_dims(32 * 32), (32, 1));
        assert_eq!(obs_dims(128 * 128 * 3), (128, 3));
    }
}
