//! Training metrics: episode-level SPL/success/score windows (paper §4.1
//! evaluation metrics), FPS accounting per the paper's methodology, and
//! CSV/JSONL logging for the figure-regeneration benches.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::io::Write;
use std::path::Path;

use anyhow::Result;

/// Sliding window over per-episode metrics.
#[derive(Clone, Debug)]
pub struct Window {
    buf: VecDeque<f32>,
    cap: usize,
    /// Reused selection/sort scratch for the percentile reads: the serve
    /// stats path polls percentiles per shard per tick, and a fresh
    /// `Vec` per call was measurable allocator churn. `RefCell` (not a
    /// lock): every `Window` sits behind a mutex or is single-owner, so
    /// the window needs `Send`, never `Sync`.
    scratch: RefCell<Vec<f32>>,
}

impl Window {
    pub fn new(cap: usize) -> Window {
        Window {
            buf: VecDeque::with_capacity(cap),
            cap,
            scratch: RefCell::new(Vec::new()),
        }
    }

    pub fn push(&mut self, x: f32) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(x);
    }

    pub fn mean(&self) -> f32 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.buf.iter().sum::<f32>() / self.buf.len() as f32
    }

    /// Nearest-rank index for quantile `q` into a window of `len` samples.
    fn rank_index(len: usize, q: f32) -> usize {
        let q = q.clamp(0.0, 1.0);
        let rank = (q * len as f32).ceil() as usize;
        rank.clamp(1, len) - 1
    }

    /// Nearest-rank percentile of the windowed samples (`q` in `[0, 1]`;
    /// `percentile(0.5)` is the median, `percentile(0.95)` the p95). Used
    /// by the serve layer's step-latency stats, where a mean hides the
    /// tail a straggling co-tenant inflicts. Returns 0.0 when empty.
    ///
    /// O(n) via `select_nth_unstable_by` — the server stats path polls
    /// this per shard per tick, so a full sort per call adds up, and the
    /// selection runs in a reused scratch buffer (no allocation after
    /// the first call at a given window size). For several quantiles of
    /// the same window use [`percentiles`] (one sort, K rank reads).
    ///
    /// [`percentiles`]: Window::percentiles
    pub fn percentile(&self, q: f32) -> f32 {
        if self.buf.is_empty() {
            return 0.0;
        }
        let mut scratch = self.scratch.borrow_mut();
        scratch.clear();
        scratch.extend(self.buf.iter().copied());
        let idx = Self::rank_index(scratch.len(), q);
        let (_, nth, _) = scratch.select_nth_unstable_by(idx, |a, b| {
            a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
        });
        *nth
    }

    /// Several nearest-rank percentiles of the same window: sorts the
    /// samples once and reads each rank, so multi-quantile consumers
    /// (p50+p95 in every stats row) don't re-scan per quantile. Bitwise
    /// identical to calling [`percentile`](Window::percentile) per `q`.
    pub fn percentiles<const K: usize>(&self, qs: [f32; K]) -> [f32; K] {
        let mut out = [0.0f32; K];
        if self.buf.is_empty() {
            return out;
        }
        let mut sorted = self.scratch.borrow_mut();
        sorted.clear();
        sorted.extend(self.buf.iter().copied());
        sorted.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        for (o, q) in out.iter_mut().zip(qs) {
            *o = sorted[Self::rank_index(sorted.len(), q)];
        }
        out
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// True once the window holds `cap` samples — the "enough evidence"
    /// gate for the scenario curriculum's advance rule.
    pub fn is_full(&self) -> bool {
        self.buf.len() == self.cap
    }

    /// Drop every sample (the curriculum clears its windows on a stage
    /// advance so each stage is judged on its own episodes).
    pub fn clear(&mut self) {
        self.buf.clear();
    }
}

/// Aggregated episode statistics (success / SPL / score / reward).
#[derive(Debug)]
pub struct EpisodeStats {
    pub success: Window,
    pub spl: Window,
    pub score: Window,
    pub reward: Window,
    pub episodes: u64,
    reward_acc: Vec<f32>,
}

impl EpisodeStats {
    pub fn new(n_envs: usize, window: usize) -> EpisodeStats {
        EpisodeStats {
            success: Window::new(window),
            spl: Window::new(window),
            score: Window::new(window),
            reward: Window::new(window),
            episodes: 0,
            reward_acc: vec![0.0; n_envs],
        }
    }

    /// Feed one batched sim step's outcome.
    pub fn update(
        &mut self,
        rewards: &[f32],
        dones: &[bool],
        successes: &[bool],
        spl: &[f32],
        scores: &[f32],
    ) {
        for i in 0..rewards.len() {
            self.reward_acc[i] += rewards[i];
            if dones[i] {
                self.episodes += 1;
                self.success.push(if successes[i] { 1.0 } else { 0.0 });
                self.spl.push(spl[i]);
                self.score.push(scores[i]);
                self.reward.push(self.reward_acc[i]);
                self.reward_acc[i] = 0.0;
            }
        }
    }
}

/// Line-buffered CSV writer for training curves (Fig. 3/4/A1/A3 series).
pub struct CsvLogger {
    file: std::io::BufWriter<std::fs::File>,
}

impl CsvLogger {
    pub fn create(path: &Path, header: &str) -> Result<CsvLogger> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(file, "{header}")?;
        Ok(CsvLogger { file })
    }

    /// Buffer one row. Rows are NOT synced per call — that cost a
    /// syscall per training step; call [`flush`](Self::flush) at a
    /// checkpoint cadence, and `Drop` flushes whatever remains (the
    /// `BufWriter` flushes on drop, so a cleanly dropped logger loses
    /// nothing).
    pub fn row(&mut self, values: &[f64]) -> Result<()> {
        let line = values
            .iter()
            .map(|v| format!("{v}"))
            .collect::<Vec<_>>()
            .join(",");
        writeln!(self.file, "{line}")?;
        Ok(())
    }

    /// Push buffered rows to the OS (crash-visibility checkpoint).
    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_caps_and_averages() {
        let mut w = Window::new(3);
        assert!(!w.is_full());
        for x in [1.0, 2.0, 3.0, 4.0] {
            w.push(x);
        }
        assert_eq!(w.len(), 3);
        assert!(w.is_full());
        assert!((w.mean() - 3.0).abs() < 1e-6);
        w.clear();
        assert!(w.is_empty() && !w.is_full());
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut w = Window::new(100);
        for x in 1..=100 {
            w.push(x as f32);
        }
        assert!((w.percentile(0.5) - 50.0).abs() < 1e-6);
        assert!((w.percentile(0.95) - 95.0).abs() < 1e-6);
        assert!((w.percentile(0.0) - 1.0).abs() < 1e-6);
        assert!((w.percentile(1.0) - 100.0).abs() < 1e-6);
        // out-of-range quantiles clamp
        assert!((w.percentile(2.0) - 100.0).abs() < 1e-6);
    }

    #[test]
    fn percentile_unordered_and_capped() {
        let mut w = Window::new(3);
        for x in [5.0, 1.0, 9.0, 3.0, 7.0] {
            w.push(x); // window keeps [9, 3, 7]
        }
        assert!((w.percentile(0.5) - 7.0).abs() < 1e-6);
        assert!((w.percentile(1.0) - 9.0).abs() < 1e-6);
        let single = {
            let mut w = Window::new(4);
            w.push(2.5);
            w
        };
        assert!((single.percentile(0.5) - 2.5).abs() < 1e-6);
        assert_eq!(Window::new(4).percentile(0.5), 0.0);
    }

    /// The single-sort multi-quantile read must agree with per-call
    /// nearest-rank selection at every rank, including the clamps.
    #[test]
    fn percentiles_match_percentile_per_quantile() {
        let mut w = Window::new(64);
        let mut x = 7u32;
        for _ in 0..50 {
            // small deterministic LCG so ranks land on unordered data
            x = x.wrapping_mul(1664525).wrapping_add(1013904223);
            w.push((x % 1000) as f32 / 10.0);
        }
        let qs = [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0, 2.0];
        let multi = w.percentiles(qs);
        for (q, m) in qs.iter().zip(multi) {
            assert_eq!(m, w.percentile(*q), "q={q}");
        }
        assert_eq!(Window::new(4).percentiles([0.5, 0.95]), [0.0, 0.0]);
    }

    #[test]
    fn episode_stats_accumulate_reward_per_episode() {
        let mut s = EpisodeStats::new(2, 10);
        s.update(&[1.0, 0.5], &[false, false], &[false, false], &[0.0, 0.0], &[0.0, 0.0]);
        s.update(&[2.0, 0.5], &[true, false], &[true, false], &[0.9, 0.0], &[1.0, 0.0]);
        assert_eq!(s.episodes, 1);
        assert!((s.reward.mean() - 3.0).abs() < 1e-6);
        assert!((s.success.mean() - 1.0).abs() < 1e-6);
        assert!((s.spl.mean() - 0.9).abs() < 1e-6);
        // env 1 still accumulating
        s.update(&[0.0, 1.0], &[false, true], &[false, false], &[0.0, 0.0], &[0.0, 0.0]);
        assert_eq!(s.episodes, 2);
        assert!((s.reward.mean() - (3.0 + 2.0) / 2.0).abs() < 1e-6);
        assert!((s.success.mean() - 0.5).abs() < 1e-6);
    }

    /// Regression for the scratch-buffer reuse: repeated percentile
    /// reads interleaved with pushes must return exactly what a fresh
    /// sort-and-rank over the window computes every time (the reused
    /// scratch must never leak stale samples between calls).
    #[test]
    fn percentile_scratch_reuse_results_unchanged() {
        let mut w = Window::new(32);
        let mut x = 42u32;
        for step in 0..100 {
            x = x.wrapping_mul(1664525).wrapping_add(1013904223);
            w.push((x % 512) as f32);
            // shrinking window sizes exercise scratch longer than buf
            if step == 60 {
                w.clear();
            }
            if w.is_empty() {
                continue;
            }
            for q in [0.0, 0.25, 0.5, 0.95, 1.0] {
                let mut fresh: Vec<f32> = w.buf.iter().copied().collect();
                fresh.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
                let expect = fresh[Window::rank_index(fresh.len(), q)];
                assert_eq!(w.percentile(q), expect, "step={step} q={q}");
                assert_eq!(w.percentiles([q])[0], expect, "step={step} q={q}");
            }
        }
    }

    #[test]
    fn csv_logger_buffers_until_flush() {
        let dir = std::env::temp_dir().join("bps_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("buffered.csv");
        let mut log = CsvLogger::create(&path, "a").unwrap();
        // small rows sit in the BufWriter until an explicit flush
        log.row(&[1.0]).unwrap();
        log.flush().unwrap();
        let after_flush = std::fs::read_to_string(&path).unwrap();
        assert!(after_flush.contains("\n1\n"), "{after_flush:?}");
        log.row(&[2.0]).unwrap();
        drop(log); // flush-on-drop lands the tail
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a\n1\n2\n");
    }

    #[test]
    fn csv_logger_writes_rows() {
        let dir = std::env::temp_dir().join("bps_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("curve.csv");
        let mut log = CsvLogger::create(&path, "a,b").unwrap();
        log.row(&[1.0, 2.5]).unwrap();
        log.row(&[2.0, 3.5]).unwrap();
        drop(log);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("a,b\n1,2.5\n"));
    }
}
