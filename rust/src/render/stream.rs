//! Asset streaming + the K-slot scene rotation (paper §3.2).
//!
//! The renderer keeps K ≪ N unique scene assets resident and shares them
//! across the batch (N:K ≤ 32 to preserve experience diversity). A
//! background loader thread continuously loads the *next* scenes from disk,
//! overlapping I/O with rollout generation and learning; when a load
//! completes, the slot's environments are queued to move to the new scene
//! at their next episode reset, and the old asset is dropped (freed once
//! the last episode on it ends, via `Arc` refcounts).

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::obs::Heartbeat;
use crate::scenario::ScenarioStream;
use crate::scene::{Dataset, SceneAsset};
use crate::sim::BatchSim;

/// Paper constraint: no scene asset shared by more than 32 envs in a batch.
pub const MAX_N_TO_K: usize = 32;

/// Background asset loader (the "asynchronous transfers" of Fig. 2).
pub struct AssetStreamer {
    req_tx: Sender<String>,
    ready_rx: Receiver<(String, Arc<SceneAsset>)>,
    _thread: JoinHandle<()>,
}

impl AssetStreamer {
    pub fn new(dataset: Dataset, with_textures: bool) -> AssetStreamer {
        let (req_tx, req_rx) = channel::<String>();
        let (ready_tx, ready_rx) = channel();
        let thread = std::thread::spawn(move || {
            while let Ok(id) = req_rx.recv() {
                match dataset.load_scene(&id, with_textures) {
                    Ok(scene) => {
                        if ready_tx.send((id, Arc::new(scene))).is_err() {
                            return;
                        }
                    }
                    Err(e) => eprintln!("asset streamer: failed to load {id}: {e:#}"),
                }
            }
        });
        AssetStreamer {
            req_tx,
            ready_rx,
            _thread: thread,
        }
    }

    pub fn request(&self, id: &str) {
        let _ = self.req_tx.send(id.to_string());
    }

    /// Non-blocking poll for completed loads.
    pub fn poll(&self) -> Vec<(String, Arc<SceneAsset>)> {
        let mut out = Vec::new();
        loop {
            match self.ready_rx.try_recv() {
                Ok(x) => out.push(x),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        out
    }

    /// Blocking wait for one load (startup only).
    pub fn wait_one(&self) -> Option<(String, Arc<SceneAsset>)> {
        self.ready_rx.recv().ok()
    }
}

/// Where a rotation's fresh scenes come from: the on-disk dataset loader
/// or the scenario engine's streaming procgen pipeline. Both prefetch in
/// the background; the enum keeps dispatch static and the dataset path
/// byte-identical to its pre-scenario behavior.
enum Feed {
    /// `.bsc` assets streamed from a dataset split (one load in flight).
    Dataset {
        streamer: AssetStreamer,
        ids: Vec<String>,
        next_scene: usize,
        inflight: bool,
    },
    /// Scenes synthesized on demand by the scenario engine (its own
    /// bounded prefetch queue; unbounded scene supply).
    Scenario(Box<ScenarioStream>),
}

/// K resident scenes rotated through the training split.
pub struct SceneRotation {
    pub k: usize,
    pub active: Vec<Arc<SceneAsset>>,
    next_slot: usize,
    feed: Feed,
    pub rotations: u64,
}

impl SceneRotation {
    /// Load the initial K scenes synchronously and start prefetching.
    pub fn new(
        dataset: Dataset,
        split_ids: Vec<String>,
        k: usize,
        with_textures: bool,
    ) -> Result<SceneRotation> {
        assert!(!split_ids.is_empty());
        let k = k.clamp(1, split_ids.len());
        let streamer = AssetStreamer::new(dataset, with_textures);
        let mut active = Vec::with_capacity(k);
        for id in split_ids.iter().take(k) {
            streamer.request(id);
        }
        for _ in 0..k {
            let (_, scene) = streamer
                .wait_one()
                .ok_or_else(|| anyhow::anyhow!("asset streamer died during startup"))?;
            active.push(scene);
        }
        let mut rot = SceneRotation {
            k,
            active,
            next_slot: 0,
            feed: Feed::Dataset {
                streamer,
                ids: split_ids,
                next_scene: k,
                inflight: false,
            },
            rotations: 0,
        };
        rot.kick_prefetch();
        Ok(rot)
    }

    /// A rotation fed by the scenario engine's streaming procgen: pull
    /// the initial K scenes (blocking — build time, like the dataset
    /// path's initial loads), then keep the stream's bounded queue warm.
    pub fn streaming(mut stream: ScenarioStream, k: usize) -> Result<SceneRotation> {
        let k = k.max(1);
        let mut active = Vec::with_capacity(k);
        for _ in 0..k {
            let scene = stream
                .next_blocking()
                .ok_or_else(|| anyhow::anyhow!("scenario procgen stream died during startup"))?;
            active.push(scene);
        }
        // startup waits are expected; stalls now measure steady state
        stream.reset_stalls();
        stream.top_up();
        Ok(SceneRotation {
            k,
            active,
            next_slot: 0,
            feed: Feed::Scenario(Box::new(stream)),
            rotations: 0,
        })
    }

    /// Forward a curriculum stage change to a scenario feed (a no-op for
    /// dataset-backed rotations — their difficulty is baked on disk).
    pub fn set_stage(&mut self, stage: u32) {
        if let Feed::Scenario(stream) = &mut self.feed {
            stream.set_stage(stage);
        }
    }

    /// Steady-state stalls of a scenario feed (0 for dataset feeds):
    /// blocking takes that found the prefetch queue cold.
    pub fn feed_stalls(&self) -> u64 {
        match &self.feed {
            Feed::Scenario(stream) => stream.stalls(),
            Feed::Dataset { .. } => 0,
        }
    }

    /// The generator thread's heartbeat for a scenario feed (`None` for
    /// dataset feeds), so a serving stack can adopt it into its watchdog.
    pub(crate) fn procgen_heartbeat(&self) -> Option<Heartbeat> {
        match &self.feed {
            Feed::Scenario(stream) => Some(stream.heartbeat()),
            Feed::Dataset { .. } => None,
        }
    }

    /// Block until a scenario feed's prefetch queue is fully warm (no-op
    /// for dataset feeds). Tests and benches use this to assert the
    /// warm-queue non-blocking property deterministically.
    pub fn wait_feed_warm(&mut self) {
        if let Feed::Scenario(stream) = &mut self.feed {
            stream.wait_warm();
        }
    }

    /// True when the feed cannot supply a scene beyond the K resident
    /// ones (a dataset split that fits entirely in the slots).
    fn exhausted(&self) -> bool {
        match &self.feed {
            Feed::Dataset { ids, .. } => ids.len() <= self.k,
            Feed::Scenario(_) => false,
        }
    }

    fn kick_prefetch(&mut self) {
        match &mut self.feed {
            Feed::Dataset { streamer, ids, next_scene, inflight } => {
                if !*inflight && ids.len() > self.k {
                    let id = &ids[*next_scene % ids.len()];
                    streamer.request(id);
                    *next_scene += 1;
                    *inflight = true;
                }
            }
            Feed::Scenario(stream) => stream.top_up(),
        }
    }

    /// Non-blocking take from the feed, if a fresh scene is ready.
    fn try_take(&mut self) -> Option<Arc<SceneAsset>> {
        match &mut self.feed {
            Feed::Dataset { streamer, inflight, .. } => {
                let mut got = None;
                for (_, scene) in streamer.poll() {
                    *inflight = false;
                    got = Some(scene);
                }
                got
            }
            Feed::Scenario(stream) => stream.try_next(),
        }
    }

    /// Blocking take (the pinned schedule's deterministic swap).
    fn take_blocking(&mut self) -> Option<Arc<SceneAsset>> {
        match &mut self.feed {
            Feed::Dataset { streamer, inflight, .. } => {
                let (_, scene) = streamer.wait_one()?;
                *inflight = false;
                Some(scene)
            }
            Feed::Scenario(stream) => stream.next_blocking(),
        }
    }

    /// Initial env -> scene assignment (round-robin over the K slots,
    /// enforcing the N:K <= 32 sharing cap).
    pub fn assign(&self, n: usize) -> Vec<Arc<SceneAsset>> {
        assert!(
            n <= self.k * MAX_N_TO_K,
            "N={n} exceeds K*32={} (paper sharing cap)",
            self.k * MAX_N_TO_K
        );
        (0..n)
            .map(|i| Arc::clone(&self.active[i % self.k]))
            .collect()
    }

    pub fn slot_of_env(&self, env: usize) -> usize {
        env % self.k
    }

    /// Called once per training iteration: if a prefetched scene is ready,
    /// swap it into the next slot and queue the slot's envs for migration
    /// at their next reset. Never blocks rollout generation — but the swap
    /// iteration therefore depends on wall-clock load latency; see
    /// [`rotate_pinned`](SceneRotation::rotate_pinned) for the
    /// reproducible variant.
    pub fn rotate(&mut self, sim: &mut BatchSim) {
        if let Some(scene) = self.try_take() {
            self.swap_in(scene, sim);
        }
        self.kick_prefetch();
    }

    /// Deterministic variant of [`rotate`](SceneRotation::rotate): block
    /// until the in-flight prefetch completes and swap exactly one slot.
    /// The swap schedule becomes a pure function of the call count instead
    /// of load latency, so A/B runs (e.g. pipelined vs synchronous
    /// stepping) rotate scenes at identical iterations even with prefetch
    /// active (`EnvBatchConfig::pin_rotation`). No-op when the whole split
    /// already fits in the K resident slots. With a warm scenario feed the
    /// blocking take pops straight off the prefetch queue — synthesis
    /// stays off this thread (asserted via `feed_stalls` in tests).
    pub fn rotate_pinned(&mut self, sim: &mut BatchSim) {
        if self.exhausted() {
            return;
        }
        self.kick_prefetch();
        let scene = match self.take_blocking() {
            Some(scene) => scene,
            None => return, // feed thread died; degrade to a no-op
        };
        self.swap_in(scene, sim);
        self.kick_prefetch();
    }

    /// Swap `scene` into the next rotation slot and queue the slot's envs
    /// for migration at their next episode reset.
    fn swap_in(&mut self, scene: Arc<SceneAsset>, sim: &mut BatchSim) {
        let slot = self.next_slot % self.k;
        self.active[slot] = Arc::clone(&scene);
        for env in 0..sim.num_envs() {
            if env % self.k == slot {
                sim.queue_scene(env, Arc::clone(&scene));
            }
        }
        self.next_slot += 1;
        self.rotations += 1;
    }

    /// Total resident asset footprint (the "GPU memory" budget check).
    pub fn resident_bytes(&self, with_textures: bool) -> usize {
        self.active
            .iter()
            .map(|s| s.footprint_bytes(with_textures))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::dataset::generate_dataset;
    use crate::scene::Complexity;
    use crate::sim::{SimConfig, SimOutputs, ACTION_LEFT};
    use crate::util::pool::WorkerPool;
    use std::path::PathBuf;

    fn dataset(name: &str, n: usize) -> (Dataset, PathBuf) {
        let dir = std::env::temp_dir().join("bps_stream_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ds = generate_dataset(&dir, n, 0, 0, Complexity::test(), 77).unwrap();
        (ds, dir)
    }

    #[test]
    fn streamer_loads_in_background() {
        let (ds, _d) = dataset("bg", 2);
        let st = AssetStreamer::new(ds, false);
        st.request("train_000");
        st.request("train_001");
        let mut got = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while got.len() < 2 && std::time::Instant::now() < deadline {
            got.extend(st.poll());
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(got.len(), 2);
        assert!(got.iter().any(|(id, _)| id == "train_000"));
    }

    #[test]
    fn rotation_respects_sharing_cap() {
        let (ds, _d) = dataset("cap", 3);
        let ids = ds.train.clone();
        let rot = SceneRotation::new(ds, ids, 2, false).unwrap();
        assert_eq!(rot.active.len(), 2);
        let assign = rot.assign(8);
        assert_eq!(assign.len(), 8);
        // round robin across 2 slots
        assert_eq!(assign[0].id, assign[2].id);
        assert_eq!(assign[1].id, assign[3].id);
        assert_ne!(assign[0].id, assign[1].id);
    }

    #[test]
    #[should_panic(expected = "sharing cap")]
    fn sharing_cap_enforced() {
        let (ds, _d) = dataset("cap2", 1);
        let ids = ds.train.clone();
        let rot = SceneRotation::new(ds, ids, 1, false).unwrap();
        let _ = rot.assign(33);
    }

    #[test]
    fn rotate_swaps_scene_into_sim() {
        let (ds, _d) = dataset("rot", 4);
        let ids = ds.train.clone();
        let mut rot = SceneRotation::new(ds, ids, 2, false).unwrap();
        let mut sim = BatchSim::new(
            SimConfig {
                max_steps: 1,
                ..SimConfig::pointnav()
            },
            rot.assign(4),
            5,
        );
        let first_scene = sim.env(0).scene.id.clone();
        // wait for the prefetch to complete, then rotate
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while rot.rotations == 0 && std::time::Instant::now() < deadline {
            rot.rotate(&mut sim);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(rot.rotations >= 1, "no rotation happened");
        // envs on the rotated slot migrate at next reset (max_steps = 1)
        let pool = WorkerPool::new(0);
        let mut out = SimOutputs::with_capacity(4);
        sim.step_batch(&pool, &[ACTION_LEFT; 4], &mut out);
        let rotated_slot = 0; // first rotation goes to slot 0
        let env_scene = sim.env(rotated_slot).scene.id.clone();
        assert_ne!(env_scene, first_scene, "scene not swapped after reset");
    }

    #[test]
    fn pinned_rotation_schedule_is_call_count_deterministic() {
        let (ds, _d) = dataset("pin", 4);
        let ids = ds.train.clone();
        let mut rot = SceneRotation::new(ds, ids, 2, false).unwrap();
        let mut sim = BatchSim::new(SimConfig::pointnav(), rot.assign(4), 5);
        // deterministic sequence: slot 0 <- train_002, slot 1 <- train_003,
        // slot 0 <- train_000 — regardless of how long each load takes
        rot.rotate_pinned(&mut sim);
        assert_eq!(rot.rotations, 1);
        assert_eq!(rot.active[0].id, "train_002");
        rot.rotate_pinned(&mut sim);
        assert_eq!(rot.rotations, 2);
        assert_eq!(rot.active[1].id, "train_003");
        rot.rotate_pinned(&mut sim);
        assert_eq!(rot.rotations, 3);
        assert_eq!(rot.active[0].id, "train_000");
    }

    #[test]
    fn pinned_rotation_noop_when_split_resident() {
        let (ds, _d) = dataset("pin_noop", 2);
        let ids = ds.train.clone();
        let mut rot = SceneRotation::new(ds, ids, 2, false).unwrap();
        let mut sim = BatchSim::new(SimConfig::pointnav(), rot.assign(2), 5);
        rot.rotate_pinned(&mut sim);
        assert_eq!(rot.rotations, 0, "nothing to rotate when K covers the split");
    }

    #[test]
    fn resident_bytes_tracks_textures() {
        let (ds, _d) = dataset("mem", 2);
        let ids = ds.train.clone();
        let rot = SceneRotation::new(ds.clone(), ids.clone(), 2, true).unwrap();
        let with_tex = rot.resident_bytes(true);
        let rot2 = SceneRotation::new(ds, ids, 2, false).unwrap();
        let depth_only = rot2.resident_bytes(false);
        assert!(with_tex > depth_only);
    }
}
