//! Agent camera: pinhole projection from a navmesh pose (position on the
//! floor + heading), eye height and FoV matching Habitat's PointGoalNav
//! sensor rig.

use crate::geom::vec::{v3, Vec2, Vec3};
use crate::geom::{Frustum, Mat4};

pub const EYE_HEIGHT: f32 = 1.25;
pub const FOV_DEG: f32 = 90.0;
pub const NEAR: f32 = 0.05;
pub const FAR: f32 = 50.0;

/// Camera pose + cached view-projection and frustum.
#[derive(Clone, Copy, Debug)]
pub struct Camera {
    pub eye: Vec3,
    pub view_proj: Mat4,
    pub frustum: Frustum,
}

impl Camera {
    /// Build from an agent pose: `pos` on the xz floor plane, `heading` in
    /// radians (0 = +x, counterclockwise when seen from above).
    pub fn from_agent(pos: Vec2, heading: f32, aspect: f32) -> Camera {
        let eye = v3(pos.x, EYE_HEIGHT, pos.y);
        let fwd = v3(heading.cos(), 0.0, heading.sin());
        let view = Mat4::look_at(eye, eye + fwd, Vec3::UP);
        let proj = Mat4::perspective(FOV_DEG.to_radians(), aspect, NEAR, FAR);
        let view_proj = proj.mul(&view);
        Camera {
            eye,
            view_proj,
            frustum: Frustum::from_view_proj(&view_proj),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::vec::v2;

    #[test]
    fn forward_point_visible_behind_not() {
        let cam = Camera::from_agent(v2(2.0, 3.0), 0.0, 1.0);
        // ahead along +x at eye height
        assert!(cam.frustum.contains_point(v3(5.0, 1.25, 3.0)));
        // behind
        assert!(!cam.frustum.contains_point(v3(-1.0, 1.25, 3.0)));
    }

    #[test]
    fn heading_rotates_view() {
        // facing +z (heading = pi/2)
        let cam = Camera::from_agent(v2(0.0, 0.0), std::f32::consts::FRAC_PI_2, 1.0);
        assert!(cam.frustum.contains_point(v3(0.0, 1.25, 4.0)));
        assert!(!cam.frustum.contains_point(v3(0.0, 1.25, -4.0)));
    }

    #[test]
    fn eye_at_agent_height() {
        let cam = Camera::from_agent(v2(1.0, 1.0), 0.3, 1.0);
        assert!((cam.eye.y - EYE_HEIGHT).abs() < 1e-6);
    }
}
