//! The batch renderer (paper §3.2): renders observations for an entire
//! simulation batch as one request — all N tiles of the "megaframe" are
//! produced by a single dynamically scheduled pass over shared scene assets
//! (K ≪ N unique assets referenced by N environments).
//!
//! Two execution modes reproduce the paper's pipelined-culling design and
//! its ablation: `Fused` runs cull+raster per environment inside one pass;
//! `Pipelined` runs frustum culling on a dedicated stage that feeds raster
//! workers through a queue, overlapping the two (the GPU analog: compute-
//! shader culling concurrent with rasterization).

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use crate::geom::Vec2;
use crate::scene::SceneAsset;
use crate::util::pool::WorkerPool;

use super::camera::Camera;
use super::raster::{cull_chunks, raster_tile, RasterStats, Sensor, TileScratch};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineMode {
    Fused,
    Pipelined,
}

/// Renderer configuration.
#[derive(Clone, Copy, Debug)]
pub struct RenderConfig {
    pub res: usize,
    pub sensor: Sensor,
    /// Supersampling factor: render at `res * scale` and box-downsample.
    /// The paper's 128px experiments render at 256px and downsample (§4.1);
    /// `scale = 2` reproduces that cost.
    pub scale: usize,
    pub mode: PipelineMode,
}

impl RenderConfig {
    pub fn depth(res: usize) -> RenderConfig {
        RenderConfig {
            res,
            sensor: Sensor::Depth,
            scale: 1,
            mode: PipelineMode::Pipelined,
        }
    }

    pub fn rgb(res: usize) -> RenderConfig {
        RenderConfig {
            sensor: Sensor::Rgb,
            ..RenderConfig::depth(res)
        }
    }

    pub fn obs_floats(&self) -> usize {
        self.res * self.res * self.sensor.channels()
    }

    fn render_res(&self) -> usize {
        self.res * self.scale.max(1)
    }
}

/// One render request: scene + agent pose.
pub struct RenderItem {
    pub scene: Arc<SceneAsset>,
    pub pos: Vec2,
    pub heading: f32,
}

struct EnvScratch {
    tile: TileScratch,
    visible: Vec<u32>,
    depth: Vec<f32>,
    rgb: Vec<f32>,
}

struct ScratchSlots(Vec<UnsafeCell<EnvScratch>>);

// SAFETY: one env index per worker per batch.
unsafe impl Sync for ScratchSlots {}

/// Batch renderer with reusable per-environment scratch buffers.
pub struct BatchRenderer {
    pub cfg: RenderConfig,
    scratch: ScratchSlots,
    pub stats_tris: AtomicUsize,
    pub stats_chunks_culled: AtomicUsize,
    pub stats_chunks_total: AtomicUsize,
}

impl BatchRenderer {
    pub fn new(cfg: RenderConfig, max_envs: usize) -> BatchRenderer {
        let rr = cfg.render_res();
        let scratch = (0..max_envs)
            .map(|_| {
                UnsafeCell::new(EnvScratch {
                    tile: TileScratch::new(rr),
                    visible: Vec::new(),
                    depth: vec![0.0; rr * rr],
                    rgb: if cfg.sensor == Sensor::Rgb {
                        vec![0.0; rr * rr * 3]
                    } else {
                        Vec::new()
                    },
                })
            })
            .collect();
        BatchRenderer {
            cfg,
            scratch: ScratchSlots(scratch),
            stats_tris: AtomicUsize::new(0),
            stats_chunks_culled: AtomicUsize::new(0),
            stats_chunks_total: AtomicUsize::new(0),
        }
    }

    /// Render the whole batch into `obs` (layout `[N, res, res, C]` f32).
    pub fn render_batch(&self, pool: &WorkerPool, items: &[RenderItem], obs: &mut [f32]) {
        let n = items.len();
        let of = self.cfg.obs_floats();
        assert!(obs.len() >= n * of, "obs buffer too small");
        assert!(n <= self.scratch.0.len(), "more envs than scratch slots");
        let obs_base = obs.as_mut_ptr() as usize;
        match self.cfg.mode {
            PipelineMode::Fused => {
                pool.parallel_for(n, 1, |i| {
                    self.render_one(items, i, obs_base);
                });
            }
            PipelineMode::Pipelined => {
                // Stage 1 (cull) feeds stage 2 (raster) through a queue so
                // culling for env i+1 overlaps rasterization of env i.
                let (tx, rx) = mpsc::channel::<usize>();
                let rx = std::sync::Mutex::new(rx);
                std::thread::scope(|s| {
                    s.spawn(move || {
                        for i in 0..n {
                            // SAFETY: writes only env i's scratch slot.
                            let sc = unsafe { &mut *self.scratch.0[i].get() };
                            let cam = Camera::from_agent(items[i].pos, items[i].heading, 1.0);
                            let cstats =
                                cull_chunks(&items[i].scene, &cam.frustum, &mut sc.visible);
                            self.stats_chunks_culled
                                .fetch_add(cstats.chunks_culled, Ordering::Relaxed);
                            self.stats_chunks_total
                                .fetch_add(cstats.chunks_total, Ordering::Relaxed);
                            if tx.send(i).is_err() {
                                return;
                            }
                        }
                    });
                    let workers = pool.num_workers().max(1);
                    for _ in 0..workers {
                        s.spawn(|| loop {
                            let i = {
                                let rx = rx.lock().unwrap();
                                match rx.recv() {
                                    Ok(i) => i,
                                    Err(_) => return,
                                }
                            };
                            self.raster_one(items, i, obs_base, /*cull=*/ false);
                        });
                    }
                });
            }
        }
    }

    fn render_one(&self, items: &[RenderItem], i: usize, obs_base: usize) {
        self.raster_one(items, i, obs_base, true);
    }

    fn raster_one(&self, items: &[RenderItem], i: usize, obs_base: usize, cull: bool) {
        // SAFETY: env-indexed scratch; obs tile slices are disjoint.
        let sc = unsafe { &mut *self.scratch.0[i].get() };
        let item = &items[i];
        let cam = Camera::from_agent(item.pos, item.heading, 1.0);
        if cull {
            let cstats = cull_chunks(&item.scene, &cam.frustum, &mut sc.visible);
            self.stats_chunks_culled
                .fetch_add(cstats.chunks_culled, Ordering::Relaxed);
            self.stats_chunks_total
                .fetch_add(cstats.chunks_total, Ordering::Relaxed);
        }
        let rr = self.cfg.render_res();
        let rgb_slice = if self.cfg.sensor == Sensor::Rgb {
            Some(&mut sc.rgb[..])
        } else {
            None
        };
        let stats = raster_tile(
            &item.scene,
            &cam,
            &sc.visible,
            rr,
            &mut sc.depth,
            rgb_slice,
            &mut sc.tile,
        );
        self.stats_tris
            .fetch_add(stats.tris_rasterized, Ordering::Relaxed);
        // write (downsampled) tile into the megaframe observation buffer
        let of = self.cfg.obs_floats();
        let out =
            unsafe { std::slice::from_raw_parts_mut((obs_base as *mut f32).add(i * of), of) };
        let res = self.cfg.res;
        let s = self.cfg.scale.max(1);
        let inv = 1.0 / (s * s) as f32;
        match self.cfg.sensor {
            Sensor::Depth => {
                for y in 0..res {
                    for x in 0..res {
                        let mut acc = 0.0;
                        for dy in 0..s {
                            for dx in 0..s {
                                acc += sc.depth[(y * s + dy) * rr + (x * s + dx)];
                            }
                        }
                        out[y * res + x] = acc * inv;
                    }
                }
            }
            Sensor::Rgb => {
                for y in 0..res {
                    for x in 0..res {
                        let mut acc = [0.0f32; 3];
                        for dy in 0..s {
                            for dx in 0..s {
                                let p = ((y * s + dy) * rr + (x * s + dx)) * 3;
                                acc[0] += sc.rgb[p];
                                acc[1] += sc.rgb[p + 1];
                                acc[2] += sc.rgb[p + 2];
                            }
                        }
                        let o = (y * res + x) * 3;
                        out[o] = acc[0] * inv;
                        out[o + 1] = acc[1] * inv;
                        out[o + 2] = acc[2] * inv;
                    }
                }
            }
        }
    }

    /// Aggregate statistics (since construction); (tris, culled, total).
    pub fn stats(&self) -> RasterStats {
        RasterStats {
            tris_rasterized: self.stats_tris.load(Ordering::Relaxed),
            chunks_culled: self.stats_chunks_culled.load(Ordering::Relaxed),
            chunks_total: self.stats_chunks_total.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::procgen::{generate, Complexity};
    use crate::util::rng::Rng;

    fn items(n: usize) -> Vec<RenderItem> {
        let s = Arc::new(generate("br", 51, Complexity::test()));
        let mut rng = Rng::new(1);
        (0..n)
            .map(|_| RenderItem {
                scene: Arc::clone(&s),
                pos: s.navmesh.random_point(&mut rng).unwrap(),
                heading: rng.range_f32(0.0, 6.28),
            })
            .collect()
    }

    #[test]
    fn fused_and_pipelined_identical_output() {
        let its = items(8);
        let pool = WorkerPool::new(3);
        let mut cfg = RenderConfig::depth(32);
        cfg.mode = PipelineMode::Fused;
        let r1 = BatchRenderer::new(cfg, 8);
        let mut o1 = vec![0.0f32; 8 * cfg.obs_floats()];
        r1.render_batch(&pool, &its, &mut o1);
        cfg.mode = PipelineMode::Pipelined;
        let r2 = BatchRenderer::new(cfg, 8);
        let mut o2 = vec![0.0f32; 8 * cfg.obs_floats()];
        r2.render_batch(&pool, &its, &mut o2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn tiles_isolated() {
        // rendering env i must not touch tile j != i
        let its = items(4);
        let pool = WorkerPool::new(2);
        let cfg = RenderConfig::depth(16);
        let r = BatchRenderer::new(cfg, 4);
        let of = cfg.obs_floats();
        let mut obs = vec![-7.0f32; 4 * of];
        r.render_batch(&pool, &its, &mut obs);
        for (i, chunk) in obs.chunks(of).enumerate() {
            assert!(
                chunk.iter().all(|&d| (0.0..=1.0).contains(&d)),
                "tile {i} has unwritten/invalid values"
            );
        }
    }

    #[test]
    fn downsampled_render_matches_direct_energy() {
        // scale=2 renders 2x and box-downsamples: means should be close to
        // a direct render (not identical: supersampling is anti-aliased)
        let its = items(2);
        let pool = WorkerPool::new(2);
        let c1 = RenderConfig::depth(32);
        let mut c2 = RenderConfig::depth(32);
        c2.scale = 2;
        let r1 = BatchRenderer::new(c1, 2);
        let r2 = BatchRenderer::new(c2, 2);
        let mut o1 = vec![0.0f32; 2 * c1.obs_floats()];
        let mut o2 = vec![0.0f32; 2 * c2.obs_floats()];
        r1.render_batch(&pool, &its, &mut o1);
        r2.render_batch(&pool, &its, &mut o2);
        let m1: f32 = o1.iter().sum::<f32>() / o1.len() as f32;
        let m2: f32 = o2.iter().sum::<f32>() / o2.len() as f32;
        assert!((m1 - m2).abs() < 0.05, "{m1} vs {m2}");
    }

    #[test]
    fn rgb_batch_shapes() {
        let its = items(3);
        let pool = WorkerPool::new(2);
        let cfg = RenderConfig::rgb(16);
        let r = BatchRenderer::new(cfg, 3);
        let mut obs = vec![0.0f32; 3 * cfg.obs_floats()];
        r.render_batch(&pool, &its, &mut obs);
        assert_eq!(cfg.obs_floats(), 16 * 16 * 3);
        assert!(obs.iter().any(|&c| c > 0.0));
    }

    #[test]
    fn stats_accumulate() {
        let its = items(4);
        let pool = WorkerPool::new(2);
        let cfg = RenderConfig::depth(16);
        let r = BatchRenderer::new(cfg, 4);
        let mut obs = vec![0.0f32; 4 * cfg.obs_floats()];
        r.render_batch(&pool, &its, &mut obs);
        let s = r.stats();
        assert!(s.tris_rasterized > 0);
        assert!(s.chunks_total >= s.chunks_culled);
    }
}
