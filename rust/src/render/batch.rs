//! The batch renderer (paper §3.2): renders observations for an entire
//! simulation batch as one request — all N tiles of the "megaframe" are
//! produced by a single dynamically scheduled pass over shared scene assets
//! (K ≪ N unique assets referenced by N environments).
//!
//! Two execution modes reproduce the paper's pipelined-culling design and
//! its ablation: `Fused` runs cull+raster per environment inside one pass;
//! `Pipelined` runs frustum culling as a stage that feeds rasterization
//! through `WorkerPool::staged_for` — an atomic ticket cursor plus a
//! lock-free readiness counter on the *persistent* worker pool, so a batch
//! costs no thread spawns, channels, or mutexes (the GPU analog:
//! compute-shader culling concurrent with rasterization).
//!
//! Dispatch is **cost-aware**: environments are issued heaviest-first
//! (LPT) by their previous-frame `tris_rasterized`, so one heavy
//! scenario-stage env no longer straggles a batch of light ones. Tiles are
//! disjoint, so dispatch order never affects output (asserted in
//! `rust/tests/render_golden.rs`).

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::geom::Vec2;
use crate::scene::SceneAsset;
use crate::util::pool::WorkerPool;

use super::camera::Camera;
use super::raster::{
    cull_chunks, raster_zbuf, resolve_depth_into, resolve_rgb_into, RasterStats, Sensor,
    StageTimes, TileScratch,
};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineMode {
    Fused,
    Pipelined,
}

/// Renderer configuration.
#[derive(Clone, Copy, Debug)]
pub struct RenderConfig {
    pub res: usize,
    pub sensor: Sensor,
    /// Supersampling factor: render at `res * scale` and box-downsample.
    /// The paper's 128px experiments render at 256px and downsample (§4.1);
    /// `scale = 2` reproduces that cost.
    pub scale: usize,
    pub mode: PipelineMode,
}

impl RenderConfig {
    pub fn depth(res: usize) -> RenderConfig {
        RenderConfig {
            res,
            sensor: Sensor::Depth,
            scale: 1,
            mode: PipelineMode::Pipelined,
        }
    }

    pub fn rgb(res: usize) -> RenderConfig {
        RenderConfig {
            sensor: Sensor::Rgb,
            ..RenderConfig::depth(res)
        }
    }

    pub fn obs_floats(&self) -> usize {
        self.res * self.res * self.sensor.channels()
    }

    fn render_res(&self) -> usize {
        self.res * self.scale.max(1)
    }
}

/// One render request: scene + agent pose.
pub struct RenderItem {
    pub scene: Arc<SceneAsset>,
    pub pos: Vec2,
    pub heading: f32,
}

struct EnvScratch {
    tile: TileScratch,
    visible: Vec<u32>,
    /// Full-resolution shaded buffer (RGB sensors only); depth resolves
    /// straight from the tile z-buffer, no intermediate copy.
    rgb: Vec<f32>,
}

struct ScratchSlots(Vec<UnsafeCell<EnvScratch>>);

// SAFETY: one env index per worker per batch; in pipelined mode the cull
// stage's writes to a slot are published through `staged_for`'s readiness
// counter (Release/Acquire) before the raster stage reads them.
unsafe impl Sync for ScratchSlots {}

/// Work + per-stage wall-time counters, `Arc`-shared so `EnvBatch` (and
/// the serve layer) can read them after the renderer moves onto a driver
/// thread.
#[derive(Default)]
pub struct RenderCounters {
    tris: AtomicUsize,
    chunks_culled: AtomicUsize,
    chunks_total: AtomicUsize,
    transform_ns: AtomicU64,
    cull_ns: AtomicU64,
    raster_ns: AtomicU64,
    resolve_ns: AtomicU64,
}

/// Snapshot of renderer work: triangle/chunk counts plus the per-stage
/// wall-time breakdown (transform / cull / raster / resolve) the Table A2
/// benches report.
#[derive(Clone, Copy, Debug, Default)]
pub struct RenderStats {
    pub tris_rasterized: usize,
    pub chunks_culled: usize,
    pub chunks_total: usize,
    pub transform_ns: u64,
    pub cull_ns: u64,
    pub raster_ns: u64,
    pub resolve_ns: u64,
}

impl RenderStats {
    /// Total wall time attributed to renderer stages (summed across
    /// workers, so it exceeds elapsed time under parallelism).
    pub fn stage_ns_total(&self) -> u64 {
        self.transform_ns + self.cull_ns + self.raster_ns + self.resolve_ns
    }
}

impl RenderCounters {
    fn peek(&self) -> RenderStats {
        RenderStats {
            tris_rasterized: self.tris.load(Ordering::Relaxed),
            chunks_culled: self.chunks_culled.load(Ordering::Relaxed),
            chunks_total: self.chunks_total.load(Ordering::Relaxed),
            transform_ns: self.transform_ns.load(Ordering::Relaxed),
            cull_ns: self.cull_ns.load(Ordering::Relaxed),
            raster_ns: self.raster_ns.load(Ordering::Relaxed),
            resolve_ns: self.resolve_ns.load(Ordering::Relaxed),
        }
    }

    /// Drain the counters (reset-on-read).
    pub(crate) fn take(&self) -> RenderStats {
        RenderStats {
            tris_rasterized: self.tris.swap(0, Ordering::Relaxed),
            chunks_culled: self.chunks_culled.swap(0, Ordering::Relaxed),
            chunks_total: self.chunks_total.swap(0, Ordering::Relaxed),
            transform_ns: self.transform_ns.swap(0, Ordering::Relaxed),
            cull_ns: self.cull_ns.swap(0, Ordering::Relaxed),
            raster_ns: self.raster_ns.swap(0, Ordering::Relaxed),
            resolve_ns: self.resolve_ns.swap(0, Ordering::Relaxed),
        }
    }
}

/// Batch renderer with reusable per-environment scratch buffers.
pub struct BatchRenderer {
    pub cfg: RenderConfig,
    scratch: ScratchSlots,
    counters: Arc<RenderCounters>,
    /// Previous-frame triangle count per env slot — the cost signal for
    /// the LPT (heaviest-first) dispatch order.
    prev_cost: Vec<AtomicUsize>,
}

impl BatchRenderer {
    pub fn new(cfg: RenderConfig, max_envs: usize) -> BatchRenderer {
        let rr = cfg.render_res();
        let scratch = (0..max_envs)
            .map(|_| {
                UnsafeCell::new(EnvScratch {
                    tile: TileScratch::new(rr),
                    visible: Vec::new(),
                    rgb: if cfg.sensor == Sensor::Rgb {
                        vec![0.0; rr * rr * 3]
                    } else {
                        Vec::new()
                    },
                })
            })
            .collect();
        BatchRenderer {
            cfg,
            scratch: ScratchSlots(scratch),
            counters: Arc::new(RenderCounters::default()),
            prev_cost: (0..max_envs).map(|_| AtomicUsize::new(0)).collect(),
        }
    }

    /// Render the whole batch into `obs` (layout `[N, res, res, C]` f32).
    pub fn render_batch(&self, pool: &WorkerPool, items: &[RenderItem], obs: &mut [f32]) {
        let n = items.len();
        let of = self.cfg.obs_floats();
        assert!(obs.len() >= n * of, "obs buffer too small");
        assert!(n <= self.scratch.0.len(), "more envs than scratch slots");
        let obs_base = obs.as_mut_ptr() as usize;
        let order = self.dispatch_order(n);
        match self.cfg.mode {
            PipelineMode::Fused => {
                pool.parallel_for(n, 1, |k| {
                    let i = order[k];
                    self.cull_one(items, i);
                    self.raster_one(items, i, obs_base);
                });
            }
            PipelineMode::Pipelined => {
                // Cull (stage 1) overlaps raster (stage 2) on the shared
                // persistent pool: tickets claim culls, a readiness prefix
                // counter releases tiles to raster workers.
                pool.staged_for(
                    n,
                    |t| self.cull_one(items, order[t]),
                    |k| self.raster_one(items, order[k], obs_base),
                );
            }
        }
    }

    /// LPT dispatch: heaviest environments (by previous-frame triangle
    /// count) first. Stable sort keeps ties — and the whole first frame —
    /// in env order. Output is order-invariant; only tail latency moves.
    fn dispatch_order(&self, n: usize) -> Vec<usize> {
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(self.prev_cost[i].load(Ordering::Relaxed)));
        order
    }

    fn cull_one(&self, items: &[RenderItem], i: usize) {
        // SAFETY: env-indexed scratch slot; published to the raster stage
        // via staged_for's readiness counter in pipelined mode.
        let sc = unsafe { &mut *self.scratch.0[i].get() };
        let item = &items[i];
        let cam = Camera::from_agent(item.pos, item.heading, 1.0);
        let t0 = Instant::now();
        let cstats = cull_chunks(&item.scene, &cam.frustum, &mut sc.visible);
        self.counters
            .cull_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.counters
            .chunks_culled
            .fetch_add(cstats.chunks_culled, Ordering::Relaxed);
        self.counters
            .chunks_total
            .fetch_add(cstats.chunks_total, Ordering::Relaxed);
    }

    fn raster_one(&self, items: &[RenderItem], i: usize, obs_base: usize) {
        // SAFETY: env-indexed scratch; obs tile slices are disjoint.
        let sc = unsafe { &mut *self.scratch.0[i].get() };
        let item = &items[i];
        let cam = Camera::from_agent(item.pos, item.heading, 1.0);
        let rr = self.cfg.render_res();
        let rgb_slice = if self.cfg.sensor == Sensor::Rgb {
            Some(&mut sc.rgb[..])
        } else {
            None
        };
        let mut times = StageTimes::default();
        let t0 = Instant::now();
        let stats = raster_zbuf(
            &item.scene,
            &cam,
            &sc.visible,
            rr,
            rgb_slice,
            &mut sc.tile,
            &mut times,
        );
        let raster_total = t0.elapsed().as_nanos() as u64;
        self.counters
            .tris
            .fetch_add(stats.tris_rasterized, Ordering::Relaxed);
        self.counters
            .transform_ns
            .fetch_add(times.transform_ns, Ordering::Relaxed);
        self.counters
            .raster_ns
            .fetch_add(raster_total.saturating_sub(times.transform_ns), Ordering::Relaxed);
        self.prev_cost[i].store(stats.tris_rasterized, Ordering::Relaxed);

        // Fused resolve: normalize + box-downsample straight into this
        // env's tile of the megaframe observation buffer.
        let of = self.cfg.obs_floats();
        // SAFETY: tile i is the half-open float range [i*of, (i+1)*of) of
        // the megaframe — index-disjoint across workers — and `obs_base`
        // comes from a `&mut [f32]` spanning n*of floats that the caller
        // holds across the whole batch (the pool joins before it returns).
        let out =
            unsafe { std::slice::from_raw_parts_mut((obs_base as *mut f32).add(i * of), of) };
        let t1 = Instant::now();
        match self.cfg.sensor {
            Sensor::Depth => resolve_depth_into(sc.tile.zbuf(), rr, self.cfg.scale, out),
            Sensor::Rgb => resolve_rgb_into(&sc.rgb, rr, self.cfg.scale, out),
        }
        self.counters
            .resolve_ns
            .fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Aggregate statistics since construction (or the last
    /// [`take_stats`](BatchRenderer::take_stats)): (tris, culled, total).
    pub fn stats(&self) -> RasterStats {
        let s = self.counters.peek();
        RasterStats {
            tris_rasterized: s.tris_rasterized,
            chunks_culled: s.chunks_culled,
            chunks_total: s.chunks_total,
        }
    }

    /// Per-batch statistics, reset on read — counts plus the per-stage
    /// wall-time breakdown (transform / cull / raster / resolve).
    pub fn take_stats(&self) -> RenderStats {
        self.counters.take()
    }

    /// The shared counters (cloned by `EnvBatch` before the renderer moves
    /// onto its driver thread).
    pub(crate) fn counters(&self) -> Arc<RenderCounters> {
        Arc::clone(&self.counters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::procgen::{generate, Complexity};
    use crate::util::rng::Rng;

    fn items(n: usize) -> Vec<RenderItem> {
        let s = Arc::new(generate("br", 51, Complexity::test()));
        let mut rng = Rng::new(1);
        (0..n)
            .map(|_| RenderItem {
                scene: Arc::clone(&s),
                pos: s.navmesh.random_point(&mut rng).unwrap(),
                heading: rng.range_f32(0.0, 6.28),
            })
            .collect()
    }

    #[test]
    fn fused_and_pipelined_identical_output() {
        let its = items(8);
        let pool = WorkerPool::new(3);
        let mut cfg = RenderConfig::depth(32);
        cfg.mode = PipelineMode::Fused;
        let r1 = BatchRenderer::new(cfg, 8);
        let mut o1 = vec![0.0f32; 8 * cfg.obs_floats()];
        r1.render_batch(&pool, &its, &mut o1);
        cfg.mode = PipelineMode::Pipelined;
        let r2 = BatchRenderer::new(cfg, 8);
        let mut o2 = vec![0.0f32; 8 * cfg.obs_floats()];
        r2.render_batch(&pool, &its, &mut o2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn tiles_isolated() {
        // rendering env i must not touch tile j != i
        let its = items(4);
        let pool = WorkerPool::new(2);
        let cfg = RenderConfig::depth(16);
        let r = BatchRenderer::new(cfg, 4);
        let of = cfg.obs_floats();
        let mut obs = vec![-7.0f32; 4 * of];
        r.render_batch(&pool, &its, &mut obs);
        for (i, chunk) in obs.chunks(of).enumerate() {
            assert!(
                chunk.iter().all(|&d| (0.0..=1.0).contains(&d)),
                "tile {i} has unwritten/invalid values"
            );
        }
    }

    #[test]
    fn downsampled_render_matches_direct_energy() {
        // scale=2 renders 2x and box-downsamples: means should be close to
        // a direct render (not identical: supersampling is anti-aliased)
        let its = items(2);
        let pool = WorkerPool::new(2);
        let c1 = RenderConfig::depth(32);
        let mut c2 = RenderConfig::depth(32);
        c2.scale = 2;
        let r1 = BatchRenderer::new(c1, 2);
        let r2 = BatchRenderer::new(c2, 2);
        let mut o1 = vec![0.0f32; 2 * c1.obs_floats()];
        let mut o2 = vec![0.0f32; 2 * c2.obs_floats()];
        r1.render_batch(&pool, &its, &mut o1);
        r2.render_batch(&pool, &its, &mut o2);
        let m1: f32 = o1.iter().sum::<f32>() / o1.len() as f32;
        let m2: f32 = o2.iter().sum::<f32>() / o2.len() as f32;
        assert!((m1 - m2).abs() < 0.05, "{m1} vs {m2}");
    }

    #[test]
    fn rgb_batch_shapes() {
        let its = items(3);
        let pool = WorkerPool::new(2);
        let cfg = RenderConfig::rgb(16);
        let r = BatchRenderer::new(cfg, 3);
        let mut obs = vec![0.0f32; 3 * cfg.obs_floats()];
        r.render_batch(&pool, &its, &mut obs);
        assert_eq!(cfg.obs_floats(), 16 * 16 * 3);
        assert!(obs.iter().any(|&c| c > 0.0));
    }

    #[test]
    fn stats_accumulate() {
        let its = items(4);
        let pool = WorkerPool::new(2);
        let cfg = RenderConfig::depth(16);
        let r = BatchRenderer::new(cfg, 4);
        let mut obs = vec![0.0f32; 4 * cfg.obs_floats()];
        r.render_batch(&pool, &its, &mut obs);
        let s = r.stats();
        assert!(s.tris_rasterized > 0);
        assert!(s.chunks_total >= s.chunks_culled);
    }

    #[test]
    fn take_stats_resets_and_reports_stages() {
        let its = items(4);
        let pool = WorkerPool::new(2);
        let cfg = RenderConfig::depth(16);
        let r = BatchRenderer::new(cfg, 4);
        let mut obs = vec![0.0f32; 4 * cfg.obs_floats()];
        r.render_batch(&pool, &its, &mut obs);
        let s1 = r.take_stats();
        assert!(s1.tris_rasterized > 0);
        assert!(s1.stage_ns_total() > 0);
        // reset-on-read: a second take with no work in between reads zero
        let s2 = r.take_stats();
        assert_eq!(s2.tris_rasterized, 0);
        assert_eq!(s2.stage_ns_total(), 0);
        // per-batch deltas line up across repeated identical batches
        r.render_batch(&pool, &its, &mut obs);
        let s3 = r.take_stats();
        assert_eq!(s3.tris_rasterized, s1.tris_rasterized);
        assert_eq!(s3.chunks_total, s1.chunks_total);
    }

    #[test]
    fn lpt_order_is_heaviest_first_and_stable() {
        let its = items(4);
        let pool = WorkerPool::new(2);
        let cfg = RenderConfig::depth(16);
        let r = BatchRenderer::new(cfg, 4);
        // frame 0: no cost signal yet -> identity order
        assert_eq!(r.dispatch_order(4), vec![0, 1, 2, 3]);
        let mut obs = vec![0.0f32; 4 * cfg.obs_floats()];
        r.render_batch(&pool, &its, &mut obs);
        // frame 1: order sorts by recorded cost, heaviest first
        let order = r.dispatch_order(4);
        let cost =
            |i: usize| r.prev_cost[i].load(Ordering::Relaxed);
        for w in order.windows(2) {
            assert!(
                cost(w[0]) > cost(w[1]) || (cost(w[0]) == cost(w[1]) && w[0] < w[1]),
                "order {order:?} not heaviest-first stable"
            );
        }
    }
}
