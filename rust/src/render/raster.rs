//! Software triangle rasterizer — the CPU stand-in for the paper's Vulkan
//! batch renderer (DESIGN.md §1, §0.7). Z-buffered edge-function
//! rasterization with perspective-correct UV interpolation, near-plane
//! clipping, frustum chunk culling (paper §3.2), point-sampled procedural
//! textures, and both sensor modalities (Depth in meters / shaded RGB).
//!
//! Hot-path structure (DESIGN.md §0.7):
//! - **Amortized transforms**: each chunk's vertex range is transformed to
//!   clip space once per (env, frame) into SoA scratch, instead of ~6× per
//!   shared vertex through a per-triangle `Mat4::mul_vec4`.
//! - **Incremental rasterization**: per-triangle setup reduces the three
//!   edge functions to affine row-start values plus per-pixel increments;
//!   the inner loop is add + compare, no cross products.
//! - **Fused resolve**: depth normalization and the supersampling
//!   box-downsample run as one pass straight from the z-buffer into the
//!   megaframe tile (`resolve_depth_into` / `resolve_rgb_into`).

use std::time::Instant;

use crate::geom::vec::{v2, Vec3};
use crate::geom::{Frustum, Vec2};
use crate::scene::mesh::NO_TEX;
use crate::scene::SceneAsset;

use super::camera::Camera;

/// Which sensor to synthesize (paper §4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sensor {
    Depth,
    Rgb,
}

impl Sensor {
    pub fn channels(&self) -> usize {
        match self {
            Sensor::Depth => 1,
            Sensor::Rgb => 3,
        }
    }
}

/// Depth normalization: sensors report meters clamped to [0, 10] / 10,
/// matching Habitat's depth camera range.
pub const DEPTH_MAX_M: f32 = 10.0;

/// Per-call culling statistics (feeds the Fig. A2 / ablation benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct RasterStats {
    pub chunks_total: usize,
    pub chunks_culled: usize,
    pub tris_rasterized: usize,
}

/// Wall time a raster call spent in sub-stages that only the callee can
/// separate (currently the vertex-transform stage; cull/raster/resolve are
/// timed at the call sites in `BatchRenderer`).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimes {
    pub transform_ns: u64,
}

/// Reusable per-tile scratch: z-buffer plus the SoA clip-space transform
/// cache — allocation-free hot path after warm-up.
pub struct TileScratch {
    zbuf: Vec<f32>,
    clip_x: Vec<f32>,
    clip_y: Vec<f32>,
    clip_w: Vec<f32>,
}

impl TileScratch {
    pub fn new(res: usize) -> TileScratch {
        TileScratch {
            zbuf: vec![f32::INFINITY; res * res],
            clip_x: Vec::new(),
            clip_y: Vec::new(),
            clip_w: Vec::new(),
        }
    }

    /// The raw z-buffer (view-space meters) filled by [`raster_zbuf`].
    pub fn zbuf(&self) -> &[f32] {
        &self.zbuf
    }
}

#[derive(Clone, Copy)]
struct ClipVert {
    /// clip-space position (x, y) with w = view-space distance. The clip z
    /// is never consumed downstream (depth resolves from w), so it is not
    /// transformed or stored.
    x: f32,
    y: f32,
    w: f32,
    u: f32,
    v: f32,
}

const CLIP_ZERO: ClipVert = ClipVert {
    x: 0.0,
    y: 0.0,
    w: 0.0,
    u: 0.0,
    v: 0.0,
};

/// Cull a scene's chunks against a frustum; visible chunk indices into
/// `out`. This is the compute-shader stage of the paper's pipelined culling.
pub fn cull_chunks(scene: &SceneAsset, frustum: &Frustum, out: &mut Vec<u32>) -> RasterStats {
    out.clear();
    let mut stats = RasterStats {
        chunks_total: scene.mesh.chunks.len(),
        ..Default::default()
    };
    for (ci, chunk) in scene.mesh.chunks.iter().enumerate() {
        if frustum.intersects_aabb(&chunk.aabb) {
            out.push(ci as u32);
        } else {
            stats.chunks_culled += 1;
        }
    }
    stats
}

/// Rasterize the visible chunks of `scene` into the scratch z-buffer
/// (`res`×`res`, view-space meters) and, for RGB sensors, `rgb_out`
/// (`res*res*3` floats in [0,1]). Depth is *not* resolved here — callers
/// run [`resolve_depth_into`], which fuses normalization with the
/// box-downsample. Returns triangle statistics; `times.transform_ns`
/// accumulates the vertex-transform stage (two clock reads per visible
/// chunk, ≲1% of a chunk's transform+raster work at bench complexities).
pub fn raster_zbuf(
    scene: &SceneAsset,
    cam: &Camera,
    visible: &[u32],
    res: usize,
    mut rgb_out: Option<&mut [f32]>,
    scratch: &mut TileScratch,
    times: &mut StageTimes,
) -> RasterStats {
    let zbuf = &mut scratch.zbuf[..res * res];
    zbuf.fill(f32::INFINITY);
    if let Some(rgb) = rgb_out.as_deref_mut() {
        rgb.fill(0.0);
    }
    let mut stats = RasterStats::default();

    let m = &cam.view_proj.m;
    let mesh = &scene.mesh;
    let light = Vec3 {
        x: 0.35,
        y: 0.85,
        z: 0.4,
    }
    .normalized();

    let mut poly = [CLIP_ZERO; 4];

    for &ci in visible {
        let chunk = &mesh.chunks[ci as usize];

        // Amortized transform: every vertex in the chunk's index range is
        // pushed through the view-projection once into SoA scratch. Shared
        // vertices (~6 triangle references each on procgen grids) no
        // longer pay a Mat4 multiply per reference.
        let (v0, v_end) = mesh.chunk_vert_range(ci as usize);
        let count = v_end - v0;
        let t_tx = Instant::now();
        if scratch.clip_x.len() < count {
            scratch.clip_x.resize(count, 0.0);
            scratch.clip_y.resize(count, 0.0);
            scratch.clip_w.resize(count, 0.0);
        }
        for (k, p) in mesh.positions[v0..v_end].iter().enumerate() {
            // rows 0, 1, 3 of column-major view_proj * (p, 1); the z row is
            // dead weight here (see ClipVert)
            scratch.clip_x[k] = m[0][0] * p.x + m[1][0] * p.y + m[2][0] * p.z + m[3][0];
            scratch.clip_y[k] = m[0][1] * p.x + m[1][1] * p.y + m[2][1] * p.z + m[3][1];
            scratch.clip_w[k] = m[0][3] * p.x + m[1][3] * p.y + m[2][3] * p.z + m[3][3];
        }
        times.transform_ns += t_tx.elapsed().as_nanos() as u64;

        let t0 = chunk.tri_start as usize;
        let t1 = t0 + chunk.tri_count as usize;
        for t in t0..t1 {
            let ia = mesh.indices[t * 3] as usize;
            let ib = mesh.indices[t * 3 + 1] as usize;
            let ic = mesh.indices[t * 3 + 2] as usize;

            let mk = |vi: usize| {
                let k = vi - v0;
                let uv = mesh.uvs[vi];
                ClipVert {
                    x: scratch.clip_x[k],
                    y: scratch.clip_y[k],
                    w: scratch.clip_w[k],
                    u: uv.x,
                    v: uv.y,
                }
            };
            let tri = [mk(ia), mk(ib), mk(ic)];

            // near-plane clip (w >= NEAR): Sutherland-Hodgman, <= 4 verts out
            let n = clip_near(&tri, &mut poly);
            if n < 3 {
                continue;
            }

            // shading inputs shared by the fan
            let shade = if rgb_out.is_some() {
                let mat = &scene.materials[mesh.tri_material[t] as usize];
                let (pa, pb, pc) = (mesh.positions[ia], mesh.positions[ib], mesh.positions[ic]);
                let normal = (pb - pa).cross(pc - pa).normalized();
                let ndl = normal.dot(light).abs(); // double-sided
                let lit = 0.45 + 0.55 * ndl;
                Some((mat, lit))
            } else {
                None
            };

            for k in 1..n - 1 {
                stats.tris_rasterized += 1;
                fill_triangle(
                    &poly[0],
                    &poly[k],
                    &poly[k + 1],
                    res,
                    zbuf,
                    rgb_out.as_deref_mut(),
                    scene,
                    shade,
                );
            }
        }
    }
    stats
}

/// Fused resolve for the Depth sensor: normalize the z-buffer (view-space
/// meters → [0, 1], untouched pixels read as max range) and box-downsample
/// `scale`× into `out` (side `rr / scale`) in one pass.
pub fn resolve_depth_into(zbuf: &[f32], rr: usize, scale: usize, out: &mut [f32]) {
    let s = scale.max(1);
    let res = rr / s;
    debug_assert!(out.len() >= res * res);
    let inv = 1.0 / (s * s) as f32;
    for y in 0..res {
        for x in 0..res {
            let mut acc = 0.0;
            for dy in 0..s {
                let row = (y * s + dy) * rr + x * s;
                for dx in 0..s {
                    let z = zbuf[row + dx];
                    acc += if z.is_finite() {
                        (z / DEPTH_MAX_M).clamp(0.0, 1.0)
                    } else {
                        1.0
                    };
                }
            }
            out[y * res + x] = acc * inv;
        }
    }
}

/// Fused resolve for the RGB sensor: box-downsample the full-resolution
/// shaded buffer `scale`× into `out` (side `rr / scale`, 3 channels).
pub fn resolve_rgb_into(rgb: &[f32], rr: usize, scale: usize, out: &mut [f32]) {
    let s = scale.max(1);
    let res = rr / s;
    debug_assert!(out.len() >= res * res * 3);
    let inv = 1.0 / (s * s) as f32;
    for y in 0..res {
        for x in 0..res {
            let mut acc = [0.0f32; 3];
            for dy in 0..s {
                let row = ((y * s + dy) * rr + x * s) * 3;
                for dx in 0..s {
                    let p = row + dx * 3;
                    acc[0] += rgb[p];
                    acc[1] += rgb[p + 1];
                    acc[2] += rgb[p + 2];
                }
            }
            let o = (y * res + x) * 3;
            out[o] = acc[0] * inv;
            out[o + 1] = acc[1] * inv;
            out[o + 2] = acc[2] * inv;
        }
    }
}

/// Rasterize the visible chunks of `scene` into one `res`×`res` tile with
/// the depth resolved in place (no downsampling) — the convenience single-
/// tile entry point; the batch path uses [`raster_zbuf`] + the fused
/// resolves directly.
///
/// `depth_out`: `res*res` floats (normalized [0,1] meters/10).
/// `rgb_out`: `Some(res*res*3)` floats in [0,1] for RGB sensors.
/// Returns triangle statistics.
pub fn raster_tile(
    scene: &SceneAsset,
    cam: &Camera,
    visible: &[u32],
    res: usize,
    depth_out: &mut [f32],
    rgb_out: Option<&mut [f32]>,
    scratch: &mut TileScratch,
) -> RasterStats {
    debug_assert_eq!(depth_out.len(), res * res);
    let mut times = StageTimes::default();
    let stats = raster_zbuf(scene, cam, visible, res, rgb_out, scratch, &mut times);
    resolve_depth_into(&scratch.zbuf[..res * res], res, 1, depth_out);
    stats
}

/// Clip a triangle against the near plane (keep w >= NEAR). Returns the
/// number of output vertices written to `out` (0, 3 or 4).
fn clip_near(tri: &[ClipVert; 3], out: &mut [ClipVert; 4]) -> usize {
    const NEAR: f32 = super::camera::NEAR;
    let inside = |v: &ClipVert| v.w >= NEAR;
    let mut n = 0usize;
    for i in 0..3 {
        let a = &tri[i];
        let b = &tri[(i + 1) % 3];
        let (ia, ib) = (inside(a), inside(b));
        if ia {
            out[n] = *a;
            n += 1;
        }
        if ia != ib {
            let t = (NEAR - a.w) / (b.w - a.w);
            out[n] = ClipVert {
                x: a.x + (b.x - a.x) * t,
                y: a.y + (b.y - a.y) * t,
                w: NEAR,
                u: a.u + (b.u - a.u) * t,
                v: a.v + (b.v - a.v) * t,
            };
            n += 1;
        }
        if n == 4 {
            break;
        }
    }
    n
}

/// Affine edge-function coefficients for directed edge (p, q):
/// `E(v) = C + v.x * A + v.y * B` equals the 2D cross `(p - v) × (q - v)`.
#[inline]
fn edge_coeffs(p: Vec2, q: Vec2) -> (f32, f32, f32) {
    (p.y - q.y, q.x - p.x, p.x * q.y - p.y * q.x)
}

#[allow(clippy::too_many_arguments)]
fn fill_triangle(
    a: &ClipVert,
    b: &ClipVert,
    c: &ClipVert,
    res: usize,
    zbuf: &mut [f32],
    mut rgb_out: Option<&mut [f32]>,
    scene: &SceneAsset,
    shade: Option<(&crate::scene::Material, f32)>,
) {
    let resf = res as f32;
    // NDC -> screen (y flipped: NDC +y is up, row 0 is top)
    let to_screen = |v: &ClipVert| {
        let inv_w = 1.0 / v.w;
        v2(
            (v.x * inv_w * 0.5 + 0.5) * resf,
            (0.5 - v.y * inv_w * 0.5) * resf,
        )
    };
    let (sa, sb, sc) = (to_screen(a), to_screen(b), to_screen(c));
    let area = (sb - sa).cross(sc - sa);
    if area.abs() < 1e-12 {
        return;
    }
    let inv_area = 1.0 / area;

    let min_x = sa.x.min(sb.x).min(sc.x).floor().max(0.0) as usize;
    let max_x = (sa.x.max(sb.x).max(sc.x).ceil() as usize).min(res);
    let min_y = sa.y.min(sb.y).min(sc.y).floor().max(0.0) as usize;
    let max_y = (sa.y.max(sb.y).max(sc.y).ceil() as usize).min(res);
    if min_x >= max_x || min_y >= max_y {
        return;
    }

    // Incremental setup: the barycentric weights are affine in screen
    // space, so each row starts from a closed-form edge value (no y-drift)
    // and each pixel advances by a constant — the three per-pixel `cross()`
    // calls this replaced are now one add + compare per edge.
    let (a0, b0, c0) = edge_coeffs(sb, sc); // -> w0
    let (a1, b1, c1) = edge_coeffs(sc, sa); // -> w1

    // perspective-correct attributes: interpolate (1/w, u/w, v/w)
    let (iwa, iwb, iwc) = (1.0 / a.w, 1.0 / b.w, 1.0 / c.w);
    let (uwa, uwb, uwc) = (a.u * iwa, b.u * iwb, c.u * iwc);
    let (vwa, vwb, vwc) = (a.v * iwa, b.v * iwb, c.v * iwc);

    let x0 = min_x as f32 + 0.5;
    for py in min_y..max_y {
        let row = py * res;
        let pyf = py as f32 + 0.5;
        let mut e0 = c0 + a0 * x0 + b0 * pyf;
        let mut e1 = c1 + a1 * x0 + b1 * pyf;
        for px in min_x..max_x {
            let w0 = e0 * inv_area;
            let w1 = e1 * inv_area;
            let w2 = 1.0 - w0 - w1;
            e0 += a0;
            e1 += a1;
            if w0 < 0.0 || w1 < 0.0 || w2 < 0.0 {
                continue;
            }
            let inv_w = w0 * iwa + w1 * iwb + w2 * iwc;
            let depth_m = 1.0 / inv_w; // view-space distance in meters
            let zi = row + px;
            if depth_m >= zbuf[zi] {
                continue;
            }
            zbuf[zi] = depth_m;
            if let Some(rgb) = rgb_out.as_deref_mut() {
                let (mat, lit) = shade.expect("rgb requires shading inputs");
                let mut col = mat.albedo;
                if mat.tex != NO_TEX {
                    if let Some(tex) = scene.textures.get(mat.tex as usize) {
                        let u = (w0 * uwa + w1 * uwb + w2 * uwc) / inv_w;
                        let v = (w0 * vwa + w1 * vwb + w2 * vwc) / inv_w;
                        let s = tex.sample(u, v);
                        col = [col[0] * s[0], col[1] * s[1], col[2] * s[2]];
                    }
                }
                let o = zi * 3;
                rgb[o] = (col[0] * lit).clamp(0.0, 1.0);
                rgb[o + 1] = (col[1] * lit).clamp(0.0, 1.0);
                rgb[o + 2] = (col[2] * lit).clamp(0.0, 1.0);
            }
        }
    }
}

/// Render one environment observation (cull + raster in one call).
pub fn render_env(
    scene: &SceneAsset,
    cam: &Camera,
    res: usize,
    depth_out: &mut [f32],
    rgb_out: Option<&mut [f32]>,
    scratch: &mut TileScratch,
    visible_scratch: &mut Vec<u32>,
) -> RasterStats {
    let cull_stats = cull_chunks(scene, &cam.frustum, visible_scratch);
    let mut stats = raster_tile(scene, cam, visible_scratch, res, depth_out, rgb_out, scratch);
    stats.chunks_total = cull_stats.chunks_total;
    stats.chunks_culled = cull_stats.chunks_culled;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::vec::v2 as gv2;
    use crate::scene::procgen::{generate, Complexity};
    use crate::util::rng::Rng;

    fn scene() -> SceneAsset {
        generate("r", 41, Complexity::test())
    }

    fn render(scene: &SceneAsset, pos: Vec2, heading: f32, res: usize, rgb: bool)
        -> (Vec<f32>, Option<Vec<f32>>, RasterStats) {
        let cam = Camera::from_agent(pos, heading, 1.0);
        let mut depth = vec![0.0f32; res * res];
        let mut color = if rgb { Some(vec![0.0f32; res * res * 3]) } else { None };
        let mut scratch = TileScratch::new(res);
        let mut vis = Vec::new();
        let stats = render_env(
            scene,
            &cam,
            res,
            &mut depth,
            color.as_deref_mut(),
            &mut scratch,
            &mut vis,
        );
        (depth, color, stats)
    }

    #[test]
    fn depth_in_unit_range_and_varied() {
        let s = scene();
        let mut rng = Rng::new(2);
        let pos = s.navmesh.random_point(&mut rng).unwrap();
        let (depth, _, stats) = render(&s, pos, 0.7, 64, false);
        assert!(depth.iter().all(|&d| (0.0..=1.0).contains(&d)));
        // indoors: walls everywhere, so some pixels must be closer than max
        let min = depth.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(min < 0.9, "min depth {min}");
        assert!(stats.tris_rasterized > 0);
    }

    #[test]
    fn floor_visible_in_lower_half() {
        let s = scene();
        let mut rng = Rng::new(3);
        let pos = s.navmesh.random_point(&mut rng).unwrap();
        let res = 64;
        let (depth, _, _) = render(&s, pos, 1.1, res, false);
        // bottom rows look at the floor right at the agent's feet: near
        let bottom = &depth[(res - 2) * res..];
        assert!(bottom.iter().any(|&d| d < 0.4), "bottom depths {bottom:?}");
    }

    #[test]
    fn nearby_wall_reads_close_depth() {
        let s = scene();
        // walk to the west perimeter wall and look at it (heading pi = -x)
        let p = gv2(0.5, s.navmesh.origin.y + 3.0);
        let p = if s.navmesh.is_walkable(p) {
            p
        } else {
            let mut rng = Rng::new(4);
            s.navmesh.random_point(&mut rng).unwrap()
        };
        let (depth, _, _) = render(&s, p, std::f32::consts::PI, 32, false);
        let center = depth[16 * 32 + 16];
        assert!(center < 1.0);
    }

    #[test]
    fn rgb_renders_colors() {
        let s = scene();
        let mut rng = Rng::new(5);
        let pos = s.navmesh.random_point(&mut rng).unwrap();
        let (_, rgb, _) = render(&s, pos, 0.0, 32, true);
        let rgb = rgb.unwrap();
        assert!(rgb.iter().all(|&c| (0.0..=1.0).contains(&c)));
        // scene is lit + textured: some channel variance expected
        let mean: f32 = rgb.iter().sum::<f32>() / rgb.len() as f32;
        assert!(mean > 0.01, "mean {mean}");
        let var: f32 =
            rgb.iter().map(|&c| (c - mean) * (c - mean)).sum::<f32>() / rgb.len() as f32;
        assert!(var > 1e-5, "flat image, var {var}");
    }

    #[test]
    fn culling_reduces_work_but_not_output() {
        let s = scene();
        let mut rng = Rng::new(6);
        let pos = s.navmesh.random_point(&mut rng).unwrap();
        let cam = Camera::from_agent(pos, 0.3, 1.0);
        let res = 48;
        // culled render
        let mut vis = Vec::new();
        let stats = cull_chunks(&s, &cam.frustum, &mut vis);
        let mut scratch = TileScratch::new(res);
        let mut d_culled = vec![0.0f32; res * res];
        raster_tile(&s, &cam, &vis, res, &mut d_culled, None, &mut scratch);
        // unculled render (all chunks)
        let all: Vec<u32> = (0..s.mesh.chunks.len() as u32).collect();
        let mut d_all = vec![0.0f32; res * res];
        raster_tile(&s, &cam, &all, res, &mut d_all, None, &mut scratch);
        assert_eq!(d_culled, d_all, "culling changed the image");
        assert!(
            stats.chunks_culled > 0,
            "expected some culling ({} chunks)",
            stats.chunks_total
        );
    }

    #[test]
    fn depth_monotonic_with_distance() {
        // two boxes straight ahead at different distances: nearer box wins
        let mut s = scene();
        s.mesh = crate::scene::Mesh::default();
        s.mesh.add_box(
            crate::geom::vec::v3(3.0, 0.0, 2.6),
            crate::geom::vec::v3(3.5, 2.5, 3.4),
            0,
            1,
        );
        s.mesh.add_box(
            crate::geom::vec::v3(5.0, 0.0, 2.0),
            crate::geom::vec::v3(5.5, 2.5, 4.0),
            0,
            1,
        );
        let (depth, _, _) = render(&s, gv2(1.0, 3.0), 0.0, 32, false);
        let center = depth[16 * 32 + 16] * DEPTH_MAX_M;
        // the near box face is at x=3.0, agent at x=1.0 -> 2.0m
        assert!((center - 2.0).abs() < 0.3, "center depth {center}m");
    }

    #[test]
    fn raster_zbuf_fills_view_space_meters() {
        let s = scene();
        let mut rng = Rng::new(8);
        let pos = s.navmesh.random_point(&mut rng).unwrap();
        let cam = Camera::from_agent(pos, 0.2, 1.0);
        let mut vis = Vec::new();
        cull_chunks(&s, &cam.frustum, &mut vis);
        let res = 32;
        let mut scratch = TileScratch::new(res);
        let mut times = StageTimes::default();
        let stats = raster_zbuf(&s, &cam, &vis, res, None, &mut scratch, &mut times);
        assert!(stats.tris_rasterized > 0);
        // z-buffer holds meters: finite hits must be below the far plane
        assert!(scratch
            .zbuf()
            .iter()
            .filter(|z| z.is_finite())
            .all(|&z| z > 0.0 && z < super::super::camera::FAR));
    }

    #[test]
    fn fused_resolve_matches_two_pass_downsample() {
        // resolve_depth_into at scale=2 must equal normalize-then-average
        let rr = 8;
        let mut zbuf = vec![f32::INFINITY; rr * rr];
        for (i, z) in zbuf.iter_mut().enumerate() {
            if i % 3 != 0 {
                *z = (i % 13) as f32;
            }
        }
        let norm: Vec<f32> = zbuf
            .iter()
            .map(|&z| if z.is_finite() { (z / DEPTH_MAX_M).clamp(0.0, 1.0) } else { 1.0 })
            .collect();
        let res = rr / 2;
        let mut two_pass = vec![0.0f32; res * res];
        for y in 0..res {
            for x in 0..res {
                let mut acc = 0.0;
                for dy in 0..2 {
                    for dx in 0..2 {
                        acc += norm[(y * 2 + dy) * rr + (x * 2 + dx)];
                    }
                }
                two_pass[y * res + x] = acc * 0.25;
            }
        }
        let mut fused = vec![0.0f32; res * res];
        resolve_depth_into(&zbuf, rr, 2, &mut fused);
        assert_eq!(fused, two_pass);
    }
}
