//! Software triangle rasterizer — the CPU stand-in for the paper's Vulkan
//! batch renderer (DESIGN.md §1). Z-buffered edge-function rasterization
//! with perspective-correct UV interpolation, near-plane clipping, frustum
//! chunk culling (paper §3.2), point-sampled procedural textures, and both
//! sensor modalities (Depth in meters / shaded RGB).

use crate::geom::vec::{v2, Vec3};
use crate::geom::{Frustum, Vec2};
use crate::scene::mesh::NO_TEX;
use crate::scene::SceneAsset;

use super::camera::Camera;

/// Which sensor to synthesize (paper §4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sensor {
    Depth,
    Rgb,
}

impl Sensor {
    pub fn channels(&self) -> usize {
        match self {
            Sensor::Depth => 1,
            Sensor::Rgb => 3,
        }
    }
}

/// Depth normalization: sensors report meters clamped to [0, 10] / 10,
/// matching Habitat's depth camera range.
pub const DEPTH_MAX_M: f32 = 10.0;

/// Per-call culling statistics (feeds the Fig. A2 / ablation benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct RasterStats {
    pub chunks_total: usize,
    pub chunks_culled: usize,
    pub tris_rasterized: usize,
}

/// Reusable per-tile scratch (z-buffer) — allocation-free hot path.
pub struct TileScratch {
    zbuf: Vec<f32>,
}

impl TileScratch {
    pub fn new(res: usize) -> TileScratch {
        TileScratch {
            zbuf: vec![f32::INFINITY; res * res],
        }
    }
}

#[derive(Clone, Copy)]
struct ClipVert {
    /// clip-space position (x, y, z, w) with w = view-space distance
    x: f32,
    y: f32,
    z: f32,
    w: f32,
    u: f32,
    v: f32,
}

/// Cull a scene's chunks against a frustum; visible chunk indices into
/// `out`. This is the compute-shader stage of the paper's pipelined culling.
pub fn cull_chunks(scene: &SceneAsset, frustum: &Frustum, out: &mut Vec<u32>) -> RasterStats {
    out.clear();
    let mut stats = RasterStats {
        chunks_total: scene.mesh.chunks.len(),
        ..Default::default()
    };
    for (ci, chunk) in scene.mesh.chunks.iter().enumerate() {
        if frustum.intersects_aabb(&chunk.aabb) {
            out.push(ci as u32);
        } else {
            stats.chunks_culled += 1;
        }
    }
    stats
}

/// Rasterize the visible chunks of `scene` into one `res`×`res` tile.
///
/// `depth_out`: `res*res` floats (normalized [0,1] meters/10).
/// `rgb_out`: `Some(res*res*3)` floats in [0,1] for RGB sensors.
/// Returns triangle statistics.
#[allow(clippy::too_many_arguments)]
pub fn raster_tile(
    scene: &SceneAsset,
    cam: &Camera,
    visible: &[u32],
    res: usize,
    depth_out: &mut [f32],
    mut rgb_out: Option<&mut [f32]>,
    scratch: &mut TileScratch,
) -> RasterStats {
    debug_assert_eq!(depth_out.len(), res * res);
    let zbuf = &mut scratch.zbuf[..res * res];
    zbuf.fill(f32::INFINITY);
    if let Some(rgb) = rgb_out.as_deref_mut() {
        rgb.fill(0.0);
    }
    let mut stats = RasterStats::default();

    let vp = &cam.view_proj;
    let mesh = &scene.mesh;
    let light = Vec3 {
        x: 0.35,
        y: 0.85,
        z: 0.4,
    }
    .normalized();

    let mut poly = [ClipVert {
        x: 0.0,
        y: 0.0,
        z: 0.0,
        w: 0.0,
        u: 0.0,
        v: 0.0,
    }; 4];

    for &ci in visible {
        let chunk = &mesh.chunks[ci as usize];
        let t0 = chunk.tri_start as usize;
        let t1 = t0 + chunk.tri_count as usize;
        for t in t0..t1 {
            let ia = mesh.indices[t * 3] as usize;
            let ib = mesh.indices[t * 3 + 1] as usize;
            let ic = mesh.indices[t * 3 + 2] as usize;
            let (pa, pb, pc) = (mesh.positions[ia], mesh.positions[ib], mesh.positions[ic]);
            let (ua, ub, uc) = (mesh.uvs[ia], mesh.uvs[ib], mesh.uvs[ic]);

            let mk = |p: Vec3, uv: Vec2| {
                let c = vp.mul_vec4(p.extend(1.0));
                ClipVert {
                    x: c.x,
                    y: c.y,
                    z: c.z,
                    w: c.w,
                    u: uv.x,
                    v: uv.y,
                }
            };
            let tri = [mk(pa, ua), mk(pb, ub), mk(pc, uc)];

            // near-plane clip (w >= NEAR): Sutherland-Hodgman, <= 4 verts out
            let n = clip_near(&tri, &mut poly);
            if n < 3 {
                continue;
            }

            // shading inputs shared by the fan
            let shade = if rgb_out.is_some() {
                let mat = &scene.materials[mesh.tri_material[t] as usize];
                let normal = (pb - pa).cross(pc - pa).normalized();
                let ndl = normal.dot(light).abs(); // double-sided
                let lit = 0.45 + 0.55 * ndl;
                Some((mat, lit))
            } else {
                None
            };

            for k in 1..n - 1 {
                stats.tris_rasterized += 1;
                fill_triangle(
                    &poly[0],
                    &poly[k],
                    &poly[k + 1],
                    res,
                    zbuf,
                    depth_out,
                    rgb_out.as_deref_mut(),
                    scene,
                    shade,
                );
            }
        }
    }

    // resolve: meters -> normalized depth; untouched pixels read as max range
    for i in 0..res * res {
        depth_out[i] = if zbuf[i].is_finite() {
            (zbuf[i] / DEPTH_MAX_M).clamp(0.0, 1.0)
        } else {
            1.0
        };
    }
    stats
}

/// Clip a triangle against the near plane (keep w >= NEAR). Returns the
/// number of output vertices written to `out` (0, 3 or 4).
fn clip_near(tri: &[ClipVert; 3], out: &mut [ClipVert; 4]) -> usize {
    const NEAR: f32 = super::camera::NEAR;
    let inside = |v: &ClipVert| v.w >= NEAR;
    let mut n = 0usize;
    for i in 0..3 {
        let a = &tri[i];
        let b = &tri[(i + 1) % 3];
        let (ia, ib) = (inside(a), inside(b));
        if ia {
            out[n] = *a;
            n += 1;
        }
        if ia != ib {
            let t = (NEAR - a.w) / (b.w - a.w);
            out[n] = ClipVert {
                x: a.x + (b.x - a.x) * t,
                y: a.y + (b.y - a.y) * t,
                z: a.z + (b.z - a.z) * t,
                w: NEAR,
                u: a.u + (b.u - a.u) * t,
                v: a.v + (b.v - a.v) * t,
            };
            n += 1;
        }
        if n == 4 {
            break;
        }
    }
    n
}

#[allow(clippy::too_many_arguments)]
fn fill_triangle(
    a: &ClipVert,
    b: &ClipVert,
    c: &ClipVert,
    res: usize,
    zbuf: &mut [f32],
    _depth_out: &mut [f32],
    mut rgb_out: Option<&mut [f32]>,
    scene: &SceneAsset,
    shade: Option<(&crate::scene::Material, f32)>,
) {
    let resf = res as f32;
    // NDC -> screen (y flipped: NDC +y is up, row 0 is top)
    let to_screen = |v: &ClipVert| {
        let inv_w = 1.0 / v.w;
        v2(
            (v.x * inv_w * 0.5 + 0.5) * resf,
            (0.5 - v.y * inv_w * 0.5) * resf,
        )
    };
    let (sa, sb, sc) = (to_screen(a), to_screen(b), to_screen(c));
    let area = (sb - sa).cross(sc - sa);
    if area.abs() < 1e-12 {
        return;
    }
    let inv_area = 1.0 / area;

    let min_x = sa.x.min(sb.x).min(sc.x).floor().max(0.0) as usize;
    let max_x = (sa.x.max(sb.x).max(sc.x).ceil() as usize).min(res);
    let min_y = sa.y.min(sb.y).min(sc.y).floor().max(0.0) as usize;
    let max_y = (sa.y.max(sb.y).max(sc.y).ceil() as usize).min(res);
    if min_x >= max_x || min_y >= max_y {
        return;
    }

    // perspective-correct attributes: interpolate (1/w, u/w, v/w)
    let (iwa, iwb, iwc) = (1.0 / a.w, 1.0 / b.w, 1.0 / c.w);
    let (uwa, uwb, uwc) = (a.u * iwa, b.u * iwb, c.u * iwc);
    let (vwa, vwb, vwc) = (a.v * iwa, b.v * iwb, c.v * iwc);

    for py in min_y..max_y {
        let row = py * res;
        let pyf = py as f32 + 0.5;
        for px in min_x..max_x {
            let p = v2(px as f32 + 0.5, pyf);
            let w0 = (sb - p).cross(sc - p) * inv_area;
            let w1 = (sc - p).cross(sa - p) * inv_area;
            let w2 = 1.0 - w0 - w1;
            if w0 < 0.0 || w1 < 0.0 || w2 < 0.0 {
                continue;
            }
            let inv_w = w0 * iwa + w1 * iwb + w2 * iwc;
            let depth_m = 1.0 / inv_w; // view-space distance in meters
            let zi = row + px;
            if depth_m >= zbuf[zi] {
                continue;
            }
            zbuf[zi] = depth_m;
            if let Some(rgb) = rgb_out.as_deref_mut() {
                let (mat, lit) = shade.expect("rgb requires shading inputs");
                let mut col = mat.albedo;
                if mat.tex != NO_TEX {
                    if let Some(tex) = scene.textures.get(mat.tex as usize) {
                        let u = (w0 * uwa + w1 * uwb + w2 * uwc) / inv_w;
                        let v = (w0 * vwa + w1 * vwb + w2 * vwc) / inv_w;
                        let s = tex.sample(u, v);
                        col = [col[0] * s[0], col[1] * s[1], col[2] * s[2]];
                    }
                }
                let o = zi * 3;
                rgb[o] = (col[0] * lit).clamp(0.0, 1.0);
                rgb[o + 1] = (col[1] * lit).clamp(0.0, 1.0);
                rgb[o + 2] = (col[2] * lit).clamp(0.0, 1.0);
            }
        }
    }
}

/// Render one environment observation (cull + raster in one call).
pub fn render_env(
    scene: &SceneAsset,
    cam: &Camera,
    res: usize,
    depth_out: &mut [f32],
    rgb_out: Option<&mut [f32]>,
    scratch: &mut TileScratch,
    visible_scratch: &mut Vec<u32>,
) -> RasterStats {
    let cull_stats = cull_chunks(scene, &cam.frustum, visible_scratch);
    let mut stats = raster_tile(scene, cam, visible_scratch, res, depth_out, rgb_out, scratch);
    stats.chunks_total = cull_stats.chunks_total;
    stats.chunks_culled = cull_stats.chunks_culled;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::vec::v2 as gv2;
    use crate::scene::procgen::{generate, Complexity};
    use crate::util::rng::Rng;

    fn scene() -> SceneAsset {
        generate("r", 41, Complexity::test())
    }

    fn render(scene: &SceneAsset, pos: Vec2, heading: f32, res: usize, rgb: bool)
        -> (Vec<f32>, Option<Vec<f32>>, RasterStats) {
        let cam = Camera::from_agent(pos, heading, 1.0);
        let mut depth = vec![0.0f32; res * res];
        let mut color = if rgb { Some(vec![0.0f32; res * res * 3]) } else { None };
        let mut scratch = TileScratch::new(res);
        let mut vis = Vec::new();
        let stats = render_env(
            scene,
            &cam,
            res,
            &mut depth,
            color.as_deref_mut(),
            &mut scratch,
            &mut vis,
        );
        (depth, color, stats)
    }

    #[test]
    fn depth_in_unit_range_and_varied() {
        let s = scene();
        let mut rng = Rng::new(2);
        let pos = s.navmesh.random_point(&mut rng).unwrap();
        let (depth, _, stats) = render(&s, pos, 0.7, 64, false);
        assert!(depth.iter().all(|&d| (0.0..=1.0).contains(&d)));
        // indoors: walls everywhere, so some pixels must be closer than max
        let min = depth.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(min < 0.9, "min depth {min}");
        assert!(stats.tris_rasterized > 0);
    }

    #[test]
    fn floor_visible_in_lower_half() {
        let s = scene();
        let mut rng = Rng::new(3);
        let pos = s.navmesh.random_point(&mut rng).unwrap();
        let res = 64;
        let (depth, _, _) = render(&s, pos, 1.1, res, false);
        // bottom rows look at the floor right at the agent's feet: near
        let bottom = &depth[(res - 2) * res..];
        assert!(bottom.iter().any(|&d| d < 0.4), "bottom depths {bottom:?}");
    }

    #[test]
    fn nearby_wall_reads_close_depth() {
        let s = scene();
        // walk to the west perimeter wall and look at it (heading pi = -x)
        let p = gv2(0.5, s.navmesh.origin.y + 3.0);
        let p = if s.navmesh.is_walkable(p) {
            p
        } else {
            let mut rng = Rng::new(4);
            s.navmesh.random_point(&mut rng).unwrap()
        };
        let (depth, _, _) = render(&s, p, std::f32::consts::PI, 32, false);
        let center = depth[16 * 32 + 16];
        assert!(center < 1.0);
    }

    #[test]
    fn rgb_renders_colors() {
        let s = scene();
        let mut rng = Rng::new(5);
        let pos = s.navmesh.random_point(&mut rng).unwrap();
        let (_, rgb, _) = render(&s, pos, 0.0, 32, true);
        let rgb = rgb.unwrap();
        assert!(rgb.iter().all(|&c| (0.0..=1.0).contains(&c)));
        // scene is lit + textured: some channel variance expected
        let mean: f32 = rgb.iter().sum::<f32>() / rgb.len() as f32;
        assert!(mean > 0.01, "mean {mean}");
        let var: f32 =
            rgb.iter().map(|&c| (c - mean) * (c - mean)).sum::<f32>() / rgb.len() as f32;
        assert!(var > 1e-5, "flat image, var {var}");
    }

    #[test]
    fn culling_reduces_work_but_not_output() {
        let s = scene();
        let mut rng = Rng::new(6);
        let pos = s.navmesh.random_point(&mut rng).unwrap();
        let cam = Camera::from_agent(pos, 0.3, 1.0);
        let res = 48;
        // culled render
        let mut vis = Vec::new();
        let stats = cull_chunks(&s, &cam.frustum, &mut vis);
        let mut scratch = TileScratch::new(res);
        let mut d_culled = vec![0.0f32; res * res];
        raster_tile(&s, &cam, &vis, res, &mut d_culled, None, &mut scratch);
        // unculled render (all chunks)
        let all: Vec<u32> = (0..s.mesh.chunks.len() as u32).collect();
        let mut d_all = vec![0.0f32; res * res];
        raster_tile(&s, &cam, &all, res, &mut d_all, None, &mut scratch);
        assert_eq!(d_culled, d_all, "culling changed the image");
        assert!(
            stats.chunks_culled > 0,
            "expected some culling ({} chunks)",
            stats.chunks_total
        );
    }

    #[test]
    fn depth_monotonic_with_distance() {
        // two boxes straight ahead at different distances: nearer box wins
        let mut s = scene();
        s.mesh = crate::scene::Mesh::default();
        s.mesh.add_box(
            crate::geom::vec::v3(3.0, 0.0, 2.6),
            crate::geom::vec::v3(3.5, 2.5, 3.4),
            0,
            1,
        );
        s.mesh.add_box(
            crate::geom::vec::v3(5.0, 0.0, 2.0),
            crate::geom::vec::v3(5.5, 2.5, 4.0),
            0,
            1,
        );
        let (depth, _, _) = render(&s, gv2(1.0, 3.0), 0.0, 32, false);
        let center = depth[16 * 32 + 16] * DEPTH_MAX_M;
        // the near box face is at x=3.0, agent at x=1.0 -> 2.0m
        assert!((center - 2.0).abs() < 0.3, "center depth {center}m");
    }
}
