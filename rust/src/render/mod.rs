//! The batch renderer (paper §3.2): software rasterizer, frustum culling,
//! the megaframe batch pass, scene-asset sharing (K ≪ N), and the
//! background asset streamer that rotates scenes during training.

pub mod batch;
pub mod camera;
pub mod raster;
pub mod stream;

pub use batch::{BatchRenderer, PipelineMode, RenderConfig, RenderItem, RenderStats};
pub use camera::Camera;
pub use raster::{RasterStats, Sensor, DEPTH_MAX_M};
pub use stream::{AssetStreamer, SceneRotation, MAX_N_TO_K};
