//! # BPS — Batch Processing Simulator
//!
//! Production-oriented reproduction of **"Large Batch Simulation for Deep
//! Reinforcement Learning"** (ICLR 2021) as a three-layer Rust + JAX +
//! Pallas stack: a Rust batch simulator + batch renderer + RL coordinator
//! (this crate) executing AOT-compiled policy/optimizer artifacts via PJRT.
//! See DESIGN.md for the architecture and the experiment index.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod env;
pub mod geom;
pub mod lint;
pub mod metrics;
pub mod optim;
pub mod policy;
pub mod rollout;
pub mod navmesh;
pub mod obs;
pub mod render;
pub mod runtime;
pub mod scenario;
pub mod scene;
pub mod serve;
pub mod sim;
pub mod util;
