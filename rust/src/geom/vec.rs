//! Small fixed-size vector types (f32).

use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec2 {
    pub x: f32,
    pub y: f32,
}

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec3 {
    pub x: f32,
    pub y: f32,
    pub z: f32,
}

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec4 {
    pub x: f32,
    pub y: f32,
    pub z: f32,
    pub w: f32,
}

pub const fn v2(x: f32, y: f32) -> Vec2 {
    Vec2 { x, y }
}

pub const fn v3(x: f32, y: f32, z: f32) -> Vec3 {
    Vec3 { x, y, z }
}

pub const fn v4(x: f32, y: f32, z: f32, w: f32) -> Vec4 {
    Vec4 { x, y, z, w }
}

impl Vec2 {
    pub fn dot(self, o: Vec2) -> f32 {
        self.x * o.x + self.y * o.y
    }

    pub fn length(self) -> f32 {
        self.dot(self).sqrt()
    }

    /// z-component of the 2D cross product (signed area ×2).
    pub fn cross(self, o: Vec2) -> f32 {
        self.x * o.y - self.y * o.x
    }
}

impl Vec3 {
    pub const ZERO: Vec3 = v3(0.0, 0.0, 0.0);
    pub const UP: Vec3 = v3(0.0, 1.0, 0.0);

    pub fn dot(self, o: Vec3) -> f32 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    pub fn cross(self, o: Vec3) -> Vec3 {
        v3(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }

    pub fn length(self) -> f32 {
        self.dot(self).sqrt()
    }

    pub fn length_sq(self) -> f32 {
        self.dot(self)
    }

    pub fn normalized(self) -> Vec3 {
        let l = self.length();
        if l > 1e-20 {
            self / l
        } else {
            Vec3::ZERO
        }
    }

    pub fn min(self, o: Vec3) -> Vec3 {
        v3(self.x.min(o.x), self.y.min(o.y), self.z.min(o.z))
    }

    pub fn max(self, o: Vec3) -> Vec3 {
        v3(self.x.max(o.x), self.y.max(o.y), self.z.max(o.z))
    }

    pub fn lerp(self, o: Vec3, t: f32) -> Vec3 {
        self + (o - self) * t
    }

    /// Horizontal (xz-plane) 2D projection — navigation happens on a floor.
    pub fn xz(self) -> Vec2 {
        v2(self.x, self.z)
    }

    pub fn extend(self, w: f32) -> Vec4 {
        v4(self.x, self.y, self.z, w)
    }
}

impl Vec4 {
    pub fn dot(self, o: Vec4) -> f32 {
        self.x * o.x + self.y * o.y + self.z * o.z + self.w * o.w
    }

    pub fn xyz(self) -> Vec3 {
        v3(self.x, self.y, self.z)
    }
}

macro_rules! impl_ops {
    ($t:ident, $($f:ident),+) => {
        impl Add for $t {
            type Output = $t;
            fn add(self, o: $t) -> $t { $t { $($f: self.$f + o.$f),+ } }
        }
        impl Sub for $t {
            type Output = $t;
            fn sub(self, o: $t) -> $t { $t { $($f: self.$f - o.$f),+ } }
        }
        impl Mul<f32> for $t {
            type Output = $t;
            fn mul(self, s: f32) -> $t { $t { $($f: self.$f * s),+ } }
        }
        impl Div<f32> for $t {
            type Output = $t;
            fn div(self, s: f32) -> $t { $t { $($f: self.$f / s),+ } }
        }
        impl Neg for $t {
            type Output = $t;
            fn neg(self) -> $t { $t { $($f: -self.$f),+ } }
        }
        impl AddAssign for $t {
            fn add_assign(&mut self, o: $t) { $(self.$f += o.$f;)+ }
        }
    };
}

impl_ops!(Vec2, x, y);
impl_ops!(Vec3, x, y, z);
impl_ops!(Vec4, x, y, z, w);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cross_orthogonal() {
        let a = v3(1.0, 0.0, 0.0);
        let b = v3(0.0, 1.0, 0.0);
        assert_eq!(a.cross(b), v3(0.0, 0.0, 1.0));
        assert!((a.cross(b).dot(a)).abs() < 1e-6);
    }

    #[test]
    fn normalize_unit_length() {
        let v = v3(3.0, 4.0, 12.0).normalized();
        assert!((v.length() - 1.0).abs() < 1e-6);
        assert_eq!(Vec3::ZERO.normalized(), Vec3::ZERO);
    }

    #[test]
    fn lerp_endpoints() {
        let a = v3(1.0, 2.0, 3.0);
        let b = v3(5.0, 6.0, 7.0);
        assert_eq!(a.lerp(b, 0.0), a);
        assert_eq!(a.lerp(b, 1.0), b);
        assert_eq!(a.lerp(b, 0.5), v3(3.0, 4.0, 5.0));
    }

    #[test]
    fn vec2_cross_sign() {
        assert!(v2(1.0, 0.0).cross(v2(0.0, 1.0)) > 0.0);
        assert!(v2(0.0, 1.0).cross(v2(1.0, 0.0)) < 0.0);
    }
}
