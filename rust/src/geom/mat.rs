//! Column-major 4×4 matrix: camera view/projection transforms for the
//! software batch renderer.

use super::vec::{v4, Vec3, Vec4};
#[cfg(test)]
use super::vec::v3;

/// Column-major (OpenGL convention): `m[col][row]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mat4 {
    pub m: [[f32; 4]; 4],
}

impl Mat4 {
    pub const IDENTITY: Mat4 = Mat4 {
        m: [
            [1.0, 0.0, 0.0, 0.0],
            [0.0, 1.0, 0.0, 0.0],
            [0.0, 0.0, 1.0, 0.0],
            [0.0, 0.0, 0.0, 1.0],
        ],
    };

    pub fn mul(&self, o: &Mat4) -> Mat4 {
        let mut r = [[0.0f32; 4]; 4];
        for c in 0..4 {
            for row in 0..4 {
                let mut s = 0.0;
                for k in 0..4 {
                    s += self.m[k][row] * o.m[c][k];
                }
                r[c][row] = s;
            }
        }
        Mat4 { m: r }
    }

    pub fn mul_vec4(&self, v: Vec4) -> Vec4 {
        v4(
            self.m[0][0] * v.x + self.m[1][0] * v.y + self.m[2][0] * v.z + self.m[3][0] * v.w,
            self.m[0][1] * v.x + self.m[1][1] * v.y + self.m[2][1] * v.z + self.m[3][1] * v.w,
            self.m[0][2] * v.x + self.m[1][2] * v.y + self.m[2][2] * v.z + self.m[3][2] * v.w,
            self.m[0][3] * v.x + self.m[1][3] * v.y + self.m[2][3] * v.z + self.m[3][3] * v.w,
        )
    }

    /// Transform a point (w=1), without perspective divide.
    pub fn transform_point(&self, p: Vec3) -> Vec3 {
        self.mul_vec4(p.extend(1.0)).xyz()
    }

    /// Right-handed look-at view matrix (camera at `eye` looking at `center`).
    pub fn look_at(eye: Vec3, center: Vec3, up: Vec3) -> Mat4 {
        let f = (center - eye).normalized();
        let s = f.cross(up).normalized();
        let u = s.cross(f);
        Mat4 {
            m: [
                [s.x, u.x, -f.x, 0.0],
                [s.y, u.y, -f.y, 0.0],
                [s.z, u.z, -f.z, 0.0],
                [-s.dot(eye), -u.dot(eye), f.dot(eye), 1.0],
            ],
        }
    }

    /// Right-handed perspective projection, depth mapped to [0, 1]
    /// (Vulkan-style, matching the paper's renderer).
    pub fn perspective(fovy_rad: f32, aspect: f32, near: f32, far: f32) -> Mat4 {
        let t = 1.0 / (fovy_rad * 0.5).tan();
        let mut m = [[0.0f32; 4]; 4];
        m[0][0] = t / aspect;
        m[1][1] = t;
        m[2][2] = far / (near - far);
        m[2][3] = -1.0;
        m[3][2] = near * far / (near - far);
        Mat4 { m }
    }

    pub fn translation(t: Vec3) -> Mat4 {
        let mut m = Mat4::IDENTITY;
        m.m[3][0] = t.x;
        m.m[3][1] = t.y;
        m.m[3][2] = t.z;
        m
    }

    /// Rotation about +Y by `angle` radians (agent heading).
    pub fn rotation_y(angle: f32) -> Mat4 {
        let (s, c) = angle.sin_cos();
        let mut m = Mat4::IDENTITY;
        m.m[0][0] = c;
        m.m[0][2] = -s;
        m.m[2][0] = s;
        m.m[2][2] = c;
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: Vec3, b: Vec3, eps: f32) -> bool {
        (a - b).length() < eps
    }

    #[test]
    fn identity_mul() {
        let m = Mat4::perspective(1.0, 1.5, 0.1, 100.0);
        assert_eq!(Mat4::IDENTITY.mul(&m), m);
        assert_eq!(m.mul(&Mat4::IDENTITY), m);
    }

    #[test]
    fn translation_moves_point() {
        let m = Mat4::translation(v3(1.0, 2.0, 3.0));
        assert_eq!(m.transform_point(v3(0.0, 0.0, 0.0)), v3(1.0, 2.0, 3.0));
    }

    #[test]
    fn rotation_y_quarter_turn() {
        let m = Mat4::rotation_y(std::f32::consts::FRAC_PI_2);
        // +Z rotates to +X under right-handed Y rotation
        assert!(close(m.transform_point(v3(0.0, 0.0, 1.0)), v3(1.0, 0.0, 0.0), 1e-5));
    }

    #[test]
    fn look_at_centers_target() {
        let view = Mat4::look_at(v3(0.0, 0.0, 5.0), Vec3::ZERO, Vec3::UP);
        let p = view.transform_point(Vec3::ZERO);
        // target lands on the -Z axis at distance 5
        assert!(close(p, v3(0.0, 0.0, -5.0), 1e-5));
    }

    #[test]
    fn perspective_depth_range() {
        let proj = Mat4::perspective(std::f32::consts::FRAC_PI_2, 1.0, 0.1, 100.0);
        // point at near plane -> ndc z = 0; far plane -> ndc z = 1
        let near = proj.mul_vec4(v4(0.0, 0.0, -0.1, 1.0));
        let far = proj.mul_vec4(v4(0.0, 0.0, -100.0, 1.0));
        assert!((near.z / near.w).abs() < 1e-5);
        assert!((far.z / far.w - 1.0).abs() < 1e-4);
    }

    #[test]
    fn view_proj_composition() {
        let view = Mat4::look_at(v3(3.0, 2.0, 3.0), Vec3::ZERO, Vec3::UP);
        let proj = Mat4::perspective(1.2, 1.0, 0.1, 50.0);
        let vp = proj.mul(&view);
        let clip = vp.mul_vec4(Vec3::ZERO.extend(1.0));
        let ndc = clip.xyz() / clip.w;
        // origin is centered in the view -> ndc x,y ~ 0
        assert!(ndc.x.abs() < 1e-5 && ndc.y.abs() < 1e-5);
        assert!((0.0..=1.0).contains(&ndc.z));
    }
}
