//! Axis-aligned bounding boxes — the culling granule of the batch renderer:
//! meshes are split into chunks at load time and each chunk's AABB is tested
//! against the per-environment camera frustum (paper §3.2).

use super::vec::{v3, Vec3};

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Aabb {
    pub min: Vec3,
    pub max: Vec3,
}

impl Aabb {
    /// Empty box (min > max); unioning with any point fixes it up.
    pub const EMPTY: Aabb = Aabb {
        min: v3(f32::INFINITY, f32::INFINITY, f32::INFINITY),
        max: v3(f32::NEG_INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY),
    };

    pub fn from_points(points: impl IntoIterator<Item = Vec3>) -> Aabb {
        let mut b = Aabb::EMPTY;
        for p in points {
            b.grow(p);
        }
        b
    }

    pub fn grow(&mut self, p: Vec3) {
        self.min = self.min.min(p);
        self.max = self.max.max(p);
    }

    pub fn union(&self, o: &Aabb) -> Aabb {
        Aabb {
            min: self.min.min(o.min),
            max: self.max.max(o.max),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.min.x > self.max.x
    }

    pub fn center(&self) -> Vec3 {
        (self.min + self.max) * 0.5
    }

    pub fn extent(&self) -> Vec3 {
        self.max - self.min
    }

    pub fn contains(&self, p: Vec3) -> bool {
        p.x >= self.min.x
            && p.x <= self.max.x
            && p.y >= self.min.y
            && p.y <= self.max.y
            && p.z >= self.min.z
            && p.z <= self.max.z
    }

    pub fn corners(&self) -> [Vec3; 8] {
        let (a, b) = (self.min, self.max);
        [
            v3(a.x, a.y, a.z),
            v3(b.x, a.y, a.z),
            v3(a.x, b.y, a.z),
            v3(b.x, b.y, a.z),
            v3(a.x, a.y, b.z),
            v3(b.x, a.y, b.z),
            v3(a.x, b.y, b.z),
            v3(b.x, b.y, b.z),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_points_bounds() {
        let b = Aabb::from_points([v3(1.0, 2.0, 3.0), v3(-1.0, 5.0, 0.0)]);
        assert_eq!(b.min, v3(-1.0, 2.0, 0.0));
        assert_eq!(b.max, v3(1.0, 5.0, 3.0));
        assert!(b.contains(v3(0.0, 3.0, 1.0)));
        assert!(!b.contains(v3(2.0, 3.0, 1.0)));
    }

    #[test]
    fn empty_behaves() {
        assert!(Aabb::EMPTY.is_empty());
        let mut b = Aabb::EMPTY;
        b.grow(v3(1.0, 1.0, 1.0));
        assert!(!b.is_empty());
        assert_eq!(b.min, b.max);
    }

    #[test]
    fn union_covers_both() {
        let a = Aabb::from_points([v3(0.0, 0.0, 0.0), v3(1.0, 1.0, 1.0)]);
        let b = Aabb::from_points([v3(2.0, -1.0, 0.5)]);
        let u = a.union(&b);
        assert!(u.contains(v3(0.5, 0.5, 0.5)));
        assert!(u.contains(v3(2.0, -1.0, 0.5)));
    }

    #[test]
    fn corners_count_and_extremes() {
        let b = Aabb::from_points([v3(0.0, 0.0, 0.0), v3(1.0, 2.0, 3.0)]);
        let cs = b.corners();
        assert_eq!(cs.len(), 8);
        assert!(cs.contains(&v3(0.0, 0.0, 0.0)));
        assert!(cs.contains(&v3(1.0, 2.0, 3.0)));
    }
}
