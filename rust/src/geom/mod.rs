//! 3D math for the simulator and renderer: vectors, 4×4 matrices, axis-
//! aligned bounding boxes, and view-frustum plane tests (used by the batch
//! renderer's pipelined geometry culling, paper §3.2).

pub mod aabb;
pub mod frustum;
pub mod mat;
pub mod vec;

pub use aabb::Aabb;
pub use frustum::Frustum;
pub use mat::Mat4;
pub use vec::{Vec2, Vec3, Vec4};
