//! View-frustum extraction and AABB rejection tests — the core primitive of
//! the renderer's pipelined geometry culling stage (paper §3.2): chunks of
//! scene geometry whose AABB lies fully outside an agent's view frustum are
//! discarded before rasterization.

use super::aabb::Aabb;
use super::mat::Mat4;
use super::vec::{v3, Vec3};

/// One plane in `ax + by + cz + d >= 0` half-space form.
#[derive(Clone, Copy, Debug)]
pub struct Plane {
    pub n: Vec3,
    pub d: f32,
}

impl Plane {
    pub fn signed_distance(&self, p: Vec3) -> f32 {
        self.n.dot(p) + self.d
    }
}

/// Six planes (left, right, bottom, top, near, far), inward-facing.
#[derive(Clone, Copy, Debug)]
pub struct Frustum {
    pub planes: [Plane; 6],
}

impl Frustum {
    /// Gribb–Hartmann extraction from a combined view-projection matrix
    /// (column-major, depth in [0,1]).
    pub fn from_view_proj(vp: &Mat4) -> Frustum {
        let m = &vp.m;
        let row = |r: usize| v3(m[0][r], m[1][r], m[2][r]);
        let roww = |r: usize| m[3][r];
        let mk = |n: Vec3, d: f32| {
            let len = n.length().max(1e-20);
            Plane { n: n / len, d: d / len }
        };
        Frustum {
            planes: [
                mk(row(3) + row(0), roww(3) + roww(0)), // left:   w + x >= 0
                mk(row(3) - row(0), roww(3) - roww(0)), // right:  w - x >= 0
                mk(row(3) + row(1), roww(3) + roww(1)), // bottom
                mk(row(3) - row(1), roww(3) - roww(1)), // top
                mk(row(2), roww(2)),                    // near:   z >= 0 ([0,1] depth)
                mk(row(3) - row(2), roww(3) - roww(2)), // far:    w - z >= 0
            ],
        }
    }

    /// Conservative AABB test: `false` only when the box is certainly
    /// outside (fully behind some plane). May return `true` for boxes that
    /// are actually outside (corner cases) — safe for culling.
    pub fn intersects_aabb(&self, b: &Aabb) -> bool {
        for pl in &self.planes {
            // pick the box corner farthest along the plane normal
            let p = v3(
                if pl.n.x >= 0.0 { b.max.x } else { b.min.x },
                if pl.n.y >= 0.0 { b.max.y } else { b.min.y },
                if pl.n.z >= 0.0 { b.max.z } else { b.min.z },
            );
            if pl.signed_distance(p) < 0.0 {
                return false;
            }
        }
        true
    }

    pub fn contains_point(&self, p: Vec3) -> bool {
        self.planes.iter().all(|pl| pl.signed_distance(p) >= 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_frustum() -> Frustum {
        // camera at origin looking down -Z, 90 deg fov, square aspect
        let view = Mat4::look_at(Vec3::ZERO, v3(0.0, 0.0, -1.0), Vec3::UP);
        let proj = Mat4::perspective(std::f32::consts::FRAC_PI_2, 1.0, 0.1, 100.0);
        Frustum::from_view_proj(&proj.mul(&view))
    }

    #[test]
    fn point_in_front_inside() {
        let f = test_frustum();
        assert!(f.contains_point(v3(0.0, 0.0, -5.0)));
        assert!(f.contains_point(v3(2.0, 0.0, -5.0))); // within 45 deg half-angle
    }

    #[test]
    fn point_behind_outside() {
        let f = test_frustum();
        assert!(!f.contains_point(v3(0.0, 0.0, 5.0)));
        assert!(!f.contains_point(v3(0.0, 0.0, 0.05))); // in front of near plane
        assert!(!f.contains_point(v3(0.0, 0.0, -200.0))); // beyond far
    }

    #[test]
    fn point_outside_fov() {
        let f = test_frustum();
        assert!(!f.contains_point(v3(10.0, 0.0, -5.0))); // > 45 deg off-axis
    }

    #[test]
    fn aabb_inside_and_outside() {
        let f = test_frustum();
        let inside = Aabb::from_points([v3(-1.0, -1.0, -6.0), v3(1.0, 1.0, -4.0)]);
        assert!(f.intersects_aabb(&inside));
        let behind = Aabb::from_points([v3(-1.0, -1.0, 2.0), v3(1.0, 1.0, 4.0)]);
        assert!(!f.intersects_aabb(&behind));
        let left = Aabb::from_points([v3(-50.0, -1.0, -5.0), v3(-40.0, 1.0, -4.0)]);
        assert!(!f.intersects_aabb(&left));
    }

    #[test]
    fn aabb_straddling_plane_kept() {
        let f = test_frustum();
        // box straddles the near plane: conservative test must keep it
        let straddle = Aabb::from_points([v3(-0.5, -0.5, 0.5), v3(0.5, 0.5, -1.0)]);
        assert!(f.intersects_aabb(&straddle));
    }

    #[test]
    fn culling_never_rejects_visible_points_property() {
        crate::util::prop::check("frustum_conservative", 200, |rng| {
            let view = Mat4::look_at(Vec3::ZERO, v3(0.0, 0.0, -1.0), Vec3::UP);
            let proj =
                Mat4::perspective(std::f32::consts::FRAC_PI_2, 1.0, 0.1, 100.0);
            let f = Frustum::from_view_proj(&proj.mul(&view));
            let p = v3(
                rng.range_f32(-20.0, 20.0),
                rng.range_f32(-20.0, 20.0),
                rng.range_f32(-90.0, -0.2),
            );
            if f.contains_point(p) {
                // any box containing a visible point must not be culled
                let e = rng.range_f32(0.01, 5.0);
                let b = Aabb::from_points([p - v3(e, e, e), p + v3(e, e, e)]);
                assert!(f.intersects_aabb(&b));
            }
        });
    }
}
