//! Scenario engine: declarative scenario specs, streaming procgen, and
//! success-driven curriculum scheduling (DESIGN.md §0.6).
//!
//! This subsystem is the single source of "what world does environment
//! *i* run" — for training shards and served tenants alike:
//!
//! - [`ScenarioSpec`] declares a workload: task, a *distribution* over
//!   scene complexity (ranges, not points), episode constraints, and
//!   domain-randomization knobs. Parse it from a spec string
//!   (`--scenario "name=maze task=pointnav tris=20k..80k stages=3"`) or a
//!   `.scenario` registry file.
//! - [`ScenarioStream`] turns a spec into scenes: a generator thread
//!   synthesizes [`SceneAsset`](crate::scene::SceneAsset)s ahead of
//!   demand on the shared `WorkerPool` into a bounded prefetch queue,
//!   replacing the eager whole-dataset `generate_dataset` path on the
//!   training hot loop.
//! - [`Curriculum`] watches success/SPL windows and deterministically
//!   advances the spec's difficulty stage; its owner forwards the change
//!   through the public env seam (`EnvBatch::set_stage` +
//!   `EnvBatch::rotate_scenes`) — no sim internals.

pub mod curriculum;
pub mod spec;
pub mod stream;

pub use curriculum::Curriculum;
pub use spec::{registry_list, ScenarioSpec, Span};
pub use stream::{synthesize_scene, ScenarioStream};

use crate::sim::{ACTION_FORWARD, ACTION_LEFT, ACTION_RIGHT, ACTION_STOP};

/// Scripted GPS+compass policy over the public observation surface: each
/// env turns toward its goal, walks, and calls STOP inside `stop_dist`.
/// Goal-free tasks (Flee/Explore read an all-zero sensor) fall back to a
/// turn/forward script parameterized by `t`. Used by `bps scenario-demo`,
/// the quickstart, and the curriculum tests — it reaches high PointNav
/// success on easy stages without any learned parameters, which is what
/// lets tests drive the curriculum deterministically.
pub fn sensor_policy(goal: &[f32], stop_dist: f32, t: usize, actions: &mut [u8]) {
    for (i, a) in actions.iter_mut().enumerate() {
        let g = &goal[i * 3..i * 3 + 3];
        let (dist, cos, sin) = (g[0] * 10.0, g[1], g[2]);
        if dist == 0.0 && cos == 0.0 && sin == 0.0 {
            // goal-free task: scripted turn/forward, never STOP
            *a = (1 + (t + i) % 3) as u8;
            continue;
        }
        let angle = sin.atan2(cos);
        *a = if dist <= stop_dist {
            ACTION_STOP
        } else if angle > 0.15 {
            ACTION_LEFT
        } else if angle < -0.15 {
            ACTION_RIGHT
        } else {
            ACTION_FORWARD
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sensor_policy_steers_toward_goal() {
        let mut actions = vec![0u8; 4];
        // [dist/10, cos, sin] per env
        let goal = vec![
            0.01, 1.0, 0.0, // within stop radius
            0.5, 1.0, 0.0, // dead ahead
            0.5, 0.0, 1.0, // 90° left
            0.5, 0.0, -1.0, // 90° right
        ];
        sensor_policy(&goal, 0.15, 0, &mut actions);
        assert_eq!(
            actions,
            vec![ACTION_STOP, ACTION_FORWARD, ACTION_LEFT, ACTION_RIGHT]
        );
    }

    #[test]
    fn sensor_policy_goal_free_never_stops() {
        let goal = vec![0.0f32; 3 * 8];
        let mut actions = vec![0u8; 8];
        for t in 0..24 {
            sensor_policy(&goal, 0.15, t, &mut actions);
            assert!(actions.iter().all(|&a| a != ACTION_STOP));
        }
    }
}
