//! [`ScenarioStream`]: streaming procgen — scenes generated ahead of
//! demand into a bounded prefetch queue.
//!
//! The eager `generate_dataset` path synthesizes every scene up front;
//! this stream instead amortizes synthesis the way the paper amortizes
//! data loading: a generator thread drains pending requests, builds the
//! batch **in parallel on the shared [`WorkerPool`]**, and delivers
//! finished [`SceneAsset`]s in request order. The consumer (the scene
//! rotation, possibly on the env driver thread) keeps the queue topped up
//! to `prefetch` scenes, so a warm rotation never waits on synthesis —
//! [`stalls`](ScenarioStream::stalls) counts the times it did.
//!
//! Determinism: every request is derived consumer-side from
//! `(spec, seed, scene index, stage at request time)` and results are
//! delivered FIFO, so the scene sequence is a pure function of the
//! consumer's call order — curriculum stage changes take effect exactly
//! `queued + in-flight` scenes later, independent of wall clock.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::obs::Heartbeat;
use crate::scene::procgen::generate;
use crate::scene::{Complexity, SceneAsset};
use crate::util::pool::WorkerPool;
use crate::util::rng::Rng;

use super::spec::ScenarioSpec;

/// Generator-thread batching cap: at most this many queued requests are
/// drained into one `parallel_for` round.
const GEN_BATCH: usize = 8;

// Watchdog thresholds for the generator thread. Scene synthesis is
// seconds-scale at the largest curriculum stages, so the bounds are
// generous; the thread marks itself idle while parked on an empty
// request queue.
const GEN_DEGRADED: Duration = Duration::from_secs(10);
const GEN_STALLED: Duration = Duration::from_secs(60);

/// One scene-synthesis request (fully determined consumer-side).
struct GenRequest {
    id: String,
    seed: u64,
    cx: Complexity,
    /// Lighting-proxy brightness applied to every material's albedo.
    light: f32,
    with_textures: bool,
}

impl GenRequest {
    /// The one derivation of scene parameters from `(spec, stage, seed)`
    /// — shared by the stream's requests and by off-stream synthesis
    /// (eval), so every consumer applies identical DR.
    fn derive(
        spec: &ScenarioSpec,
        stage: u32,
        id: String,
        seed: u64,
        with_textures: bool,
    ) -> GenRequest {
        let mut rng = Rng::new(seed ^ 0xD1FF);
        let cx = spec.complexity_at(stage, &mut rng);
        let light = spec.light_at(stage, &mut rng);
        GenRequest {
            id,
            seed,
            cx,
            light,
            with_textures,
        }
    }
}

/// Synthesize one scene for `spec` at `stage` from `(id, seed)`, with the
/// full domain-randomization pipeline (complexity + lighting proxy +
/// texture stripping) — exactly what the stream generates, without the
/// stream. Evaluation uses this for unseen val layouts.
pub fn synthesize_scene(
    spec: &ScenarioSpec,
    stage: u32,
    id: &str,
    seed: u64,
    with_textures: bool,
) -> SceneAsset {
    synthesize(&GenRequest::derive(spec, stage, id.to_string(), seed, with_textures))
}

/// The streaming procgen pipeline (see module docs).
pub struct ScenarioStream {
    spec: ScenarioSpec,
    seed: u64,
    with_textures: bool,
    stage: u32,
    next_index: u64,
    prefetch: usize,
    /// Requests sent but not yet received back.
    outstanding: usize,
    /// Delivered scenes awaiting consumption (the warm queue).
    ready: VecDeque<Arc<SceneAsset>>,
    req_tx: Option<Sender<GenRequest>>,
    ready_rx: Receiver<Arc<SceneAsset>>,
    stalls: u64,
    delivered: u64,
    thread: Option<JoinHandle<()>>,
    /// The generator thread's liveness heartbeat. Standalone until a
    /// serving stack adopts it into its watchdog
    /// ([`heartbeat`](ScenarioStream::heartbeat)).
    heartbeat: Heartbeat,
}

impl ScenarioStream {
    /// Start the generator thread and kick the initial prefetch.
    /// `prefetch` bounds the queue (clamped to at least 1);
    /// `with_textures = false` strips texture payloads (Depth agents).
    pub fn new(
        spec: ScenarioSpec,
        seed: u64,
        prefetch: usize,
        with_textures: bool,
        pool: Arc<WorkerPool>,
    ) -> ScenarioStream {
        let (req_tx, req_rx) = channel::<GenRequest>();
        let (ready_tx, ready_rx) = channel();
        let heartbeat = Heartbeat::new("procgen", GEN_DEGRADED, GEN_STALLED);
        let gen_hb = heartbeat.clone();
        let thread = std::thread::Builder::new()
            .name("scenario-procgen".into())
            .spawn(move || gen_loop(pool, req_rx, ready_tx, gen_hb))
            .expect("spawn scenario procgen thread");
        let mut stream = ScenarioStream {
            spec,
            seed,
            with_textures,
            stage: 0,
            next_index: 0,
            prefetch: prefetch.max(1),
            outstanding: 0,
            ready: VecDeque::new(),
            req_tx: Some(req_tx),
            ready_rx,
            stalls: 0,
            delivered: 0,
            thread: Some(thread),
            heartbeat,
        };
        stream.top_up();
        stream
    }

    pub fn spec(&self) -> &ScenarioSpec {
        &self.spec
    }

    pub fn stage(&self) -> u32 {
        self.stage
    }

    /// Set the curriculum stage for *future* requests. Scenes already
    /// queued or in flight still deliver at their request-time stage
    /// (bounded by `prefetch`), keeping the sequence deterministic.
    pub fn set_stage(&mut self, stage: u32) {
        self.stage = stage.min(self.spec.stages.saturating_sub(1));
    }

    /// Times a blocking take found the queue cold (post-startup). The
    /// "never synchronously generates when warm" property in tests.
    pub fn stalls(&self) -> u64 {
        self.stalls
    }

    /// Scenes handed to the consumer so far.
    pub fn delivered(&self) -> u64 {
        self.delivered
    }

    /// A clone of the generator thread's heartbeat, for adoption into a
    /// serving stack's watchdog (`Watchdog::adopt`).
    pub fn heartbeat(&self) -> Heartbeat {
        self.heartbeat.clone()
    }

    /// Ready scenes currently queued (drains the delivery channel first).
    pub fn ready_len(&mut self) -> usize {
        self.pump();
        self.ready.len()
    }

    /// Block until every outstanding request has been delivered — the
    /// queue is as warm as it gets. Used at startup and by tests.
    pub fn wait_warm(&mut self) {
        self.pump();
        while self.outstanding > 0 {
            match self.ready_rx.recv() {
                Ok(s) => {
                    self.outstanding -= 1;
                    self.ready.push_back(s);
                }
                Err(_) => break, // generator died; degrade gracefully
            }
        }
    }

    /// Issue requests until `queued + in-flight` reaches the prefetch
    /// bound. Non-blocking.
    pub fn top_up(&mut self) {
        self.pump();
        while self.outstanding + self.ready.len() < self.prefetch {
            let req = self.make_request();
            let sent = match &self.req_tx {
                Some(tx) => tx.send(req).is_ok(),
                None => false,
            };
            if !sent {
                break; // generator died; consumers see an empty queue
            }
            self.outstanding += 1;
        }
    }

    /// Non-blocking take; `None` when the queue is cold. Tops the queue
    /// back up after a successful take.
    pub fn try_next(&mut self) -> Option<Arc<SceneAsset>> {
        self.pump();
        let scene = self.ready.pop_front()?;
        self.delivered += 1;
        self.top_up();
        Some(scene)
    }

    /// Blocking take (pinned rotation / startup). Counts a stall when the
    /// queue was cold. `None` only if the generator thread died.
    pub fn next_blocking(&mut self) -> Option<Arc<SceneAsset>> {
        if let Some(scene) = self.try_next() {
            return Some(scene);
        }
        if self.outstanding == 0 {
            self.top_up();
        }
        if self.outstanding == 0 {
            return None; // generator unreachable
        }
        self.stalls += 1;
        match self.ready_rx.recv() {
            Ok(scene) => {
                self.outstanding -= 1;
                self.delivered += 1;
                self.top_up();
                Some(scene)
            }
            Err(_) => None,
        }
    }

    /// Forget startup stalls so the counter reflects steady state only.
    pub fn reset_stalls(&mut self) {
        self.stalls = 0;
    }

    /// Drain completed deliveries into the ready queue.
    fn pump(&mut self) {
        loop {
            match self.ready_rx.try_recv() {
                Ok(s) => {
                    self.outstanding -= 1;
                    self.ready.push_back(s);
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
    }

    /// Derive the next request — a pure function of
    /// `(spec, seed, index, stage)`.
    fn make_request(&mut self) -> GenRequest {
        let idx = self.next_index;
        self.next_index += 1;
        let seed = self
            .seed
            .wrapping_add(0x5CE0)
            .wrapping_add(idx.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let id = format!("{}_s{}_{idx:05}", self.spec.name, self.stage);
        GenRequest::derive(&self.spec, self.stage, id, seed, self.with_textures)
    }
}

impl Drop for ScenarioStream {
    fn drop(&mut self) {
        drop(self.req_tx.take()); // close the request channel
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

/// Synthesize one scene per request, applying the domain-randomization
/// post-passes (lighting proxy, texture stripping).
fn synthesize(req: &GenRequest) -> SceneAsset {
    let mut scene = generate(&req.id, req.seed, req.cx);
    if req.light != 1.0 {
        for m in scene.materials.iter_mut() {
            for c in m.albedo.iter_mut() {
                *c = (*c * req.light).clamp(0.0, 1.0);
            }
        }
    }
    if !req.with_textures {
        scene.textures.clear();
    }
    scene
}

/// Generator-thread loop: drain pending requests into a batch, build the
/// batch in parallel on the shared pool, deliver in request order.
fn gen_loop(
    pool: Arc<WorkerPool>,
    req_rx: Receiver<GenRequest>,
    ready_tx: Sender<Arc<SceneAsset>>,
    hb: Heartbeat,
) {
    loop {
        // Parked on an empty request queue: deliberate, possibly forever
        // (a fully-warm prefetch queue issues nothing until consumed).
        hb.idle();
        let Ok(first) = req_rx.recv() else {
            return;
        };
        hb.beat();
        let mut batch = vec![first];
        while batch.len() < GEN_BATCH {
            match req_rx.try_recv() {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
        if batch.len() == 1 {
            // common steady-state case: skip the slot machinery
            if ready_tx.send(Arc::new(synthesize(&batch[0]))).is_err() {
                return;
            }
            continue;
        }
        let slots: Vec<Mutex<Option<SceneAsset>>> =
            batch.iter().map(|_| Mutex::new(None)).collect();
        pool.parallel_for(batch.len(), 1, |i| {
            *slots[i].lock().unwrap() = Some(synthesize(&batch[i]));
        });
        for slot in slots {
            let scene = slot
                .into_inner()
                .unwrap()
                .expect("parallel_for filled every slot");
            if ready_tx.send(Arc::new(scene)).is_err() {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(stages: u32) -> ScenarioSpec {
        ScenarioSpec::parse(&format!(
            "name=st task=pointnav stages={stages} tris=400..1200 extent=6..8 \
             clutter=0..1 mats=1..2 tex=16"
        ))
        .unwrap()
    }

    #[test]
    fn delivers_in_request_order_and_deterministically() {
        let pool = Arc::new(WorkerPool::new(2));
        let take = |n: usize| -> Vec<(String, usize)> {
            let mut st = ScenarioStream::new(tiny_spec(1), 9, 2, false, Arc::clone(&pool));
            (0..n)
                .map(|_| {
                    let s = st.next_blocking().unwrap();
                    (s.id.clone(), s.mesh.num_tris())
                })
                .collect()
        };
        let a = take(5);
        let b = take(5);
        assert_eq!(a, b, "scene sequence must be a pure function of the seed");
        assert_eq!(a[0].0, "st_s0_00000");
        assert_eq!(a[4].0, "st_s0_00004");
    }

    #[test]
    fn warm_queue_takes_do_not_stall() {
        let pool = Arc::new(WorkerPool::new(2));
        let mut st = ScenarioStream::new(tiny_spec(1), 4, 3, false, pool);
        st.wait_warm();
        assert_eq!(st.ready_len(), 3);
        st.reset_stalls();
        let s = st.next_blocking().unwrap();
        assert!(s.mesh.num_tris() > 0);
        assert_eq!(st.stalls(), 0, "warm take must not wait on synthesis");
        assert!(st.try_next().is_some());
        assert_eq!(st.stalls(), 0);
    }

    #[test]
    fn stage_change_applies_after_pipeline_latency() {
        let pool = Arc::new(WorkerPool::new(2));
        let prefetch = 2;
        let mut st = ScenarioStream::new(tiny_spec(3), 4, prefetch, false, pool);
        st.set_stage(2);
        // the first `prefetch` scenes were requested at stage 0
        for _ in 0..prefetch {
            let s = st.next_blocking().unwrap();
            assert!(s.id.contains("_s0_"), "{}", s.id);
        }
        let s = st.next_blocking().unwrap();
        assert!(s.id.contains("_s2_"), "{}", s.id);
        // stage clamps to the spec's last stage
        st.set_stage(99);
        assert_eq!(st.stage(), 2);
    }

    #[test]
    fn textures_stripped_for_depth_and_light_applied() {
        let pool = Arc::new(WorkerPool::new(0));
        let spec = ScenarioSpec::parse(
            "name=dr stages=1 tris=400..400 extent=6..6 clutter=0..0 \
             mats=1..1 tex=16 light=0.5..0.5",
        )
        .unwrap();
        let mut depth = ScenarioStream::new(spec.clone(), 7, 1, false, Arc::clone(&pool));
        let d = depth.next_blocking().unwrap();
        assert!(d.textures.is_empty());
        let mut rgb = ScenarioStream::new(spec.clone(), 7, 1, true, pool);
        let r = rgb.next_blocking().unwrap();
        assert!(!r.textures.is_empty());
        // lighting proxy halved every albedo vs a light=1 generation
        let unlit = {
            let mut rng = Rng::new((7u64.wrapping_add(0x5CE0)) ^ 0xD1FF);
            let cx = spec.complexity_at(0, &mut rng);
            crate::scene::procgen::generate("dr_s0_00000", 7u64.wrapping_add(0x5CE0), cx)
        };
        assert!((d.materials[0].albedo[0] - unlit.materials[0].albedo[0] * 0.5).abs() < 1e-6);
    }
}
