//! [`ScenarioSpec`]: the declarative description of a workload — task,
//! a *distribution* over scene complexity (ranges, not points), episode
//! constraints, and domain-randomization knobs.
//!
//! Specs parse from a compact spec string
//! (`--scenario "name=maze task=pointnav tris=20k..80k stages=3"`) and
//! from `.scenario` files in a registry directory (same grammar, any
//! whitespace, `#` comments). Every ranged knob is interpreted per
//! curriculum stage: stage `s` of `S` samples uniformly from the
//! `[s/S, (s+1)/S]` band of the range, so difficulty grows monotonically
//! while every stage still randomizes within its band.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::scene::Complexity;
use crate::sim::{SimConfig, Task};
use crate::util::rng::Rng;

/// A closed numeric range `[lo, hi]` (a point when `lo == hi`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Span {
    pub lo: f32,
    pub hi: f32,
}

impl Span {
    pub fn point(x: f32) -> Span {
        Span { lo: x, hi: x }
    }

    pub fn new(lo: f32, hi: f32) -> Span {
        Span { lo, hi }
    }

    /// Linear interpolation across the span (`t` in `[0, 1]`).
    pub fn at(&self, t: f32) -> f32 {
        self.lo + (self.hi - self.lo) * t.clamp(0.0, 1.0)
    }

    /// Uniform sample from the `[band_lo, band_hi]` fraction of the span.
    pub fn sample_band(&self, band_lo: f32, band_hi: f32, rng: &mut Rng) -> f32 {
        let t = if band_hi > band_lo {
            rng.range_f32(band_lo, band_hi)
        } else {
            band_lo
        };
        self.at(t)
    }

    fn is_point(&self) -> bool {
        self.lo == self.hi
    }
}

/// Declarative scenario: what world every environment runs (see module
/// docs). Scene knobs are [`Span`]s sampled per generated scene; episode
/// constraints are scalars applied through [`SimConfig`].
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub task: Task,
    /// Curriculum stage count (1 = no curriculum, full-range DR).
    pub stages: u32,
    /// Triangle-budget distribution (drives the procgen `detail` knob).
    pub tris: Span,
    /// World extent in meters.
    pub extent: Span,
    /// Clutter objects per room (clutter-density DR knob).
    pub clutter: Span,
    /// Procedural texture/material count (material DR knob).
    pub mats: Span,
    /// Procedural texture resolution.
    pub tex_res: usize,
    /// Lighting proxy: global albedo brightness scale (lighting DR knob).
    pub light: Span,
    /// Episode constraint: minimum start→goal geodesic distance (m).
    pub min_geodesic: f32,
    /// Episode constraint: step budget per episode.
    pub max_steps: u32,
}

impl Default for ScenarioSpec {
    fn default() -> ScenarioSpec {
        ScenarioSpec {
            name: "scenario".into(),
            task: Task::PointNav,
            stages: 1,
            tris: Span::new(5_000.0, 20_000.0),
            extent: Span::new(8.0, 10.0),
            clutter: Span::new(1.0, 4.0),
            mats: Span::new(2.0, 6.0),
            tex_res: 64,
            light: Span::point(1.0),
            min_geodesic: 1.0,
            max_steps: 500,
        }
    }
}

impl ScenarioSpec {
    /// Parse a spec string: whitespace-separated `key=value` tokens.
    /// Ranges are `lo..hi`; numbers accept `k`/`m` suffixes.
    pub fn parse(s: &str) -> Result<ScenarioSpec> {
        let mut spec = ScenarioSpec::default();
        for tok in s.split_whitespace() {
            let Some((k, v)) = tok.split_once('=') else {
                bail!("scenario token {tok:?} is not key=value");
            };
            spec.set(k, v)?;
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Load a `.scenario` file (spec-string grammar over any whitespace;
    /// `#` starts a comment). The file stem is the default name.
    pub fn load(path: &Path) -> Result<ScenarioSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read scenario file {path:?}"))?;
        let stripped: String = text
            .lines()
            .map(|l| l.split('#').next().unwrap_or(""))
            .collect::<Vec<_>>()
            .join(" ");
        let mut spec = ScenarioSpec::parse(&stripped)
            .with_context(|| format!("parse scenario file {path:?}"))?;
        if spec.name == ScenarioSpec::default().name {
            if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
                spec.name = stem.to_string();
            }
        }
        Ok(spec)
    }

    /// Resolve a `--scenario` argument: an inline spec string when it
    /// contains `=`, otherwise a name looked up as
    /// `<registry>/<name>.scenario`.
    pub fn resolve(arg: &str, registry: &Path) -> Result<ScenarioSpec> {
        if arg.contains('=') {
            ScenarioSpec::parse(arg)
        } else {
            let path = registry.join(format!("{arg}.scenario"));
            if !path.exists() {
                let known = registry_list(registry).unwrap_or_default();
                bail!(
                    "scenario {arg:?} not found in registry {registry:?} \
                     (known: {known:?}); pass an inline spec like \
                     \"name=maze task=pointnav tris=20k..80k stages=3\""
                );
            }
            ScenarioSpec::load(&path)
        }
    }

    fn set(&mut self, key: &str, v: &str) -> Result<()> {
        match key {
            "name" => {
                if v.is_empty()
                    || !v
                        .chars()
                        .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
                {
                    bail!("scenario name {v:?} must be [A-Za-z0-9_-]+");
                }
                self.name = v.to_string();
            }
            "task" => {
                self.task = Task::parse(v)
                    .ok_or_else(|| anyhow::anyhow!("bad task {v:?} (pointnav|flee|explore)"))?
            }
            "stages" => self.stages = parse_num(v)? as u32,
            "tris" => self.tris = parse_span(v)?,
            "extent" => self.extent = parse_span(v)?,
            "clutter" => self.clutter = parse_span(v)?,
            "mats" => self.mats = parse_span(v)?,
            "tex" | "tex-res" | "tex_res" => self.tex_res = parse_num(v)? as usize,
            "light" => self.light = parse_span(v)?,
            "min-geo" | "min_geo" | "min-geodesic" | "min_geodesic" => {
                self.min_geodesic = parse_num(v)?
            }
            "max-steps" | "max_steps" => self.max_steps = parse_num(v)? as u32,
            other => bail!(
                "unknown scenario key {other:?} (name task stages tris extent \
                 clutter mats tex light min-geo max-steps)"
            ),
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.stages == 0 || self.stages > 32 {
            bail!("stages must be in 1..=32, got {}", self.stages);
        }
        for (name, s) in [
            ("tris", self.tris),
            ("extent", self.extent),
            ("clutter", self.clutter),
            ("mats", self.mats),
            ("light", self.light),
        ] {
            if !s.lo.is_finite() || !s.hi.is_finite() || s.lo > s.hi || s.lo < 0.0 {
                bail!("scenario {name} range [{}, {}] is invalid", s.lo, s.hi);
            }
        }
        if self.extent.lo < 5.0 {
            bail!(
                "extent floor {} m is too small for episode sampling (>= 5)",
                self.extent.lo
            );
        }
        if self.tris.hi > 5_000_000.0 {
            bail!("tris ceiling {} exceeds the 5M sanity cap", self.tris.hi);
        }
        if self.max_steps == 0 {
            bail!("max-steps must be positive");
        }
        if !(self.min_geodesic.is_finite() && self.min_geodesic >= 0.0) {
            bail!("min-geo must be a non-negative number");
        }
        if !(8..=1024).contains(&self.tex_res) {
            bail!("tex resolution {} out of 8..=1024", self.tex_res);
        }
        Ok(())
    }

    /// The stage's band within `[0, 1]`: stage `s` of `S` covers
    /// `[s/S, (s+1)/S]`, so the last stage samples the hardest fraction
    /// of every range. A single-stage spec covers the full range.
    pub fn stage_band(&self, stage: u32) -> (f32, f32) {
        let s = self.stages.max(1) as f32;
        let i = stage.min(self.stages.saturating_sub(1)) as f32;
        (i / s, (i + 1.0) / s)
    }

    /// Sample a concrete [`Complexity`] for one scene at `stage`.
    /// Deterministic given the `rng` state: the stream derives `rng` from
    /// `(seed, scene index)`, so scene content is a pure function of
    /// `(spec, seed, index, stage)`.
    pub fn complexity_at(&self, stage: u32, rng: &mut Rng) -> Complexity {
        let (b0, b1) = self.stage_band(stage);
        let tris = self.tris.sample_band(b0, b1, rng);
        let extent = self.extent.sample_band(b0, b1, rng).clamp(5.0, 64.0);
        let clutter = self.clutter.sample_band(b0, b1, rng).round().max(0.0) as usize;
        let mats = (self.mats.sample_band(b0, b1, rng).round() as usize).clamp(1, 16);
        // The floor quad dominates the triangle count: subdiv (8·detail)
        // gives ~2·(8·detail)² = 128·detail² tris, plus wall/clutter boxes
        // — calibrate detail ≈ sqrt(tris / 150).
        let detail = ((tris / 150.0).sqrt().round() as usize).clamp(1, 24);
        Complexity {
            extent,
            min_room: (extent / 4.0).clamp(2.0, 4.0),
            clutter_per_room: clutter,
            detail,
            tex_res: self.tex_res,
            tex_count: mats,
        }
    }

    /// Lighting-proxy brightness for one scene at `stage`.
    pub fn light_at(&self, stage: u32, rng: &mut Rng) -> f32 {
        let (b0, b1) = self.stage_band(stage);
        self.light.sample_band(b0, b1, rng).clamp(0.05, 4.0)
    }

    /// The simulator config this scenario's episode constraints imply.
    pub fn sim_config(&self) -> SimConfig {
        SimConfig {
            max_steps: self.max_steps,
            min_geodesic: self.min_geodesic,
            ..SimConfig::for_task(self.task)
        }
    }

    /// Compact single-line round-trippable form (registry listings, logs).
    pub fn summary(&self) -> String {
        let span = |s: Span| {
            if s.is_point() {
                format!("{}", s.lo)
            } else {
                format!("{}..{}", s.lo, s.hi)
            }
        };
        format!(
            "name={} task={} stages={} tris={} extent={} clutter={} \
             mats={} tex={} light={} min-geo={} max-steps={}",
            self.name,
            self.task.name(),
            self.stages,
            span(self.tris),
            span(self.extent),
            span(self.clutter),
            span(self.mats),
            self.tex_res,
            span(self.light),
            self.min_geodesic,
            self.max_steps,
        )
    }
}

/// Scenario names available in a registry directory (`*.scenario` files),
/// sorted for stable listings.
pub fn registry_list(dir: &Path) -> Result<Vec<String>> {
    let mut names = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(names), // missing registry = empty registry
    };
    for entry in entries {
        let path: PathBuf = entry?.path();
        if path.extension().and_then(|e| e.to_str()) == Some("scenario") {
            if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
                names.push(stem.to_string());
            }
        }
    }
    names.sort();
    Ok(names)
}

/// Parse a number with optional `k` (×10³) / `m` (×10⁶) suffix.
fn parse_num(s: &str) -> Result<f32> {
    let (body, mult) = match s.strip_suffix(&['k', 'K'][..]) {
        Some(b) => (b, 1_000.0),
        None => match s.strip_suffix(&['m', 'M'][..]) {
            Some(b) => (b, 1_000_000.0),
            None => (s, 1.0),
        },
    };
    let x: f32 = body
        .parse()
        .map_err(|e| anyhow::anyhow!("bad number {s:?}: {e}"))?;
    Ok(x * mult)
}

/// Parse `lo..hi` (or a single point) with `k`/`m` suffixes.
fn parse_span(s: &str) -> Result<Span> {
    match s.split_once("..") {
        Some((lo, hi)) => Ok(Span::new(parse_num(lo)?, parse_num(hi)?)),
        None => Ok(Span::point(parse_num(s)?)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spec_string_with_ranges_and_suffixes() {
        let s = ScenarioSpec::parse(
            "name=maze task=pointnav tris=20k..80k stages=3 extent=8..14 \
             clutter=0..6 mats=2..8 tex=32 light=0.5..1.5 min-geo=2.5 max-steps=400",
        )
        .unwrap();
        assert_eq!(s.name, "maze");
        assert_eq!(s.task, Task::PointNav);
        assert_eq!(s.stages, 3);
        assert_eq!(s.tris, Span::new(20_000.0, 80_000.0));
        assert_eq!(s.extent, Span::new(8.0, 14.0));
        assert_eq!(s.mats, Span::new(2.0, 8.0));
        assert_eq!(s.tex_res, 32);
        assert_eq!(s.light, Span::new(0.5, 1.5));
        assert!((s.min_geodesic - 2.5).abs() < 1e-6);
        assert_eq!(s.max_steps, 400);
        // round-trips through the summary form verbatim
        let back = ScenarioSpec::parse(&s.summary()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn rejects_bad_tokens() {
        assert!(ScenarioSpec::parse("tris").is_err()); // not key=value
        assert!(ScenarioSpec::parse("warp=9").is_err()); // unknown key
        assert!(ScenarioSpec::parse("task=swim").is_err());
        assert!(ScenarioSpec::parse("stages=0").is_err());
        assert!(ScenarioSpec::parse("tris=80k..20k").is_err()); // inverted
        assert!(ScenarioSpec::parse("extent=1..3").is_err()); // too small
        assert!(ScenarioSpec::parse("name=bad name").is_err());
    }

    #[test]
    fn stage_bands_partition_the_range() {
        let s = ScenarioSpec::parse("stages=4").unwrap();
        assert_eq!(s.stage_band(0), (0.0, 0.25));
        assert_eq!(s.stage_band(3), (0.75, 1.0));
        // out-of-range stages clamp to the last band
        assert_eq!(s.stage_band(9), (0.75, 1.0));
        let single = ScenarioSpec::default();
        assert_eq!(single.stage_band(0), (0.0, 1.0));
    }

    #[test]
    fn complexity_scales_with_stage() {
        let s = ScenarioSpec::parse("tris=1k..100k extent=6..16 clutter=0..8 stages=4").unwrap();
        let mut lo_rng = Rng::new(1);
        let mut hi_rng = Rng::new(1);
        let lo = s.complexity_at(0, &mut lo_rng);
        let hi = s.complexity_at(3, &mut hi_rng);
        assert!(hi.detail > lo.detail, "{} vs {}", hi.detail, lo.detail);
        assert!(hi.extent > lo.extent);
        assert!(hi.clutter_per_room >= lo.clutter_per_room);
        // deterministic for equal rng state
        let mut again = Rng::new(1);
        assert_eq!(s.complexity_at(0, &mut again), lo);
    }

    #[test]
    fn file_and_registry_resolution() {
        let dir = std::env::temp_dir().join("bps_scenario_spec_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("warehouse.scenario"),
            "# a big cluttered scenario\ntask=explore\ntris=10k..40k  stages=2\n",
        )
        .unwrap();
        let by_name = ScenarioSpec::resolve("warehouse", &dir).unwrap();
        assert_eq!(by_name.name, "warehouse"); // stem becomes the name
        assert_eq!(by_name.task, Task::Explore);
        assert_eq!(by_name.stages, 2);
        assert_eq!(registry_list(&dir).unwrap(), vec!["warehouse".to_string()]);
        // inline strings bypass the registry
        let inline = ScenarioSpec::resolve("task=flee", &dir).unwrap();
        assert_eq!(inline.task, Task::Flee);
        // unknown names fail with the registry listing in the message
        let err = ScenarioSpec::resolve("nope", &dir).unwrap_err().to_string();
        assert!(err.contains("warehouse"), "{err}");
    }

    #[test]
    fn sim_config_carries_episode_constraints() {
        let s = ScenarioSpec::parse("task=pointnav min-geo=3 max-steps=123").unwrap();
        let cfg = s.sim_config();
        assert_eq!(cfg.max_steps, 123);
        assert!((cfg.min_geodesic - 3.0).abs() < 1e-6);
        assert_eq!(cfg.task, Task::PointNav);
    }
}
