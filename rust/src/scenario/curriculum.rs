//! [`Curriculum`]: success-driven stage scheduling.
//!
//! A deterministic state machine over episode outcomes: per stage it
//! accumulates success/SPL into sliding windows ([`metrics::Window`])
//! and advances to the next stage once the window is full **and** the
//! windowed success rate clears the threshold. Advancing clears the
//! windows, so each stage is judged only on its own episodes — the
//! natural cooldown. Everything is a pure function of the observed
//! `(dones, successes, spl)` stream, so equal rollouts produce equal
//! stage schedules (the bitwise-reproducibility gate in
//! `rust/tests/scenario.rs`).
//!
//! The curriculum never touches sim internals: its owner forwards stage
//! changes through the public seam (`EnvBatch::set_stage`, then
//! `EnvBatch::rotate_scenes` streams in scenes generated at the new
//! difficulty).

use crate::metrics::Window;

/// The scheduler (see module docs).
#[derive(Debug)]
pub struct Curriculum {
    stages: u32,
    stage: u32,
    success: Window,
    spl: Window,
    threshold: f32,
    /// Total episodes observed (all stages).
    episodes: u64,
    /// Episode count at each past advance (diagnostics + determinism
    /// assertions in tests).
    advanced_at: Vec<u64>,
}

impl Curriculum {
    /// `stages` from the scenario spec; `window` episodes of evidence per
    /// stage; advance when the windowed success rate reaches `threshold`.
    pub fn new(stages: u32, window: usize, threshold: f32) -> Curriculum {
        let window = window.max(1);
        Curriculum {
            stages: stages.max(1),
            stage: 0,
            success: Window::new(window),
            spl: Window::new(window),
            threshold: threshold.clamp(0.0, 1.0),
            episodes: 0,
            advanced_at: Vec::new(),
        }
    }

    pub fn stage(&self) -> u32 {
        self.stage
    }

    pub fn num_stages(&self) -> u32 {
        self.stages
    }

    pub fn episodes(&self) -> u64 {
        self.episodes
    }

    /// Windowed success rate at the current stage (0 until evidence).
    pub fn success_rate(&self) -> f32 {
        self.success.mean()
    }

    /// Windowed mean SPL at the current stage.
    pub fn mean_spl(&self) -> f32 {
        self.spl.mean()
    }

    /// Episode counts at which past advances happened.
    pub fn advanced_at(&self) -> &[u64] {
        &self.advanced_at
    }

    /// Feed one batch step's outcome (the `StepView` outcome arrays).
    pub fn observe(&mut self, dones: &[bool], successes: &[bool], spl: &[f32]) {
        for ((&done, &success), &spl) in dones.iter().zip(successes).zip(spl) {
            if done {
                self.episodes += 1;
                self.success.push(if success { 1.0 } else { 0.0 });
                self.spl.push(spl);
            }
        }
    }

    /// The advance rule, evaluated once per training iteration: a full
    /// window at or above the threshold moves to the next stage (and
    /// clears the windows). Returns the new stage when it advanced.
    pub fn advance_if_ready(&mut self) -> Option<u32> {
        if self.stage + 1 >= self.stages {
            return None; // already at the hardest stage
        }
        if !self.success.is_full() || self.success.mean() < self.threshold {
            return None;
        }
        self.stage += 1;
        self.success.clear();
        self.spl.clear();
        self.advanced_at.push(self.episodes);
        Some(self.stage)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed(cur: &mut Curriculum, episodes: usize, success: bool) {
        for _ in 0..episodes {
            cur.observe(&[true], &[success], &[if success { 0.8 } else { 0.0 }]);
        }
    }

    #[test]
    fn advances_on_full_window_above_threshold() {
        let mut cur = Curriculum::new(3, 4, 0.75);
        assert_eq!(cur.stage(), 0);
        feed(&mut cur, 3, true);
        assert_eq!(cur.advance_if_ready(), None, "window not full yet");
        feed(&mut cur, 1, true);
        assert_eq!(cur.advance_if_ready(), Some(1));
        assert_eq!(cur.advanced_at(), &[4]);
        // windows cleared: stage 1 needs its own evidence
        assert_eq!(cur.advance_if_ready(), None);
        assert_eq!(cur.success_rate(), 0.0);
    }

    #[test]
    fn failures_hold_the_stage() {
        let mut cur = Curriculum::new(2, 4, 0.75);
        feed(&mut cur, 2, true);
        feed(&mut cur, 2, false); // 50% < 75%
        assert_eq!(cur.advance_if_ready(), None);
        // the sliding window recovers as successes displace failures
        feed(&mut cur, 4, true);
        assert_eq!(cur.advance_if_ready(), Some(1));
    }

    #[test]
    fn never_advances_past_last_stage() {
        let mut cur = Curriculum::new(2, 2, 0.5);
        feed(&mut cur, 2, true);
        assert_eq!(cur.advance_if_ready(), Some(1));
        feed(&mut cur, 8, true);
        assert_eq!(cur.advance_if_ready(), None);
        assert_eq!(cur.stage(), 1);
        // single-stage curricula never move at all
        let mut flat = Curriculum::new(1, 1, 0.0);
        feed(&mut flat, 4, true);
        assert_eq!(flat.advance_if_ready(), None);
    }

    #[test]
    fn deterministic_given_equal_outcome_streams() {
        let run = || {
            let mut cur = Curriculum::new(4, 3, 0.6);
            let mut stages = Vec::new();
            for e in 0..40u64 {
                let ok = e % 4 != 0; // 75% success pattern
                cur.observe(&[true, false], &[ok, false], &[0.5, 0.0]);
                if let Some(s) = cur.advance_if_ready() {
                    stages.push((e, s));
                }
            }
            (stages, cur.episodes(), cur.advanced_at().to_vec())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn spl_window_tracks_current_stage() {
        let mut cur = Curriculum::new(2, 2, 0.9);
        cur.observe(&[true], &[true], &[0.6]);
        cur.observe(&[false], &[false], &[0.0]); // not done: ignored
        cur.observe(&[true], &[true], &[1.0]);
        assert!((cur.mean_spl() - 0.8).abs() < 1e-6);
        assert_eq!(cur.episodes(), 2);
    }
}
