//! Shared harness for the paper-table benches (`rust/benches/*`) and the
//! examples: dataset caching, coordinator construction from a named system
//! row (BPS / BPS-R50 / WIJMANS++ / WIJMANS20 — Table 1), and FPS
//! measurement per the paper's methodology (§4.1: samples of experience
//! over rollout-generation + training wall time).

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::config::{Config, SimArch};
use crate::coordinator::Coordinator;
use crate::scene::{generate_dataset, Complexity, Dataset};
use crate::sim::Task;

/// Generate (once) and return a cached benchmark dataset directory.
pub fn ensure_dataset(complexity: &str, n_train: usize) -> Result<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("datasets")
        .join(format!("bench_{complexity}"));
    if !dir.join("splits.json").exists() {
        let cx = match complexity {
            "gibson" => Complexity::gibson_like(),
            "thor" => Complexity::thor_like(),
            _ => Complexity::test(),
        };
        eprintln!("generating bench dataset {dir:?} ...");
        generate_dataset(&dir, n_train, 2, 2, cx, 2024)?;
    }
    Ok(dir)
}

pub fn dataset(complexity: &str) -> Result<Dataset> {
    Dataset::open(&ensure_dataset(complexity, 8)?)
}

/// One row of Table 1: a named system configuration.
#[derive(Clone, Debug)]
pub struct SystemRow {
    pub system: &'static str,
    pub cnn: &'static str,
    pub res: usize,
    pub cfg: Config,
}

/// Build the Table 1 system rows for one sensor ("depth" | "rgb").
///
/// CPU-scaled mapping of the paper's Table A5 (documented in DESIGN.md §1):
/// env counts / rollout lengths are set to the exported artifact geometry;
/// WIJMANS20 renders at 2x and downsamples and runs 2 PPO epochs, exactly
/// as in the paper's configuration.
pub fn table1_rows(sensor: &str, shards: usize) -> Vec<SystemRow> {
    let rgb = sensor == "rgb";
    let mk = |variant: &str,
              arch: SimArch,
              n: usize,
              l: usize,
              mb: usize,
              epochs: usize,
              scale: usize| Config {
        variant: variant.to_string(),
        arch,
        num_envs: n,
        rollout_len: l,
        num_minibatches: mb,
        ppo_epochs: epochs,
        shards,
        k_scenes: 4,
        render_scale: scale,
        complexity: "gibson".into(),
        memory_budget_mb: 16 * 1024,
        total_frames: u64::MAX, // bench loops control iteration count
        ..Config::default()
    };
    let se9 = if rgb { "rgb64" } else { "depth64" };
    let r50 = if rgb { "r50_rgb128" } else { "r50_depth128" };
    vec![
        SystemRow {
            system: "BPS",
            cnn: "SE-ResNet9",
            res: 64,
            cfg: mk(se9, SimArch::Bps, 64, 32, 2, 1, 1),
        },
        SystemRow {
            system: "BPS-R50",
            cnn: "ResNet50",
            res: 128,
            cfg: mk(r50, SimArch::Bps, 16, 16, 4, 1, 2),
        },
        SystemRow {
            system: "WIJMANS++",
            cnn: "SE-ResNet9",
            res: 64,
            cfg: mk(se9, SimArch::Workers, 16, 16, 2, 1, 1),
        },
        SystemRow {
            system: "WIJMANS20",
            cnn: "ResNet50",
            res: 128,
            cfg: mk(r50, SimArch::Workers, 16, 16, 4, 2, 2),
        },
    ]
}

/// Measured result of running a system row.
#[derive(Clone, Copy, Debug)]
pub struct FpsResult {
    pub fps: f64,
    pub frames: u64,
    /// µs/frame: (simulation+rendering, inference, learning)
    pub breakdown: (f64, f64, f64),
    /// µs/frame inside the renderer, worker-summed:
    /// (transform, cull, raster, resolve)
    pub render_stages: (f64, f64, f64, f64),
}

/// Run `iters` training iterations (after `warmup`) and report FPS +
/// the Fig. 5 / Table A2 runtime breakdown.
pub fn measure_fps(mut cfg: Config, dataset_dir: &Path, warmup: usize, iters: usize)
    -> Result<FpsResult> {
    cfg.dataset_dir = dataset_dir.to_path_buf();
    let mut coord = Coordinator::new(cfg)?;
    for _ in 0..warmup {
        coord.train_iteration()?;
    }
    coord.prof.reset();
    let t0 = std::time::Instant::now();
    let mut frames = 0u64;
    for _ in 0..iters {
        frames += coord.train_iteration()?.frames;
    }
    let secs = t0.elapsed().as_secs_f64();
    let rows = coord.prof.breakdown(frames);
    let get = |k: &str| {
        rows.iter()
            .find(|(n, _)| n == k)
            .map(|(_, v)| *v)
            .unwrap_or(0.0)
    };
    Ok(FpsResult {
        fps: frames as f64 / secs,
        frames,
        breakdown: (get("sim") + get("render"), get("inference"), get("learn")),
        render_stages: (
            get("render.transform"),
            get("render.cull"),
            get("render.raster"),
            get("render.resolve"),
        ),
    })
}

/// Task-specific config for the Flee/Explore rows (Table A3): thor-like
/// scenes, depth sensor.
pub fn taskrow_config(task: Task) -> Config {
    Config {
        variant: "depth64".into(),
        task,
        num_envs: 64,
        rollout_len: 32,
        num_minibatches: 2,
        k_scenes: 4,
        complexity: "thor".into(),
        memory_budget_mb: 16 * 1024,
        total_frames: u64::MAX,
        ..Config::default()
    }
}

/// Bench iteration counts, overridable: BPS_BENCH_ITERS=warmup,measure
pub fn bench_iters(default_warmup: usize, default_iters: usize) -> (usize, usize) {
    if let Ok(s) = std::env::var("BPS_BENCH_ITERS") {
        if let Some((w, i)) = s.split_once(',') {
            if let (Ok(w), Ok(i)) = (w.parse(), i.parse()) {
                return (w, i);
            }
        }
    }
    (default_warmup, default_iters)
}

/// True when the manifest has this variant (benches skip gracefully).
pub fn have_variant(name: &str) -> bool {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    crate::runtime::Manifest::load(&dir)
        .map(|m| m.variants.contains_key(name))
        .unwrap_or(false)
}

pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Heavy rows (ResNet50 / 128px render-at-256) only run when
/// BPS_BENCH_FULL=1 — on small CPU testbeds they dominate bench time.
pub fn bench_full() -> bool {
    std::env::var("BPS_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// One measured renderer configuration — shared by `bench_render` and the
/// `bps bench` subcommand so the human-readable and machine-readable
/// reports can never diverge on what they measure.
#[derive(Clone, Copy, Debug)]
pub struct RenderBenchResult {
    pub fps: f64,
    pub p50_ms: f32,
    pub p95_ms: f32,
    pub tris_per_s: f64,
    /// µs/frame per stage (worker-summed): transform, cull, raster, resolve.
    pub stage_us: [f64; 4],
    pub cull_pct: f64,
}

/// Measure one renderer configuration: warm up, drain the reset-on-read
/// counters, then time `reps` megaframes (per-rep latency feeds p50/p95).
pub fn measure_render(
    renderer: &crate::render::BatchRenderer,
    pool: &crate::util::pool::WorkerPool,
    items: &[crate::render::RenderItem],
    obs: &mut [f32],
    warmup: usize,
    reps: usize,
) -> RenderBenchResult {
    use crate::metrics::Window;
    let reps = reps.max(1);
    // warmup = 0 is honored: cold first-megaframe latency is measurable
    for _ in 0..warmup {
        renderer.render_batch(pool, items, obs);
    }
    let _ = renderer.take_stats(); // reset-on-read: drop warmup counters
    let mut lat = Window::new(reps);
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        let t = std::time::Instant::now();
        renderer.render_batch(pool, items, obs);
        lat.push(t.elapsed().as_secs_f32() * 1e3);
    }
    let secs = t0.elapsed().as_secs_f64();
    let st = renderer.take_stats();
    let frames = (items.len() * reps) as f64;
    let us = |ns: u64| ns as f64 / 1e3 / frames;
    let [p50_ms, p95_ms] = lat.percentiles([0.5, 0.95]);
    RenderBenchResult {
        fps: frames / secs,
        p50_ms,
        p95_ms,
        tris_per_s: st.tris_rasterized as f64 / secs,
        stage_us: [
            us(st.transform_ns),
            us(st.cull_ns),
            us(st.raster_ns),
            us(st.resolve_ns),
        ],
        cull_pct: 100.0 * st.chunks_culled as f64 / st.chunks_total.max(1) as f64,
    }
}

/// Quick mode (BPS_BENCH_QUICK=1): benches shrink to CI-smoke size —
/// test-complexity scenes, small batches, a couple of reps.
pub fn bench_quick() -> bool {
    std::env::var("BPS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Append `record` to a JSON-array benchmark trajectory file (e.g.
/// `BENCH_render.json`), creating it when missing. Each record is one
/// measured configuration; the array accumulates the perf trajectory
/// across PRs.
pub fn append_bench_record(path: &Path, record: crate::util::json::Json) -> Result<()> {
    use crate::util::json::Json;
    let mut arr = match std::fs::read_to_string(path) {
        Ok(text) if !text.trim().is_empty() => match Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?
        {
            Json::Arr(v) => v,
            other => vec![other],
        },
        _ => Vec::new(),
    };
    arr.push(record);
    let mut text = Json::Arr(arr).to_string();
    text.push('\n');
    std::fs::write(path, text).map_err(|e| anyhow::anyhow!("write {path:?}: {e}"))?;
    Ok(())
}
