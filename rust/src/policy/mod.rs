//! Policy inference on the rollout hot path: batched forward through the
//! AOT `infer` executable, recurrent state ownership, and categorical
//! action sampling (sampling stays in Rust so the artifacts are pure
//! functions and the whole system is reproducible from one seed).

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::runtime::{lit_f32, to_f32, Exec, Manifest, Runtime, Variant};
use crate::util::rng::Rng;

/// Argmax over one row of action logits. Factored out so the greedy
/// eval loop and the serve-tenant driver pick bitwise-identical actions
/// from identical logits (ties and NaN handling included).
pub fn argmax_action(row: &[f32]) -> u8 {
    row.iter()
        .enumerate()
        .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
        .map(|(k, _)| k as u8)
        .unwrap_or(0)
}

/// Batched recurrent policy bound to one `infer_n{N}` executable.
pub struct Policy {
    infer: Rc<Exec>,
    pub n: usize,
    pub res: usize,
    pub in_ch: usize,
    pub hidden: usize,
    pub num_actions: usize,
    num_params: usize,
    /// Recurrent state, owned here ([N, hidden] each).
    pub h: Vec<f32>,
    pub c: Vec<f32>,
    rng: Rng,
}

/// Outputs of one batched inference step.
pub struct PolicyStep {
    pub actions: Vec<u8>,
    pub logp: Vec<f32>,
    pub values: Vec<f32>,
}

impl Policy {
    pub fn new(
        rt: &Runtime,
        man: &Manifest,
        variant: &Variant,
        n: usize,
        seed: u64,
    ) -> Result<Policy> {
        if !variant.infer_ns.contains(&n) {
            bail!(
                "no infer artifact for N={n} in variant {:?} (exported: {:?}); \
                 add it to the preset in python/compile/aot.py and re-run make artifacts",
                variant.name,
                variant.infer_ns
            );
        }
        let infer = Rc::new(rt.load(&man.artifact_path(variant, &format!("infer_n{n}"))?)?);
        Ok(Policy::with_exec(infer, variant, n, seed))
    }

    /// Build from an already-compiled executable (shared across shards —
    /// compiling once and sharing matters when S x compile time adds up).
    pub fn with_exec(infer: Rc<Exec>, variant: &Variant, n: usize, seed: u64) -> Policy {
        Policy {
            infer,
            n,
            res: variant.res,
            in_ch: variant.in_ch,
            hidden: variant.hidden,
            num_actions: variant.num_actions,
            num_params: variant.num_params,
            h: vec![0.0; n * variant.hidden],
            c: vec![0.0; n * variant.hidden],
            rng: Rng::new(seed),
        }
    }

    fn forward(
        &self,
        params: &[f32],
        obs: &[f32],
        goal: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let n = self.n as i64;
        let out = self.infer.run(&[
            lit_f32(params, &[self.num_params as i64])?,
            lit_f32(obs, &[n, self.res as i64, self.res as i64, self.in_ch as i64])?,
            lit_f32(goal, &[n, 3])?,
            lit_f32(&self.h, &[n, self.hidden as i64])?,
            lit_f32(&self.c, &[n, self.hidden as i64])?,
        ])?;
        Ok((
            to_f32(&out[0])?,
            to_f32(&out[1])?,
            to_f32(&out[2])?,
            to_f32(&out[3])?,
        ))
    }

    /// Sampled step (training rollouts): advances the recurrent state and
    /// samples actions from the categorical policy.
    pub fn step(&mut self, params: &[f32], obs: &[f32], goal: &[f32]) -> Result<PolicyStep> {
        let (logits, values, h2, c2) = self.forward(params, obs, goal)?;
        self.h = h2;
        self.c = c2;
        let a = self.num_actions;
        let mut actions = Vec::with_capacity(self.n);
        let mut logp = Vec::with_capacity(self.n);
        for i in 0..self.n {
            let (act, lp) = self.rng.categorical(&logits[i * a..(i + 1) * a]);
            actions.push(act as u8);
            logp.push(lp);
        }
        Ok(PolicyStep {
            actions,
            logp,
            values,
        })
    }

    /// Greedy step (evaluation): argmax actions, recurrent state advances.
    pub fn step_greedy(&mut self, params: &[f32], obs: &[f32], goal: &[f32]) -> Result<Vec<u8>> {
        let logits = self.logits_step(params, obs, goal)?;
        let a = self.num_actions;
        Ok((0..self.n)
            .map(|i| argmax_action(&logits[i * a..(i + 1) * a]))
            .collect())
    }

    /// Forward with recurrent-state advance, returning the raw logits.
    /// The serve-tenant driver selects from these per tenant (each
    /// tenant samples on its own RNG stream, so co-tenancy never
    /// perturbs a tenant's action sequence).
    pub fn logits_step(&mut self, params: &[f32], obs: &[f32], goal: &[f32]) -> Result<Vec<f32>> {
        let (logits, _, h2, c2) = self.forward(params, obs, goal)?;
        self.h = h2;
        self.c = c2;
        Ok(logits)
    }

    /// Value estimate WITHOUT advancing the recurrent state (rollout
    /// bootstrap at step L).
    pub fn values_only(&self, params: &[f32], obs: &[f32], goal: &[f32]) -> Result<Vec<f32>> {
        let (_, values, _, _) = self.forward(params, obs, goal)?;
        Ok(values)
    }

    /// Zero the recurrent state of environments whose episode ended.
    pub fn reset_done(&mut self, dones: &[bool]) {
        for (i, &d) in dones.iter().enumerate() {
            if d {
                self.h[i * self.hidden..(i + 1) * self.hidden].fill(0.0);
                self.c[i * self.hidden..(i + 1) * self.hidden].fill(0.0);
            }
        }
    }
}
