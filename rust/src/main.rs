//! `bps` — the launcher CLI for the Batch Processing Simulator.
//!
//! Subcommands:
//!   gen-dataset    generate a procedural scene dataset with splits
//!   train          end-to-end RL training (paper Fig. 2 loop)
//!   eval           evaluate a checkpoint on a dataset split
//!   serve          front a SimServer with the TCP wire transport
//!   connect        remote demo client for a `bps serve` server
//!   stats          scrape a `bps serve` server's metrics registry over
//!                  the wire (STATS frame) and print the Prometheus text
//!   trace          run an in-process serve pipeline with tracing on and
//!                  write a Chrome trace_event JSON (chrome://tracing)
//!   agent          remote policy-tenant client: lease slots + a
//!                  server-side policy, post a goal, stream trajectories
//!   serve-demo     multi-client serving demo over the SimServer layer
//!   scenario-demo  scenario engine demo: streaming procgen + curriculum
//!   bench          standalone batch-renderer benchmark (--json appends the
//!                  machine-readable perf trajectory to BENCH_render.json)
//!   lint           static analysis: enforce the repo's concurrency
//!                  invariants (SAFETY comments, lock discipline, thread
//!                  hygiene, wire-protocol drift — DESIGN.md §0.13)
//!   info           print manifest / artifact information
//!   help           describe the batched environment API + all options
//!
//! Training and eval drive environments through the `bps::env` batched
//! request/response API: each shard is an `EnvBatch` the coordinator
//! steps with `submit(actions) → StepHandle::wait() → StepView`, with
//! simulation+rendering of the next step double-buffered against the
//! caller (disable with `--overlap false`).

use std::path::PathBuf;

use anyhow::{bail, Result};

use bps::config::Config;
use bps::coordinator::Coordinator;
use bps::metrics::CsvLogger;
use bps::runtime::{Manifest, ParamStore};
use bps::scene::{generate_dataset, Complexity};
use bps::util::args::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut args = Args::from_env()?;
    if args.flag("help")? {
        print_help();
        return Ok(());
    }
    // Only serve/connect/agent/stats take a positional operand (the
    // address); every other subcommand rejects strays up front — `bps
    // train cfg.toml` must fail immediately, not after a defaults-run
    // finishes.
    if !matches!(
        args.subcommand.as_deref(),
        Some("serve") | Some("connect") | Some("agent") | Some("stats")
    ) {
        args.ensure_no_operands()?;
    }
    let result = match args.subcommand.as_deref() {
        Some("gen-dataset") => gen_dataset(&mut args),
        Some("train") => train(&mut args),
        Some("eval") => eval(&mut args),
        Some("serve") => serve(&mut args),
        Some("connect") => connect(&mut args),
        Some("agent") => agent(&mut args),
        Some("stats") => stats(&mut args),
        Some("trace") => trace_cmd(&mut args),
        Some("serve-demo") => serve_demo(&mut args),
        Some("scenario-demo") => scenario_demo(&mut args),
        Some("bench") => bench(&mut args),
        Some("lint") => lint_cmd(&mut args),
        Some("info") => info(&mut args),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        other => {
            bail!(
                "unknown subcommand {other:?}\n\
                 usage: bps <gen-dataset|train|eval|serve|connect|agent|stats|trace|\
                 serve-demo|scenario-demo|bench|lint|info|help> [--key value ...]"
            )
        }
    };
    // Subcommands consume their operands (serve/connect take an address);
    // anything left over is a typo, rejected like before operands existed.
    result.and_then(|()| args.ensure_no_operands())
}

fn print_help() {
    println!(
        "\
bps — Batch Processing Simulator (Large Batch Simulation for Deep RL)

USAGE:  bps <subcommand> [--key value | --key=value | --flag] ...

SUBCOMMANDS
  gen-dataset  generate a procedural scene dataset with train/val/test splits
               (--dir PATH --train N --val N --test N --complexity gibson|thor|test --seed S)
  train        end-to-end RL training, the paper's Fig. 2 loop
               (--config cfg.toml --curve out.csv --checkpoint-out ckpt.bin --log-every K
                --event-log FILE  curriculum stage advances as JSONL
                --metrics-addr A  scrape endpoint over the run's registry
                (train.frames/fps/reward_mean/success_mean gauges)
                --trace-out FILE  per-iteration spans as Chrome trace JSON)
  eval         greedy evaluation on a dataset split
               (--checkpoint ckpt.bin --split val --episodes N)
  serve        front a SimServer with the TCP wire transport
               (bps::serve::wire, DESIGN.md §0.8) so remote processes can
               lease env slots: bps serve --listen 127.0.0.1:7447
               (--shards S --slots N --res R --task NAME --seed S
                --straggler noop|repeat|wait --deadline-ticks K
                --threads T --mem-budget MB --outbox FRAMES  per-conn
                outbox bound before the slow-reader disconnect fires
                --inbox SUBMITS  per-session submit queue bound before
                the flood shed fires (ERR_RETRY_AFTER, not a disconnect)
                --idle-timeout SECS  reap connections idle this long,
                releasing their leases (0 = never, the default)
                --park-ttl SECS  park dropped connections' env sessions
                for resume (RESUME frame / bps connect --retries) instead
                of releasing their leases immediately (0 = off)
                --fault SPEC  arm the fault-injection plane, e.g.
                conn_drop:p=0.01,panic:shard=0,delay_write:ms=50,
                corrupt:every=100,stall:role=NAME,seed=N (also via the
                BPS_FAULT env var; BPS_FAULT_STALL=role[,role...] folds
                in as stall clauses)
                --heal-ms MS  self-heal loop: restart quarantined shards
                in place every MS milliseconds (0 = off)
                --artifacts-dir PATH --checkpoint CKPT --policy-seed S
                with AOT artifacts present, also serve *policies*: agents
                lease slots + a server-side checkpoint (bps agent below)
                --metrics-addr A  plaintext scrape endpoint: GET /metrics
                serves the registry's Prometheus text; /healthz answers
                real watchdog readiness (503 + the stalled role while any
                registered thread is stalled); GET /debug/dump triggers a
                flight-recorder bundle when --dump-dir is set
                --dump-dir DIR  arm the flight recorder: stalls, slow
                ticks, panics, and manual dumps write incident bundles
                (metrics + trace + event tail + watchdog + sessions)
                under DIR, rate-limited and retention-capped
                --trace-out FILE  record per-tick pipeline spans and write
                Chrome trace_event JSON on clean shutdown (--once runs)
                --event-log FILE  append lifecycle events as JSONL
                (lease grant/release, idle reap, slow-reader disconnect,
                bad submits, error frames), rotating at --event-log-bytes
                (default 8 MiB)
                --stats-every SECS --once  exit once every accepted
                connection has closed (at least one), for smoke tests)
  connect      remote demo client: lease slots on a `bps serve` server,
               drive them with a scripted policy, report FPS + latency
               p50/p95: bps connect 127.0.0.1:7447 --task pointnav
               (--addr A --task NAME --envs N --steps T
                --retries N  resume dropped connections with capped
                exponential backoff, up to N attempts per drop; the end
                summary reports resumes=N backoff_ms_total=M)
  agent        remote policy-tenant client: lease slots *plus* a
               server-side policy, post a goal, and stream the
               server-driven trajectory back (obs/action/reward/done per
               step): bps agent 127.0.0.1:7447 --envs 4 --steps 64
               (--addr A --task NAME --envs N --steps T --variant NAME
                --sample --seed S  sample actions instead of greedy
                --retries N  reconnect/backoff budget, summary as connect)
  stats        scrape a `bps serve` server's metrics over the wire (the
               STATS frame) and print the Prometheus text — byte-identical
               to the server's own /metrics endpoint:
               bps stats 127.0.0.1:7447  (--addr A)
               --dump  trigger a flight-recorder incident bundle instead
               and print its server-local path (needs serve --dump-dir)
  trace        run an in-process serve pipeline with span tracing enabled
               and write Chrome trace_event JSON for chrome://tracing or
               Perfetto (--out trace.json --steps T --envs N --res R
                --task NAME --seed S --threads T)
  serve-demo   drive M concurrent synthetic clients through the SimServer
               multi-tenant serving layer (bps::serve) and report aggregate
               FPS, occupancy, and per-client step-latency p50/p95
               (--clients M --envs-per-client E --steps T --shards S
                --task NAME --res R --straggler wait|noop|repeat
                --deadline-ticks K --threads T --seed S --rotate-every K
                --mem-budget MB  admission-control budget, 0 = unlimited)
  scenario-demo drive the scenario engine (bps::scenario) with a scripted
               GPS+compass policy: scenes stream from procgen ahead of
               demand and a success-driven curriculum advances difficulty
               (--scenario SPEC|NAME --scenario-dir DIR --envs N --steps T
                --k K --prefetch P --rotate-every K --res R --seed S
                --threads T --window E --threshold F --event-log FILE --list)
  bench        standalone batch-renderer benchmark across pipeline modes
               and sensors: FPS, p50/p95 megaframe latency, triangle
               throughput, and the per-stage breakdown (transform / cull /
               raster / resolve). --json appends one record per measured
               configuration to a JSON-array trajectory file, so renderer
               perf is tracked across PRs
               (--complexity gibson|thor|test --n N --res R --warmup W
                --reps K --threads T --json --out BENCH_render.json;
                BPS_BENCH_QUICK=1 shrinks everything to CI-smoke size)
  lint         static analysis over rust/src: enforce the concurrency
               invariants of DESIGN.md §0.13 with stable rule IDs —
               L001 unsafe needs // SAFETY:, L002 control-flow Relaxed
               needs // relaxed:, L003 serve lock discipline, L004
               thread naming + watchdog heartbeats, L005 wire-protocol /
               DESIGN.md drift. Exits nonzero on any violation; scoped
               escapes via `// bps-lint: allow(L00X, reason)`
               (--root DIR  repo root, default: nearest ancestor with
                rust/src; --json  machine-readable report)
  info         print the AOT artifact manifest (--artifacts-dir PATH)
  help         this text

SCENARIO SPECS
  A scenario declares what world every environment runs: task, a
  *distribution* over scene complexity (ranges, not points), episode
  constraints, and domain-randomization knobs. Inline spec strings are
  key=value tokens; names resolve to <scenario-dir>/<name>.scenario:
    --scenario \"name=maze task=pointnav tris=20k..80k stages=3
                extent=8..14 clutter=0..6 mats=2..8 tex=64
                light=0.5..1.5 min-geo=2 max-steps=400\"
  With stages=S, difficulty stage s samples the [s/S, (s+1)/S] band of
  every range; the curriculum advances stages when the windowed success
  rate clears --curriculum-threshold. Scenes are synthesized ahead of
  demand on the worker pool (bounded prefetch queue), so scene rotation
  never blocks on procgen.

ENVIRONMENT API
  Training and eval step environments through the batched request/response
  surface in bps::env (the paper's core design): the coordinator builds one
  EnvBatch per shard via EnvBatchConfig, submits a batch of actions with
  EnvBatch::submit, and receives the next observations / rewards / dones as
  borrowed SoA slices from StepHandle::wait. The EnvBatch owns the batch
  simulator, batch renderer and scene rotation, and double-buffers so
  simulation+rendering of step t+1 overlaps consumption of step t.

  Multi-client traffic goes through bps::serve (see serve-demo): a
  SimServer owns N EnvBatch shards sharing one worker pool; clients
  connect(task, n_envs) to lease env slots, submit partial action
  batches, and wait on tickets for their slice of each coalesced batch
  step — so one EnvBatch step serves many tenants and the paper's
  amortization survives multi-tenancy. Remote processes reach the same
  surface over TCP via `bps serve` / `bps connect` (bps::serve::wire):
  RemoteSession speaks the identical submit -> wait -> view cycle with
  bitwise-identical observation streams.

SHARED TRAINING OPTIONS (CLI overrides the TOML config)
  --variant NAME        AOT model variant (depth64, rgb64, r50_depth128, ...)
  --artifacts-dir PATH  AOT artifact directory        --dataset PATH  scene dataset
  --arch bps|workers    simulation architecture (Table 1 rows)
  --pipeline fused|pipelined   renderer culling/raster pipeline mode
  --overlap true|false  double-buffered pipelined env stepping (default true;
                        false = synchronous — bitwise-identical rollouts when
                        the scene-rotation schedule matches)
  --rotate-every K      pin the scene-rotation schedule: one blocking slot
                        swap every K training iterations instead of the
                        wall-clock prefetch poll, so pipelined-vs-sync A/B
                        runs are exactly reproducible (0 = off, the default)
  --envs N --rollout-len L --minibatches M --ppo-epochs E --shards S
  --k-scenes K          resident scene slots (N:K <= 32 sharing cap)
  --task NAME           pointnav | flee | explore
  --tasks a,b,...       heterogeneous per-shard tasks, round-robin over shards
  --optimizer lamb|adam --lr X --lr-scaling BOOL --gamma X --gae-lambda X
  --normalize-adv BOOL  --frames N --seed S --threads T --out DIR
  --render-scale K      supersampling factor   --memory-mb MB  accelerator budget
  --scenario SPEC|NAME  run the scenario engine instead of a dataset (above)
  --scenario-dir DIR    .scenario registry (default scenarios/)
  --prefetch P          scenario prefetch-queue depth (default 2)
  --curriculum-window E --curriculum-threshold F   stage-advance rule"
    );
}

fn gen_dataset(args: &mut Args) -> Result<()> {
    let dir = PathBuf::from(args.opt_or("dir", "datasets/gibson_like"));
    let n_train = args.usize_or("train", 12)?;
    let n_val = args.usize_or("val", 3)?;
    let n_test = args.usize_or("test", 3)?;
    let seed = args.u64_or("seed", 1)?;
    let cx = match args.opt_or("complexity", "gibson").as_str() {
        "gibson" => Complexity::gibson_like(),
        "thor" => Complexity::thor_like(),
        "test" => Complexity::test(),
        other => bail!("unknown complexity {other:?}"),
    };
    println!("generating {n_train}+{n_val}+{n_test} scenes into {dir:?} ...");
    let t0 = std::time::Instant::now();
    let ds = generate_dataset(&dir, n_train, n_val, n_test, cx, seed)?;
    let sample = ds.load_scene(&ds.train[0], true)?;
    println!(
        "done in {:.1}s — sample scene: {} tris, {:.1} MB geometry, {:.1} MB textures, \
         {:.0} m^2 navigable",
        t0.elapsed().as_secs_f64(),
        sample.mesh.num_tris(),
        sample.geometry_bytes() as f64 / 1e6,
        sample.texture_bytes() as f64 / 1e6,
        sample.navmesh.area(),
    );
    Ok(())
}

fn train(args: &mut Args) -> Result<()> {
    let cfg_path = args.opt("config").map(PathBuf::from);
    let curve_path = args.opt("curve").map(PathBuf::from);
    let ckpt_out = args.opt("checkpoint-out").map(PathBuf::from);
    let log_every = args.usize_or("log-every", 5)?;
    let event_log = args.opt("event-log").map(PathBuf::from);
    let metrics_addr = args.opt("metrics-addr");
    let trace_out = args.opt("trace-out").map(PathBuf::from);
    let cfg = Config::load(cfg_path.as_deref(), args)?;
    println!(
        "training: variant={} arch={:?} N={} L={} shards={} optimizer={} frames={}",
        cfg.variant,
        cfg.arch,
        cfg.num_envs,
        cfg.rollout_len,
        cfg.shards,
        cfg.optimizer,
        cfg.total_frames
    );
    let mut coord = Coordinator::new(cfg)?;
    if let Some(p) = &event_log {
        // Lifecycle events (curriculum stage advances) as size-capped JSONL.
        coord.events.arm(p, bps::obs::DEFAULT_EVENT_LOG_BYTES)?;
    }
    if trace_out.is_some() {
        coord.trace.enable();
    }
    // Scrape surface for long runs: the listener holds the registry for
    // the whole loop and drops with this binding at fn exit.
    let _metrics = match &metrics_addr {
        Some(a) => {
            let m = bps::obs::MetricsServer::listen(a.as_str(), coord.registry.clone())?;
            println!("metrics: http://{}/metrics", m.local_addr());
            Some(m)
        }
        None => None,
    };
    let train_gauges = (
        coord.registry.gauge("train.frames", &[]),
        coord.registry.gauge("train.fps", &[]),
        coord.registry.gauge("train.reward_mean", &[]),
        coord.registry.gauge("train.success_mean", &[]),
    );
    let mut curve = match &curve_path {
        Some(p) => Some(CsvLogger::create(
            p,
            "iter,frames,seconds,fps,reward,success,spl,policy_loss,value_loss,entropy,lr",
        )?),
        None => None,
    };
    let mut iter = 0u64;
    while coord.frames() < coord.cfg.total_frames {
        let iter_from = if coord.trace.enabled() {
            Some((coord.trace.now_us(), std::time::Instant::now()))
        } else {
            None
        };
        let it = coord.train_iteration()?;
        iter += 1;
        if let Some((from, at)) = iter_from {
            coord.trace.span(0, "train", "train.iteration", from, at.elapsed(), iter);
        }
        train_gauges.0.set(coord.frames() as f64);
        train_gauges.1.set(coord.fps());
        train_gauges.2.set(coord.stats.reward.mean() as f64);
        train_gauges.3.set(coord.stats.success.mean() as f64);
        if iter % log_every as u64 == 0 {
            let l = it.losses;
            let stage = if coord.cfg.scenario.is_some() {
                format!(" stage {:?}", coord.stages())
            } else {
                String::new()
            };
            println!(
                "iter {iter:>5} frames {:>9} fps {:>8.0} | reward {:+.3} success {:.2} \
                 spl {:.2} | pi {:+.4} v {:.4} H {:.3} lr {:.2e} (eps {}){stage}",
                coord.frames(),
                coord.fps(),
                coord.stats.reward.mean(),
                coord.stats.success.mean(),
                coord.stats.spl.mean(),
                l.policy,
                l.value,
                l.entropy,
                l.lr,
                coord.stats.episodes,
            );
        }
        if let Some(c) = curve.as_mut() {
            let l = it.losses;
            c.row(&[
                iter as f64,
                coord.frames() as f64,
                coord.fps.elapsed().as_secs_f64(),
                coord.fps(),
                coord.stats.reward.mean() as f64,
                coord.stats.success.mean() as f64,
                coord.stats.spl.mean() as f64,
                l.policy as f64,
                l.value as f64,
                l.entropy as f64,
                l.lr as f64,
            ])?;
            // Rows buffer in-process now; land them at the log cadence so
            // a tail -f of the curve stays fresh without per-row syscalls.
            if iter % log_every as u64 == 0 {
                c.flush()?;
            }
        }
    }
    println!(
        "finished: {} frames in {:.1}s = {:.0} FPS (paper methodology)",
        coord.frames(),
        coord.fps.elapsed().as_secs_f64(),
        coord.fps()
    );
    for (name, us) in coord.prof.breakdown(coord.frames()) {
        println!("  {name:<10} {us:>9.1} us/frame");
    }
    if let Some(p) = &trace_out {
        let spans = coord.trace.spans().len();
        std::fs::write(p, coord.trace.to_chrome_json())?;
        println!("trace: {spans} spans -> {}", p.display());
    }
    if let Some(p) = ckpt_out {
        coord.params.save(&p)?;
        println!("checkpoint saved to {p:?}");
    }
    Ok(())
}

fn eval(args: &mut Args) -> Result<()> {
    let cfg_path = args.opt("config").map(PathBuf::from);
    let ckpt = args.opt("checkpoint").map(PathBuf::from);
    let split = args.opt_or("split", "val");
    let episodes = args.usize_or("episodes", 64)?;
    let cfg = Config::load(cfg_path.as_deref(), args)?;
    let mut coord = Coordinator::new(cfg)?;
    if let Some(p) = ckpt {
        coord.params = ParamStore::load(&p)?;
        println!("loaded checkpoint {p:?} (step {})", coord.params.step);
    }
    let (spl, success, score) = coord.evaluate(&split, episodes)?;
    println!(
        "{split}: SPL {:.1} Success {:.1} Score {:.2} over {episodes} episodes",
        spl * 100.0,
        success * 100.0,
        score
    );
    Ok(())
}

/// Print the serve-layer stats the wire front-end exposes: per-shard
/// rows (incl. `bad_submits`, the hostile-slot-index counter) and the
/// per-connection wire rows. The `--once` smoke job greps these.
fn print_serve_stats(server: &bps::serve::SimServer, conns: &[bps::serve::ConnStats]) {
    for (i, st) in server.stats().iter().enumerate() {
        println!(
            "shard {i}: task {:?} leased {}/{} steps {} straggler_fills={} bad_submits={} \
             latency p50 {:.2} ms p95 {:.2} ms",
            st.task,
            st.leased,
            st.slots,
            st.steps,
            st.straggler_fills,
            st.bad_submits,
            st.latency_p50 * 1e3,
            st.latency_p95 * 1e3
        );
        if let Some(t) = &st.tenant {
            println!(
                "  tenants {}: agent_steps {} infer_runs {} infer_batch {} idle_fills {} \
                 infer p50 {:.2} ms p95 {:.2} ms",
                t.tenants,
                t.agent_steps,
                t.infer_runs,
                t.infer_batch_size,
                t.idle_fills,
                t.infer_p50 * 1e3,
                t.infer_p95 * 1e3
            );
        }
    }
    let slow = server.slowest_sessions(8);
    if !slow.is_empty() {
        println!("slowest sessions (by max submit->result latency):");
        for s in &slow {
            println!(
                "  session {} shard {}: steps {} mean {:.2} ms max {:.2} ms",
                s.session,
                s.shard,
                s.steps,
                s.mean_us as f64 / 1e3,
                s.max_us as f64 / 1e3
            );
        }
    }
    for c in conns {
        println!(
            "conn {} {}: sessions {}/{} frames in/out {}/{} bytes in/out {}/{} bad_frames={}{}{}{}",
            c.id,
            c.peer,
            c.sessions_open,
            c.sessions_opened,
            c.frames_in,
            c.frames_out,
            c.bytes_in,
            c.bytes_out,
            c.bad_frames,
            if c.dropped_slow { " dropped-slow" } else { "" },
            if c.reaped { " reaped" } else { "" },
            if c.closed { " closed" } else { "" }
        );
    }
}

/// Front a `SimServer` with the TCP wire transport (`bps::serve::wire`):
/// remote processes lease env slots with `bps connect` and drive them
/// through the same coalesced batch steps as in-process tenants.
fn serve(args: &mut Args) -> Result<()> {
    use bps::env::EnvBatchConfig;
    use bps::render::RenderConfig;
    use bps::scene::procgen::{generate, Complexity};
    use bps::serve::{
        FillAction, PolicyVault, ShardSpec, SimServer, StragglerPolicy, WireConfig, WireServer,
    };
    use bps::sim::Task;
    use bps::util::pool::WorkerPool;
    use std::sync::Arc;

    let listen = args
        .operand()
        .or_else(|| args.opt("listen"))
        .unwrap_or_else(|| "127.0.0.1:7447".into());
    args.ensure_no_operands()?; // a second address is a typo; fail now
    let shards = args.usize_or("shards", 1)?.max(1);
    let slots = args.usize_or("slots", 16)?.max(1);
    let res = args.usize_or("res", 32)?.max(4);
    let seed = args.u64_or("seed", 7)?;
    let threads = args.usize_or("threads", 0)?;
    let ticks = args.usize_or("deadline-ticks", 2)? as u32;
    let outbox = args.usize_or("outbox", 256)?.max(1);
    let inbox = args.usize_or("inbox", 64)?.max(1);
    let idle_timeout = args.f64_or("idle-timeout", 0.0)?.max(0.0);
    let park_ttl = args.f64_or("park-ttl", 0.0)?.max(0.0);
    let heal_ms = args.u64_or("heal-ms", 0)?;
    let fault_arg = args.opt("fault");
    let mem_budget_mb = args.usize_or("mem-budget", 0)?;
    let stats_every = args.f64_or("stats-every", 10.0)?.max(0.2);
    let once = args.flag("once")?;
    let metrics_addr = args.opt("metrics-addr");
    let trace_out = args.opt("trace-out").map(PathBuf::from);
    let event_log = args.opt("event-log").map(PathBuf::from);
    let event_log_bytes = args.u64_or("event-log-bytes", bps::obs::DEFAULT_EVENT_LOG_BYTES)?;
    let dump_dir = args.opt("dump-dir").map(PathBuf::from);
    let artifacts_dir = PathBuf::from(args.opt_or("artifacts-dir", "artifacts"));
    let checkpoint = args.opt("checkpoint").map(PathBuf::from);
    let policy_seed = args.u64_or("policy-seed", 1)?;
    let task = {
        let name = args.opt_or("task", "pointnav");
        Task::parse(&name).ok_or_else(|| anyhow::anyhow!("bad task {name:?}"))?
    };
    // Hardened default: deadline coalescing, so a remote tenant that
    // vanishes (or turns hostile) cannot stall its co-tenants the way a
    // silent `Wait` tenant would.
    let straggler = match args.opt_or("straggler", "noop").as_str() {
        "wait" => StragglerPolicy::Wait,
        "noop" => StragglerPolicy::Deadline {
            ticks,
            fill: FillAction::NoOp,
        },
        "repeat" => StragglerPolicy::Deadline {
            ticks,
            fill: FillAction::Repeat,
        },
        other => bail!("bad straggler policy {other:?} (wait|noop|repeat)"),
    };

    let scene = Arc::new(generate("serve_wire", seed, Complexity::test()));
    let pool = Arc::new(WorkerPool::new(if threads == 0 {
        WorkerPool::default_size()
    } else {
        threads
    }));
    let mut specs = Vec::with_capacity(shards);
    for s in 0..shards {
        let cfg = EnvBatchConfig::new(task, RenderConfig::depth(res))
            .seed(seed.wrapping_add(s as u64 * 7919));
        let scenes = (0..slots).map(|_| Arc::clone(&scene)).collect();
        specs.push(ShardSpec::with_scenes(cfg, scenes).straggler(straggler));
    }
    let budget = match mem_budget_mb {
        0 => None,
        mb => Some(mb * 1024 * 1024),
    };
    // Policy tenancy is gated on the AOT manifest exactly like the
    // coordinator's eval: without artifacts the server still serves
    // envs, but LEASE_POLICY requests are declined diagnosably.
    let vault = PolicyVault::open_if_present(&artifacts_dir, checkpoint, policy_seed)?;
    let vault_banner = vault.as_ref().map(|v| v.describe());
    let server = Arc::new(SimServer::with_vault(specs, pool, budget, vault)?);
    // Arm the obs sinks before the listener: the first connection's
    // lease events and spans must land, not race the setup.
    if let Some(p) = &event_log {
        server.events().arm(p, event_log_bytes)?;
        println!("event log: {} (rotating at {event_log_bytes} bytes)", p.display());
    }
    if trace_out.is_some() {
        server.trace().enable();
    }
    if let Some(dir) = &dump_dir {
        let rec = server.arm_recorder(dir)?;
        println!("flight recorder: {}", rec.dir().display());
        // Panic anywhere in the process snapshots an incident bundle
        // before the default hook prints the backtrace — the post-mortem
        // exists even if the process dies right after. Shard and tenant
        // driver panics are excluded: their supervisors quarantine and
        // cut a richer `driver.panic` bundle, which this hook would
        // pre-empt through the recorder's rate limit.
        let prev = std::panic::take_hook();
        let panic_rec = Arc::clone(&rec);
        std::panic::set_hook(Box::new(move |info| {
            let supervised = std::thread::current()
                .name()
                .is_some_and(|n| n == "sim-serve-shard" || n == "sim-serve-tenant");
            if !supervised {
                let _ = panic_rec.trigger(bps::obs::Trigger::Panic(info.to_string()));
            }
            prev(info);
        }));
    }
    // The unified fault-injection plane (DESIGN.md §0.12): `--fault SPEC`
    // or BPS_FAULT=SPEC, clauses like `conn_drop:p=0.01,panic:shard=0,
    // delay_write:ms=50,corrupt:every=100,stall:role=NAME,seed=N`.
    // BPS_FAULT_STALL=role[,role...] (the older health-smoke knob) folds
    // into the same spec as `stall:` clauses.
    let fault_spec = {
        let base = match fault_arg.or_else(|| std::env::var("BPS_FAULT").ok().filter(|s| !s.is_empty())) {
            Some(s) => bps::serve::FaultSpec::parse(&s)?,
            None => bps::serve::FaultSpec::default(),
        };
        let mut spec = base;
        if let Ok(roles) = std::env::var("BPS_FAULT_STALL") {
            spec.add_stall_roles(&roles);
        }
        spec
    };
    let injector = if fault_spec.is_empty() {
        None
    } else {
        let inj = Arc::new(bps::serve::Injector::new(fault_spec));
        server.arm_faults(Arc::clone(&inj))?;
        println!("fault injection: {}", inj.spec().describe());
        Some(inj)
    };
    // Self-healing drill loop: rebuild quarantined shards in place every
    // `--heal-ms`, so an injected `panic:shard=` flows through
    // quarantine → Dead watchdog → restart → healthy without operator
    // action (the chaos smoke asserts /healthz recovers).
    if heal_ms > 0 {
        let healer = Arc::downgrade(&server);
        std::thread::Builder::new()
            .name("bps-serve-heal".into())
            .spawn(move || loop {
                std::thread::sleep(std::time::Duration::from_millis(heal_ms.max(10)));
                let Some(server) = healer.upgrade() else { break };
                for idx in 0..server.num_shards() {
                    if server.shard_quarantined(idx) {
                        match server.restart_shard(idx) {
                            Ok(()) => println!("heal: restarted quarantined shard {idx}"),
                            Err(e) => eprintln!("heal: shard {idx}: {e:#}"),
                        }
                    }
                }
            })
            .map_err(|e| anyhow::anyhow!("spawn heal thread: {e}"))?;
        println!("self-heal: scanning for quarantined shards every {heal_ms} ms");
    }
    let _metrics = match &metrics_addr {
        Some(a) => {
            let mut hooks = bps::obs::HttpHooks::default();
            let wd = server.watchdog();
            hooks.health = Some(Arc::new(move || {
                let r = wd.report();
                (r.healthy(), r.to_json())
            }));
            if let Some(rec) = server.recorder() {
                hooks.dump = Some(Arc::new(move || {
                    match rec.trigger(bps::obs::Trigger::Manual) {
                        Ok(Some(path)) => {
                            let mut obj = std::collections::BTreeMap::new();
                            obj.insert(
                                "bundle".to_string(),
                                bps::util::json::Json::Str(path.display().to_string()),
                            );
                            Ok(bps::util::json::Json::Obj(obj).to_string())
                        }
                        Ok(None) => Err("dump suppressed (rate limit)".into()),
                        Err(e) => Err(format!("dump failed: {e}")),
                    }
                }));
            }
            let m = bps::obs::MetricsServer::listen_with(a.as_str(), server.registry(), hooks)?;
            // the scrape surface is a long-lived thread like any other:
            // fold its heartbeat into the server's watchdog so a wedged
            // /metrics accept loop is visible in /healthz
            server.watchdog().adopt(m.heartbeat());
            println!("metrics: http://{}/metrics", m.local_addr());
            Some(m)
        }
        None => None,
    };
    let wire = WireServer::listen_with(
        &listen,
        Arc::clone(&server),
        WireConfig {
            outbox_frames: outbox,
            inbox_submits: inbox,
            // TICK is 1 ms, so seconds → ticks is a factor of 1000.
            idle_timeout_ticks: if idle_timeout > 0.0 {
                Some((idle_timeout * 1000.0) as u64)
            } else {
                None
            },
            park_ttl_ticks: if park_ttl > 0.0 {
                Some((park_ttl * 1000.0) as u64)
            } else {
                None
            },
            fault: injector.clone(),
        },
    )?;
    println!(
        "serving {shards} shard(s) x {slots} slots ({task:?}, res {res}) on {}",
        wire.local_addr()
    );
    match &vault_banner {
        Some(d) => println!("policy tenancy: {d}"),
        None => println!(
            "policy tenancy: off (no {} — env leases only)",
            artifacts_dir.join("manifest.json").display()
        ),
    }
    if once {
        println!("--once: exiting after all accepted connections close");
    }

    let mut last_stats = std::time::Instant::now();
    // --once exit wants "all clients done", not "all sockets closed":
    // with --park-ttl (or --fault conn_drop) a killed connection leaves
    // every conn closed while the lease sits parked and the client backs
    // off toward a resume. Hold the exit while anything is parked, and
    // require the drained state on two consecutive polls so the
    // microseconds between a conn closing and its session parking can't
    // read as done.
    let mut drained_polls = 0u32;
    loop {
        std::thread::sleep(std::time::Duration::from_millis(200));
        let conns = wire.conn_stats();
        let drained = wire.accepted() > 0
            && conns.iter().all(|c| c.closed)
            && wire.parked_open() == 0;
        drained_polls = if drained { drained_polls + 1 } else { 0 };
        if once && drained_polls >= 2 {
            break;
        }
        if last_stats.elapsed().as_secs_f64() >= stats_every {
            print_serve_stats(&server, &conns);
            last_stats = std::time::Instant::now();
        }
    }
    // Final report (the smoke job asserts bad_submits=0 on these rows).
    print_serve_stats(&server, &wire.conn_stats());
    if let Some(p) = &trace_out {
        let spans = server.trace().spans().len();
        std::fs::write(p, server.trace().to_chrome_json())?;
        println!("trace: {spans} spans -> {}", p.display());
    }
    println!("serve: clean shutdown");
    Ok(())
}

/// Scrape a `bps serve` server's metrics registry over the wire (the
/// STATS frame) and print the Prometheus text. The header goes to stderr
/// so stdout is the exact snapshot rendering — byte-identical to the
/// server's own `/metrics` endpoint, pipeable into files or graders.
fn stats(args: &mut Args) -> Result<()> {
    use bps::serve::RemoteClient;

    let addr = args
        .operand()
        .or_else(|| args.opt("addr"))
        .unwrap_or_else(|| "127.0.0.1:7447".into());
    let dump = args.flag("dump")?;
    args.ensure_no_operands()?; // a second address is a typo; fail now
    let client = RemoteClient::connect(&addr)?;
    if dump {
        // Manual flight-recorder trigger: the server writes an incident
        // bundle and replies with its path (server-local).
        let bundle = client.dump()?;
        println!("incident bundle (server-local): {bundle}");
        return Ok(());
    }
    let (version, text) = client.stats_text()?;
    eprintln!("# scrape of {addr} (snapshot version {version})");
    print!("{text}");
    Ok(())
}

/// Run an in-process serve pipeline with span tracing enabled and write
/// the Chrome `trace_event` JSON: the quickest way to look at one tick's
/// submit → coalesce → sim → render-stage → publish timeline without
/// standing up a server (load the file in chrome://tracing or Perfetto).
fn trace_cmd(args: &mut Args) -> Result<()> {
    use bps::env::EnvBatchConfig;
    use bps::render::RenderConfig;
    use bps::scene::procgen::{generate, Complexity};
    use bps::serve::{ShardSpec, SimServer};
    use bps::sim::Task;
    use bps::util::pool::WorkerPool;
    use std::sync::Arc;

    let out = PathBuf::from(args.opt_or("out", "trace.json"));
    let envs = args.usize_or("envs", 8)?.max(1);
    let steps = args.usize_or("steps", 32)?.max(1);
    let res = args.usize_or("res", 32)?.max(4);
    let seed = args.u64_or("seed", 7)?;
    let threads = args.usize_or("threads", 0)?;
    let task = {
        let name = args.opt_or("task", "pointnav");
        Task::parse(&name).ok_or_else(|| anyhow::anyhow!("bad task {name:?}"))?
    };

    let scene = Arc::new(generate("trace", seed, Complexity::test()));
    let pool = Arc::new(WorkerPool::new(if threads == 0 {
        WorkerPool::default_size()
    } else {
        threads
    }));
    let cfg = EnvBatchConfig::new(task, RenderConfig::depth(res)).seed(seed);
    let scenes = (0..envs).map(|_| Arc::clone(&scene)).collect();
    let server = SimServer::start(vec![ShardSpec::with_scenes(cfg, scenes)], pool)?;
    server.trace().enable();
    let mut session = server.connect(task, envs)?;
    let mut actions = vec![0u8; envs];
    for t in 0..steps {
        for (j, a) in actions.iter_mut().enumerate() {
            // turn/forward script, never STOP
            *a = (1 + (t + j) % 3) as u8;
        }
        session.step(&actions)?;
    }
    drop(session);
    let spans = server.trace().spans().len();
    std::fs::write(&out, server.trace().to_chrome_json())?;
    println!(
        "trace: {spans} spans over {steps} steps x {envs} envs -> {}",
        out.display()
    );
    Ok(())
}

/// Remote demo client for `bps serve`: lease slots over TCP, drive them
/// with the scripted turn/forward policy, and report FPS + latency.
fn connect(args: &mut Args) -> Result<()> {
    use bps::serve::{RemoteClient, ResumeCfg};
    use bps::sim::Task;

    let addr = args
        .operand()
        .or_else(|| args.opt("addr"))
        .unwrap_or_else(|| "127.0.0.1:7447".into());
    args.ensure_no_operands()?; // a second address is a typo; fail now
    let envs = args.usize_or("envs", 8)?.max(1);
    let steps = args.usize_or("steps", 256)?.max(1);
    let retries = args.u64_or("retries", 0)? as u32;
    let task = {
        let name = args.opt_or("task", "pointnav");
        Task::parse(&name).ok_or_else(|| anyhow::anyhow!("bad task {name:?}"))?
    };

    // --retries N arms session resume: dropped connections reconnect
    // with capped exponential backoff and the step stream continues
    // bitwise-identically. Resume exhaustion propagates the server's
    // last error out of step() and exits nonzero.
    let client = if retries > 0 {
        RemoteClient::connect_with_resume(
            &addr,
            ResumeCfg {
                max_retries: retries,
                ..Default::default()
            },
        )?
    } else {
        RemoteClient::connect(&addr)?
    };
    let mut session = client.open_session(task, envs)?;
    println!(
        "connected to {addr}: {} shard(s), leased {} x {task:?} slots {:?}",
        client.num_shards(),
        session.num_envs(),
        session.slots()
    );
    let mut actions = vec![0u8; envs];
    let mut reward = 0.0f32;
    let mut episodes = 0u32;
    let t0 = std::time::Instant::now();
    for t in 0..steps {
        for (j, a) in actions.iter_mut().enumerate() {
            // turn/forward script, never STOP
            *a = (1 + (t + j) % 3) as u8;
        }
        let v = session.step(&actions)?;
        reward += v.rewards.iter().sum::<f32>();
        episodes += v.dones.iter().filter(|&&d| d).count() as u32;
    }
    let wall = t0.elapsed().as_secs_f64();
    let (p50, p95) = session.latency();
    session.detach()?;
    println!(
        "{steps} steps x {envs} envs in {wall:.2}s = {:.0} FPS | reward {reward:+.2} \
         episodes {episodes} | step latency p50 {:.2} ms p95 {:.2} ms",
        (steps * envs) as f64 / wall,
        p50 * 1e3,
        p95 * 1e3
    );
    let (resumes, backoff_ms) = client.resume_stats();
    println!("connect: detached cleanly | resumes={resumes} backoff_ms_total={backoff_ms}");
    Ok(())
}

/// Remote policy-tenant client: lease env slots *plus* a server-side
/// policy on a `bps serve` server (started with AOT artifacts), post one
/// goal, and stream the server-driven trajectory back. The client never
/// runs the policy — it only reads (obs, action, reward, done) steps.
fn agent(args: &mut Args) -> Result<()> {
    use bps::serve::{RemoteClient, ResumeCfg};
    use bps::sim::Task;

    let addr = args
        .operand()
        .or_else(|| args.opt("addr"))
        .unwrap_or_else(|| "127.0.0.1:7447".into());
    args.ensure_no_operands()?; // a second address is a typo; fail now
    let envs = args.usize_or("envs", 4)?.max(1);
    let steps = args.usize_or("steps", 64)?.max(1);
    let retries = args.u64_or("retries", 0)? as u32;
    let variant = args.opt_or("variant", "test");
    let sample = args.flag("sample")?;
    let seed = args.u64_or("seed", 7)?;
    let task = {
        let name = args.opt_or("task", "pointnav");
        Task::parse(&name).ok_or_else(|| anyhow::anyhow!("bad task {name:?}"))?
    };

    // Agent leases are never parked server-side (the server-driven
    // rollout state is not reconstructible), but --retries still arms
    // reconnect/backoff for the initial dial and surfaces the resume
    // summary uniformly with `bps connect`.
    let client = if retries > 0 {
        RemoteClient::connect_with_resume(
            &addr,
            ResumeCfg {
                max_retries: retries,
                ..Default::default()
            },
        )?
    } else {
        RemoteClient::connect(&addr)?
    };
    let mut agent = client.open_agent(task, envs, &variant, !sample, seed)?;
    println!(
        "connected to {addr}: leased {} x {task:?} slots {:?} + policy {variant:?} ({})",
        agent.num_envs(),
        agent.slots(),
        if sample { "sampled" } else { "greedy" }
    );
    agent.set_goal(steps as u32)?;
    let mut reward = 0.0f32;
    let mut episodes = 0u32;
    let mut stops = 0u64;
    let t0 = std::time::Instant::now();
    while agent.steps() < steps as u64 {
        match agent.next_traj()? {
            Some(tr) => {
                reward += tr.view.rewards.iter().sum::<f32>();
                episodes += tr.view.dones.iter().filter(|&&d| d).count() as u32;
                stops += tr.actions.iter().filter(|&&a| a == 0).count() as u64;
            }
            None => bail!("server ended the trajectory stream early"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    agent.detach()?;
    println!(
        "{steps} server-driven steps x {envs} envs in {wall:.2}s = {:.0} agent-steps/s | \
         reward {reward:+.2} episodes {episodes} stop-actions {stops}",
        (steps * envs) as f64 / wall
    );
    let (resumes, backoff_ms) = client.resume_stats();
    println!("agent: detached cleanly | resumes={resumes} backoff_ms_total={backoff_ms}");
    Ok(())
}

/// Drive M concurrent synthetic clients (threads with scripted policies)
/// through the `bps::serve` multi-tenant layer and report aggregate FPS,
/// occupancy, and step-latency percentiles.
fn serve_demo(args: &mut Args) -> Result<()> {
    use bps::env::EnvBatchConfig;
    use bps::render::RenderConfig;
    use bps::scene::procgen::{generate, Complexity};
    use bps::serve::{FillAction, ShardSpec, SimServer, StragglerPolicy};
    use bps::sim::Task;
    use bps::util::pool::WorkerPool;
    use std::sync::Arc;

    let clients = args.usize_or("clients", 4)?.max(1);
    let epc = args.usize_or("envs-per-client", 8)?.max(1);
    let steps = args.usize_or("steps", 256)?.max(1);
    let shards = args.usize_or("shards", 2)?.clamp(1, clients);
    let res = args.usize_or("res", 32)?.max(4);
    let seed = args.u64_or("seed", 7)?;
    let threads = args.usize_or("threads", 0)?;
    let ticks = args.usize_or("deadline-ticks", 2)? as u32;
    let mem_budget_mb = args.usize_or("mem-budget", 0)?;
    let task = {
        let name = args.opt_or("task", "pointnav");
        Task::parse(&name).ok_or_else(|| anyhow::anyhow!("bad task {name:?}"))?
    };
    let straggler = match args.opt_or("straggler", "wait").as_str() {
        "wait" => StragglerPolicy::Wait,
        "noop" => StragglerPolicy::Deadline {
            ticks,
            fill: FillAction::NoOp,
        },
        "repeat" => StragglerPolicy::Deadline {
            ticks,
            fill: FillAction::Repeat,
        },
        other => bail!("bad straggler policy {other:?} (wait|noop|repeat)"),
    };

    // Shards sized so every client fits: ceil(M/S) client groups per shard.
    let clients_per_shard = clients.div_ceil(shards);
    let slots_per_shard = clients_per_shard * epc;
    let scene = Arc::new(generate("serve_demo", seed, Complexity::test()));
    let pool = Arc::new(WorkerPool::new(if threads == 0 {
        WorkerPool::default_size()
    } else {
        threads
    }));
    let mut specs = Vec::with_capacity(shards);
    for s in 0..shards {
        let cfg = EnvBatchConfig::new(task, RenderConfig::depth(res))
            .seed(seed.wrapping_add(s as u64 * 7919));
        let scenes = (0..slots_per_shard).map(|_| Arc::clone(&scene)).collect();
        specs.push(ShardSpec::with_scenes(cfg, scenes).straggler(straggler));
    }
    let budget = match mem_budget_mb {
        0 => None,
        mb => Some(mb * 1024 * 1024),
    };
    let server = SimServer::with_budget(specs, pool, budget)?;
    println!(
        "serve-demo: {clients} clients x {epc} envs on {shards} shard(s) x \
         {slots_per_shard} slots, task {task:?}, {steps} steps each"
    );

    // Lease every client's slots before any thread submits, so the first
    // coalesced step on each shard already includes all of its tenants (a
    // lone early tenant would otherwise race private batch steps in under
    // the Wait policy and the reported stats would vary run to run).
    let sessions = (0..clients)
        .map(|_| server.connect(task, epc))
        .collect::<Result<Vec<_>>>()?;
    let t0 = std::time::Instant::now();
    let results = std::thread::scope(|sc| {
        let mut handles = Vec::with_capacity(clients);
        for (c, mut session) in sessions.into_iter().enumerate() {
            handles.push(sc.spawn(move || -> Result<(f32, u32, f32, f32)> {
                let mut actions = vec![0u8; epc];
                let mut reward = 0.0f32;
                let mut episodes = 0u32;
                for t in 0..steps {
                    for (j, a) in actions.iter_mut().enumerate() {
                        // turn/forward script, never STOP
                        *a = (1 + (t + c + j) % 3) as u8;
                    }
                    let v = session.step(&actions)?;
                    reward += v.rewards.iter().sum::<f32>();
                    episodes += v.dones.iter().filter(|&&d| d).count() as u32;
                }
                let (p50, p95) = session.latency();
                Ok((reward, episodes, p50, p95))
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread panicked"))
            .collect::<Result<Vec<_>>>()
    })?;
    let wall = t0.elapsed().as_secs_f64();

    for (c, (reward, episodes, p50, p95)) in results.iter().enumerate() {
        println!(
            "  client {c:>3}: reward {reward:+9.2}  episodes {episodes:>4}  \
             step latency p50 {:.2} ms  p95 {:.2} ms",
            p50 * 1e3,
            p95 * 1e3
        );
    }
    let frames = (clients * epc * steps) as f64;
    println!(
        "aggregate: {frames:.0} frames in {wall:.2}s = {:.0} FPS, \
         occupancy {}/{}",
        frames / wall,
        clients * epc,
        shards * slots_per_shard
    );
    for (i, st) in server.stats().iter().enumerate() {
        println!(
            "  shard {i}: task {:?} steps {} straggler-fills {} bad-submits {} \
             resident {:.1} MB latency p50 {:.2} ms p95 {:.2} ms",
            st.task,
            st.steps,
            st.straggler_fills,
            st.bad_submits,
            st.resident_bytes as f64 / 1e6,
            st.latency_p50 * 1e3,
            st.latency_p95 * 1e3
        );
    }
    Ok(())
}

/// Drive the scenario engine end to end without any AOT artifacts: a
/// scripted GPS+compass policy steps an `EnvBatch` whose scenes stream
/// from procedural generation, while a success-driven curriculum advances
/// the spec's difficulty stages. The CI smoke job runs this for a handful
/// of steps.
fn scenario_demo(args: &mut Args) -> Result<()> {
    use bps::env::EnvBatchConfig;
    use bps::render::{RenderConfig, SceneRotation};
    use bps::scenario::{registry_list, sensor_policy, Curriculum, ScenarioSpec, ScenarioStream};
    use bps::util::pool::WorkerPool;
    use std::path::Path;
    use std::sync::Arc;

    let dir = args.opt_or("scenario-dir", "scenarios");
    if args.flag("list")? {
        for name in registry_list(Path::new(&dir))? {
            let spec = ScenarioSpec::resolve(&name, Path::new(&dir))?;
            println!("{name}: {}", spec.summary());
        }
        return Ok(());
    }
    let spec_arg = args.opt_or(
        "scenario",
        "name=demo task=pointnav stages=3 tris=1k..6k extent=6..9 \
         clutter=0..2 mats=1..3 tex=32 min-geo=1 max-steps=200",
    );
    let spec = ScenarioSpec::resolve(&spec_arg, Path::new(&dir))?;
    let n = args.usize_or("envs", 8)?.max(1);
    let steps = args.usize_or("steps", 256)?.max(1);
    let k = args.usize_or("k", 2)?.max(1);
    let prefetch = args.usize_or("prefetch", 2)?.max(1);
    let rotate_every = args.u64_or("rotate-every", 8)?.max(1);
    let res = args.usize_or("res", 16)?.max(4);
    let seed = args.u64_or("seed", 7)?;
    let threads = args.usize_or("threads", 0)?;
    let window = args.usize_or("window", 12)?.max(1);
    let threshold = args.f64_or("threshold", 0.6)? as f32;
    let events = bps::obs::EventLog::disabled();
    if let Some(p) = args.opt("event-log").map(PathBuf::from) {
        events.arm(&p, bps::obs::DEFAULT_EVENT_LOG_BYTES)?;
    }

    println!("scenario: {}", spec.summary());
    let pool = Arc::new(WorkerPool::new(if threads == 0 {
        WorkerPool::default_size()
    } else {
        threads
    }));
    let stream = ScenarioStream::new(spec.clone(), seed, prefetch, false, Arc::clone(&pool));
    let rot = SceneRotation::streaming(stream, k)?;
    let mut env = EnvBatchConfig::new(spec.task, RenderConfig::depth(res))
        .sim(spec.sim_config())
        .seed(seed)
        .pin_rotation(rotate_every)
        .build_with_rotation(rot, n, pool)?;
    let mut cur = Curriculum::new(spec.stages, window, threshold);
    let stop_dist = spec.sim_config().success_dist * 0.75;
    let mut actions = vec![0u8; n];
    let (mut episodes, mut successes) = (0u64, 0u64);
    let t0 = std::time::Instant::now();
    for t in 0..steps {
        sensor_policy(env.view().goal, stop_dist, t, &mut actions);
        let v = env.step(&actions)?;
        cur.observe(v.dones, v.successes, v.spl);
        episodes += v.dones.iter().filter(|&&d| d).count() as u64;
        successes += v.successes.iter().filter(|&&s| s).count() as u64;
        if let Some(stage) = cur.advance_if_ready() {
            env.set_stage(stage)?;
            events.emit(
                "curriculum.stage_advance",
                &[
                    ("stage", bps::util::json::Json::Num(stage as f64)),
                    ("episodes", bps::util::json::Json::Num(cur.episodes() as f64)),
                ],
            );
            println!(
                "  step {t:>5}: stage -> {stage}/{} ({} episodes so far)",
                spec.stages - 1,
                cur.episodes()
            );
        }
        env.rotate_scenes()?;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{steps} steps x {n} envs in {wall:.2}s = {:.0} FPS | episodes {episodes} \
         success {:.0}% | stage {}/{} | rotations {}",
        (steps * n) as f64 / wall,
        if episodes > 0 {
            100.0 * successes as f64 / episodes as f64
        } else {
            0.0
        },
        cur.stage(),
        spec.stages - 1,
        env.rotations()
    );
    Ok(())
}

/// Standalone batch-renderer benchmark (the `bench_render` ablation as a
/// first-class subcommand): measures FPS, p50/p95 megaframe latency,
/// triangle throughput, and the per-stage wall-time breakdown for every
/// pipeline-mode × sensor configuration. With `--json`, appends one record
/// per configuration to a JSON-array trajectory file (`BENCH_render.json`)
/// so the renderer's perf history is machine-readable across PRs.
fn bench(args: &mut Args) -> Result<()> {
    use bps::bench::{append_bench_record, bench_iters, bench_quick, dataset, measure_render};
    use bps::render::{BatchRenderer, PipelineMode, RenderConfig, RenderItem, Sensor};
    use bps::util::json::{num, obj, s};
    use bps::util::pool::WorkerPool;
    use bps::util::rng::Rng;
    use std::sync::Arc;

    let quick = bench_quick();
    let complexity = args.opt_or("complexity", if quick { "test" } else { "gibson" });
    let n = args.usize_or("n", if quick { 8 } else { 64 })?.max(1);
    let res = args.usize_or("res", 64)?.max(4);
    let (dw, dr) = bench_iters(if quick { 1 } else { 3 }, if quick { 3 } else { 20 });
    let warmup = args.usize_or("warmup", dw)?;
    let reps = args.usize_or("reps", dr)?.max(1);
    let threads = args.usize_or("threads", 0)?;
    let json = args.flag("json")?;
    let out_path = PathBuf::from(args.opt_or("out", "BENCH_render.json"));

    let ds = dataset(&complexity)?;
    let scene = Arc::new(ds.load_scene(&ds.train[0], true)?);
    let pool = WorkerPool::new(if threads == 0 {
        WorkerPool::default_size()
    } else {
        threads
    });
    let mut rng = Rng::new(5);
    let mut items = Vec::with_capacity(n);
    for _ in 0..n {
        let pos = scene
            .navmesh
            .random_point(&mut rng)
            .ok_or_else(|| anyhow::anyhow!("scene has no navigable point"))?;
        items.push(RenderItem {
            scene: Arc::clone(&scene),
            pos,
            heading: rng.range_f32(0.0, std::f32::consts::TAU),
        });
    }
    println!(
        "# bench render: N={n} res={res} complexity={complexity} tris/scene={} \
         workers={} warmup={warmup} reps={reps}",
        scene.mesh.num_tris(),
        pool.num_workers(),
    );
    println!(
        "{:<18} {:>9} {:>9} {:>9} {:>11} | {:>9} {:>8} {:>9} {:>8}  us/frame",
        "config", "FPS", "p50 ms", "p95 ms", "Mtris/s", "transform", "cull", "raster", "resolve"
    );
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    for (mode, mode_name) in [
        (PipelineMode::Fused, "fused"),
        (PipelineMode::Pipelined, "pipelined"),
    ] {
        for (sensor, sensor_name) in [(Sensor::Depth, "depth"), (Sensor::Rgb, "rgb")] {
            let cfg = RenderConfig { res, sensor, scale: 1, mode };
            let renderer = BatchRenderer::new(cfg, n);
            let mut obs = vec![0.0f32; n * cfg.obs_floats()];
            let r = measure_render(&renderer, &pool, &items, &mut obs, warmup, reps);
            let [tx, cu, ra, re] = r.stage_us;
            println!(
                "{:<18} {:>9.0} {:>9.2} {:>9.2} {:>11.2} | {tx:>9.1} {cu:>8.1} {ra:>9.1} {re:>8.1}",
                format!("{sensor_name} {mode_name}"),
                r.fps,
                r.p50_ms,
                r.p95_ms,
                r.tris_per_s / 1e6,
            );
            if json {
                let record = obj(vec![
                    ("bench", s("render")),
                    ("ts", num(ts as f64)),
                    ("complexity", s(&complexity)),
                    ("n", num(n as f64)),
                    ("res", num(res as f64)),
                    ("mode", s(mode_name)),
                    ("sensor", s(sensor_name)),
                    ("reps", num(reps as f64)),
                    ("threads", num(pool.num_workers() as f64)),
                    ("fps", num(r.fps)),
                    ("p50_ms", num(r.p50_ms as f64)),
                    ("p95_ms", num(r.p95_ms as f64)),
                    ("tris_per_s", num(r.tris_per_s)),
                    (
                        "stage_us_per_frame",
                        obj(vec![
                            ("transform", num(tx)),
                            ("cull", num(cu)),
                            ("raster", num(ra)),
                            ("resolve", num(re)),
                        ]),
                    ),
                ]);
                append_bench_record(&out_path, record)?;
            }
        }
    }
    if json {
        println!("appended 4 records to {out_path:?}");
    }
    Ok(())
}

/// `bps lint` — run the repo's static-analysis rules (DESIGN.md §0.13)
/// over `rust/src` and exit nonzero on any violation.
fn lint_cmd(args: &mut Args) -> Result<()> {
    let root = match args.opt("root") {
        Some(r) => PathBuf::from(r),
        None => bps::lint::find_root()?,
    };
    let json = args.flag("json")?;
    let report = bps::lint::lint_tree(&root)?;
    if json {
        println!("{}", report.to_json().to_string());
    } else {
        print!("{}", report.render_text());
    }
    if !report.clean() {
        // findings already printed; a nonzero exit is the CI contract
        std::process::exit(1);
    }
    Ok(())
}

fn info(args: &mut Args) -> Result<()> {
    let dir = PathBuf::from(args.opt_or("artifacts-dir", "artifacts"));
    let man = Manifest::load(&dir)?;
    println!("artifacts in {dir:?}:");
    for (name, v) in &man.variants {
        println!(
            "  {name}: encoder={} res={} ch={} hidden={} params={} infer_ns={:?} grad_bls={:?}",
            v.encoder, v.res, v.in_ch, v.hidden, v.num_params, v.infer_ns, v.grad_bls
        );
    }
    Ok(())
}
