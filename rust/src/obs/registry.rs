//! Metrics registry: typed `Counter`/`Gauge`/`Histogram` handles under
//! dotted names with small static label sets.
//!
//! Design constraints (DESIGN.md §0.10):
//!
//! - **Lock-light hot path.** A handle is an `Arc`'d atomic cell;
//!   `inc`/`add`/`observe`/`set` are single `fetch_add`/`store`s with no
//!   registry lock. The registry's own mutex is touched only at
//!   registration time and when a scrape takes a [`Snapshot`].
//! - **Shared cells, not shadow copies.** Producers that already keep an
//!   atomic (e.g. `EnvBatch`'s rotation counter) attach *that* cell via
//!   [`Registry::attach_counter`], so a scrape and the legacy
//!   `SimServer::stats()` read the very same memory — the bitwise-match
//!   acceptance criterion falls out by construction instead of by
//!   sampling discipline.
//! - **Deterministic, mergeable snapshots.** Histograms use fixed log2
//!   buckets ([`Histogram::bucket_index`]), so two snapshots from
//!   different shards/processes merge by plain element-wise addition
//!   ([`HistogramSnapshot::merge`]) and the same samples always land in
//!   the same buckets. Snapshot iteration order is the registry's
//!   `BTreeMap` order: sorted by name, then by label set.
//!
//! The text exposition ([`Snapshot::to_prometheus`]) is the *single*
//! canonical rendering: the `/metrics` HTTP endpoint, the `STATS` wire
//! frame, and `bps stats` all emit exactly this string, so every scrape
//! path agrees byte-for-byte on the same snapshot.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Version stamped into every [`Snapshot`] (and the `STATS` wire reply).
/// Bump when metric semantics change incompatibly.
pub const SNAPSHOT_VERSION: u32 = 1;

/// Fixed bucket count for every histogram: bucket `i < 31` counts values
/// in `[2^i, 2^(i+1))` (bucket 0 also takes 0), bucket 31 is overflow.
pub const HIST_BUCKETS: usize = 32;

/// Monotonic counter. Cheap to clone; clones share the cell.
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    /// Wrap an existing atomic as a counter handle, so a producer's
    /// legacy cell and the registry share storage (see module docs).
    pub fn from_cell(cell: Arc<AtomicU64>) -> Counter {
        Counter(cell)
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time value (occupancy, queue depth). Stores `f64` bits in an
/// `AtomicU64`; `add` is a CAS loop but gauges are off the per-step hot
/// path (they change on lease/release, not per tick).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn add(&self, delta: f64) {
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
                Some((f64::from_bits(bits) + delta).to_bits())
            });
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

#[derive(Debug, Default)]
struct HistCore {
    count: AtomicU64,
    /// Sum of observed values (integer units, e.g. microseconds).
    sum: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

/// Log2-bucketed histogram over non-negative integer samples
/// (microseconds, bytes). Fixed buckets keep snapshots deterministic and
/// mergeable across shards; ~2x relative resolution is plenty for
/// latency tails.
#[derive(Clone, Debug, Default)]
pub struct Histogram(Arc<HistCore>);

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Bucket for value `v`: 0 for `v <= 1`, else `floor(log2 v)`,
    /// saturating into the overflow bucket (`HIST_BUCKETS - 1`).
    pub fn bucket_index(v: u64) -> usize {
        if v <= 1 {
            return 0;
        }
        ((63 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }

    /// Inclusive upper edge of bucket `i`, or `None` for the overflow
    /// bucket (rendered as `le="+Inf"`).
    pub fn bucket_le(i: usize) -> Option<u64> {
        if i + 1 >= HIST_BUCKETS {
            None
        } else {
            Some((1u64 << (i + 1)) - 1)
        }
    }

    pub fn observe(&self, v: u64) {
        let c = &self.0;
        c.count.fetch_add(1, Ordering::Relaxed);
        c.sum.fetch_add(v, Ordering::Relaxed);
        c.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        let c = &self.0;
        let mut s = HistogramSnapshot {
            count: c.count.load(Ordering::Relaxed),
            sum: c.sum.load(Ordering::Relaxed),
            buckets: [0; HIST_BUCKETS],
        };
        for (o, b) in s.buckets.iter_mut().zip(c.buckets.iter()) {
            *o = b.load(Ordering::Relaxed);
        }
        s
    }
}

/// Frozen histogram state. Element-wise addable: merging per-shard
/// snapshots gives exactly the histogram a single global recorder would
/// have produced (same fixed buckets).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    pub buckets: [u64; HIST_BUCKETS],
}

impl Default for HistogramSnapshot {
    fn default() -> HistogramSnapshot {
        HistogramSnapshot {
            count: 0,
            sum: 0,
            buckets: [0; HIST_BUCKETS],
        }
    }
}

impl HistogramSnapshot {
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        self.count += other.count;
        self.sum += other.sum;
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
    }
}

/// One metric's frozen value.
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(f64),
    Histogram(HistogramSnapshot),
}

/// One registered series in a [`Snapshot`].
#[derive(Clone, Debug)]
pub struct MetricSnapshot {
    pub name: String,
    /// Sorted by label key (canonical order).
    pub labels: Vec<(String, String)>,
    pub value: MetricValue,
}

/// A versioned, ordered freeze of every registered series.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub version: u32,
    pub metrics: Vec<MetricSnapshot>,
}

#[derive(Clone)]
enum Cell {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

type Key = (String, Vec<(String, String)>);

/// The process-wide (or server-wide) series table. See module docs.
pub struct Registry {
    cells: Mutex<BTreeMap<Key, Cell>>,
    /// Registry creation time, exported as `process_uptime_seconds` so
    /// scrapes and incident bundles are self-dating.
    epoch: Instant,
}

impl Default for Registry {
    fn default() -> Registry {
        Registry {
            cells: Mutex::new(BTreeMap::new()),
            epoch: Instant::now(),
        }
    }
}

fn key(name: &str, labels: &[(&str, &str)]) -> Key {
    let mut l: Vec<(String, String)> = labels
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect();
    l.sort();
    (name.to_string(), l)
}

impl Registry {
    pub fn new() -> Arc<Registry> {
        Arc::new(Registry::default())
    }

    /// Get-or-create the counter `name{labels}`. Returns a shared handle:
    /// registering the same series twice yields the same cell.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        let mut cells = self.cells.lock().unwrap();
        match cells
            .entry(key(name, labels))
            .or_insert_with(|| Cell::Counter(Counter::new()))
        {
            Cell::Counter(c) => c.clone(),
            _ => Counter::new(), // type clash: detached handle, never scraped
        }
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        let mut cells = self.cells.lock().unwrap();
        match cells
            .entry(key(name, labels))
            .or_insert_with(|| Cell::Gauge(Gauge::new()))
        {
            Cell::Gauge(g) => g.clone(),
            _ => Gauge::new(),
        }
    }

    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Histogram {
        let mut cells = self.cells.lock().unwrap();
        match cells
            .entry(key(name, labels))
            .or_insert_with(|| Cell::Histogram(Histogram::new()))
        {
            Cell::Histogram(h) => h.clone(),
            _ => Histogram::new(),
        }
    }

    /// Register an existing counter handle under `name{labels}` (replaces
    /// any prior cell for the series). This is how legacy producer
    /// atomics become scrapeable without a shadow copy.
    pub fn attach_counter(&self, name: &str, labels: &[(&str, &str)], c: &Counter) {
        let mut cells = self.cells.lock().unwrap();
        cells.insert(key(name, labels), Cell::Counter(c.clone()));
    }

    pub fn attach_gauge(&self, name: &str, labels: &[(&str, &str)], g: &Gauge) {
        let mut cells = self.cells.lock().unwrap();
        cells.insert(key(name, labels), Cell::Gauge(g.clone()));
    }

    pub fn attach_histogram(&self, name: &str, labels: &[(&str, &str)], h: &Histogram) {
        let mut cells = self.cells.lock().unwrap();
        cells.insert(key(name, labels), Cell::Histogram(h.clone()));
    }

    /// Freeze every series. Holds the registry mutex only while cloning
    /// handles; the atomic loads happen outside it.
    ///
    /// Every snapshot is self-identifying: `bps_build_info{version=...}`
    /// names the build and `process_uptime_seconds` (whole seconds, so
    /// the page is stable across back-to-back scrapes within a second)
    /// dates it.
    pub fn snapshot(&self) -> Snapshot {
        self.gauge("bps_build_info", &[("version", env!("CARGO_PKG_VERSION"))])
            .set(1.0);
        self.gauge("process.uptime_seconds", &[])
            .set(self.epoch.elapsed().as_secs() as f64);
        let frozen: Vec<(Key, Cell)> = {
            let cells = self.cells.lock().unwrap();
            cells.iter().map(|(k, c)| (k.clone(), c.clone())).collect()
        };
        let metrics = frozen
            .into_iter()
            .map(|((name, labels), cell)| MetricSnapshot {
                name,
                labels,
                value: match cell {
                    Cell::Counter(c) => MetricValue::Counter(c.get()),
                    Cell::Gauge(g) => MetricValue::Gauge(g.get()),
                    Cell::Histogram(h) => MetricValue::Histogram(h.snapshot()),
                },
            })
            .collect();
        Snapshot {
            version: SNAPSHOT_VERSION,
            metrics,
        }
    }
}

impl Snapshot {
    fn find(&self, name: &str, labels: &[(&str, &str)]) -> Option<&MetricSnapshot> {
        let (_, want) = key(name, labels);
        self.metrics
            .iter()
            .find(|m| m.name == name && m.labels == want)
    }

    /// Counter value, or `None` if the series is absent or not a counter.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Option<u64> {
        match self.find(name, labels)?.value {
            MetricValue::Counter(v) => Some(v),
            _ => None,
        }
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        match self.find(name, labels)?.value {
            MetricValue::Gauge(v) => Some(v),
            _ => None,
        }
    }

    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Option<&HistogramSnapshot> {
        match &self.find(name, labels)?.value {
            MetricValue::Histogram(h) => Some(h),
            _ => None,
        }
    }

    /// Render Prometheus text format (the canonical exposition — see
    /// module docs). Dotted names sanitize `.` → `_`; label values get
    /// the standard `\\` / `\"` / `\n` escapes.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "# bps snapshot v{}", self.version);
        let mut last_name = "";
        for m in &self.metrics {
            let pname = sanitize_name(&m.name);
            if m.name != last_name {
                let kind = match m.value {
                    MetricValue::Counter(_) => "counter",
                    MetricValue::Gauge(_) => "gauge",
                    MetricValue::Histogram(_) => "histogram",
                };
                let _ = writeln!(out, "# TYPE {pname} {kind}");
                last_name = &m.name;
            }
            match &m.value {
                MetricValue::Counter(v) => {
                    let _ = writeln!(out, "{pname}{} {v}", label_block(&m.labels, None));
                }
                MetricValue::Gauge(v) => {
                    let _ = writeln!(out, "{pname}{} {v}", label_block(&m.labels, None));
                }
                MetricValue::Histogram(h) => {
                    let mut cum = 0u64;
                    for (i, b) in h.buckets.iter().enumerate() {
                        cum += b;
                        // Elide interior empty-prefix noise? No: Prometheus
                        // requires every bucket to be cumulative, but emitting
                        // all 32 per series bloats the page. Emit a bucket
                        // line only when its cumulative count changes, plus
                        // the final +Inf line — still a valid cumulative
                        // histogram, much smaller.
                        let le = match Histogram::bucket_le(i) {
                            Some(edge) => edge.to_string(),
                            None => "+Inf".to_string(),
                        };
                        let is_last = i + 1 == HIST_BUCKETS;
                        if *b > 0 || is_last {
                            let _ = writeln!(
                                out,
                                "{pname}_bucket{} {cum}",
                                label_block(&m.labels, Some(&le))
                            );
                        }
                    }
                    let _ = writeln!(out, "{pname}_sum{} {}", label_block(&m.labels, None), h.sum);
                    let _ = writeln!(
                        out,
                        "{pname}_count{} {}",
                        label_block(&m.labels, None),
                        h.count
                    );
                }
            }
        }
        out
    }
}

/// Prometheus metric names allow `[a-zA-Z0-9_:]`; everything else
/// (notably the `.` in our dotted names) maps to `_`.
fn sanitize_name(name: &str) -> String {
    name.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn label_block(labels: &[(String, String)], le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut out = String::from("{");
    let mut first = true;
    for (k, v) in labels {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(out, "{}=\"{}\"", sanitize_name(k), escape_label_value(v));
    }
    if let Some(le) = le {
        if !first {
            out.push(',');
        }
        let _ = write!(out, "le=\"{le}\"");
    }
    out.push('}');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_basics() {
        let r = Registry::new();
        let c = r.counter("a.b", &[("shard", "0")]);
        c.inc();
        c.add(4);
        // same series -> same cell
        assert_eq!(r.counter("a.b", &[("shard", "0")]).get(), 5);
        // different labels -> different cell
        assert_eq!(r.counter("a.b", &[("shard", "1")]).get(), 0);
        let g = r.gauge("occ", &[]);
        g.set(0.5);
        g.add(0.25);
        assert!((r.gauge("occ", &[]).get() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn attach_shares_the_cell() {
        let r = Registry::new();
        let cell = Arc::new(AtomicU64::new(7));
        let c = Counter::from_cell(Arc::clone(&cell));
        r.attach_counter("env.rotations", &[("shard", "0")], &c);
        cell.fetch_add(3, Ordering::Relaxed);
        let snap = r.snapshot();
        assert_eq!(snap.counter("env.rotations", &[("shard", "0")]), Some(10));
    }

    #[test]
    fn histogram_log2_bucket_edges() {
        // Boundary cases: 0 and 1 share bucket 0; each power of two
        // starts a new bucket; the top bucket absorbs everything huge.
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(2), 1);
        assert_eq!(Histogram::bucket_index(3), 1);
        assert_eq!(Histogram::bucket_index(4), 2);
        assert_eq!(Histogram::bucket_index(7), 2);
        assert_eq!(Histogram::bucket_index(8), 3);
        assert_eq!(Histogram::bucket_index((1 << 31) - 1), 30);
        assert_eq!(Histogram::bucket_index(1 << 31), 31);
        assert_eq!(Histogram::bucket_index(u64::MAX), 31);
        // inclusive upper edges match the index rule exactly
        for i in 0..HIST_BUCKETS - 1 {
            let le = Histogram::bucket_le(i).unwrap();
            assert_eq!(Histogram::bucket_index(le), i, "le of bucket {i}");
            assert_eq!(Histogram::bucket_index(le + 1), i + 1);
        }
        assert_eq!(Histogram::bucket_le(HIST_BUCKETS - 1), None);
    }

    #[test]
    fn histogram_observe_and_snapshot() {
        let h = Histogram::new();
        for v in [0, 1, 2, 3, 1000, u64::MAX] {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 6);
        assert_eq!(s.sum, 0u64.wrapping_add(1 + 2 + 3 + 1000).wrapping_add(u64::MAX));
        assert_eq!(s.buckets[0], 2);
        assert_eq!(s.buckets[1], 2);
        assert_eq!(s.buckets[9], 1); // 1000 in [512, 1024)
        assert_eq!(s.buckets[HIST_BUCKETS - 1], 1);
    }

    /// Merge must be associative and commutative: merging per-shard
    /// snapshots in any grouping equals one global recorder.
    #[test]
    fn histogram_merge_associative() {
        let mk = |vals: &[u64]| {
            let h = Histogram::new();
            for &v in vals {
                h.observe(v);
            }
            h.snapshot()
        };
        let (a, b, c) = (mk(&[1, 5, 9]), mk(&[2, 1 << 20]), mk(&[0, 7, 7, 4096]));
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc);
        let global = mk(&[1, 5, 9, 2, 1 << 20, 0, 7, 7, 4096]);
        assert_eq!(ab_c, global);
    }

    /// Concurrent observers on one shared histogram lose nothing: the
    /// final snapshot equals a serial replay of every observation. Runs
    /// under Miri in CI (small iteration count) so the Relaxed atomics
    /// get checked as a concurrency protocol, not just as arithmetic.
    #[test]
    fn histogram_concurrent_observe_loses_nothing() {
        let threads = 4u64;
        let per: u64 = if cfg!(miri) { 16 } else { 2000 };
        let shared = Histogram::new();
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let h = shared.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        h.observe((t * per + i) % 3000);
                    }
                })
            })
            .collect();
        for j in handles {
            j.join().unwrap();
        }
        let serial = Histogram::new();
        for v in 0..threads * per {
            serial.observe(v % 3000);
        }
        assert_eq!(shared.snapshot(), serial.snapshot());
    }

    #[test]
    fn snapshot_order_is_deterministic() {
        let r = Registry::new();
        r.counter("z.last", &[]).inc();
        r.counter("a.first", &[("shard", "1")]).inc();
        r.counter("a.first", &[("shard", "0")]).inc();
        let names: Vec<String> = r
            .snapshot()
            .metrics
            .iter()
            .map(|m| format!("{}{:?}", m.name, m.labels))
            .collect();
        assert_eq!(
            names,
            vec![
                "a.first[(\"shard\", \"0\")]".to_string(),
                "a.first[(\"shard\", \"1\")]".to_string(),
                format!(
                    "bps_build_info[(\"version\", \"{}\")]",
                    env!("CARGO_PKG_VERSION")
                ),
                "process.uptime_seconds[]".to_string(),
                "z.last[]".to_string(),
            ]
        );
        // twice in a row: identical text modulo the uptime line (which
        // may legitimately tick across a second boundary)
        let strip = |s: String| -> String {
            s.lines()
                .filter(|l| !l.starts_with("process_uptime_seconds"))
                .collect::<Vec<_>>()
                .join("\n")
        };
        assert_eq!(
            strip(r.snapshot().to_prometheus()),
            strip(r.snapshot().to_prometheus())
        );
    }

    #[test]
    fn snapshot_is_self_identifying() {
        let r = Registry::new();
        let text = r.snapshot().to_prometheus();
        assert!(
            text.contains(&format!(
                "bps_build_info{{version=\"{}\"}} 1",
                env!("CARGO_PKG_VERSION")
            )),
            "{text}"
        );
        assert!(text.contains("# TYPE process_uptime_seconds gauge"), "{text}");
    }

    #[test]
    fn prometheus_text_escaping_and_names() {
        let r = Registry::new();
        r.counter("wire.bad_frames", &[("conn", "a\\b\"c\nd")]).add(2);
        let text = r.snapshot().to_prometheus();
        assert!(text.contains("# TYPE wire_bad_frames counter"), "{text}");
        assert!(
            text.contains("wire_bad_frames{conn=\"a\\\\b\\\"c\\nd\"} 2"),
            "{text}"
        );
        // dotted name sanitized, dots gone
        assert!(!text.contains("wire.bad_frames"), "{text}");
    }

    #[test]
    fn prometheus_histogram_rendering() {
        let r = Registry::new();
        let h = r.histogram("lat.us", &[("shard", "0")]);
        h.observe(1);
        h.observe(3);
        h.observe(3);
        let text = r.snapshot().to_prometheus();
        assert!(text.contains("# TYPE lat_us histogram"), "{text}");
        assert!(text.contains("lat_us_bucket{shard=\"0\",le=\"1\"} 1"), "{text}");
        assert!(text.contains("lat_us_bucket{shard=\"0\",le=\"3\"} 3"), "{text}");
        assert!(text.contains("lat_us_bucket{shard=\"0\",le=\"+Inf\"} 3"), "{text}");
        assert!(text.contains("lat_us_sum{shard=\"0\"} 7"), "{text}");
        assert!(text.contains("lat_us_count{shard=\"0\"} 3"), "{text}");
    }
}
