//! Structured event log: bounded JSONL for discrete lifecycle events
//! (lease grant/release, policy-lease decline, curriculum stage advance,
//! idle reap, slow-reader disconnect, bad submits, error frames).
//!
//! Unlike metrics (rates) and traces (per-tick timing), events answer
//! "what happened to session 17?" — low-volume, high-information
//! records. Each line is a self-contained JSON object:
//!
//! ```json
//! {"event":"lease.grant","ts_ms":1723111845123,"session":3,"shard":0,"n_envs":8}
//! ```
//!
//! The log is size-capped: when a write would push the file past
//! `max_bytes` it rotates to `<path>.1` (replacing any previous `.1`),
//! so a long-running server holds at most ~2x the cap on disk. Write
//! errors are swallowed (a full disk must not take down serving); the
//! `dropped` counter records how many events failed to land.
//!
//! Like the trace sink, an unarmed log is a single atomic load per
//! `emit` — no allocation, no formatting, no syscalls.

use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::Result;

use crate::util::json::Json;

/// Default rotation cap: 8 MiB per file.
pub const DEFAULT_EVENT_LOG_BYTES: u64 = 8 << 20;

struct LogState {
    path: PathBuf,
    file: File,
    written: u64,
    max_bytes: u64,
}

/// Shared, initially-disarmed event sink. See module docs.
pub struct EventLog {
    enabled: AtomicBool,
    dropped: AtomicU64,
    state: Mutex<Option<LogState>>,
}

impl EventLog {
    /// A disarmed log: every `emit` is a no-op until [`arm`](Self::arm).
    pub fn disabled() -> EventLog {
        EventLog {
            enabled: AtomicBool::new(false),
            dropped: AtomicU64::new(0),
            state: Mutex::new(None),
        }
    }

    /// Open (truncate) `path` and start accepting events, rotating to
    /// `<path>.1` when the file would exceed `max_bytes`.
    pub fn arm(&self, path: &Path, max_bytes: u64) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let file = File::create(path)?;
        *self.state.lock().unwrap() = Some(LogState {
            path: path.to_path_buf(),
            file,
            written: 0,
            max_bytes: max_bytes.max(1024),
        });
        self.enabled.store(true, Ordering::Relaxed);
        Ok(())
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Events that failed to land (I/O error on write or rotate).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Append one event line. No-op when disarmed.
    pub fn emit(&self, event: &str, fields: &[(&str, Json)]) {
        if !self.enabled() {
            return;
        }
        let ts_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("event".to_string(), Json::Str(event.to_string()));
        obj.insert("ts_ms".to_string(), Json::Num(ts_ms as f64));
        for (k, v) in fields {
            obj.insert(k.to_string(), v.clone());
        }
        let mut line = Json::Obj(obj).to_string();
        line.push('\n');

        let mut guard = self.state.lock().unwrap();
        let Some(st) = guard.as_mut() else { return };
        if st.written + line.len() as u64 > st.max_bytes && st.written > 0 {
            if Self::rotate(st).is_err() {
                self.dropped.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        let ok = st
            .file
            .write_all(line.as_bytes())
            .and_then(|()| st.file.flush())
            .is_ok();
        if ok {
            st.written += line.len() as u64;
        } else {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Read back up to `max_bytes` of the current file's tail (whole
    /// lines — a cut line at the window edge is dropped). `None` when
    /// disarmed. Used by the flight recorder's `events.tail.jsonl`.
    pub fn tail(&self, max_bytes: u64) -> Option<String> {
        let mut guard = self.state.lock().unwrap();
        let st = guard.as_mut()?;
        let _ = st.file.flush();
        let text = std::fs::read_to_string(&st.path).ok()?;
        if text.len() as u64 <= max_bytes {
            return Some(text);
        }
        let start = text.len() - max_bytes as usize;
        let from = text
            .as_bytes()
            .iter()
            .skip(start)
            .position(|&b| b == b'\n')
            .map(|p| start + p + 1)
            .unwrap_or(text.len());
        Some(text.get(from..).unwrap_or_default().to_string())
    }

    fn rotate(st: &mut LogState) -> std::io::Result<()> {
        let mut rotated = st.path.as_os_str().to_owned();
        rotated.push(".1");
        // Rename replaces any previous .1: at most ~2x max_bytes on disk.
        std::fs::rename(&st.path, PathBuf::from(rotated))?;
        st.file = File::create(&st.path)?;
        st.written = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("bps_obs_event_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn disarmed_log_is_a_noop() {
        let log = EventLog::disabled();
        log.emit("x", &[]);
        assert!(!log.enabled());
        assert_eq!(log.dropped(), 0);
    }

    #[test]
    fn emits_parseable_jsonl_with_fields() {
        let path = tmp("basic.jsonl");
        let log = EventLog::disabled();
        log.arm(&path, 1 << 20).unwrap();
        log.emit(
            "lease.grant",
            &[("session", Json::Num(3.0)), ("shard", Json::Num(0.0))],
        );
        log.emit("lease.release", &[("session", Json::Num(3.0))]);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.req("event").unwrap().as_str().unwrap(), "lease.grant");
        assert_eq!(first.req("session").unwrap().as_f64().unwrap(), 3.0);
        assert!(first.req("ts_ms").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn tail_returns_whole_recent_lines() {
        let path = tmp("tail.jsonl");
        let log = EventLog::disabled();
        assert!(log.tail(1024).is_none(), "disarmed log has no tail");
        log.arm(&path, 1 << 20).unwrap();
        for i in 0..32 {
            log.emit("tick", &[("i", Json::Num(i as f64))]);
        }
        let full = log.tail(1 << 20).unwrap();
        assert_eq!(full.lines().count(), 32);
        let tail = log.tail(128).unwrap();
        assert!(tail.len() <= 128);
        assert!(!tail.is_empty());
        for line in tail.lines() {
            Json::parse(line).unwrap();
        }
        // the tail ends where the log ends
        assert!(full.ends_with(&tail));
    }

    #[test]
    fn rotates_at_size_cap() {
        let path = tmp("rotate.jsonl");
        let log = EventLog::disabled();
        log.arm(&path, 1024).unwrap(); // min cap
        for i in 0..64 {
            log.emit("tick", &[("i", Json::Num(i as f64))]);
        }
        let rotated = PathBuf::from({
            let mut s = path.as_os_str().to_owned();
            s.push(".1");
            s
        });
        assert!(rotated.exists(), "rotation file missing");
        assert!(std::fs::metadata(&rotated).unwrap().len() <= 1024);
        // both files still hold only whole, parseable lines
        for p in [&path, &rotated] {
            let text = std::fs::read_to_string(p).unwrap();
            assert!(!text.is_empty());
            for line in text.lines() {
                Json::parse(line).unwrap();
            }
        }
        assert_eq!(log.dropped(), 0);
    }
}
