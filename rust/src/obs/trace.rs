//! Megaframe tracing: a bounded per-tick span recorder exportable as
//! Chrome `trace_event` JSON (load the file in Perfetto / `chrome://tracing`).
//!
//! Every pipeline stage of a served tick records a [`Span`]: coalesce
//! wait, sim step, render transform/cull/raster/resolve, tenant
//! gather/infer/step, wire encode/flush. Spans land in a bounded ring
//! (oldest evicted first), so a long-running server keeps the most
//! recent window of ticks and one Perfetto load shows exactly where a
//! straggler megaframe went.
//!
//! Recording is gated on an `AtomicBool`: with tracing disabled (the
//! default), `record` is a single relaxed load and the pipeline does not
//! even construct spans — observability must never perturb the
//! simulation (the sync stepping path stays bitwise-identical).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Default ring capacity: ~64k spans ≈ several thousand ticks.
pub const DEFAULT_TRACE_SPANS: usize = 1 << 16;

/// Chrome-trace "process id" used for spans that belong to the wire
/// layer rather than to a shard.
pub const WIRE_PID: u32 = 9999;

/// Chrome-trace pid for the tenant (in-server policy) layer.
pub const TENANT_PID: u32 = 9000;

/// One completed pipeline stage. `lane` groups spans onto a Perfetto
/// track (a Chrome-trace "thread") within the `pid` process row.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Span {
    /// Microseconds since the sink's epoch.
    pub ts_us: u64,
    pub dur_us: u64,
    /// Shard index, or [`WIRE_PID`] / [`TENANT_PID`].
    pub pid: u32,
    pub lane: &'static str,
    pub name: &'static str,
    /// Driver tick / step number the span belongs to.
    pub tick: u64,
}

struct Ring {
    spans: VecDeque<Span>,
    cap: usize,
    /// Spans evicted since enable (ring overflow), for the export footer.
    dropped: u64,
}

/// Shared span recorder (one per `SimServer`). See module docs.
pub struct TraceSink {
    enabled: AtomicBool,
    epoch: Instant,
    ring: Mutex<Ring>,
}

impl TraceSink {
    pub fn new(cap: usize) -> TraceSink {
        TraceSink {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            ring: Mutex::new(Ring {
                spans: VecDeque::with_capacity(cap.min(4096)),
                cap: cap.max(1),
                dropped: 0,
            }),
        }
    }

    pub fn enable(&self) {
        self.enabled.store(true, Ordering::Relaxed);
    }

    /// Hot-path gate: producers skip span construction entirely when
    /// this is false.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Microseconds since this sink's epoch (span timestamp base).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    pub fn record(&self, span: Span) {
        if !self.enabled() {
            return;
        }
        let mut ring = self.ring.lock().unwrap();
        if ring.spans.len() == ring.cap {
            ring.spans.pop_front();
            ring.dropped += 1;
        }
        ring.spans.push_back(span);
    }

    /// Convenience: record a span from a start timestamp and a duration.
    pub fn span(
        &self,
        pid: u32,
        lane: &'static str,
        name: &'static str,
        start_us: u64,
        dur: Duration,
        tick: u64,
    ) {
        self.record(Span {
            ts_us: start_us,
            dur_us: dur.as_micros() as u64,
            pid,
            lane,
            name,
            tick,
        });
    }

    /// Current ring contents, oldest first.
    pub fn spans(&self) -> Vec<Span> {
        self.ring.lock().unwrap().spans.iter().cloned().collect()
    }

    pub fn dropped(&self) -> u64 {
        self.ring.lock().unwrap().dropped
    }

    /// Export the ring as Chrome `trace_event` JSON (the object form,
    /// `{"traceEvents": [...]}`), with process/thread name metadata so
    /// Perfetto shows "shard 0 / render" instead of bare pids.
    pub fn to_chrome_json(&self) -> String {
        let spans = self.spans();
        let mut events: Vec<Json> = Vec::with_capacity(spans.len() + 16);

        let mut pids: Vec<u32> = spans.iter().map(|s| s.pid).collect();
        pids.sort_unstable();
        pids.dedup();
        let mut lanes: Vec<(u32, &'static str)> = spans.iter().map(|s| (s.pid, s.lane)).collect();
        lanes.sort_unstable();
        lanes.dedup();

        let meta = |name: &str, pid: u32, tid: u64, arg_name: &str| {
            let mut args = std::collections::BTreeMap::new();
            args.insert("name".to_string(), Json::Str(arg_name.to_string()));
            let mut ev = std::collections::BTreeMap::new();
            ev.insert("ph".to_string(), Json::Str("M".to_string()));
            ev.insert("name".to_string(), Json::Str(name.to_string()));
            ev.insert("pid".to_string(), Json::Num(pid as f64));
            ev.insert("tid".to_string(), Json::Num(tid as f64));
            ev.insert("args".to_string(), Json::Obj(args));
            Json::Obj(ev)
        };
        for &pid in &pids {
            let pname = match pid {
                WIRE_PID => "wire".to_string(),
                TENANT_PID => "tenant".to_string(),
                i => format!("shard {i}"),
            };
            events.push(meta("process_name", pid, 0, &pname));
        }
        // tid = 1 + index of the lane within its pid (0 is the meta row)
        let tid_of = |pid: u32, lane: &str| -> u64 {
            1 + lanes
                .iter()
                .filter(|(p, _)| *p == pid)
                .position(|(_, l)| *l == lane)
                .unwrap_or(0) as u64
        };
        for &(pid, lane) in &lanes {
            events.push(meta("thread_name", pid, tid_of(pid, lane), lane));
        }

        for s in &spans {
            let mut args = std::collections::BTreeMap::new();
            args.insert("tick".to_string(), Json::Num(s.tick as f64));
            let mut ev = std::collections::BTreeMap::new();
            ev.insert("ph".to_string(), Json::Str("X".to_string()));
            ev.insert("name".to_string(), Json::Str(s.name.to_string()));
            ev.insert("cat".to_string(), Json::Str(s.lane.to_string()));
            ev.insert("pid".to_string(), Json::Num(s.pid as f64));
            ev.insert("tid".to_string(), Json::Num(tid_of(s.pid, s.lane) as f64));
            ev.insert("ts".to_string(), Json::Num(s.ts_us as f64));
            ev.insert("dur".to_string(), Json::Num(s.dur_us as f64));
            ev.insert("args".to_string(), Json::Obj(args));
            events.push(Json::Obj(ev));
        }

        let mut root = std::collections::BTreeMap::new();
        root.insert("traceEvents".to_string(), Json::Arr(events));
        root.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
        root.insert(
            "bpsDroppedSpans".to_string(),
            Json::Num(self.dropped() as f64),
        );
        Json::Obj(root).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp(name: &'static str, ts: u64) -> Span {
        Span {
            ts_us: ts,
            dur_us: 5,
            pid: 0,
            lane: "driver",
            name,
            tick: ts,
        }
    }

    #[test]
    fn disabled_sink_records_nothing() {
        let t = TraceSink::new(8);
        t.record(sp("a", 1));
        assert!(t.spans().is_empty());
        t.enable();
        t.record(sp("a", 1));
        assert_eq!(t.spans().len(), 1);
    }

    /// Ring eviction is strictly oldest-first and counts drops.
    #[test]
    fn ring_evicts_oldest_first() {
        let t = TraceSink::new(3);
        t.enable();
        for i in 0..5 {
            t.record(sp("s", i));
        }
        let got: Vec<u64> = t.spans().iter().map(|s| s.ts_us).collect();
        assert_eq!(got, vec![2, 3, 4]);
        assert_eq!(t.dropped(), 2);
    }

    #[test]
    fn chrome_export_is_valid_json_with_metadata() {
        let t = TraceSink::new(16);
        t.enable();
        t.record(Span {
            ts_us: 10,
            dur_us: 3,
            pid: 0,
            lane: "render",
            name: "raster",
            tick: 1,
        });
        t.record(Span {
            ts_us: 14,
            dur_us: 2,
            pid: WIRE_PID,
            lane: "wire",
            name: "encode",
            tick: 1,
        });
        let text = t.to_chrome_json();
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.req("traceEvents").unwrap().as_arr().unwrap();
        // 2 process_name + 2 thread_name + 2 spans
        assert_eq!(events.len(), 6);
        let xs: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str().ok()) == Some("X"))
            .collect();
        assert_eq!(xs.len(), 2);
        assert_eq!(xs[0].req("name").unwrap().as_str().unwrap(), "raster");
        assert_eq!(xs[0].req("ts").unwrap().as_f64().unwrap(), 10.0);
        assert_eq!(xs[0].req("dur").unwrap().as_f64().unwrap(), 3.0);
        assert!(text.contains("\"process_name\""));
        assert!(text.contains("\"shard 0\""));
        assert!(text.contains("\"wire\""));
    }
}
