//! Minimal plaintext exposition endpoint: `GET /metrics` serves the
//! registry's Prometheus text, `GET /healthz` a readiness answer, and
//! `GET /debug/dump` a manual flight-recorder trigger.
//!
//! Hand-rolled HTTP/1.1 like the wire layer — no new dependencies. The
//! accept thread hands each connection to a short-lived worker thread
//! (capped at [`MAX_CONNS`]; excess connections get an immediate 503),
//! so one slow-loris scraper can no longer delay a health probe — the
//! exact property a watchdog-driven `/healthz` needs. Requests are read
//! with a short timeout and every response closes the connection.
//!
//! The dynamic endpoints are wired through [`HttpHooks`]: without hooks
//! (`bps train --metrics-addr`, unit tests) `/healthz` degenerates to
//! the legacy static `ok` and `/debug/dump` to 404; `bps serve` installs
//! watchdog + recorder backed hooks.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::registry::Registry;
use super::watchdog::Heartbeat;

/// How often the accept loop polls for shutdown.
const ACCEPT_POLL: Duration = Duration::from_millis(25);
/// Watchdog thresholds for the accept thread: it wakes at least every
/// [`ACCEPT_POLL`], so a second of silence means the scrape surface (and
/// with it `/healthz`) is wedged.
const HTTP_DEGRADED: Duration = Duration::from_secs(1);
const HTTP_STALLED: Duration = Duration::from_secs(5);
/// Per-request read deadline and cap on the request head we will buffer.
const READ_TIMEOUT: Duration = Duration::from_secs(2);
const MAX_REQUEST_HEAD: usize = 4096;
/// Concurrent connection cap; connection 33 gets an inline 503.
pub const MAX_CONNS: usize = 32;

/// Dynamic answers for the active endpoints. `Default` keeps the legacy
/// static behaviour (`/healthz` → `ok`, `/debug/dump` → 404).
#[derive(Clone, Default)]
pub struct HttpHooks {
    /// `(healthy, json_body)` — unhealthy renders as 503 so a router or
    /// orchestrator stops placing leases on a sick server.
    pub health: Option<Arc<dyn Fn() -> (bool, String) + Send + Sync>>,
    /// Manual flight-recorder trigger; `Ok(json_body)` names the bundle.
    pub dump: Option<Arc<dyn Fn() -> std::result::Result<String, String> + Send + Sync>>,
}

/// Background `/metrics` + `/healthz` + `/debug/dump` server. Dropping
/// it stops the accept thread (in-flight connection workers finish on
/// their own; they hold only `Arc`s).
pub struct MetricsServer {
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
    heartbeat: Heartbeat,
}

impl MetricsServer {
    /// Listen with the legacy static endpoints only.
    pub fn listen<A: ToSocketAddrs>(addr: A, registry: Arc<Registry>) -> Result<MetricsServer> {
        Self::listen_with(addr, registry, HttpHooks::default())
    }

    /// Listen with dynamic `/healthz` and `/debug/dump` hooks.
    pub fn listen_with<A: ToSocketAddrs>(
        addr: A,
        registry: Arc<Registry>,
        hooks: HttpHooks,
    ) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr).context("bind metrics addr")?;
        listener
            .set_nonblocking(true)
            .context("metrics listener nonblocking")?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let heartbeat = Heartbeat::new("metrics-http", HTTP_DEGRADED, HTTP_STALLED);
        let thread = {
            let shutdown = Arc::clone(&shutdown);
            let hb = heartbeat.clone();
            std::thread::Builder::new()
                .name("bps-metrics-http".into())
                .spawn(move || accept_loop(listener, registry, hooks, shutdown, hb))
                .context("spawn metrics thread")?
        };
        Ok(MetricsServer {
            addr,
            shutdown,
            thread: Some(thread),
            heartbeat,
        })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// The accept thread's liveness beacon. Standalone uses (tests,
    /// `bps train --metrics-addr`) may ignore it; `bps serve` adopts it
    /// into the server's watchdog so a wedged scrape surface shows up in
    /// `/healthz` like any other stalled role.
    pub fn heartbeat(&self) -> &Heartbeat {
        &self.heartbeat
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// RAII slot in the connection cap: decrements on drop, so a worker that
/// panics (or a closure dropped by a failed spawn) still frees its slot.
struct ConnSlot(Arc<AtomicUsize>);

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

fn accept_loop(
    listener: TcpListener,
    registry: Arc<Registry>,
    hooks: HttpHooks,
    shutdown: Arc<AtomicBool>,
    hb: Heartbeat,
) {
    let active = Arc::new(AtomicUsize::new(0));
    while !shutdown.load(Ordering::SeqCst) {
        hb.beat();
        match listener.accept() {
            Ok((stream, _)) => {
                if active.fetch_add(1, Ordering::SeqCst) >= MAX_CONNS {
                    active.fetch_sub(1, Ordering::SeqCst);
                    let _ = reply_overloaded(stream);
                    continue;
                }
                let slot = ConnSlot(Arc::clone(&active));
                let registry = Arc::clone(&registry);
                let hooks = hooks.clone();
                let _ = std::thread::Builder::new()
                    .name("bps-metrics-conn".into())
                    .spawn(move || {
                        let _slot = slot;
                        let _ = handle(stream, &registry, &hooks);
                    });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

fn reply_overloaded(mut stream: TcpStream) -> std::io::Result<()> {
    // Over the cap: answer without reading the request at all, so the
    // flood cannot cost us a read timeout per connection.
    let body = "overloaded\n";
    let header = format!(
        "HTTP/1.1 503 Service Unavailable\r\nContent-Type: text/plain\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

fn handle(mut stream: TcpStream, registry: &Registry, hooks: &HttpHooks) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    let mut head = Vec::with_capacity(256);
    let mut buf = [0u8; 512];
    // Read until the end of the request head; the body (none expected
    // for GET/HEAD) is ignored.
    while !head.windows(4).any(|w| w == b"\r\n\r\n") && head.len() < MAX_REQUEST_HEAD {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => head.extend_from_slice(&buf[..n]),
            Err(_) => break,
        }
    }
    let line = head
        .split(|&b| b == b'\r' || b == b'\n')
        .next()
        .unwrap_or(&[]);
    let line = String::from_utf8_lossy(line);
    let mut parts = line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));

    let (status, ctype, body) = if method != "GET" && method != "HEAD" {
        (
            "405 Method Not Allowed",
            "text/plain",
            "method not allowed\n".to_string(),
        )
    } else {
        match path {
            "/metrics" => (
                "200 OK",
                // version=0.0.4 is the Prometheus text-format content type
                "text/plain; version=0.0.4; charset=utf-8",
                registry.snapshot().to_prometheus(),
            ),
            "/healthz" => match &hooks.health {
                Some(h) => {
                    let (ok, body) = h();
                    let status = if ok { "200 OK" } else { "503 Service Unavailable" };
                    (status, "application/json", body)
                }
                None => ("200 OK", "text/plain", "ok\n".to_string()),
            },
            "/debug/dump" => match &hooks.dump {
                Some(d) => match d() {
                    Ok(body) => ("200 OK", "application/json", body),
                    Err(msg) => ("503 Service Unavailable", "application/json", msg),
                },
                None => ("404 Not Found", "text/plain", "not found\n".to_string()),
            },
            _ => ("404 Not Found", "text/plain", "not found\n".to_string()),
        }
    };
    let header = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    // HEAD gets the same status and headers (including the true
    // Content-Length) with no body bytes.
    if method != "HEAD" {
        stream.write_all(body.as_bytes())?;
    }
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn request(addr: std::net::SocketAddr, method: &str, path: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "{method} {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        let (head, body) = out.split_once("\r\n\r\n").unwrap();
        (head.to_string(), body.to_string())
    }

    fn get(addr: std::net::SocketAddr, path: &str) -> (String, String) {
        request(addr, "GET", path)
    }

    /// Drop the uptime line before exact-compare: it may tick across a
    /// second boundary between two renders.
    fn strip_uptime(s: &str) -> String {
        s.lines()
            .filter(|l| !l.starts_with("process_uptime_seconds"))
            .collect::<Vec<_>>()
            .join("\n")
    }

    #[test]
    fn serves_metrics_healthz_and_404() {
        let registry = Registry::new();
        registry.counter("serve.shard.steps", &[("shard", "0")]).add(3);
        let srv = MetricsServer::listen("127.0.0.1:0", Arc::clone(&registry)).unwrap();
        let addr = srv.local_addr();

        let (head, body) = get(addr, "/metrics");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(body.contains("serve_shard_steps{shard=\"0\"} 3"), "{body}");
        // scrape matches the registry's own canonical rendering exactly
        assert_eq!(
            strip_uptime(&body),
            strip_uptime(&registry.snapshot().to_prometheus())
        );

        let (head, body) = get(addr, "/healthz");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert_eq!(body, "ok\n");

        let (head, _) = get(addr, "/nope");
        assert!(head.starts_with("HTTP/1.1 404"), "{head}");

        // without a hook the dump endpoint does not exist
        let (head, _) = get(addr, "/debug/dump");
        assert!(head.starts_with("HTTP/1.1 404"), "{head}");
    }

    #[test]
    fn head_gets_headers_and_no_body() {
        let registry = Registry::new();
        let srv = MetricsServer::listen("127.0.0.1:0", Arc::clone(&registry)).unwrap();
        let addr = srv.local_addr();

        let (head, body) = request(addr, "HEAD", "/metrics");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(body.is_empty(), "HEAD must not carry a body: {body:?}");
        // ...but the advertised length is the real one
        let len: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .unwrap()
            .parse()
            .unwrap();
        assert!(len > 0);

        let (head, _) = request(addr, "POST", "/metrics");
        assert!(head.starts_with("HTTP/1.1 405"), "{head}");
    }

    #[test]
    fn hooks_drive_healthz_and_dump() {
        let registry = Registry::new();
        let healthy = Arc::new(AtomicBool::new(true));
        let h = Arc::clone(&healthy);
        let hooks = HttpHooks {
            health: Some(Arc::new(move || {
                if h.load(Ordering::SeqCst) {
                    (true, "{\"status\":\"ok\"}".to_string())
                } else {
                    (false, "{\"status\":\"stalled\",\"stalled\":[\"shard-driver\"]}".to_string())
                }
            })),
            dump: Some(Arc::new(|| Ok("{\"bundle\":\"/tmp/x\"}".to_string()))),
        };
        let srv = MetricsServer::listen_with("127.0.0.1:0", registry, hooks).unwrap();
        let addr = srv.local_addr();

        let (head, body) = get(addr, "/healthz");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(body.contains("\"ok\""), "{body}");

        healthy.store(false, Ordering::SeqCst);
        let (head, body) = get(addr, "/healthz");
        assert!(head.starts_with("HTTP/1.1 503"), "{head}");
        assert!(body.contains("shard-driver"), "{body}");

        let (head, body) = get(addr, "/debug/dump");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(body.contains("bundle"), "{body}");
    }
}
