//! Minimal plaintext exposition endpoint: `GET /metrics` serves the
//! registry's Prometheus text, `GET /healthz` a liveness line.
//!
//! Hand-rolled HTTP/1.1 like the wire layer — no new dependencies. One
//! accept thread handles connections serially (scrapes are rare and the
//! response is a single pre-rendered string); requests are read with a
//! short timeout and every response closes the connection, so a stuck
//! scraper cannot wedge the endpoint for more than the read timeout.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::registry::Registry;

/// How often the accept loop polls for shutdown.
const ACCEPT_POLL: Duration = Duration::from_millis(25);
/// Per-request read deadline and cap on the request head we will buffer.
const READ_TIMEOUT: Duration = Duration::from_secs(2);
const MAX_REQUEST_HEAD: usize = 4096;

/// Background `/metrics` + `/healthz` server. Dropping it stops the
/// accept thread.
pub struct MetricsServer {
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl MetricsServer {
    pub fn listen<A: ToSocketAddrs>(addr: A, registry: Arc<Registry>) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr).context("bind metrics addr")?;
        listener
            .set_nonblocking(true)
            .context("metrics listener nonblocking")?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let thread = {
            let shutdown = Arc::clone(&shutdown);
            std::thread::Builder::new()
                .name("bps-metrics-http".into())
                .spawn(move || accept_loop(listener, registry, shutdown))
                .context("spawn metrics thread")?
        };
        Ok(MetricsServer {
            addr,
            shutdown,
            thread: Some(thread),
        })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn accept_loop(listener: TcpListener, registry: Arc<Registry>, shutdown: Arc<AtomicBool>) {
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                // Serve inline: the response is one pre-rendered string.
                let _ = handle(stream, &registry);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

fn handle(mut stream: TcpStream, registry: &Registry) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    let mut head = Vec::with_capacity(256);
    let mut buf = [0u8; 512];
    // Read until the end of the request head; the body (none expected
    // for GET) is ignored.
    while !head.windows(4).any(|w| w == b"\r\n\r\n") && head.len() < MAX_REQUEST_HEAD {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => head.extend_from_slice(&buf[..n]),
            Err(_) => break,
        }
    }
    let line = head
        .split(|&b| b == b'\r' || b == b'\n')
        .next()
        .unwrap_or(&[]);
    let line = String::from_utf8_lossy(line);
    let mut parts = line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));

    let (status, ctype, body) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain", "method not allowed\n".to_string())
    } else {
        match path {
            "/metrics" => (
                "200 OK",
                // version=0.0.4 is the Prometheus text-format content type
                "text/plain; version=0.0.4; charset=utf-8",
                registry.snapshot().to_prometheus(),
            ),
            "/healthz" => ("200 OK", "text/plain", "ok\n".to_string()),
            _ => ("404 Not Found", "text/plain", "not found\n".to_string()),
        }
    };
    let header = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: std::net::SocketAddr, path: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        let (head, body) = out.split_once("\r\n\r\n").unwrap();
        (head.to_string(), body.to_string())
    }

    #[test]
    fn serves_metrics_healthz_and_404() {
        let registry = Registry::new();
        registry.counter("serve.shard.steps", &[("shard", "0")]).add(3);
        let srv = MetricsServer::listen("127.0.0.1:0", Arc::clone(&registry)).unwrap();
        let addr = srv.local_addr();

        let (head, body) = get(addr, "/metrics");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(body.contains("serve_shard_steps{shard=\"0\"} 3"), "{body}");
        // scrape matches the registry's own canonical rendering exactly
        assert_eq!(body, registry.snapshot().to_prometheus());

        let (head, body) = get(addr, "/healthz");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert_eq!(body, "ok\n");

        let (head, _) = get(addr, "/nope");
        assert!(head.starts_with("HTTP/1.1 404"), "{head}");
    }
}
