//! Health watchdog: liveness classification for every long-lived thread
//! (DESIGN.md §0.11).
//!
//! Each driver/pump thread registers a [`Heartbeat`] and calls
//! [`Heartbeat::beat`] once per loop iteration — a relaxed atomic store,
//! nothing else. Threads that legitimately park for unbounded time (a
//! `Wait`-policy shard driver between submits, a wire reader on a quiet
//! peer) call [`Heartbeat::idle`] *before* blocking, so silence while
//! parked classifies Healthy instead of Stalled; the next `beat` clears
//! the marker.
//!
//! A background thread ([`Watchdog::start`]) rescans the table every
//! [`SCAN_INTERVAL`] and classifies each instance Healthy / Degraded /
//! Stalled against its per-role thresholds. Transitions are debounced
//! (two consecutive scans must agree) and then acted on:
//!
//! - `obs.watchdog.state{role}` gauges (0 = healthy, 1 = degraded,
//!   2 = stalled, 3 = dead) and the `obs.watchdog.stalls` counter on
//!   the registry;
//! - `watchdog.stall` / `watchdog.recover` events on the event log;
//! - an incident bundle via the flight [`Recorder`] when one is armed;
//! - [`Watchdog::report`], which backs `GET /healthz`: a stalled role
//!   flips the endpoint to 503 with a JSON body naming the role, so a
//!   router can stop placing leases on a sick server.
//!
//! Heartbeats deregister themselves: when every clone outside the
//! watchdog is dropped (thread exited, cleanly or by panic-unwind while
//! holding its only clone), the next scan reaps the entry. A thread that
//! dies while its heartbeat is still reachable (e.g. a shard driver
//! whose handle lives in `ShardShared`) keeps its entry and goes Stalled
//! — a dead driver *is* a sick server.
//!
//! Test hooks: [`Watchdog::inject_stall`] forces a role to report
//! Stalled (also reachable via the `BPS_FAULT_STALL` environment
//! variable in `bps serve`), and [`Watchdog::scan_once`] runs one scan
//! at an explicit instant for sleep-free unit tests.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::json::Json;

use super::event::EventLog;
use super::recorder::{Recorder, Trigger};
use super::registry::{Counter, Registry};

/// Background rescan cadence. Detection latency is roughly
/// `threshold + 2 * SCAN_INTERVAL` (two scans of debounce).
pub const SCAN_INTERVAL: Duration = Duration::from_millis(50);

/// Consecutive scans that must agree before a level change commits, so
/// one delayed scan cannot flap `/healthz`.
const DEBOUNCE_SCANS: u32 = 2;

/// Health classification of one heartbeat (or the worst of a role).
///
/// `Dead` is terminal and declared, not inferred: a supervisor that
/// *caught* the thread's panic calls [`Heartbeat::dead`], and the next
/// scan commits it immediately (no debounce — a confessed death needs
/// no second opinion). Only [`Heartbeat::revive`] (shard restart)
/// clears it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Healthy,
    Degraded,
    Stalled,
    Dead,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Healthy => "healthy",
            Level::Degraded => "degraded",
            Level::Stalled => "stalled",
            Level::Dead => "dead",
        }
    }

    fn gauge_value(self) -> f64 {
        self as i32 as f64
    }
}

struct Cell {
    role: &'static str,
    degraded: Duration,
    stalled: Duration,
    ticks: AtomicU64,
    idle: AtomicBool,
    dead: AtomicBool,
}

/// A per-thread liveness handle. Cheap to clone; clones share the cell.
/// Constructible before any watchdog exists (the procgen generator
/// spawns before the `SimServer` does) and adopted later via
/// [`Watchdog::adopt`].
#[derive(Clone)]
pub struct Heartbeat {
    cell: Arc<Cell>,
}

impl Heartbeat {
    pub fn new(role: &'static str, degraded: Duration, stalled: Duration) -> Heartbeat {
        Heartbeat {
            cell: Arc::new(Cell {
                role,
                degraded,
                stalled: stalled.max(degraded),
                ticks: AtomicU64::new(0),
                idle: AtomicBool::new(false),
                dead: AtomicBool::new(false),
            }),
        }
    }

    /// Record one loop iteration of progress (and clear any idle
    /// marker). One relaxed store + one relaxed add — hot-path safe.
    pub fn beat(&self) {
        self.cell.idle.store(false, Ordering::Relaxed);
        self.cell.ticks.fetch_add(1, Ordering::Relaxed);
    }

    /// Mark the thread as deliberately parked (about to block for
    /// unbounded time on a condvar / channel / socket read). Idle
    /// instances classify Healthy until the next [`beat`](Self::beat).
    pub fn idle(&self) {
        self.cell.idle.store(true, Ordering::Relaxed);
    }

    /// Declare the owning thread dead (its panic was caught by a
    /// supervisor). Terminal until [`revive`](Self::revive); the next
    /// scan commits [`Level::Dead`] with no debounce.
    pub fn dead(&self) {
        self.cell.dead.store(true, Ordering::Relaxed);
    }

    /// Lift a [`dead`](Self::dead) declaration after the thread has
    /// been respawned (e.g. `SimServer::restart_shard`), counting one
    /// beat so the fresh thread starts Healthy, not Stalled.
    pub fn revive(&self) {
        self.cell.dead.store(false, Ordering::Relaxed);
        self.beat();
    }

    pub fn is_dead(&self) -> bool {
        self.cell.dead.load(Ordering::Relaxed)
    }

    pub fn role(&self) -> &'static str {
        self.cell.role
    }
}

struct Tracked {
    cell: Arc<Cell>,
    last_ticks: u64,
    last_progress: Instant,
    committed: Level,
    pending: Level,
    pending_scans: u32,
}

struct Inner {
    registry: Arc<Registry>,
    events: Arc<EventLog>,
    tracked: Mutex<Vec<Tracked>>,
    /// Roles forced to Stalled (tests / `BPS_FAULT_STALL`); the bool
    /// records whether the stall event has been announced.
    injected: Mutex<BTreeMap<String, bool>>,
    /// Every role ever tracked, so its state gauge keeps rendering
    /// (Healthy) after all instances retire.
    roles: Mutex<BTreeSet<&'static str>>,
    recorder: Mutex<Option<Arc<Recorder>>>,
    stalls: Counter,
    stop: AtomicBool,
}

/// What `/healthz` answers: dead/stalled/degraded role names,
/// deduplicated. A dead role (quarantined shard driver) is reported
/// separately from a stalled one — the former needs `restart_shard`,
/// the latter may recover on its own.
#[derive(Clone, Debug, Default)]
pub struct HealthReport {
    pub dead: Vec<String>,
    pub stalled: Vec<String>,
    pub degraded: Vec<String>,
}

impl HealthReport {
    pub fn healthy(&self) -> bool {
        self.dead.is_empty() && self.stalled.is_empty()
    }

    /// JSON body for the health endpoint, e.g.
    /// `{"status":"stalled","dead":[],"stalled":["shard-driver"],"degraded":[]}`.
    pub fn to_json(&self) -> String {
        let status = if !self.dead.is_empty() {
            "dead"
        } else if !self.stalled.is_empty() {
            "stalled"
        } else if !self.degraded.is_empty() {
            "degraded"
        } else {
            "ok"
        };
        let arr = |v: &[String]| Json::Arr(v.iter().map(|r| Json::Str(r.clone())).collect());
        let mut obj = BTreeMap::new();
        obj.insert("status".to_string(), Json::Str(status.to_string()));
        obj.insert("dead".to_string(), arr(&self.dead));
        obj.insert("stalled".to_string(), arr(&self.stalled));
        obj.insert("degraded".to_string(), arr(&self.degraded));
        Json::Obj(obj).to_string()
    }
}

/// The watchdog itself. See module docs.
pub struct Watchdog {
    inner: Arc<Inner>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

impl Watchdog {
    /// Build without a background thread (unit tests drive
    /// [`scan_once`](Self::scan_once) explicitly).
    pub fn unstarted(registry: Arc<Registry>, events: Arc<EventLog>) -> Watchdog {
        let stalls = registry.counter("obs.watchdog.stalls", &[]);
        Watchdog {
            inner: Arc::new(Inner {
                registry,
                events,
                tracked: Mutex::new(Vec::new()),
                injected: Mutex::new(BTreeMap::new()),
                roles: Mutex::new(BTreeSet::new()),
                recorder: Mutex::new(None),
                stalls,
                stop: AtomicBool::new(false),
            }),
            thread: Mutex::new(None),
        }
    }

    /// Build and spawn the background scan thread (stopped by
    /// [`stop`](Self::stop) or `Drop`).
    pub fn start(registry: Arc<Registry>, events: Arc<EventLog>) -> Arc<Watchdog> {
        let wd = Watchdog::unstarted(registry, events);
        let inner = Arc::clone(&wd.inner);
        let handle = std::thread::Builder::new()
            .name("bps-watchdog".into())
            .spawn(move || {
                // relaxed: shutdown poll — a stale read delays exit by at
                // most one SCAN_INTERVAL; stop() joins the thread, so no
                // state is read after the flag is observed
                while !inner.stop.load(Ordering::Relaxed) {
                    std::thread::sleep(SCAN_INTERVAL);
                    scan(&inner, Instant::now());
                }
            })
            .expect("spawn watchdog thread");
        *wd.thread.lock().unwrap() = Some(handle);
        Arc::new(wd)
    }

    /// Register a fresh heartbeat for `role` with the given thresholds.
    pub fn register(
        &self,
        role: &'static str,
        degraded: Duration,
        stalled: Duration,
    ) -> Heartbeat {
        let hb = Heartbeat::new(role, degraded, stalled);
        self.adopt(&hb);
        hb
    }

    /// Track an externally-created heartbeat (e.g. the scenario
    /// generator's, created before the server existed).
    pub fn adopt(&self, hb: &Heartbeat) {
        let mut t = self.inner.tracked.lock().unwrap();
        t.push(Tracked {
            last_ticks: hb.cell.ticks.load(Ordering::Relaxed),
            last_progress: Instant::now(),
            committed: Level::Healthy,
            pending: Level::Healthy,
            pending_scans: 0,
            cell: Arc::clone(&hb.cell),
        });
    }

    /// Wire the flight recorder: committed stalls trigger an incident
    /// bundle (rate-limited by the recorder itself).
    pub fn set_recorder(&self, rec: Arc<Recorder>) {
        *self.inner.recorder.lock().unwrap() = Some(rec);
    }

    /// Force `role` to report Stalled until [`clear_stall`]
    /// (Self::clear_stall)] — the test/CI fault-injection hook. Takes
    /// effect on `report()` immediately and on gauges/events/bundles at
    /// the next scan.
    pub fn inject_stall(&self, role: &str) {
        self.inner
            .injected
            .lock()
            .unwrap()
            .entry(role.to_string())
            .or_insert(false);
    }

    /// Lift an injected stall; emits `watchdog.recover` if the stall had
    /// been announced.
    pub fn clear_stall(&self, role: &str) {
        let announced = self.inner.injected.lock().unwrap().remove(role);
        if announced == Some(true) {
            self.inner.events.emit(
                "watchdog.recover",
                &[
                    ("role", Json::Str(role.to_string())),
                    ("injected", Json::Bool(true)),
                ],
            );
        }
    }

    /// Current health: worst committed level per role, plus injected
    /// stalls. Reads committed state only — no scan, no blocking beyond
    /// two short mutexes — so a health probe stays cheap.
    pub fn report(&self) -> HealthReport {
        let mut dead: BTreeSet<String> = BTreeSet::new();
        let mut stalled: BTreeSet<String> = BTreeSet::new();
        let mut degraded: BTreeSet<String> = BTreeSet::new();
        {
            let t = self.inner.tracked.lock().unwrap();
            for e in t.iter() {
                // relaxed: a death declaration takes effect on report()
                // immediately, even before the next scan commits it; the
                // flag is monotonic and carries no payload, so a stale
                // read only reports Dead one call later.
                if e.cell.dead.load(Ordering::Relaxed) {
                    dead.insert(e.cell.role.to_string());
                    continue;
                }
                match e.committed {
                    Level::Dead => {
                        dead.insert(e.cell.role.to_string());
                    }
                    Level::Stalled => {
                        stalled.insert(e.cell.role.to_string());
                    }
                    Level::Degraded => {
                        degraded.insert(e.cell.role.to_string());
                    }
                    Level::Healthy => {}
                }
            }
        }
        for role in self.inner.injected.lock().unwrap().keys() {
            stalled.insert(role.clone());
        }
        let stalled: BTreeSet<String> = stalled.difference(&dead).cloned().collect();
        let degraded = degraded
            .difference(&stalled)
            .cloned()
            .collect::<BTreeSet<String>>()
            .difference(&dead)
            .cloned()
            .collect();
        HealthReport {
            dead: dead.into_iter().collect(),
            stalled: stalled.into_iter().collect(),
            degraded,
        }
    }

    /// The full per-instance state table as JSON — one of the flight
    /// recorder's bundle artifacts.
    pub fn table_json(&self) -> String {
        let now = Instant::now();
        let rows: Vec<Json> = {
            let t = self.inner.tracked.lock().unwrap();
            t.iter()
                .map(|e| {
                    let silent = now.saturating_duration_since(e.last_progress);
                    let mut row = BTreeMap::new();
                    row.insert("role".to_string(), Json::Str(e.cell.role.to_string()));
                    row.insert(
                        "level".to_string(),
                        Json::Str(e.committed.name().to_string()),
                    );
                    row.insert(
                        "silent_ms".to_string(),
                        Json::Num(silent.as_millis() as f64),
                    );
                    row.insert(
                        "idle".to_string(),
                        Json::Bool(e.cell.idle.load(Ordering::Relaxed)),
                    );
                    Json::Obj(row)
                })
                .collect()
        };
        let injected: Vec<Json> = self
            .inner
            .injected
            .lock()
            .unwrap()
            .keys()
            .map(|r| Json::Str(r.clone()))
            .collect();
        let mut obj = BTreeMap::new();
        obj.insert("roles".to_string(), Json::Arr(rows));
        obj.insert("injected".to_string(), Json::Arr(injected));
        Json::Obj(obj).to_string()
    }

    /// Run exactly one scan at `now` (unit-test hook; the background
    /// thread calls the same code with `Instant::now()`).
    pub fn scan_once(&self, now: Instant) {
        scan(&self.inner, now);
    }

    /// Stop and join the background thread (idempotent).
    pub fn stop(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.thread.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.stop();
    }
}

fn scan(inner: &Inner, now: Instant) {
    // (role, from, to, silent) per committed transition this scan.
    let mut transitions: Vec<(&'static str, Level, Level, Duration)> = Vec::new();
    let mut worst: BTreeMap<String, Level> = BTreeMap::new();
    {
        let mut t = inner.tracked.lock().unwrap();
        // Reap heartbeats whose every outside clone is gone: the thread
        // exited (cleanly or by unwinding) and can never beat again.
        t.retain(|e| Arc::strong_count(&e.cell) > 1);
        for e in t.iter_mut() {
            let ticks = e.cell.ticks.load(Ordering::Relaxed);
            // relaxed: liveness scan over monotonic beat/idle counters —
            // a torn-in-time view errs by one SCAN_INTERVAL in the
            // degraded/stalled classification, which the debounce below
            // absorbs; no data is transferred through these atomics
            if ticks != e.last_ticks || e.cell.idle.load(Ordering::Relaxed) {
                e.last_ticks = ticks;
                e.last_progress = now;
            }
            let silent = now.saturating_duration_since(e.last_progress);
            // relaxed: same argument as the scan loads above; Dead is
            // additionally re-checked by report() directly
            let raw = if e.cell.dead.load(Ordering::Relaxed) {
                Level::Dead
            } else if silent >= e.cell.stalled {
                Level::Stalled
            } else if silent >= e.cell.degraded {
                Level::Degraded
            } else {
                Level::Healthy
            };
            // Dead is declared by a panic supervisor, not inferred from
            // silence — commit immediately, no debounce, either way
            // (revive() beats, so the way back starts Healthy).
            if raw == Level::Dead && e.committed != Level::Dead {
                transitions.push((e.cell.role, e.committed, raw, silent));
                e.committed = raw;
                e.pending = raw;
                e.pending_scans = 0;
            } else if e.committed == Level::Dead && raw != Level::Dead {
                transitions.push((e.cell.role, e.committed, raw, silent));
                e.committed = raw;
                e.pending = raw;
                e.pending_scans = 0;
            } else if raw == e.committed {
                e.pending = raw;
                e.pending_scans = 0;
            } else if raw == e.pending {
                e.pending_scans += 1;
                if e.pending_scans >= DEBOUNCE_SCANS {
                    transitions.push((e.cell.role, e.committed, raw, silent));
                    e.committed = raw;
                    e.pending_scans = 0;
                }
            } else {
                e.pending = raw;
                e.pending_scans = 1;
            }
            let w = worst
                .entry(e.cell.role.to_string())
                .or_insert(Level::Healthy);
            if e.committed > *w {
                *w = e.committed;
            }
        }
    }
    {
        // Roles whose instances all retired keep a Healthy gauge, so a
        // scrape's series set stays stable across connection churn.
        let mut roles = inner.roles.lock().unwrap();
        let t = inner.tracked.lock().unwrap();
        for e in t.iter() {
            roles.insert(e.cell.role);
        }
        drop(t);
        for role in roles.iter() {
            worst.entry((*role).to_string()).or_insert(Level::Healthy);
        }
    }
    // Injected stalls override their role and announce once.
    let mut injected_now: Vec<String> = Vec::new();
    {
        let mut inj = inner.injected.lock().unwrap();
        for (role, announced) in inj.iter_mut() {
            worst.insert(role.clone(), Level::Stalled);
            if !*announced {
                *announced = true;
                injected_now.push(role.clone());
            }
        }
    }
    for (role, level) in &worst {
        inner
            .registry
            .gauge("obs.watchdog.state", &[("role", role)])
            .set(level.gauge_value());
    }
    for (role, from, to, silent) in transitions {
        if to == Level::Dead {
            // The panic supervisor already captured a `driver.panic`
            // bundle; the watchdog just records the state flip.
            inner.events.emit(
                "watchdog.dead",
                &[
                    ("role", Json::Str(role.to_string())),
                    ("from", Json::Str(from.name().to_string())),
                ],
            );
        } else if to == Level::Stalled {
            inner.stalls.inc();
            inner.events.emit(
                "watchdog.stall",
                &[
                    ("role", Json::Str(role.to_string())),
                    ("silent_ms", Json::Num(silent.as_millis() as f64)),
                ],
            );
            trigger_recorder(inner, role);
        } else if from == Level::Stalled || from == Level::Dead {
            inner.events.emit(
                "watchdog.recover",
                &[
                    ("role", Json::Str(role.to_string())),
                    ("level", Json::Str(to.name().to_string())),
                ],
            );
        }
    }
    for role in injected_now {
        inner.stalls.inc();
        inner.events.emit(
            "watchdog.stall",
            &[
                ("role", Json::Str(role.clone())),
                ("injected", Json::Bool(true)),
            ],
        );
        trigger_recorder(inner, &role);
    }
}

fn trigger_recorder(inner: &Inner, role: &str) {
    let rec = inner.recorder.lock().unwrap().clone();
    if let Some(rec) = rec {
        let _ = rec.trigger(Trigger::Stall(role.to_string()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wd() -> Watchdog {
        Watchdog::unstarted(Registry::new(), Arc::new(EventLog::disabled()))
    }

    const MS: Duration = Duration::from_millis(1);

    #[test]
    fn classifies_against_thresholds_with_debounce() {
        let w = wd();
        let hb = w.register("role-a", 50 * MS, 200 * MS);
        let t0 = Instant::now();
        w.scan_once(t0);
        assert!(w.report().healthy());

        // Past the stall threshold: one scan is pending, two commit.
        w.scan_once(t0 + 300 * MS);
        assert!(w.report().healthy(), "single scan must not commit");
        w.scan_once(t0 + 310 * MS);
        let r = w.report();
        assert!(!r.healthy());
        assert_eq!(r.stalled, vec!["role-a".to_string()]);
        assert!(r.to_json().contains("\"stalled\""));
        drop(hb);
    }

    #[test]
    fn degraded_band_sits_between_thresholds() {
        let w = wd();
        let _hb = w.register("role-b", 50 * MS, 200 * MS);
        let t0 = Instant::now();
        w.scan_once(t0);
        w.scan_once(t0 + 100 * MS);
        w.scan_once(t0 + 110 * MS);
        let r = w.report();
        assert!(r.healthy(), "degraded still answers healthy");
        assert_eq!(r.degraded, vec!["role-b".to_string()]);
    }

    #[test]
    fn beat_recovers_a_stalled_role() {
        let registry = Registry::new();
        let w = Watchdog::unstarted(Arc::clone(&registry), Arc::new(EventLog::disabled()));
        let hb = w.register("role-c", 50 * MS, 200 * MS);
        let t0 = Instant::now();
        w.scan_once(t0);
        w.scan_once(t0 + 300 * MS);
        w.scan_once(t0 + 310 * MS);
        assert!(!w.report().healthy());
        assert_eq!(
            registry.snapshot().counter("obs.watchdog.stalls", &[]),
            Some(1)
        );
        assert_eq!(
            registry
                .snapshot()
                .gauge("obs.watchdog.state", &[("role", "role-c")]),
            Some(2.0)
        );

        hb.beat();
        w.scan_once(t0 + 320 * MS);
        w.scan_once(t0 + 330 * MS);
        assert!(w.report().healthy());
        assert_eq!(
            registry
                .snapshot()
                .gauge("obs.watchdog.state", &[("role", "role-c")]),
            Some(0.0)
        );
    }

    #[test]
    fn idle_instances_stay_healthy_forever() {
        let w = wd();
        let hb = w.register("role-d", 50 * MS, 200 * MS);
        hb.idle();
        let t0 = Instant::now();
        w.scan_once(t0);
        w.scan_once(t0 + 10_000 * MS);
        w.scan_once(t0 + 20_000 * MS);
        assert!(w.report().healthy());
    }

    #[test]
    fn dropped_heartbeats_are_reaped() {
        let w = wd();
        let hb = w.register("role-e", 50 * MS, 200 * MS);
        drop(hb);
        let t0 = Instant::now();
        w.scan_once(t0 + 10_000 * MS);
        w.scan_once(t0 + 10_010 * MS);
        assert!(w.report().healthy(), "a retired thread is not a stall");
    }

    /// Beats from a worker thread race the scanner's counter loads —
    /// the exact access pattern the CI Miri job checks. Sleep-free:
    /// scans use explicit instants, so Miri never waits on wall time.
    #[test]
    fn concurrent_beats_race_the_scanner() {
        let w = wd();
        let hb = w.register("role-f", 50 * MS, 200 * MS);
        let beats: u64 = if cfg!(miri) { 64 } else { 10_000 };
        let worker = {
            let hb = hb.clone();
            std::thread::spawn(move || {
                for _ in 0..beats {
                    hb.beat();
                }
            })
        };
        let t0 = Instant::now();
        for k in 0..8u32 {
            w.scan_once(t0 + k * 10 * MS);
        }
        worker.join().unwrap();
        w.scan_once(t0 + 90 * MS);
        assert!(w.report().healthy(), "a beating thread never stalls");
    }

    #[test]
    fn dead_commits_without_debounce_and_revive_recovers() {
        let registry = Registry::new();
        let w = Watchdog::unstarted(Arc::clone(&registry), Arc::new(EventLog::disabled()));
        let hb = w.register("shard-driver", 50 * MS, 200 * MS);
        let t0 = Instant::now();
        w.scan_once(t0);
        assert!(w.report().healthy());

        // A declared death flips report() instantly and commits on the
        // very next scan — no two-scan debounce for a caught panic.
        hb.dead();
        let r = w.report();
        assert!(!r.healthy());
        assert_eq!(r.dead, vec!["shard-driver".to_string()]);
        assert!(r.to_json().contains("\"dead\""));
        w.scan_once(t0 + 10 * MS);
        assert_eq!(
            registry
                .snapshot()
                .gauge("obs.watchdog.state", &[("role", "shard-driver")]),
            Some(3.0)
        );

        // Silence never clears it: Dead is terminal until revive().
        w.scan_once(t0 + 10_000 * MS);
        assert!(!w.report().healthy());

        // revive() beats, so the respawned thread scans Healthy at once.
        hb.revive();
        assert!(w.report().healthy());
        w.scan_once(t0 + 10_020 * MS);
        assert!(w.report().healthy());
        assert_eq!(
            registry
                .snapshot()
                .gauge("obs.watchdog.state", &[("role", "shard-driver")]),
            Some(0.0)
        );
    }

    #[test]
    fn injected_stall_flips_report_and_clears() {
        let w = wd();
        w.inject_stall("wire-reader");
        let r = w.report();
        assert!(!r.healthy());
        assert_eq!(r.stalled, vec!["wire-reader".to_string()]);
        assert!(w.table_json().contains("wire-reader"));
        w.clear_stall("wire-reader");
        assert!(w.report().healthy());
    }
}
