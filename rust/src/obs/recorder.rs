//! Flight recorder: anomaly-triggered incident bundles (DESIGN.md §0.11).
//!
//! A [`Recorder`] owns a bundle directory (`--dump-dir`). When
//! [`trigger`](Recorder::trigger)ed — by a watchdog stall, a slow-tick
//! anomaly, a panic hook, or a manual `GET /debug/dump` / `bps stats
//! ADDR --dump` — it freezes the evidence that already exists in memory
//! into `incident-NNNN-<reason>/`:
//!
//! | file               | contents                                      |
//! |--------------------|-----------------------------------------------|
//! | `manifest.json`    | reason, seq, snapshot version, build version  |
//! | `metrics.prom`     | full registry snapshot (text exposition)      |
//! | `trace.json`       | Chrome-trace JSON of the span ring            |
//! | `events.tail.jsonl`| last 64 KiB of the event log (armed only)     |
//! | *extra artifacts*  | e.g. `watchdog.json`, `sessions.json`         |
//!
//! Automatic triggers are rate-limited ([`MIN_AUTO_INTERVAL`]) so a
//! stall storm cannot fill the disk, and the directory keeps only the
//! newest [`RETAIN_BUNDLES`] incidents. Manual triggers bypass the rate
//! limit (a human asked) but still count against retention.
//!
//! Everything here runs off the hot path: a trigger costs a registry
//! snapshot plus a few file writes, and nothing in this module is
//! touched by the stepping loop, preserving the disarmed-is-bitwise-
//! identical invariant.

use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant, SystemTime};

use crate::util::json::Json;

use super::event::EventLog;
use super::registry::{Counter, Registry, SNAPSHOT_VERSION};
use super::trace::TraceSink;

/// Minimum spacing between *automatic* bundles (stall / slow-tick /
/// panic). Closer triggers are counted in `obs.recorder.suppressed`.
pub const MIN_AUTO_INTERVAL: Duration = Duration::from_secs(5);

/// Newest incident directories kept; older ones are deleted after each
/// new bundle lands.
pub const RETAIN_BUNDLES: usize = 8;

/// How much of the event log's tail each bundle carries.
pub const EVENT_TAIL_BYTES: u64 = 64 << 10;

/// Why a bundle was written. The slug becomes part of the directory
/// name; the detail lands in `manifest.json`.
#[derive(Clone, Debug)]
pub enum Trigger {
    /// `GET /debug/dump` or `bps stats ADDR --dump`.
    Manual,
    /// The watchdog committed a role to Stalled.
    Stall(String),
    /// A shard tick ran anomalously long versus its trailing window.
    SlowTick { tick_us: u64, p95_us: u64 },
    /// A thread panicked (`bps serve` installs the hook).
    Panic(String),
    /// A shard or tenant driver panicked and its shard was quarantined
    /// (`serve`'s `catch_unwind` isolation; DESIGN.md §0.12). Distinct
    /// from [`Trigger::Panic`]: the server keeps running.
    DriverPanic(String),
}

impl Trigger {
    fn slug(&self) -> &'static str {
        match self {
            Trigger::Manual => "manual",
            Trigger::Stall(_) => "stall",
            Trigger::SlowTick { .. } => "slowtick",
            Trigger::Panic(_) => "panic",
            Trigger::DriverPanic(_) => "driver.panic",
        }
    }

    fn detail(&self) -> String {
        match self {
            Trigger::Manual => String::new(),
            Trigger::Stall(role) => format!("stalled role: {role}"),
            Trigger::SlowTick { tick_us, p95_us } => {
                format!("tick {tick_us}us vs trailing p95 {p95_us}us")
            }
            Trigger::Panic(msg) => msg.clone(),
            Trigger::DriverPanic(msg) => msg.clone(),
        }
    }

    fn is_auto(&self) -> bool {
        !matches!(self, Trigger::Manual)
    }
}

type Provider = Box<dyn Fn() -> String + Send + Sync>;

/// The flight recorder. See module docs.
pub struct Recorder {
    dir: PathBuf,
    registry: Arc<Registry>,
    trace: Arc<TraceSink>,
    events: Arc<EventLog>,
    /// Extra bundle artifacts: (file name, producer). Producers must not
    /// hold strong references back to anything that owns the recorder.
    providers: Mutex<Vec<(&'static str, Provider)>>,
    seq: AtomicU64,
    last_auto: Mutex<Option<Instant>>,
    bundles: Counter,
    suppressed: Counter,
}

impl Recorder {
    /// Create (or reuse) the bundle directory `dir`.
    pub fn new(
        dir: &Path,
        registry: Arc<Registry>,
        trace: Arc<TraceSink>,
        events: Arc<EventLog>,
    ) -> io::Result<Recorder> {
        fs::create_dir_all(dir)?;
        let bundles = registry.counter("obs.recorder.bundles", &[]);
        let suppressed = registry.counter("obs.recorder.suppressed", &[]);
        Ok(Recorder {
            dir: dir.to_path_buf(),
            registry,
            trace,
            events,
            providers: Mutex::new(Vec::new()),
            seq: AtomicU64::new(0),
            last_auto: Mutex::new(None),
            bundles,
            suppressed,
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Register an extra per-bundle artifact, e.g. the watchdog state
    /// table. `name` is the file name inside each bundle directory.
    pub fn add_artifact(&self, name: &'static str, f: impl Fn() -> String + Send + Sync + 'static) {
        self.providers.lock().unwrap().push((name, Box::new(f)));
    }

    /// Write a bundle for `trigger`. Returns `Ok(None)` when an
    /// automatic trigger was rate-limited, otherwise the bundle path.
    pub fn trigger(&self, trigger: Trigger) -> io::Result<Option<PathBuf>> {
        if trigger.is_auto() {
            let mut last = self.last_auto.lock().unwrap();
            if let Some(t) = *last {
                if t.elapsed() < MIN_AUTO_INTERVAL {
                    self.suppressed.inc();
                    return Ok(None);
                }
            }
            *last = Some(Instant::now());
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed) + 1;
        let dir = self.dir.join(format!("incident-{seq:05}-{}", trigger.slug()));
        fs::create_dir_all(&dir)?;

        fs::write(dir.join("metrics.prom"), self.registry.snapshot().to_prometheus())?;
        fs::write(dir.join("trace.json"), self.trace.to_chrome_json())?;
        fs::write(
            dir.join("events.tail.jsonl"),
            self.events.tail(EVENT_TAIL_BYTES).unwrap_or_default(),
        )?;
        let mut artifacts = vec![
            "manifest.json".to_string(),
            "metrics.prom".to_string(),
            "trace.json".to_string(),
            "events.tail.jsonl".to_string(),
        ];
        {
            let providers = self.providers.lock().unwrap();
            for (name, f) in providers.iter() {
                fs::write(dir.join(name), f())?;
                artifacts.push((*name).to_string());
            }
        }

        let unix_ms = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .map(|d| d.as_millis() as f64)
            .unwrap_or(0.0);
        let mut manifest = BTreeMap::new();
        manifest.insert(
            "snapshot_version".to_string(),
            Json::Num(SNAPSHOT_VERSION as f64),
        );
        manifest.insert("seq".to_string(), Json::Num(seq as f64));
        manifest.insert(
            "reason".to_string(),
            Json::Str(trigger.slug().to_string()),
        );
        manifest.insert("detail".to_string(), Json::Str(trigger.detail()));
        manifest.insert(
            "bps_version".to_string(),
            Json::Str(env!("CARGO_PKG_VERSION").to_string()),
        );
        manifest.insert("unix_ms".to_string(), Json::Num(unix_ms));
        manifest.insert(
            "artifacts".to_string(),
            Json::Arr(artifacts.into_iter().map(Json::Str).collect()),
        );
        fs::write(dir.join("manifest.json"), Json::Obj(manifest).to_string())?;

        self.bundles.inc();
        self.events.emit(
            "recorder.bundle",
            &[
                ("reason", Json::Str(trigger.slug().to_string())),
                ("path", Json::Str(dir.display().to_string())),
            ],
        );
        self.prune()?;
        Ok(Some(dir))
    }

    /// Delete all but the newest [`RETAIN_BUNDLES`] incident dirs. Seq
    /// numbers are zero-padded, so lexicographic order is creation order.
    fn prune(&self) -> io::Result<()> {
        let mut dirs: Vec<PathBuf> = fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.is_dir()
                    && p.file_name()
                        .and_then(|n| n.to_str())
                        .is_some_and(|n| n.starts_with("incident-"))
            })
            .collect();
        dirs.sort();
        while dirs.len() > RETAIN_BUNDLES {
            fs::remove_dir_all(dirs.remove(0))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recorder(dir: &Path) -> Recorder {
        Recorder::new(
            dir,
            Registry::new(),
            Arc::new(TraceSink::new(16)),
            Arc::new(EventLog::disabled()),
        )
        .unwrap()
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("bps-recorder-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn manual_bundle_has_parseable_artifacts() {
        let dir = tmpdir("manual");
        let rec = recorder(&dir);
        rec.add_artifact("extra.json", || "{\"x\":1}".to_string());
        let path = rec.trigger(Trigger::Manual).unwrap().expect("bundle");

        let manifest =
            Json::parse(&fs::read_to_string(path.join("manifest.json")).unwrap()).unwrap();
        assert_eq!(
            manifest.get("reason").and_then(|j| j.as_str().ok()),
            Some("manual")
        );
        assert_eq!(
            manifest.get("snapshot_version").and_then(|j| j.as_f64().ok()),
            Some(SNAPSHOT_VERSION as f64)
        );
        let metrics = fs::read_to_string(path.join("metrics.prom")).unwrap();
        assert!(metrics.starts_with("# bps snapshot v"));
        let trace = Json::parse(&fs::read_to_string(path.join("trace.json")).unwrap()).unwrap();
        assert!(trace.get("traceEvents").is_some());
        // disabled event log → empty (but present) tail
        assert_eq!(
            fs::read_to_string(path.join("events.tail.jsonl")).unwrap(),
            ""
        );
        let extra = Json::parse(&fs::read_to_string(path.join("extra.json")).unwrap()).unwrap();
        assert_eq!(extra.get("x").and_then(|j| j.as_f64().ok()), Some(1.0));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn auto_triggers_are_rate_limited_but_manual_is_not() {
        let dir = tmpdir("rate");
        let rec = recorder(&dir);
        let first = rec.trigger(Trigger::Stall("role".to_string())).unwrap();
        assert!(first.is_some());
        let second = rec
            .trigger(Trigger::SlowTick {
                tick_us: 9000,
                p95_us: 1000,
            })
            .unwrap();
        assert!(second.is_none(), "back-to-back auto trigger must be dropped");
        assert_eq!(rec.suppressed.get(), 1);
        let manual = rec.trigger(Trigger::Manual).unwrap();
        assert!(manual.is_some(), "manual bypasses the rate limit");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn retention_keeps_only_newest_bundles() {
        let dir = tmpdir("retain");
        let rec = recorder(&dir);
        for _ in 0..(RETAIN_BUNDLES + 4) {
            rec.trigger(Trigger::Manual).unwrap().expect("bundle");
        }
        let n = fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.file_name()
                    .to_str()
                    .is_some_and(|n| n.starts_with("incident-"))
            })
            .count();
        assert_eq!(n, RETAIN_BUNDLES);
        // the survivors are the newest ones
        let last = dir.join(format!("incident-{:05}-manual", RETAIN_BUNDLES + 4));
        assert!(last.is_dir());
        let _ = fs::remove_dir_all(&dir);
    }
}
