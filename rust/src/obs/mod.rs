//! `bps::obs` — the unified observability layer (DESIGN.md §0.10).
//!
//! The paper's headline throughput (19k FPS single-GPU, 72k on eight)
//! exists because every pipeline stage was measured and the stragglers
//! amortized; this module is the measuring side for our serve tier. Four
//! surfaces, one substrate:
//!
//! - [`Registry`] — typed [`Counter`]/[`Gauge`]/[`Histogram`] handles
//!   (atomics on the hot path, no registry lock after registration)
//!   under dotted names with small label sets (`shard`, `stage`,
//!   `conn`). Every stats producer (coalescers, render counters, wire
//!   conn accounting, curriculum) reports into this; `SimServer::stats`
//!   and every scrape read out of it — the *same* atomic cells, so all
//!   views agree bitwise.
//! - [`TraceSink`] — per-tick megaframe spans (coalesce wait → sim →
//!   render transform/cull/raster/resolve → tenant infer → wire
//!   encode/flush) in a bounded ring, exportable as Chrome
//!   `trace_event` JSON (`bps serve --trace-out`, `bps trace`).
//! - [`MetricsServer`] — hand-rolled `GET /metrics` (Prometheus text) +
//!   `/healthz` endpoint (`bps serve --metrics-addr`), and the `STATS`
//!   wire frame which returns the identical rendering in-band
//!   (`bps stats ADDR`).
//! - [`EventLog`] — size-capped JSONL of lifecycle events
//!   (`--event-log`): lease grant/release, policy decline, curriculum
//!   advance, idle reap, slow-reader disconnect, bad submits, error
//!   frames.
//!
//! PR 8 adds the *active* layer on the same substrate (DESIGN.md §0.11):
//!
//! - [`Watchdog`] — per-thread [`Heartbeat`]s classified Healthy /
//!   Degraded / Stalled, backing a real `/healthz` readiness answer,
//!   `obs.watchdog.*` gauges, and `watchdog.stall`/`recover` events.
//! - [`Recorder`] — the flight recorder: anomaly-triggered (stall,
//!   slow tick, panic, manual `GET /debug/dump`) incident bundles of
//!   metrics + trace + event tail + watchdog table, rate-limited and
//!   retention-capped (`bps serve --dump-dir`).
//!
//! All of it is disabled-by-default and gates on one atomic load (a
//! heartbeat is one relaxed store), so the sync stepping path with obs
//! compiled in is bitwise-identical to a build without it.

pub mod event;
pub mod http;
pub mod recorder;
pub mod registry;
pub mod trace;
pub mod watchdog;

pub use event::{EventLog, DEFAULT_EVENT_LOG_BYTES};
pub use http::{HttpHooks, MetricsServer};
pub use recorder::{Recorder, Trigger, MIN_AUTO_INTERVAL, RETAIN_BUNDLES};
pub use watchdog::{HealthReport, Heartbeat, Level, Watchdog};
pub use registry::{
    Counter, Gauge, Histogram, HistogramSnapshot, MetricSnapshot, MetricValue, Registry, Snapshot,
    HIST_BUCKETS, SNAPSHOT_VERSION,
};
pub use trace::{Span, TraceSink, DEFAULT_TRACE_SPANS, TENANT_PID, WIRE_PID};
