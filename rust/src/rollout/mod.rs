//! Rollout storage + GAE (paper §3.4): `N x L` steps of experience per
//! rollout, generalized advantage estimation in Rust (Table A4:
//! gamma = 0.99, GAE-lambda = 0.95), and minibatch assembly — splits over
//! the env dimension so BPTT sees full L-step sequences.

/// Storage layout is step-major (`[L, N, ...]`) because that is the order
/// experience arrives in; minibatch assembly transposes to `[B, L, ...]`.
pub struct Rollout {
    pub n: usize,
    pub l: usize,
    pub obs_f: usize,
    pub hidden: usize,
    pub obs: Vec<f32>,     // [L, N, obs_f]
    pub goal: Vec<f32>,    // [L, N, 3]
    pub actions: Vec<i32>, // [L, N]
    pub logp: Vec<f32>,    // [L, N]
    pub values: Vec<f32>,  // [L, N]
    pub rewards: Vec<f32>, // [L, N]
    /// `dones[t*n+i]`: the action at step t ended env i's episode.
    pub dones: Vec<bool>, // [L, N]
    /// `notdone[t*n+i]`: obs t continues the episode begun earlier
    /// (0 exactly when obs t is the first observation of a new episode).
    pub notdone: Vec<f32>, // [L, N]
    pub h0: Vec<f32>,      // [N, hidden] recurrent state at rollout start
    pub c0: Vec<f32>,
    pub bootstrap: Vec<f32>, // [N] V(s_L)
    pub returns: Vec<f32>,   // [L, N]
    pub adv: Vec<f32>,       // [L, N]
}

/// One minibatch in the exact argument layout of the `grad` artifact.
pub struct MiniBatch {
    pub b: usize,
    pub l: usize,
    pub obs: Vec<f32>,  // [B, L, obs_f]
    pub goal: Vec<f32>, // [B, L, 3]
    pub h0: Vec<f32>,   // [B, hidden]
    pub c0: Vec<f32>,
    pub actions: Vec<i32>, // [B, L]
    pub logp: Vec<f32>,
    pub returns: Vec<f32>,
    pub adv: Vec<f32>,
    pub notdone: Vec<f32>,
}

impl Rollout {
    pub fn new(n: usize, l: usize, obs_f: usize, hidden: usize) -> Rollout {
        Rollout {
            n,
            l,
            obs_f,
            hidden,
            obs: vec![0.0; l * n * obs_f],
            goal: vec![0.0; l * n * 3],
            actions: vec![0; l * n],
            logp: vec![0.0; l * n],
            values: vec![0.0; l * n],
            rewards: vec![0.0; l * n],
            dones: vec![false; l * n],
            notdone: vec![1.0; l * n],
            h0: vec![0.0; n * hidden],
            c0: vec![0.0; n * hidden],
            bootstrap: vec![0.0; n],
            returns: vec![0.0; l * n],
            adv: vec![0.0; l * n],
        }
    }

    pub fn frames(&self) -> u64 {
        (self.n * self.l) as u64
    }

    /// Snapshot the recurrent state at the start of the rollout.
    pub fn begin(&mut self, h: &[f32], c: &[f32], prev_dones: &[bool]) {
        self.h0.copy_from_slice(h);
        self.c0.copy_from_slice(c);
        for i in 0..self.n {
            self.notdone[i] = if prev_dones[i] { 0.0 } else { 1.0 };
        }
    }

    /// Record the policy IO of step `t` (before stepping the simulator).
    pub fn record_step(
        &mut self,
        t: usize,
        obs: &[f32],
        goal: &[f32],
        actions: &[u8],
        logp: &[f32],
        values: &[f32],
    ) {
        let (n, of) = (self.n, self.obs_f);
        self.obs[t * n * of..(t + 1) * n * of].copy_from_slice(obs);
        self.goal[t * n * 3..(t + 1) * n * 3].copy_from_slice(goal);
        for i in 0..n {
            self.actions[t * n + i] = actions[i] as i32;
        }
        self.logp[t * n..(t + 1) * n].copy_from_slice(logp);
        self.values[t * n..(t + 1) * n].copy_from_slice(values);
    }

    /// Record the environment outcome of step `t` (after the sim step).
    pub fn record_outcome(&mut self, t: usize, rewards: &[f32], dones: &[bool]) {
        let n = self.n;
        self.rewards[t * n..(t + 1) * n].copy_from_slice(rewards);
        self.dones[t * n..(t + 1) * n].copy_from_slice(dones);
        if t + 1 < self.l {
            for i in 0..n {
                self.notdone[(t + 1) * n + i] = if dones[i] { 0.0 } else { 1.0 };
            }
        }
    }

    /// GAE over every env stream; optionally normalizes advantages across
    /// the whole rollout (habitat-baselines default; the paper disables
    /// only *per-minibatch* normalization, Table A4).
    pub fn compute_gae(&mut self, gamma: f32, lam: f32, normalize: bool) {
        let (n, l) = (self.n, self.l);
        for i in 0..n {
            let mut acc = 0.0f32;
            for t in (0..l).rev() {
                let idx = t * n + i;
                let nd = if self.dones[idx] { 0.0 } else { 1.0 };
                let v_next = if t == l - 1 {
                    self.bootstrap[i]
                } else {
                    self.values[(t + 1) * n + i]
                };
                let delta = self.rewards[idx] + gamma * v_next * nd - self.values[idx];
                acc = delta + gamma * lam * nd * acc;
                self.adv[idx] = acc;
                self.returns[idx] = acc + self.values[idx];
            }
        }
        if normalize {
            let m = self.adv.len() as f32;
            let mean: f32 = self.adv.iter().sum::<f32>() / m;
            let var: f32 =
                self.adv.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / m;
            let inv_std = 1.0 / (var.sqrt() + 1e-5);
            for a in &mut self.adv {
                *a = (*a - mean) * inv_std;
            }
        }
    }

    /// Assemble the minibatch for env indices `[env_lo, env_hi)` —
    /// transposes `[L, N]` storage to the `[B, L]` layout of the artifact.
    pub fn minibatch(&self, env_lo: usize, env_hi: usize) -> MiniBatch {
        let b = env_hi - env_lo;
        let (n, l, of, h) = (self.n, self.l, self.obs_f, self.hidden);
        let mut mb = MiniBatch {
            b,
            l,
            obs: vec![0.0; b * l * of],
            goal: vec![0.0; b * l * 3],
            h0: vec![0.0; b * h],
            c0: vec![0.0; b * h],
            actions: vec![0; b * l],
            logp: vec![0.0; b * l],
            returns: vec![0.0; b * l],
            adv: vec![0.0; b * l],
            notdone: vec![0.0; b * l],
        };
        for (bi, i) in (env_lo..env_hi).enumerate() {
            mb.h0[bi * h..(bi + 1) * h].copy_from_slice(&self.h0[i * h..(i + 1) * h]);
            mb.c0[bi * h..(bi + 1) * h].copy_from_slice(&self.c0[i * h..(i + 1) * h]);
            for t in 0..l {
                let src = t * n + i;
                let dst = bi * l + t;
                mb.obs[dst * of..(dst + 1) * of]
                    .copy_from_slice(&self.obs[src * of..(src + 1) * of]);
                mb.goal[dst * 3..(dst + 1) * 3]
                    .copy_from_slice(&self.goal[src * 3..(src + 1) * 3]);
                mb.actions[dst] = self.actions[src];
                mb.logp[dst] = self.logp[src];
                mb.returns[dst] = self.returns[src];
                mb.adv[dst] = self.adv[src];
                mb.notdone[dst] = self.notdone[src];
            }
        }
        mb
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize, l: usize) -> Rollout {
        let mut r = Rollout::new(n, l, 2, 4);
        for t in 0..l {
            for i in 0..n {
                r.rewards[t * n + i] = 1.0;
                r.values[t * n + i] = 0.5;
            }
        }
        r
    }

    #[test]
    fn gae_matches_naive_reference() {
        // naive O(L^2) reference per env
        let n = 2;
        let l = 5;
        let mut r = toy(n, l);
        r.rewards[2 * n] = -1.0; // vary env 0
        r.dones[1 * n + 1] = true; // env 1 episode break after t=1
        r.bootstrap = vec![0.7, -0.3];
        let (gamma, lam) = (0.99f32, 0.95f32);
        r.compute_gae(gamma, lam, false);
        for i in 0..n {
            for t in 0..l {
                // naive: sum_k (gamma*lam)^k * delta_{t+k}, stopping at done
                let mut expect = 0.0f32;
                let mut factor = 1.0f32;
                for k in t..l {
                    let idx = k * n + i;
                    let nd = if r.dones[idx] { 0.0 } else { 1.0 };
                    let v_next = if k == l - 1 {
                        r.bootstrap[i]
                    } else {
                        r.values[(k + 1) * n + i]
                    };
                    let delta = r.rewards[idx] + gamma * v_next * nd - r.values[idx];
                    expect += factor * delta;
                    if nd == 0.0 {
                        break;
                    }
                    factor *= gamma * lam;
                }
                let got = r.adv[t * n + i];
                assert!(
                    (got - expect).abs() < 1e-4,
                    "env {i} t {t}: {got} vs {expect}"
                );
            }
        }
    }

    #[test]
    fn done_blocks_credit_flow() {
        let n = 1;
        let l = 4;
        let mut r = toy(n, l);
        r.rewards[3] = 100.0; // big reward at the last step
        r.dones[1] = true; // episode ends after t=1
        r.bootstrap = vec![0.0];
        r.compute_gae(0.99, 0.95, false);
        // adv at t=0,1 must not see the t=3 reward
        assert!(r.adv[0].abs() < 5.0, "leaked credit: {}", r.adv[0]);
        assert!(r.adv[3] > 50.0);
    }

    #[test]
    fn normalization_zero_mean_unit_std() {
        let mut r = toy(3, 8);
        for (k, x) in r.rewards.iter_mut().enumerate() {
            *x = (k % 7) as f32 - 3.0;
        }
        r.compute_gae(0.99, 0.95, true);
        let m = r.adv.iter().sum::<f32>() / r.adv.len() as f32;
        let v = r.adv.iter().map(|a| (a - m) * (a - m)).sum::<f32>() / r.adv.len() as f32;
        assert!(m.abs() < 1e-4);
        assert!((v - 1.0).abs() < 1e-2);
    }

    #[test]
    fn minibatch_transpose_correct() {
        let n = 4;
        let l = 3;
        let mut r = Rollout::new(n, l, 2, 2);
        // tag every slot with a recognizable value
        for t in 0..l {
            for i in 0..n {
                r.obs[(t * n + i) * 2] = (100 * t + i) as f32;
                r.actions[t * n + i] = (10 * t + i) as i32;
                r.adv[t * n + i] = (t + i) as f32;
            }
        }
        for i in 0..n {
            r.h0[i * 2] = i as f32;
        }
        let mb = r.minibatch(1, 3);
        assert_eq!(mb.b, 2);
        // env 1, t 2 lands at batch row 0, seq pos 2
        assert_eq!(mb.obs[(0 * l + 2) * 2], 201.0);
        assert_eq!(mb.actions[0 * l + 2], 21);
        assert_eq!(mb.h0[0], 1.0);
        // env 2 row
        assert_eq!(mb.obs[(1 * l + 0) * 2], 2.0);
        assert_eq!(mb.adv[1 * l + 1], 3.0);
    }

    #[test]
    fn notdone_tracks_dones_shifted() {
        let n = 2;
        let l = 3;
        let mut r = Rollout::new(n, l, 1, 1);
        r.begin(&[0.0; 2], &[0.0; 2], &[true, false]);
        assert_eq!(&r.notdone[0..2], &[0.0, 1.0]);
        r.record_outcome(0, &[0.0, 0.0], &[false, true]);
        assert_eq!(&r.notdone[2..4], &[1.0, 0.0]);
        r.record_outcome(1, &[0.0, 0.0], &[false, false]);
        assert_eq!(&r.notdone[4..6], &[1.0, 1.0]);
    }
}
