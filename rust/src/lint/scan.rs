//! Line/token-aware Rust source scanner for `bps lint`.
//!
//! Hand-rolled in the spirit of `util/toml.rs`/`util/json.rs`: no syn, no
//! proc-macro machinery — a single forward pass that separates *code* from
//! *comments* and blanks out string/char literal contents, so the rules in
//! [`super::rules`] can match tokens without being fooled by `"unsafe"`
//! inside a string or `.lock()` inside a doc comment. The scanner also
//! derives the structural facts every rule needs: per-line brace depth,
//! function spans, the trailing `#[cfg(test)]` region, and
//! `// bps-lint: allow(...)` directives.
//!
//! The scanner is deliberately heuristic (it does not parse Rust); its
//! contract is documented in DESIGN.md §0.13 and every assumption it
//! bakes in (tests live in a trailing `#[cfg(test)]` module, statements
//! end in `;`/`{`/`}`) matches how this repository is written — the
//! fixture suite in `rust/tests/lint.rs` pins the behaviour.

/// One physical source line, split into code and comment channels.
pub struct Line {
    /// Source text with comments removed and string/char literal contents
    /// blanked (the delimiting quotes are kept, so `""` marks "a string
    /// was here").
    pub code: String,
    /// Concatenated comment text on this line (line + block comments,
    /// including doc comments), without the `//`/`/*` markers.
    pub comment: String,
    /// Brace depth (code braces only) at the start of the line.
    pub depth_before: usize,
    /// Brace depth at the end of the line.
    pub depth_after: usize,
    /// Number of `{` seen in code on this line.
    pub opens: usize,
}

impl Line {
    /// No code tokens — only comment text (doc comments included).
    pub fn comment_only(&self) -> bool {
        self.code.trim().is_empty() && !self.comment.trim().is_empty()
    }

    /// An attribute line (`#[...]` / `#![...]`), treated like a comment
    /// when walking a statement's leading block.
    pub fn attr_only(&self) -> bool {
        let t = self.code.trim();
        t.starts_with("#[") || t.starts_with("#![")
    }

    pub fn blank(&self) -> bool {
        self.code.trim().is_empty() && self.comment.trim().is_empty()
    }
}

/// A `fn` item with a body, located by the scanner. Lines are 0-indexed.
pub struct FnSpan {
    pub name: String,
    pub start: usize,
    pub end: usize,
}

/// A parsed `// bps-lint: allow(RULE, reason)` directive.
pub struct Allow {
    pub rule: String,
    /// 0-indexed line the directive appears on.
    pub line: usize,
    /// Comment-only line → applies from `line` to end of file;
    /// trailing on a code line → applies to that line only.
    pub file_scoped: bool,
    /// The reason text (may be empty — rules reject that as L000).
    pub reason: String,
}

/// A scanned source file plus the structural indexes the rules consume.
pub struct SourceFile {
    /// Path label used in diagnostics (repo-relative by convention).
    pub path: String,
    pub lines: Vec<Line>,
    /// First line of the trailing `#[cfg(test)]` region, if any; the
    /// region extends to end of file (repo convention: unit tests are
    /// the last item of a module).
    pub test_start: Option<usize>,
    pub fns: Vec<FnSpan>,
    pub allows: Vec<Allow>,
}

/// Lexer state for the code/comment split.
enum Mode {
    Normal,
    LineComment,
    BlockComment(usize),
    Str,
    RawStr(usize),
    Char,
}

impl SourceFile {
    pub fn parse(path: &str, text: &str) -> SourceFile {
        let lines = split_lines(text);
        let test_start = lines
            .iter()
            .position(|l| l.code.trim() == "#[cfg(test)]");
        let fns = find_fns(&lines);
        let allows = find_allows(&lines);
        SourceFile {
            path: path.to_string(),
            lines,
            test_start,
            fns,
            allows,
        }
    }

    /// True when `line` is inside the trailing test region.
    pub fn in_tests(&self, line: usize) -> bool {
        self.test_start.is_some_and(|t| line >= t)
    }

    /// Walk back from `line` to the first line of its statement: stop when
    /// the previous code line ends a statement (`;`, `{` or `}`) or is not
    /// code at all.
    pub fn stmt_start(&self, line: usize) -> usize {
        let mut s = line;
        while s > 0 {
            let prev = &self.lines[s - 1];
            let code = prev.code.trim_end();
            if code.trim().is_empty() {
                break;
            }
            match code.chars().last() {
                Some(';') | Some('{') | Some('}') => break,
                _ => s -= 1,
            }
        }
        s
    }

    /// The statement's code from its first line through `line`, joined
    /// with single spaces (enough context for keyword checks — tokens
    /// after the flagged line belong to later checks on those lines).
    pub fn stmt_code(&self, line: usize) -> String {
        let s = self.stmt_start(line);
        let mut out = String::new();
        for l in &self.lines[s..=line] {
            out.push_str(l.code.trim());
            out.push(' ');
        }
        out
    }

    /// The statement's code with *all* whitespace removed, extended
    /// forward until braces opened inside the statement are balanced and
    /// a `;`/`{`/`}` terminator is reached. This is the view used for
    /// call-chain matching (`.lock().unwrap()` split across lines) and
    /// for reading a whole spawn expression including its closure body.
    pub fn stmt_code_full(&self, line: usize) -> String {
        let s = self.stmt_start(line);
        let mut out = String::new();
        let mut depth: isize = 0;
        for l in &self.lines[s..] {
            for ch in l.code.chars() {
                if !ch.is_whitespace() {
                    out.push(ch);
                }
                match ch {
                    '{' => depth += 1,
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            let t = l.code.trim_end();
            let terminated = matches!(t.chars().last(), Some(';') | Some('{') | Some('}'));
            if terminated && depth <= 0 {
                break;
            }
        }
        out
    }

    /// True when the comment channel of the statement containing `line`,
    /// or of the contiguous comment/attribute block directly above it,
    /// contains `needle` (case-insensitive).
    pub fn has_note(&self, line: usize, needle: &str) -> bool {
        let needle = needle.to_ascii_lowercase();
        let s = self.stmt_start(line);
        for l in &self.lines[s..=line] {
            if l.comment.to_ascii_lowercase().contains(&needle) {
                return true;
            }
        }
        // the comment/attribute block directly above the statement
        let mut i = s;
        while i > 0 {
            let prev = &self.lines[i - 1];
            if prev.comment_only() || prev.attr_only() {
                if prev.comment.to_ascii_lowercase().contains(&needle) {
                    return true;
                }
                i -= 1;
            } else {
                break;
            }
        }
        false
    }

    /// True when a scoped allow directive covers `rule` for a diagnostic
    /// anchored at `line` (whose statement starts at `stmt_start(line)`).
    pub fn allowed(&self, rule: &str, line: usize) -> bool {
        let s = self.stmt_start(line);
        self.allows.iter().any(|a| {
            a.rule == rule
                && !a.reason.trim().is_empty()
                && if a.file_scoped {
                    a.line <= line
                } else {
                    a.line >= s && a.line <= line
                }
        })
    }

    /// The span of the `fn` whose body contains `line`, if any (smallest
    /// enclosing span wins, so methods beat their `impl` siblings).
    pub fn enclosing_fn(&self, line: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| f.start <= line && line <= f.end)
            .min_by_key(|f| f.end - f.start)
    }
}

/// Split `text` into code/comment channels, tracking brace depth.
fn split_lines(text: &str) -> Vec<Line> {
    let mut lines = Vec::new();
    let bytes: Vec<char> = text.chars().collect();
    let mut mode = Mode::Normal;
    let mut code = String::new();
    let mut comment = String::new();
    let mut depth: usize = 0;
    let mut depth_before = 0usize;
    let mut opens = 0usize;
    let mut i = 0;
    let n = bytes.len();
    macro_rules! flush_line {
        () => {{
            lines.push(Line {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                depth_before,
                depth_after: depth,
                opens,
            });
            depth_before = depth;
            opens = 0;
        }};
    }
    while i < n {
        let c = bytes[i];
        if c == '\n' {
            if matches!(mode, Mode::LineComment) {
                mode = Mode::Normal;
            }
            flush_line!();
            i += 1;
            continue;
        }
        match mode {
            Mode::Normal => {
                if c == '/' && i + 1 < n && bytes[i + 1] == '/' {
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == '/' && i + 1 < n && bytes[i + 1] == '*' {
                    mode = Mode::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    // raw string? count '#'s backwards to an 'r'
                    let mut h = 0usize;
                    let mut j = code.len();
                    let cb: Vec<char> = code.chars().collect();
                    while j > 0 && cb[j - 1] == '#' {
                        h += 1;
                        j -= 1;
                    }
                    if j > 0 && cb[j - 1] == 'r' {
                        mode = Mode::RawStr(h);
                    } else {
                        mode = Mode::Str;
                    }
                    code.push('"');
                    i += 1;
                } else if c == '\'' {
                    // char literal vs lifetime
                    if i + 1 < n && bytes[i + 1] == '\\' {
                        mode = Mode::Char;
                        code.push('\'');
                        i += 2; // consume the backslash too
                    } else if i + 2 < n && bytes[i + 2] == '\'' {
                        // 'x' — blank the payload char
                        code.push('\'');
                        code.push('\'');
                        i += 3;
                    } else {
                        // lifetime marker: plain code
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    if c == '{' {
                        depth += 1;
                        opens += 1;
                    } else if c == '}' {
                        depth = depth.saturating_sub(1);
                    }
                    code.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                comment.push(c);
                i += 1;
            }
            Mode::BlockComment(d) => {
                if c == '*' && i + 1 < n && bytes[i + 1] == '/' {
                    mode = if d == 1 {
                        Mode::Normal
                    } else {
                        Mode::BlockComment(d - 1)
                    };
                    i += 2;
                } else if c == '/' && i + 1 < n && bytes[i + 1] == '*' {
                    mode = Mode::BlockComment(d + 1);
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    // skip the escaped char (contents are blanked) — but a
                    // `\` line-continuation must still flush the line
                    if i + 1 < n && bytes[i + 1] == '\n' {
                        flush_line!();
                    }
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    mode = Mode::Normal;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::RawStr(h) => {
                if c == '"' {
                    let mut k = 0usize;
                    while k < h && i + 1 + k < n && bytes[i + 1 + k] == '#' {
                        k += 1;
                    }
                    if k == h {
                        code.push('"');
                        for _ in 0..h {
                            code.push('#');
                        }
                        mode = Mode::Normal;
                        i += 1 + h;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
            Mode::Char => {
                if c == '\'' {
                    code.push('\'');
                    mode = Mode::Normal;
                }
                i += 1;
            }
        }
    }
    flush_line!();
    lines
}

/// True when `word` appears in `code` delimited by non-identifier chars.
pub fn has_word(code: &str, word: &str) -> bool {
    let b = code.as_bytes();
    let w = word.as_bytes();
    let ident = |c: u8| c == b'_' || c.is_ascii_alphanumeric();
    let mut i = 0;
    while i + w.len() <= b.len() {
        if &b[i..i + w.len()] == w {
            let before_ok = i == 0 || !ident(b[i - 1]);
            let after_ok = i + w.len() == b.len() || !ident(b[i + w.len()]);
            if before_ok && after_ok {
                return true;
            }
        }
        i += 1;
    }
    false
}

/// Locate `fn` items with bodies by brace counting from the declaration.
fn find_fns(lines: &[Line]) -> Vec<FnSpan> {
    let mut out = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        let Some(name) = fn_name(&l.code) else {
            continue;
        };
        // walk forward to the body's closing brace (or a bodyless `;`)
        let mut depth: isize = 0;
        let mut opened = false;
        for (j, lj) in lines.iter().enumerate().skip(i) {
            for ch in lj.code.chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                out.push(FnSpan {
                    name: name.clone(),
                    start: i,
                    end: j,
                });
                break;
            }
            if !opened && lj.code.contains(';') {
                break; // trait method declaration without a body
            }
        }
    }
    out
}

/// Extract the identifier after a `fn` keyword token, if present.
fn fn_name(code: &str) -> Option<String> {
    let b = code.as_bytes();
    let ident = |c: u8| c == b'_' || c.is_ascii_alphanumeric();
    let mut i = 0;
    while i + 2 <= b.len() {
        if &b[i..i + 2] == b"fn" && (i == 0 || !ident(b[i - 1])) {
            let mut j = i + 2;
            if j < b.len() && !ident(b[j]) {
                while j < b.len() && b[j] == b' ' {
                    j += 1;
                }
                let s = j;
                while j < b.len() && ident(b[j]) {
                    j += 1;
                }
                if j > s {
                    return Some(code[s..j].to_string());
                }
            }
        }
        i += 1;
    }
    None
}

/// Parse every `bps-lint: allow(RULE, reason)` directive in the file's
/// comment channel. A malformed directive is recorded with an empty rule
/// so the caller can report it (L000) instead of silently ignoring it.
///
/// A directive must *begin* its comment: `// bps-lint: ...` (trailing on
/// a code line or alone). Doc comments (`///`, `//!`) keep their extra
/// `/` or `!` in the comment channel, so prose and examples that merely
/// mention the marker — including this module's own documentation — are
/// never parsed as directives.
fn find_allows(lines: &[Line]) -> Vec<Allow> {
    let mut out = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        let Some(rest) = l.comment.trim_start().strip_prefix("bps-lint:") else {
            continue;
        };
        let parsed = rest.trim_start().strip_prefix("allow(").and_then(|r| {
            let close = r.find(')')?;
            let inner = &r[..close];
            let (rule, reason) = match inner.split_once(',') {
                Some((a, b)) => (a.trim(), b.trim()),
                None => (inner.trim(), ""),
            };
            Some((rule.to_string(), reason.to_string()))
        });
        let (rule, reason) = parsed.unwrap_or_default();
        out.push(Allow {
            rule,
            line: i,
            file_scoped: l.comment_only(),
            reason,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_separated() {
        let src = "let a = \"unsafe // not code\"; // trailing unsafe note\nlet b = 'x';\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(!f.lines[0].code.contains("unsafe"));
        assert!(f.lines[0].comment.contains("unsafe note"));
        assert_eq!(f.lines[1].code, "let b = '';");
    }

    #[test]
    fn lifetimes_are_not_chars() {
        let f = SourceFile::parse("t.rs", "fn f<'a>(x: &'a str) -> &'a str { x }\n");
        assert!(f.lines[0].code.contains("'a"));
        assert_eq!(f.fns.len(), 1);
        assert_eq!(f.fns[0].name, "f");
    }

    #[test]
    fn raw_strings_are_blanked() {
        let src = "let s = r#\"unsafe { }\"#;\nlet t = 1;\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(!f.lines[0].code.contains("unsafe"), "{}", f.lines[0].code);
        assert!(f.lines[1].code.contains("let t"));
    }

    #[test]
    fn string_line_continuations_keep_line_numbers() {
        let src = "let s = \"a \\\nb\";\nlet t = 1;\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(f.lines[2].code.contains("let t"), "{}", f.lines[2].code);
    }

    #[test]
    fn block_comments_nest() {
        let src = "/* outer /* inner */ still comment */ let x = 1;\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(f.lines[0].code.contains("let x"));
        assert!(!f.lines[0].code.contains("outer"));
    }

    #[test]
    fn fn_spans_and_depth() {
        let src = "fn a() {\n    inner();\n}\n\nfn b(x: usize) -> usize {\n    x\n}\n";
        let f = SourceFile::parse("t.rs", src);
        let names: Vec<&str> = f.fns.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["a", "b"]);
        assert_eq!((f.fns[0].start, f.fns[0].end), (0, 2));
        assert_eq!((f.fns[1].start, f.fns[1].end), (4, 6));
        assert_eq!(f.lines[1].depth_before, 1);
    }

    #[test]
    fn stmt_walkback_joins_continuations() {
        let src = "let x = foo(\n    bar,\n    baz,\n);\n";
        let f = SourceFile::parse("t.rs", src);
        assert_eq!(f.stmt_start(2), 0);
        assert!(f.stmt_code(2).contains("foo("));
    }

    #[test]
    fn stmt_code_full_spans_closures() {
        let src =
            "let t = Builder::new()\n    .name(\"x\")\n    .spawn(move || {\n        run_loop();\n    });\n";
        let f = SourceFile::parse("t.rs", src);
        let full = f.stmt_code_full(0);
        assert!(full.contains(".name("), "{full}");
        assert!(full.contains("run_loop"), "{full}");
    }

    #[test]
    fn allow_directives_parse_and_scope() {
        let src = "\
// bps-lint: allow(L002, counters only)
let a = x.load(Ordering::Relaxed); // bps-lint: allow(L003, demo)
// bps-lint: allow(
/// docs may mention bps-lint: allow(L001, x) without arming it
// prose about the bps-lint: allow syntax is not a directive either
";
        let f = SourceFile::parse("t.rs", src);
        assert_eq!(f.allows.len(), 3, "doc/prose mentions must not parse");
        assert!(f.allows[0].file_scoped);
        assert_eq!(f.allows[0].rule, "L002");
        assert_eq!(f.allows[0].reason, "counters only");
        assert!(!f.allows[1].file_scoped);
        assert!(f.allows[2].rule.is_empty(), "malformed keeps empty rule");
        assert!(f.allowed("L002", 1));
        assert!(!f.allowed("L003", 0));
    }

    #[test]
    fn test_region_detected() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n}\n";
        let f = SourceFile::parse("t.rs", src);
        assert_eq!(f.test_start, Some(1));
        assert!(f.in_tests(2));
        assert!(!f.in_tests(0));
    }

    #[test]
    fn has_note_sees_statement_and_leading_block() {
        let src = "\
// SAFETY: fine here
#[inline]
unsafe fn f() {}
";
        let f = SourceFile::parse("t.rs", src);
        assert!(f.has_note(2, "safety:"));
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("unsafe impl Send for T {}", "unsafe"));
        assert!(!has_word("let unsafely = 1;", "unsafe"));
        assert!(!has_word("dyn Fn(usize)", "fn"));
    }
}
