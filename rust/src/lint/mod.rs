//! `bps lint` — dependency-free static analysis for this repository's
//! concurrency invariants (DESIGN.md §0.13).
//!
//! The batch simulator's throughput rests on hand-rolled lock-free code:
//! the `WorkerPool` lifetime erasure, a hundred-plus `Ordering::Relaxed`
//! sites, and the serve layer's poison-recovering lock discipline. Those
//! invariants live in comments and reviewers' heads; this module turns
//! them into machine-checked rules with stable IDs (L001–L005, plus L000
//! for the directives themselves) so CI can enforce them deny-by-default.
//!
//! Usage: `bps lint [--root DIR] [--json]` — scans `rust/src/**/*.rs`
//! plus DESIGN.md, exits nonzero on any violation. Scoped escapes use
//! `// bps-lint: allow(L00X, reason)`: trailing on a code line it covers
//! that statement only; on a comment-only line it covers the rest of the
//! file. A missing reason is itself an error (L000).

pub mod rules;
pub mod scan;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{num, obj, s, Json};
pub use rules::Diag;
use scan::SourceFile;

/// The result of linting a tree: ordered findings plus scan stats.
pub struct LintReport {
    pub diags: Vec<Diag>,
    pub files_scanned: usize,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.diags.is_empty()
    }

    /// Machine-readable rendering (the `--json` surface; schema pinned by
    /// `rust/tests/lint.rs`).
    pub fn to_json(&self) -> Json {
        let violations: Vec<Json> = self
            .diags
            .iter()
            .map(|d| {
                obj(vec![
                    ("rule", s(d.rule)),
                    ("file", s(&d.file)),
                    ("line", num(d.line as f64)),
                    ("msg", s(&d.msg)),
                ])
            })
            .collect();
        obj(vec![
            ("version", num(1.0)),
            ("clean", Json::Bool(self.clean())),
            ("files_scanned", num(self.files_scanned as f64)),
            ("violations", Json::Arr(violations)),
        ])
    }

    /// Human rendering: one `file:line: [rule] msg` per finding plus a
    /// summary line.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for d in &self.diags {
            out.push_str(&format!("{}:{}: [{}] {}\n", d.file, d.line, d.rule, d.msg));
        }
        out.push_str(&format!(
            "bps lint: {} file(s) scanned, {} violation(s)\n",
            self.files_scanned,
            self.diags.len()
        ));
        out
    }
}

/// Lint a single source string (the fixture-test entry point — same code
/// path the tree walk uses, minus the L005 cross-file check).
pub fn lint_str(path: &str, src: &str) -> Vec<Diag> {
    let f = SourceFile::parse(path, src);
    let mut diags = Vec::new();
    rules::check_file(&f, &mut diags);
    diags
}

/// Run the L005 protocol-drift check over explicit sources (fixture
/// entry point).
pub fn lint_protocol(frame_src: &str, design: &str) -> Vec<Diag> {
    let f = SourceFile::parse("rust/src/serve/wire/frame.rs", frame_src);
    let mut diags = Vec::new();
    rules::l005_protocol_drift(&f, design, &mut diags);
    diags
}

/// Lint the repository at `root`: every `.rs` file under `rust/src`, plus
/// the frame/DESIGN.md drift check.
pub fn lint_tree(root: &Path) -> Result<LintReport> {
    let src = root.join("rust").join("src");
    if !src.is_dir() {
        bail!("{} has no rust/src — not a repo root?", root.display());
    }
    let mut files = Vec::new();
    collect_rs(&src, &mut files)?;
    files.sort();
    let mut diags = Vec::new();
    let mut frame: Option<SourceFile> = None;
    for p in &files {
        let text =
            std::fs::read_to_string(p).with_context(|| format!("read {}", p.display()))?;
        let label = p
            .strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        let sf = SourceFile::parse(&label, &text);
        rules::check_file(&sf, &mut diags);
        if label.ends_with("serve/wire/frame.rs") {
            frame = Some(sf);
        }
    }
    match frame {
        Some(f) => {
            let design = std::fs::read_to_string(root.join("DESIGN.md")).unwrap_or_default();
            rules::l005_protocol_drift(&f, &design, &mut diags);
        }
        None => diags.push(Diag {
            rule: "L005",
            file: "rust/src/serve/wire/frame.rs".into(),
            line: 0,
            msg: "wire frame definition file not found".into(),
        }),
    }
    diags.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(LintReport {
        diags,
        files_scanned: files.len(),
    })
}

/// Walk up from the current directory to the repo root (the first
/// ancestor containing `rust/src`), so `bps lint` works from anywhere in
/// the checkout.
pub fn find_root() -> Result<PathBuf> {
    let mut dir = std::env::current_dir().context("current dir")?;
    loop {
        if dir.join("rust").join("src").is_dir() {
            return Ok(dir);
        }
        if !dir.pop() {
            bail!("no repo root (directory containing rust/src) above the current directory");
        }
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir).with_context(|| format!("read dir {}", dir.display()))? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_text_and_json() {
        let report = LintReport {
            diags: vec![Diag {
                rule: "L001",
                file: "rust/src/x.rs".into(),
                line: 3,
                msg: "`unsafe` without a `// SAFETY:` justification".into(),
            }],
            files_scanned: 2,
        };
        assert!(!report.clean());
        let text = report.render_text();
        assert!(text.contains("rust/src/x.rs:3: [L001]"), "{text}");
        assert!(text.contains("2 file(s) scanned, 1 violation(s)"), "{text}");
        let j = report.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.req("version").unwrap().as_f64().unwrap() as i64, 1);
        assert_eq!(parsed.req("violations").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn lint_str_is_the_rule_pipeline() {
        let d = lint_str("rust/src/a.rs", "fn f(p: *const u8) {\n    unsafe { p.read() };\n}\n");
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, "L001");
        assert_eq!(d[0].line, 2, "1-indexed display line");
    }
}
