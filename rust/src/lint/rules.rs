//! The `bps lint` rule set (stable IDs L000–L005).
//!
//! Each rule is a pure function over a scanned [`SourceFile`] (plus, for
//! L005, the wire-protocol source and DESIGN.md). Rationale, scope, and
//! the allow-directive syntax are documented in DESIGN.md §0.13; the
//! fixture corpus in `rust/tests/lint.rs` seeds one violation and one
//! clean sample per rule.
//!
//! | id   | invariant |
//! |------|-----------|
//! | L000 | every `bps-lint:` directive parses and carries a reason |
//! | L001 | every `unsafe` carries a `// SAFETY:` justification |
//! | L002 | control-flow `Ordering::Relaxed` carries a `// relaxed:` note |
//! | L003 | serve code locks state/tenant maps via the poison-recovering helpers, state before tenants |
//! | L004 | long-lived threads in serve/obs/scenario are named and heartbeat-monitored |
//! | L005 | wire frame types / ERR codes stay in sync with `payload_cap` and DESIGN.md |

use super::scan::{has_word, SourceFile};

/// One linter finding. `line` is 1-indexed for display.
#[derive(Debug, Clone)]
pub struct Diag {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

fn diag(diags: &mut Vec<Diag>, rule: &'static str, file: &SourceFile, line0: usize, msg: String) {
    diags.push(Diag {
        rule,
        file: file.path.clone(),
        line: line0 + 1,
        msg,
    });
}

/// Run every per-file rule over `file`.
pub fn check_file(file: &SourceFile, diags: &mut Vec<Diag>) {
    l000_directives(file, diags);
    l001_unsafe_safety(file, diags);
    l002_relaxed_control_flow(file, diags);
    l003_serve_lock_discipline(file, diags);
    l004_thread_hygiene(file, diags);
}

/// L000: a malformed or reason-less allow directive is itself an error —
/// otherwise a typo silently disables a rule.
fn l000_directives(file: &SourceFile, diags: &mut Vec<Diag>) {
    for a in &file.allows {
        if a.rule.is_empty() {
            diag(
                diags,
                "L000",
                file,
                a.line,
                "malformed bps-lint directive (expected `bps-lint: allow(L00X, reason)`)".into(),
            );
        } else if !matches!(a.rule.as_str(), "L001" | "L002" | "L003" | "L004" | "L005") {
            diag(
                diags,
                "L000",
                file,
                a.line,
                format!("unknown rule {:?} in bps-lint directive", a.rule),
            );
        } else if a.reason.trim().is_empty() {
            diag(
                diags,
                "L000",
                file,
                a.line,
                format!("bps-lint allow({}) needs a reason", a.rule),
            );
        }
    }
}

/// L001: every `unsafe` token (block, fn, impl) must have a `SAFETY:`
/// note on its statement or in the comment block directly above it.
fn l001_unsafe_safety(file: &SourceFile, diags: &mut Vec<Diag>) {
    for (i, l) in file.lines.iter().enumerate() {
        if !has_word(&l.code, "unsafe") {
            continue;
        }
        if file.allowed("L001", i) {
            continue;
        }
        if !file.has_note(i, "safety:") {
            diag(
                diags,
                "L001",
                file,
                i,
                "`unsafe` without a `// SAFETY:` justification".into(),
            );
        }
    }
}

/// L002: an `Ordering::Relaxed` load/RMW inside a control-flow statement
/// (`if`/`while`/`match`/assertions) must carry a `// relaxed:` note
/// explaining why no stronger ordering is needed. Pure counter bumps and
/// stores are exempt; test modules are exempt.
fn l002_relaxed_control_flow(file: &SourceFile, diags: &mut Vec<Diag>) {
    let mut reported_stmt = usize::MAX;
    for (i, l) in file.lines.iter().enumerate() {
        if file.in_tests(i) || !l.code.contains("Ordering::Relaxed") {
            continue;
        }
        let stmt = file.stmt_start(i);
        if stmt == reported_stmt {
            continue; // one diagnostic per statement
        }
        let code = file.stmt_code(i);
        let control = has_word(&code, "if")
            || has_word(&code, "while")
            || has_word(&code, "match")
            || code.contains("assert!")
            || code.contains("assert_eq!")
            || code.contains("assert_ne!")
            || code.contains("debug_assert");
        if !control {
            continue;
        }
        if file.allowed("L002", i) {
            continue;
        }
        if !file.has_note(i, "relaxed:") {
            reported_stmt = stmt;
            diag(
                diags,
                "L002",
                file,
                i,
                "control-flow `Ordering::Relaxed` without a `// relaxed:` note".into(),
            );
        }
    }
}

/// L003: serve-layer lock discipline. (a) state/tenant mutexes must go
/// through the poison-recovering helpers (`lock_state`/`lock_tenants`/
/// `lock_tenancy`), never `.lock().unwrap()` — a quarantined shard's
/// poisoned mutex would otherwise cascade panics. (b) lock ordering:
/// while a `lock_tenants` guard is live, taking `lock_state` inverts the
/// documented state-before-tenants order and can deadlock.
fn l003_serve_lock_discipline(file: &SourceFile, diags: &mut Vec<Diag>) {
    if !file.path.contains("serve/") {
        return;
    }
    // (a) raw unwrap on a state/tenant mutex
    for (i, l) in file.lines.iter().enumerate() {
        if file.in_tests(i) || !l.code.contains(".lock()") {
            continue;
        }
        let full = file.stmt_code_full(i);
        let Some(pos) = full.find(".lock().unwrap()") else {
            continue;
        };
        let recv = receiver_before(&full, pos);
        if recv.contains("state") || recv.contains("tenant") || recv.contains("tenancy") {
            if file.allowed("L003", i) {
                continue;
            }
            diag(
                diags,
                "L003",
                file,
                i,
                format!(
                    "`{recv}.lock().unwrap()` on a state/tenant mutex — use the \
                     poison-recovering helper (lock_state/lock_tenants/lock_tenancy)"
                ),
            );
        }
    }
    // (b) lock_state while a let-bound lock_tenants guard is live
    let mut guard: Option<(usize, usize)> = None; // (line, depth at binding)
    for (i, l) in file.lines.iter().enumerate() {
        if file.in_tests(i) {
            break;
        }
        if let Some((_, d)) = guard {
            if l.depth_before < d {
                guard = None;
            }
        }
        let stripped: String = l.code.chars().filter(|c| !c.is_whitespace()).collect();
        if guard.is_some() && stripped.contains("lock_state(") && !file.allowed("L003", i) {
            diag(
                diags,
                "L003",
                file,
                i,
                "lock_state taken while a lock_tenants guard is held — \
                 acquire state before tenants"
                    .into(),
            );
        }
        // a guard binding is `let <pat> = lock_tenants(...);` with nothing
        // chained after the call (a chained temporary drops immediately)
        if let Some(p) = stripped.find("=lock_tenants(") {
            let after = &stripped[p + "=lock_tenants".len()..];
            if balanced_call_then_semicolon(after) {
                guard = Some((i, l.depth_before));
            }
        }
    }
}

/// The receiver chain immediately before byte offset `pos` in a
/// whitespace-stripped statement: identifier/path/field chars only.
fn receiver_before(full: &str, pos: usize) -> String {
    let b = full.as_bytes();
    let mut s = pos;
    while s > 0 {
        let c = b[s - 1];
        if c.is_ascii_alphanumeric() || c == b'_' || c == b'.' || c == b':' {
            s -= 1;
        } else {
            break;
        }
    }
    full[s..pos].to_string()
}

/// True when `s` starts with a balanced `( ... )` call argument list
/// followed directly by `;` — i.e. the call result is bound, not chained.
fn balanced_call_then_semicolon(s: &str) -> bool {
    let b = s.as_bytes();
    if b.first() != Some(&b'(') {
        return false;
    }
    let mut depth = 0usize;
    for (i, &c) in b.iter().enumerate() {
        match c {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return b.get(i + 1) == Some(&b';');
                }
            }
            _ => {}
        }
    }
    false
}

/// L004: thread hygiene in the long-running layers (serve/, obs/,
/// scenario/): every spawn must use `Builder::new().name(...)`, and the
/// spawn site must be covered by watchdog evidence — a `Heartbeat`/
/// watchdog reference in the enclosing function or in a same-file
/// function the spawn statement calls (loops often register their role
/// from inside the spawned function).
fn l004_thread_hygiene(file: &SourceFile, diags: &mut Vec<Diag>) {
    let p = &file.path;
    if !(p.contains("serve/") || p.contains("obs/") || p.contains("scenario/")) {
        return;
    }
    let mut reported_stmt = usize::MAX;
    for (i, l) in file.lines.iter().enumerate() {
        if file.in_tests(i) {
            continue;
        }
        let is_spawn = l.code.contains("thread::spawn(") || l.code.contains(".spawn(");
        if !is_spawn {
            continue;
        }
        let stmt = file.stmt_start(i);
        if stmt == reported_stmt {
            continue;
        }
        if file.allowed("L004", i) {
            continue;
        }
        let full = file.stmt_code_full(i);
        let thread_spawn = full.contains("thread::spawn(")
            || (full.contains("Builder::new(") && full.contains(".spawn("));
        if !thread_spawn {
            continue;
        }
        if !full.contains("Builder::new(") {
            reported_stmt = stmt;
            diag(
                diags,
                "L004",
                file,
                i,
                "bare thread::spawn — use Builder::new().name(...) so crash \
                 reports and debuggers see a role"
                    .into(),
            );
            continue;
        }
        if !full.contains(".name(") {
            reported_stmt = stmt;
            diag(diags, "L004", file, i, "spawned thread has no .name(...)".into());
            continue;
        }
        if !heartbeat_evidence(file, i, &full) {
            reported_stmt = stmt;
            diag(
                diags,
                "L004",
                file,
                i,
                "spawned thread has no watchdog Heartbeat in scope (register \
                 one, or `bps-lint: allow(L004, reason)` for short-lived \
                 helpers)"
                    .into(),
            );
        }
    }
}

/// Heartbeat/watchdog token in the enclosing fn, or in the body of any
/// same-file fn the spawn statement mentions (drivers register their
/// role from inside the spawned loop).
fn heartbeat_evidence(file: &SourceFile, line: usize, full_stmt: &str) -> bool {
    let hit = |lo: usize, hi: usize| {
        file.lines[lo..=hi].iter().any(|l| {
            let c = l.code.to_ascii_lowercase();
            c.contains("heartbeat") || c.contains("watchdog")
        })
    };
    if let Some(f) = file.enclosing_fn(line) {
        if hit(f.start, f.end) {
            return true;
        }
    }
    for f in &file.fns {
        if has_word(full_stmt, &f.name) && hit(f.start, f.end) {
            return true;
        }
    }
    false
}

/// L005: wire-protocol drift detection. `frame` is the source of
/// `serve/wire/frame.rs`, `design` the text of DESIGN.md. Checks:
/// frame-type and error-code value uniqueness, a `payload_cap` arm per
/// frame type, a §0.8 table row per frame type, and an `ERR_*` mention
/// in DESIGN.md per error code.
pub fn l005_protocol_drift(frame: &SourceFile, design: &str, diags: &mut Vec<Diag>) {
    let consts = find_wire_consts(frame);
    let fts: Vec<&(String, u32, usize)> =
        consts.iter().filter(|(n, _, _)| n.starts_with("FT_")).collect();
    let errs: Vec<&(String, u32, usize)> =
        consts.iter().filter(|(n, _, _)| n.starts_with("ERR_")).collect();
    for (kind, set) in [("frame type", &fts), ("error code", &errs)] {
        for (ai, a) in set.iter().enumerate() {
            for b in set.iter().skip(ai + 1) {
                if a.1 == b.1 {
                    diag(
                        diags,
                        "L005",
                        frame,
                        b.2,
                        format!("{kind} value {} reused by {} and {}", a.1, a.0, b.0),
                    );
                }
            }
        }
    }
    // every frame type has a payload_cap arm
    if let Some(cap) = frame.fns.iter().find(|f| f.name == "payload_cap") {
        for (name, _, line) in consts.iter().filter(|(n, _, _)| n.starts_with("FT_")) {
            let covered = frame.lines[cap.start..=cap.end]
                .iter()
                .any(|l| has_word(&l.code, name));
            if !covered {
                diag(
                    diags,
                    "L005",
                    frame,
                    *line,
                    format!("{name} has no arm in payload_cap()"),
                );
            }
        }
    } else {
        diag(diags, "L005", frame, 0, "payload_cap() not found in frame.rs".into());
    }
    // every frame type has a DESIGN.md §0.8 row; every ERR code is documented
    for (name, _, line) in &consts {
        if let Some(short) = name.strip_prefix("FT_") {
            let row = format!("`{short}`");
            if !design.contains(&row) {
                diag(
                    diags,
                    "L005",
                    frame,
                    *line,
                    format!("frame type {name} has no `{short}` row in DESIGN.md §0.8"),
                );
            }
        } else if name.starts_with("ERR_") && !contains_word(design, name) {
            diag(
                diags,
                "L005",
                frame,
                *line,
                format!("{name} is not documented in DESIGN.md"),
            );
        }
    }
}

/// `pub const NAME: u8 = N;` / `: u16 = N;` declarations in code, with
/// their values. Frame types are `u8`, error codes `u16` — both widths
/// must be visible or the ERR_* half of L005 silently checks nothing.
fn find_wire_consts(file: &SourceFile) -> Vec<(String, u32, usize)> {
    let mut out = Vec::new();
    for (i, l) in file.lines.iter().enumerate() {
        let t = l.code.trim();
        let Some(rest) = t
            .strip_prefix("pub const ")
            .or_else(|| t.strip_prefix("const "))
        else {
            continue;
        };
        let Some((name, tail)) = rest.split_once(':') else {
            continue;
        };
        let ty = tail.trim_start();
        let width = if ty.starts_with("u16") {
            3
        } else if ty.starts_with("u8") {
            2
        } else {
            continue;
        };
        if ty.as_bytes().get(width).is_some_and(|c| c.is_ascii_alphanumeric() || *c == b'_') {
            continue;
        }
        let Some((_, val)) = tail.split_once('=') else {
            continue;
        };
        let val = val.trim().trim_end_matches(';').trim();
        if let Ok(v) = val.parse::<u32>() {
            out.push((name.trim().to_string(), v, i));
        }
    }
    out
}

/// Word-boundary `contains` over arbitrary text (used for ERR_* mentions
/// in DESIGN.md, where ERR_SHARD must not match ERR_SHARD_DOWN).
fn contains_word(text: &str, word: &str) -> bool {
    let b = text.as_bytes();
    let w = word.as_bytes();
    let ident = |c: u8| c == b'_' || c.is_ascii_alphanumeric();
    let mut i = 0;
    while i + w.len() <= b.len() {
        if &b[i..i + w.len()] == w
            && (i == 0 || !ident(b[i - 1]))
            && (i + w.len() == b.len() || !ident(b[i + w.len()]))
        {
            return true;
        }
        i += 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(path: &str, src: &str) -> Vec<Diag> {
        let f = SourceFile::parse(path, src);
        let mut d = Vec::new();
        check_file(&f, &mut d);
        d
    }

    fn rules(d: &[Diag]) -> Vec<&str> {
        d.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn l001_flags_and_accepts() {
        let bad = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        assert_eq!(rules(&lint("a.rs", bad)), ["L001"]);
        let good =
            "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller promises p is valid\n    unsafe { *p }\n}\n";
        assert!(lint("a.rs", good).is_empty());
    }

    #[test]
    fn l002_only_control_flow() {
        let counter = "fn f(c: &AtomicUsize) {\n    c.fetch_add(1, Ordering::Relaxed);\n}\n";
        assert!(lint("a.rs", counter).is_empty());
        let branch =
            "fn f(c: &AtomicBool) {\n    if c.load(Ordering::Relaxed) {\n        stop();\n    }\n}\n";
        assert_eq!(rules(&lint("a.rs", branch)), ["L002"]);
        let noted = "fn f(c: &AtomicBool) {\n    // relaxed: advisory only, \
            re-checked under the lock\n    if c.load(Ordering::Relaxed) {\n        stop();\n    }\n}\n";
        assert!(lint("a.rs", noted).is_empty());
    }

    #[test]
    fn l002_multiline_statement_one_diag() {
        let src = "fn f(a: &AtomicBool, b: &AtomicU64) {\n    if !a.load(Ordering::Relaxed)\n        \
            && b.load(Ordering::Relaxed) > 3\n    {\n        stop();\n    }\n}\n";
        let d = lint("a.rs", src);
        assert_eq!(rules(&d), ["L002"], "{d:?}");
    }

    #[test]
    fn l003_scoped_to_serve_and_receiver() {
        let src = "fn f(s: &Shard) {\n    let g = s.state.lock().unwrap();\n}\n";
        assert!(lint("rust/src/util/a.rs", src).is_empty(), "only serve/");
        assert_eq!(rules(&lint("rust/src/serve/a.rs", src)), ["L003"]);
        let other = "fn f(s: &Shard) {\n    let g = s.mailbox.lock().unwrap();\n}\n";
        assert!(lint("rust/src/serve/a.rs", other).is_empty());
        let helper = "fn f(s: &Shard) {\n    let g = lock_state(&s.state);\n}\n";
        assert!(lint("rust/src/serve/a.rs", helper).is_empty());
    }

    #[test]
    fn l003_ordering_inversion() {
        let src = "\
fn f(s: &Shard) {
    let t = lock_tenants(&s.state);
    let g = lock_state(&s.state);
}
";
        assert_eq!(rules(&lint("rust/src/serve/a.rs", src)), ["L003"]);
        // a chained temporary is not a live guard
        let tmp = "\
fn f(s: &Shard) {
    let fill = lock_tenants(&s.state).coal.policy();
    let g = lock_state(&s.state);
}
";
        assert!(lint("rust/src/serve/a.rs", tmp).is_empty());
        // guard dies with its block
        let scoped = "\
fn f(s: &Shard) {
    {
        let t = lock_tenants(&s.state);
    }
    let g = lock_state(&s.state);
}
";
        assert!(lint("rust/src/serve/a.rs", scoped).is_empty());
    }

    #[test]
    fn l004_name_and_heartbeat() {
        let bare = "fn f() {\n    std::thread::spawn(|| loop_fn());\n}\n";
        assert_eq!(rules(&lint("rust/src/serve/a.rs", bare)), ["L004"]);
        assert!(lint("rust/src/env/a.rs", bare).is_empty(), "env/ out of scope");
        let unnamed =
            "fn f() {\n    std::thread::Builder::new().spawn(|| loop_fn()).unwrap();\n}\n";
        assert!(rules(&lint("rust/src/obs/a.rs", unnamed)).contains(&"L004"));
        let no_hb =
            "fn f() {\n    std::thread::Builder::new().name(\"x\".into()).spawn(|| {}).unwrap();\n}\n";
        assert!(rules(&lint("rust/src/obs/a.rs", no_hb)).contains(&"L004"));
        let hb = "\
fn f(w: &Watchdog) {
    let hb = w.register(\"x\");
    std::thread::Builder::new().name(\"x\".into()).spawn(move || run(hb)).unwrap();
}
";
        assert!(lint("rust/src/obs/a.rs", hb).is_empty());
    }

    #[test]
    fn l004_heartbeat_inside_spawned_fn() {
        let src = "\
fn listen(w: Wd) {
    std::thread::Builder::new()
        .name(\"x\".into())
        .spawn(move || accept_loop(w))
        .unwrap();
}

fn accept_loop(w: Wd) {
    let hb = w.watchdog().register(\"accept\");
    loop {
        hb.beat();
    }
}
";
        assert!(lint("rust/src/serve/a.rs", src).is_empty());
    }

    #[test]
    fn allow_directive_scoping() {
        let line_scoped = "\
fn f() {
    std::thread::spawn(|| {}); // bps-lint: allow(L004, short-lived test helper)
    std::thread::spawn(|| {});
}
";
        let d = lint("rust/src/serve/a.rs", line_scoped);
        assert_eq!(rules(&d), ["L004"], "second spawn still flagged: {d:?}");
        let file_scoped = "\
// bps-lint: allow(L004, demo binary, no watchdog exists here)
fn f() {
    std::thread::spawn(|| {});
    std::thread::spawn(|| {});
}
";
        assert!(lint("rust/src/serve/a.rs", file_scoped).is_empty());
    }

    #[test]
    fn l000_rejects_bad_directives() {
        let d = lint("a.rs", "// bps-lint: allow(L002)\n");
        assert_eq!(rules(&d), ["L000"]);
        let d = lint("a.rs", "// bps-lint: allow(L999, nope)\n");
        assert_eq!(rules(&d), ["L000"]);
    }

    #[test]
    fn l005_detects_drift() {
        let frame_src = "\
pub const FT_HELLO: u8 = 1;
pub const FT_STEP: u8 = 2;
pub const ERR_PROTOCOL: u8 = 1;
pub const ERR_LEASE: u8 = 1;

pub fn payload_cap(ftype: u8) -> usize {
    match ftype {
        FT_HELLO => 64,
        _ => 0,
    }
}
";
        let frame = SourceFile::parse("rust/src/serve/wire/frame.rs", frame_src);
        let design = "| `HELLO` | hi |\nERR_PROTOCOL is sent on malformed frames.\n";
        let mut d = Vec::new();
        l005_protocol_drift(&frame, design, &mut d);
        let msgs: Vec<&str> = d.iter().map(|x| x.msg.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("value 1 reused")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("FT_STEP has no arm")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("no `STEP` row")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("ERR_LEASE is not documented")), "{msgs:?}");
        assert!(d.iter().all(|x| x.rule == "L005"));
    }
}
