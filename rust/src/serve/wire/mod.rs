//! Wire transport for the serve layer: remote sessions over TCP.
//!
//! The in-process session API (`SimServer::connect → Session`) is
//! deliberately transport-agnostic; this module is the first transport
//! in front of it — a dependency-free, length-prefixed binary protocol
//! (see [`frame`] and DESIGN.md §0.8) carried over blocking TCP:
//!
//! ```text
//!  client process                     server process
//!  RemoteSession::submit ──SUBMIT──►  reader thread ──► session pump
//!       │                                                │ Session::submit_at
//!       │                                                ▼
//!       │                                       Coalescer / shard driver
//!       │                                                │ one batch step
//!  RemoteTicket::wait  ◄──STEP─────  outbox ◄── pump ◄───┘  for all tenants
//! ```
//!
//! [`WireServer::listen`] serves an existing
//! [`SimServer`](crate::serve::SimServer); [`RemoteClient::connect`] /
//! [`RemoteClient::open_session`] give remote processes the exact
//! `submit → wait → view` shape of the in-process `Session`, with
//! bitwise-identical observation streams (`rust/tests/serve_remote.rs`).
//! The paper's whole-batch amortization is preserved because remote
//! submissions still coalesce into single shard steps — the wire layer
//! adds tenants, not step paths.
//!
//! `bps serve --listen ADDR` and `bps connect ADDR` drive both ends from
//! the CLI; `benches/bench_serve.rs` measures loopback-vs-direct
//! overhead.
//!
//! Policy tenants ride the same socket: `LEASE_POLICY` leases env slots
//! plus a server-side policy, `GOAL` asks the server to drive them, and
//! `TRAJ` frames stream the server-chosen actions and results back
//! ([`RemoteClient::open_agent`] / [`RemoteAgent`]; `bps agent ADDR` on
//! the CLI; DESIGN.md §0.9). Connections idle past
//! [`WireConfig::idle_timeout_ticks`] are reaped, releasing their
//! leases.

pub mod client;
pub mod frame;
pub mod server;

pub use client::{RemoteAgent, RemoteClient, RemoteSession, RemoteTicket, RemoteTraj, ResumeCfg};
pub use server::{ConnStats, WireConfig, WireServer};
