//! Wire transport for the serve layer: remote sessions over TCP.
//!
//! The in-process session API (`SimServer::connect → Session`) is
//! deliberately transport-agnostic; this module is the first transport
//! in front of it — a dependency-free, length-prefixed binary protocol
//! (see [`frame`] and DESIGN.md §0.8) carried over blocking TCP:
//!
//! ```text
//!  client process                     server process
//!  RemoteSession::submit ──SUBMIT──►  reader thread ──► session pump
//!       │                                                │ Session::submit_at
//!       │                                                ▼
//!       │                                       Coalescer / shard driver
//!       │                                                │ one batch step
//!  RemoteTicket::wait  ◄──STEP─────  outbox ◄── pump ◄───┘  for all tenants
//! ```
//!
//! [`WireServer::listen`] serves an existing
//! [`SimServer`](crate::serve::SimServer); [`RemoteClient::connect`] /
//! [`RemoteClient::open_session`] give remote processes the exact
//! `submit → wait → view` shape of the in-process `Session`, with
//! bitwise-identical observation streams (`rust/tests/serve_remote.rs`).
//! The paper's whole-batch amortization is preserved because remote
//! submissions still coalesce into single shard steps — the wire layer
//! adds tenants, not step paths.
//!
//! `bps serve --listen ADDR` and `bps connect ADDR` drive both ends from
//! the CLI; `benches/bench_serve.rs` measures loopback-vs-direct
//! overhead.

pub mod client;
pub mod frame;
pub mod server;

pub use client::{RemoteClient, RemoteSession, RemoteTicket};
pub use server::{ConnStats, WireConfig, WireServer};
