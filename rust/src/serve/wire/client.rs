//! [`RemoteClient`] / [`RemoteSession`]: the in-process session API over
//! TCP.
//!
//! A `RemoteClient` owns one connection to a
//! [`WireServer`](super::WireServer) and multiplexes any number of
//! [`RemoteSession`]s over it
//! (a background reader thread routes incoming frames to per-session
//! mailboxes). A `RemoteSession` mirrors the in-process
//! [`Session`](crate::serve::Session) shape exactly — `submit(actions) →
//! RemoteTicket → wait() → SessionView` — and because observation floats
//! cross the wire as raw IEEE-754 bits, the views it returns are
//! *bitwise identical* to in-process serving of the same-seeded shard
//! (`rust/tests/serve_remote.rs`).
//!
//! Sessions are `Send` and independent of the `RemoteClient` value
//! (both hold the same `Arc`ed connection state): open them on one
//! thread, drive them from others. Dropping the client closes the
//! socket, which errors out all of its sessions and — server-side —
//! detaches their leases.
//!
//! With [`RemoteClient::connect_with_resume`], a dropped connection is
//! no longer fatal: sessions reconnect with capped exponential backoff
//! (jittered), present their grant's resume token, and the server
//! reattaches the parked lease — replaying the one step that may have
//! been applied but not delivered, while the client re-sends submits
//! the server never saw. The delivered observation stream is bitwise
//! identical to an undisturbed run (`rust/tests/serve_chaos.rs`).
//! Overload sheds (`ERR_RETRY_AFTER`) are also absorbed transparently:
//! the client sleeps out the server's retry-after hint and re-sends the
//! shed submit. Resume covers plain env sessions only — agent tenancies
//! hold server-side recurrent state a reconnecting client cannot prove
//! continuity for, so their leases release on disconnect.

use std::collections::{HashMap, VecDeque};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::metrics::Window;
use crate::serve::session::SessionView;
use crate::sim::Task;

use super::frame::{
    self, retry_after_ms, Frame, ReadError, StepFrame, ERR_LEASE, ERR_RETRY_AFTER,
};

/// How many latency samples a remote session keeps for its p50/p95.
const REMOTE_LATENCY_WINDOW: usize = 1024;

/// Set on every client-chosen request id (lease/stats/dump). Server-
/// chosen wire session ids are small counters that never reach this
/// bit, so an `ERROR`'s `re` field routes unambiguously even though
/// the two id spaces would otherwise collide numerically.
const REQ_BIT: u64 = 1 << 62;

/// Set on resume request ids — their own namespace, distinct from both
/// plain requests and session ids, so `RESUMED` / resume-refusal
/// errors can never be misrouted to a lease waiter or a mailbox.
const RESUME_REQ_BIT: u64 = 1 << 63;

/// Cap on transparent re-submits after shed (`ERR_RETRY_AFTER`)
/// answers, per submit — beyond this the shed surfaces as an error.
const MAX_SHED_RETRIES: u32 = 64;

/// What the reader routes into a session's mailbox.
enum SessMsg {
    Step {
        step: u64,
        view: StepFrame,
    },
    /// One server-driven trajectory step of a policy tenancy.
    Traj {
        step: u64,
        actions: Vec<u8>,
        view: StepFrame,
    },
    Detached,
    Error {
        code: u16,
        msg: String,
    },
}

/// A granted lease, delivered from the reader to `open_session`.
struct GrantMsg {
    session: u64,
    token: u64,
    task: Task,
    obs_floats: u32,
    slots: Vec<u32>,
    mailbox: Receiver<SessMsg>,
}

type LeaseReply = std::result::Result<GrantMsg, String>;

/// Answer to a `Stats` scrape: snapshot version + Prometheus text.
type StatsReply = (u32, String);

/// Answer to a `Dump` request: ok flag + bundle path or decline reason.
type DumpReply = (bool, String);

/// Answer to a `Resume` request: the server's applied count, or the
/// refusal message.
type ResumeReply = std::result::Result<u64, String>;

#[derive(Default)]
struct Routes {
    leases: HashMap<u64, Sender<LeaseReply>>,
    sessions: HashMap<u64, Sender<SessMsg>>,
    stats: HashMap<u64, Sender<StatsReply>>,
    dumps: HashMap<u64, Sender<DumpReply>>,
    resumes: HashMap<u64, Sender<ResumeReply>>,
}

/// Reconnect/backoff policy for [`RemoteClient::connect_with_resume`].
/// Attempt `k` sleeps `min(cap_ms, base_ms · 2^(k-1))` ± 25% jitter.
#[derive(Clone, Copy, Debug)]
pub struct ResumeCfg {
    /// Reconnect+resume attempts per outage before giving up.
    pub max_retries: u32,
    /// First backoff delay, in milliseconds (doubles per attempt).
    pub base_ms: u64,
    /// Backoff ceiling, in milliseconds.
    pub cap_ms: u64,
    /// Jitter seed. Deterministic per (seed, attempt); give each client
    /// of a fleet its own seed so their retries spread out.
    pub seed: u64,
}

impl Default for ResumeCfg {
    fn default() -> ResumeCfg {
        ResumeCfg {
            max_retries: 8,
            base_ms: 50,
            cap_ms: 2000,
            seed: 0,
        }
    }
}

/// Reconnect machinery, present only on `connect_with_resume` clients.
struct ResumeMeta {
    addr: String,
    cfg: ResumeCfg,
    /// Serializes re-dials: one session reconnects, the rest block here
    /// and then find the connection already healthy.
    gate: Mutex<()>,
    /// Sessions successfully resumed (lease reattached and reconciled).
    resumes: AtomicU64,
    /// Sockets re-dialed (≤ resumes: one reconnect serves every session
    /// of the client).
    reconnects: AtomicU64,
    /// Total milliseconds callers spent in reconnect backoff.
    backoff_ms: AtomicU64,
}

/// Capped exponential backoff with deterministic ±25% jitter
/// (splitmix64 over `(seed, attempt)` — no RNG state to carry).
fn backoff_delay(cfg: &ResumeCfg, attempt: u32) -> u64 {
    let exp = u64::from(attempt.saturating_sub(1).min(20));
    let capped = cfg.base_ms.max(1).saturating_mul(1 << exp).min(cfg.cap_ms.max(1));
    let mut z = cfg
        .seed
        .wrapping_add(u64::from(attempt).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    let jitter = capped / 4;
    if jitter == 0 {
        capped
    } else {
        capped - jitter / 2 + z % jitter
    }
}

struct ClientShared {
    /// All client→server frames are written under this lock. Swapped in
    /// place on reconnect.
    writer: Mutex<TcpStream>,
    /// Shutdown handle of the *current* socket (also swapped on
    /// reconnect); closing it unblocks the live reader thread.
    conn: Mutex<TcpStream>,
    routes: Mutex<Routes>,
    /// Why the connection died, once it has. Cleared by a reconnect.
    dead: Mutex<Option<String>>,
    next_req: AtomicU64,
    /// Reader threads spawned over this client's lifetime (one per
    /// (re)connect); all joined on drop.
    readers: Mutex<Vec<JoinHandle<()>>>,
    /// Set by `RemoteClient::drop`: no further reconnects may start.
    closing: AtomicBool,
    /// Frames the reader rejected as malformed (corruption guard).
    bad_frames: AtomicU64,
    /// Reconnect/resume machinery; `None` on plain `connect`.
    resume: Option<ResumeMeta>,
}

fn death(shared: &ClientShared) -> String {
    shared
        .dead
        .lock()
        .unwrap()
        .clone()
        .unwrap_or_else(|| "connection closed".into())
}

fn send_frame(shared: &ClientShared, f: &Frame) -> Result<()> {
    if let Some(msg) = shared.dead.lock().unwrap().clone() {
        bail!("connection lost: {msg}");
    }
    let mut w = shared.writer.lock().unwrap();
    frame::write_frame(&mut *w, f).context("write frame")
}

/// Dial and perform the hello/welcome handshake; returns the socket
/// (reader end), plus writer and shutdown clones, and the shard count.
fn dial(addr: &str) -> Result<(TcpStream, TcpStream, TcpStream, u32)> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    let _ = stream.set_nodelay(true);
    frame::write_frame(&mut stream, &Frame::Hello).context("send hello")?;
    let shards = match frame::read_frame_dir(&mut stream, false) {
        Ok(Frame::Welcome { shards }) => shards,
        Ok(other) => bail!("handshake: unexpected frame {other:?}"),
        Err(e) => bail!("handshake with {addr} failed: {e}"),
    };
    let shutdown = stream.try_clone().context("clone socket")?;
    let writer = stream.try_clone().context("clone socket")?;
    Ok((stream, writer, shutdown, shards))
}

fn spawn_reader(shared: &Arc<ClientShared>, stream: TcpStream) -> Result<()> {
    let for_reader = Arc::clone(shared);
    // bps-lint: allow(L004, client process — no watchdog exists here; the reader's liveness is the socket's)
    let h = std::thread::Builder::new()
        .name("bps-wire-client".into())
        .spawn(move || client_reader(stream, for_reader))
        .context("spawn client reader")?;
    shared.readers.lock().unwrap().push(h);
    Ok(())
}

/// Re-dial after a connection death, serialized by the resume gate: the
/// winner swaps the writer/shutdown sockets and spawns a fresh reader;
/// losers block on the gate, then find `dead` already cleared. Backoff
/// is the caller's job — between attempts, never under the gate.
fn ensure_connected(shared: &Arc<ClientShared>) -> Result<()> {
    let meta = shared
        .resume
        .as_ref()
        .expect("ensure_connected without resume");
    let _gate = meta.gate.lock().unwrap();
    if shared.closing.load(Ordering::SeqCst) {
        bail!("client is shutting down");
    }
    if shared.dead.lock().unwrap().is_none() {
        return Ok(()); // another session already reconnected
    }
    let (stream, writer, shutdown, _shards) = dial(&meta.addr)?;
    *shared.writer.lock().unwrap() = writer;
    *shared.conn.lock().unwrap() = shutdown;
    *shared.dead.lock().unwrap() = None;
    if let Err(e) = spawn_reader(shared, stream) {
        // No reader means mailboxes would starve forever — mark the
        // connection dead again so callers keep retrying or fail.
        *shared.dead.lock().unwrap() = Some(format!("spawn reader: {e:#}"));
        return Err(e);
    }
    meta.reconnects.fetch_add(1, Ordering::Relaxed);
    Ok(())
}

/// One TCP connection to a `WireServer` (see module docs).
pub struct RemoteClient {
    shared: Arc<ClientShared>,
    shards: u32,
}

impl RemoteClient {
    /// Dial `addr` (e.g. `"127.0.0.1:7447"`) and perform the
    /// hello/welcome handshake. A dropped connection is fatal to the
    /// client's sessions; see
    /// [`connect_with_resume`](RemoteClient::connect_with_resume).
    pub fn connect(addr: &str) -> Result<RemoteClient> {
        RemoteClient::connect_inner(addr, None)
    }

    /// Like [`connect`](RemoteClient::connect), but sessions survive
    /// connection drops: they reconnect under `cfg`'s backoff policy and
    /// resume their parked lease (server `--park-ttl`), transparently to
    /// the `submit → wait → view` caller. See the module docs.
    pub fn connect_with_resume(addr: &str, cfg: ResumeCfg) -> Result<RemoteClient> {
        RemoteClient::connect_inner(addr, Some(cfg))
    }

    fn connect_inner(addr: &str, resume: Option<ResumeCfg>) -> Result<RemoteClient> {
        let (stream, writer, shutdown, shards) = dial(addr)?;
        let shared = Arc::new(ClientShared {
            writer: Mutex::new(writer),
            conn: Mutex::new(shutdown),
            routes: Mutex::new(Routes::default()),
            dead: Mutex::new(None),
            next_req: AtomicU64::new(0),
            readers: Mutex::new(Vec::new()),
            closing: AtomicBool::new(false),
            bad_frames: AtomicU64::new(0),
            resume: resume.map(|cfg| ResumeMeta {
                addr: addr.to_string(),
                cfg,
                gate: Mutex::new(()),
                resumes: AtomicU64::new(0),
                reconnects: AtomicU64::new(0),
                backoff_ms: AtomicU64::new(0),
            }),
        });
        spawn_reader(&shared, stream)?;
        Ok(RemoteClient { shared, shards })
    }

    /// Shards the server advertised in its welcome.
    pub fn num_shards(&self) -> usize {
        self.shards as usize
    }

    /// `(resumes, backoff_ms_total)` over this client's lifetime: how
    /// many session resumes completed, and how long callers spent in
    /// reconnect backoff. Zeros for plain [`connect`] clients.
    ///
    /// [`connect`]: RemoteClient::connect
    pub fn resume_stats(&self) -> (u64, u64) {
        match &self.shared.resume {
            Some(m) => (
                m.resumes.load(Ordering::Relaxed),
                m.backoff_ms.load(Ordering::Relaxed),
            ),
            None => (0, 0),
        }
    }

    /// Frames the reader rejected as malformed. Fault-injected payload
    /// corruption lands here: the client refuses the frame and treats
    /// the connection as dead rather than adopting garbage.
    pub fn bad_frames(&self) -> u64 {
        self.shared.bad_frames.load(Ordering::Relaxed)
    }

    /// Lease `n_envs` slots of `task` on the server — the remote
    /// counterpart of `SimServer::connect`. Blocks until the server
    /// grants (or rejects) the lease and the initial observations have
    /// arrived, so `view()` works immediately.
    pub fn open_session(&self, task: Task, n_envs: usize) -> Result<RemoteSession> {
        if n_envs > frame::MAX_SESSION_ENVS {
            bail!(
                "open_session: {n_envs} envs exceeds the wire transport's \
                 per-session cap of {} (lease several sessions instead)",
                frame::MAX_SESSION_ENVS
            );
        }
        let req = (self.shared.next_req.fetch_add(1, Ordering::Relaxed) + 1) | REQ_BIT;
        let (tx, rx) = channel();
        self.shared.routes.lock().unwrap().leases.insert(req, tx);
        let lease = Frame::Lease {
            req,
            task,
            n_envs: n_envs as u32,
        };
        if let Err(e) = send_frame(&self.shared, &lease) {
            // the reply can never arrive; don't leak the route entry
            self.shared.routes.lock().unwrap().leases.remove(&req);
            return Err(e);
        }
        let grant = match rx.recv() {
            Ok(Ok(g)) => g,
            Ok(Err(msg)) => bail!("lease rejected: {msg}"),
            Err(_) => bail!("connection lost: {}", death(&self.shared)),
        };
        let n = grant.slots.len();
        let of = grant.obs_floats as usize;
        let mut session = RemoteSession {
            shared: Arc::clone(&self.shared),
            id: grant.session,
            token: grant.token,
            task: grant.task,
            obs_floats: of,
            slots: grant.slots.iter().map(|&s| s as usize).collect(),
            mailbox: grant.mailbox,
            obs: vec![0.0; n * of],
            goal: vec![0.0; n * 3],
            rewards: vec![0.0; n],
            dones: vec![false; n],
            successes: vec![false; n],
            spl: vec![0.0; n],
            scores: vec![0.0; n],
            synced: 0,
            submitted_seq: 0,
            delivered_seq: 0,
            steps_recv: 0,
            unacked: VecDeque::new(),
            shed_retries: 0,
            latency: Window::new(REMOTE_LATENCY_WINDOW),
            detached: false,
        };
        // The server sends the latest published observations right after
        // the grant; adopt them so `view()` matches the in-process seed.
        session.recv_step().context("initial observation")?;
        Ok(session)
    }

    /// Lease `n_envs` slots of `task` *plus* the named policy `variant`,
    /// server-driven — the remote counterpart of
    /// `SimServer::connect_with_policy`. `greedy = false` samples
    /// actions server-side from a per-tenant RNG seeded with `seed`
    /// (ignored when greedy). Blocks until the server grants (or
    /// rejects) the lease and the initial observations have arrived.
    pub fn open_agent(
        &self,
        task: Task,
        n_envs: usize,
        variant: &str,
        greedy: bool,
        seed: u64,
    ) -> Result<RemoteAgent> {
        if n_envs > frame::MAX_SESSION_ENVS {
            bail!(
                "open_agent: {n_envs} envs exceeds the wire transport's \
                 per-session cap of {} (lease several agents instead)",
                frame::MAX_SESSION_ENVS
            );
        }
        if variant.len() > frame::MAX_VARIANT_NAME {
            bail!(
                "open_agent: variant name exceeds {} bytes",
                frame::MAX_VARIANT_NAME
            );
        }
        let req = (self.shared.next_req.fetch_add(1, Ordering::Relaxed) + 1) | REQ_BIT;
        let (tx, rx) = channel();
        self.shared.routes.lock().unwrap().leases.insert(req, tx);
        let lease = Frame::LeasePolicy {
            req,
            task,
            n_envs: n_envs as u32,
            greedy,
            seed,
            variant: variant.into(),
        };
        if let Err(e) = send_frame(&self.shared, &lease) {
            self.shared.routes.lock().unwrap().leases.remove(&req);
            return Err(e);
        }
        let grant = match rx.recv() {
            Ok(Ok(g)) => g,
            Ok(Err(msg)) => bail!("policy lease rejected: {msg}"),
            Err(_) => bail!("connection lost: {}", death(&self.shared)),
        };
        let mut agent = RemoteAgent {
            shared: Arc::clone(&self.shared),
            id: grant.session,
            task: grant.task,
            obs_floats: grant.obs_floats as usize,
            slots: grant.slots.iter().map(|&s| s as usize).collect(),
            mailbox: grant.mailbox,
            initial_step: 0,
            initial: StepFrame::default(),
            steps: 0,
            detached: false,
        };
        // The initial snapshot arrives as a plain Step frame (nothing
        // was stepped yet, so there are no actions to report).
        match agent.mailbox.recv() {
            Ok(SessMsg::Step { step, view }) => {
                agent.check_shape(&view).context("initial observation")?;
                agent.initial_step = step;
                agent.initial = view;
            }
            Ok(SessMsg::Error { msg, .. }) => bail!("serve: {msg}"),
            Ok(_) => bail!("open_agent: unexpected frame before the initial observation"),
            Err(_) => bail!("connection lost: {}", death(&self.shared)),
        }
        Ok(agent)
    }

    /// Scrape the server's metrics registry over the session connection:
    /// returns the snapshot version and the Prometheus text exposition —
    /// byte-identical to what the server's `GET /metrics` endpoint would
    /// serve at the same instant. Blocks until the reply arrives.
    pub fn stats_text(&self) -> Result<(u32, String)> {
        let req = (self.shared.next_req.fetch_add(1, Ordering::Relaxed) + 1) | REQ_BIT;
        let (tx, rx) = channel();
        self.shared.routes.lock().unwrap().stats.insert(req, tx);
        if let Err(e) = send_frame(&self.shared, &Frame::Stats { req }) {
            self.shared.routes.lock().unwrap().stats.remove(&req);
            return Err(e);
        }
        match rx.recv() {
            Ok((version, text)) => Ok((version, text)),
            Err(_) => bail!("connection lost: {}", death(&self.shared)),
        }
    }

    /// Ask the server to write a manual flight-recorder incident bundle
    /// (`bps stats ADDR --dump`). Returns the server-side bundle
    /// directory path; fails when the server's recorder is not armed
    /// (no `--dump-dir`) or the bundle write failed. Blocks until the
    /// reply arrives.
    pub fn dump(&self) -> Result<String> {
        let req = (self.shared.next_req.fetch_add(1, Ordering::Relaxed) + 1) | REQ_BIT;
        let (tx, rx) = channel();
        self.shared.routes.lock().unwrap().dumps.insert(req, tx);
        if let Err(e) = send_frame(&self.shared, &Frame::Dump { req }) {
            self.shared.routes.lock().unwrap().dumps.remove(&req);
            return Err(e);
        }
        match rx.recv() {
            Ok((true, path)) => Ok(path),
            Ok((false, msg)) => bail!("dump declined: {msg}"),
            Err(_) => bail!("connection lost: {}", death(&self.shared)),
        }
    }
}

impl Drop for RemoteClient {
    fn drop(&mut self) {
        // Order matters: flag first (no new reconnects may start), then
        // wait out any in-flight re-dial under the gate (so the reader
        // it spawns is in `readers` before the join sweep), then cut the
        // current socket to unblock the live reader.
        self.shared.closing.store(true, Ordering::SeqCst);
        if let Some(meta) = &self.shared.resume {
            drop(meta.gate.lock());
        }
        {
            let conn = self
                .shared
                .conn
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            let _ = conn.shutdown(Shutdown::Both);
        }
        let handles: Vec<_> = {
            let mut r = self
                .shared
                .readers
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            r.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Route incoming frames to lease waiters and session mailboxes until
/// the connection dies, then fail everything that is still waiting.
fn client_reader(stream: TcpStream, shared: Arc<ClientShared>) {
    let mut why: Option<String> = None;
    let mut src = &stream;
    loop {
        let f = match frame::read_frame_dir(&mut src, false) {
            Ok(f) => f,
            Err(ReadError::Eof) => break,
            Err(e) => {
                if matches!(e, ReadError::Wire(_)) {
                    // Malformed bytes (corruption, not transport): count
                    // the rejection — chaos tests assert the client
                    // refused the frame instead of adopting garbage.
                    shared.bad_frames.fetch_add(1, Ordering::Relaxed);
                }
                why = Some(e.to_string());
                break;
            }
        };
        match f {
            Frame::Grant {
                req,
                session,
                token,
                task,
                obs_floats,
                slots,
            } => {
                let mut r = shared.routes.lock().unwrap();
                let (tx, mailbox) = channel();
                r.sessions.insert(session, tx);
                match r.leases.remove(&req) {
                    Some(reply) => {
                        let _ = reply.send(Ok(GrantMsg {
                            session,
                            token,
                            task,
                            obs_floats,
                            slots,
                            mailbox,
                        }));
                    }
                    None => {
                        r.sessions.remove(&session); // unsolicited grant
                    }
                }
            }
            Frame::Step {
                session, step, view, ..
            } => {
                let r = shared.routes.lock().unwrap();
                if let Some(tx) = r.sessions.get(&session) {
                    let _ = tx.send(SessMsg::Step { step, view });
                }
            }
            Frame::Traj {
                session,
                step,
                actions,
                view,
                ..
            } => {
                let r = shared.routes.lock().unwrap();
                if let Some(tx) = r.sessions.get(&session) {
                    let _ = tx.send(SessMsg::Traj {
                        step,
                        actions,
                        view,
                    });
                }
            }
            Frame::Detached { session } => {
                let mut r = shared.routes.lock().unwrap();
                if let Some(tx) = r.sessions.remove(&session) {
                    let _ = tx.send(SessMsg::Detached);
                }
            }
            Frame::Resumed { req, applied, .. } => {
                let mut r = shared.routes.lock().unwrap();
                if let Some(reply) = r.resumes.remove(&req) {
                    let _ = reply.send(Ok(applied));
                }
            }
            Frame::Error { re, code, msg } => {
                if re == 0 {
                    why = Some(format!("server error: {msg}"));
                    break;
                }
                // Route by id namespace (see REQ_BIT / RESUME_REQ_BIT):
                // resume refusals first, then client-chosen request ids
                // (lease declines — terminal ERR_LEASE or retry-after
                // overload sheds), then server-chosen session ids.
                let mut r = shared.routes.lock().unwrap();
                if let Some(reply) = r.resumes.remove(&re) {
                    let _ = reply.send(Err(msg));
                } else if (code == ERR_LEASE || code == ERR_RETRY_AFTER)
                    && r.leases.contains_key(&re)
                {
                    if let Some(reply) = r.leases.remove(&re) {
                        let _ = reply.send(Err(msg));
                    }
                } else if let Some(tx) = r.sessions.get(&re) {
                    let _ = tx.send(SessMsg::Error { code, msg });
                }
            }
            Frame::StatsReply { req, version, text } => {
                let mut r = shared.routes.lock().unwrap();
                if let Some(reply) = r.stats.remove(&req) {
                    let _ = reply.send((version, text));
                }
            }
            Frame::DumpReply { req, ok, msg } => {
                let mut r = shared.routes.lock().unwrap();
                if let Some(reply) = r.dumps.remove(&req) {
                    let _ = reply.send((ok, msg));
                }
            }
            Frame::Hello
            | Frame::Welcome { .. }
            | Frame::Lease { .. }
            | Frame::Submit { .. }
            | Frame::Detach { .. }
            | Frame::LeasePolicy { .. }
            | Frame::Goal { .. }
            | Frame::Stats { .. }
            | Frame::Dump { .. }
            | Frame::Resume { .. } => {
                why = Some("unexpected client-bound frame".into());
                break;
            }
        }
    }
    // Routes first, *then* the death note. Dropping the senders errors
    // out every blocked lease/step wait; resuming sessions key their
    // reconnect on `dead`, so it must become `Some` only after this
    // (old) reader can no longer wipe the new connection's routes.
    {
        let mut r = shared.routes.lock().unwrap();
        r.leases.clear();
        r.sessions.clear();
        r.stats.clear();
        r.dumps.clear();
        r.resumes.clear();
    }
    *shared.dead.lock().unwrap() = Some(why.unwrap_or_else(|| "connection closed".into()));
}

/// A lease on a remote shard, driven through the same
/// `submit → wait → view` cycle as the in-process `Session`.
pub struct RemoteSession {
    shared: Arc<ClientShared>,
    id: u64,
    /// Opaque resume token minted with the grant; presented on RESUME
    /// to prove ownership of the parked lease.
    token: u64,
    task: Task,
    obs_floats: usize,
    slots: Vec<usize>,
    mailbox: Receiver<SessMsg>,
    // Session-local SoA buffers, adopted from Step frames.
    obs: Vec<f32>,
    goal: Vec<f32>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
    successes: Vec<bool>,
    spl: Vec<f32>,
    scores: Vec<f32>,
    /// Shard step the buffers were last synced to.
    synced: u64,
    /// Submits sent so far; each produces exactly one `Step` frame.
    submitted_seq: u64,
    /// Step frames consumed from the mailbox so far. Tracking both lets
    /// `RemoteTicket::wait` drain frames left behind by tickets that
    /// were dropped without waiting, instead of desyncing one-behind.
    delivered_seq: u64,
    /// Step frames *adopted* so far, counting the seed — the resume
    /// protocol's `delivered` ordinal. Distinct from `delivered_seq`,
    /// which also counts error frames for ticket sequencing.
    steps_recv: u64,
    /// Submits not yet answered by an adopted step, tagged with the
    /// `steps_recv` ordinal each will land on. On resume, entries the
    /// server never applied are re-sent; applied ones are matched to
    /// the replayed step. Popped as their steps arrive.
    unacked: VecDeque<(u64, Vec<(u32, u8)>)>,
    /// Transparent re-submits after shed answers, since the last step.
    shed_retries: u32,
    latency: Window,
    detached: bool,
}

impl RemoteSession {
    /// Envs leased by this session.
    pub fn num_envs(&self) -> usize {
        self.slots.len()
    }

    /// Floats per env observation tile (shard render config).
    pub fn obs_floats(&self) -> usize {
        self.obs_floats
    }

    pub fn task(&self) -> Task {
        self.task
    }

    /// The shard-absolute slot indices backing this lease, in view order.
    pub fn slots(&self) -> &[usize] {
        &self.slots
    }

    /// This session's view of the last step it received.
    pub fn view(&self) -> SessionView<'_> {
        SessionView {
            step: self.synced,
            obs: &self.obs,
            goal: &self.goal,
            rewards: &self.rewards,
            dones: &self.dones,
            successes: &self.successes,
            spl: &self.spl,
            scores: &self.scores,
        }
    }

    /// Submit one action per leased slot (`actions[j]` steps
    /// `self.slots()[j]`), exactly like `Session::submit`.
    pub fn submit(&mut self, actions: &[u8]) -> Result<RemoteTicket<'_>> {
        if self.detached {
            bail!("submit on a detached session");
        }
        if actions.len() != self.slots.len() {
            bail!(
                "submit: {} actions for a {}-env session",
                actions.len(),
                self.slots.len()
            );
        }
        let pairs: Vec<(u32, u8)> = self
            .slots
            .iter()
            .zip(actions)
            .map(|(&s, &a)| (s as u32, a))
            .collect();
        // Record before sending: if the write races a connection drop,
        // only the resume reconciliation can tell whether the server
        // applied this submit (replay it) or never saw it (re-send it).
        let expected = self.steps_recv + self.unacked.len() as u64 + 1;
        self.unacked.push_back((expected, pairs.clone()));
        let submit = Frame::Submit {
            session: self.id,
            pairs,
        };
        if let Err(e) = send_frame(&self.shared, &submit) {
            if self.shared.resume.is_some() {
                // try_resume re-sends the unacked queue — including this
                // submit if (and only if) the server never applied it.
                if let Err(re) = self.try_resume(&format!("{e:#}")) {
                    self.unacked.pop_back();
                    return Err(re);
                }
            } else {
                self.unacked.pop_back();
                return Err(e);
            }
        }
        self.submitted_seq += 1;
        let seq = self.submitted_seq;
        Ok(RemoteTicket {
            session: self,
            seq,
            submitted: Instant::now(),
        })
    }

    /// Convenience: submit and immediately wait.
    pub fn step(&mut self, actions: &[u8]) -> Result<SessionView<'_>> {
        self.submit(actions)?.wait()
    }

    /// Release the lease and wait for the server's acknowledgement, so
    /// the freed slots are provably re-leasable when this returns.
    /// Idempotent; `Drop` sends a best-effort detach without waiting.
    pub fn detach(&mut self) -> Result<()> {
        if self.detached {
            return Ok(());
        }
        self.detached = true;
        let send = send_frame(&self.shared, &Frame::Detach { session: self.id });
        let mut errored: Option<String> = None;
        if send.is_ok() {
            loop {
                match self.mailbox.recv() {
                    Ok(SessMsg::Detached) => break,
                    // drain late step views still in flight
                    Ok(SessMsg::Step { .. }) | Ok(SessMsg::Traj { .. }) => continue,
                    // A session error here means the pump is dead or
                    // dying (shard failure / unknown session) — it
                    // released the lease on exit and will never send
                    // `Detached`, so waiting longer would hang forever.
                    // Surface it: a caller that only detaches (e.g. the
                    // CLI's clean-shutdown path) must still exit nonzero
                    // when the server reported a failure mid-stream.
                    Ok(SessMsg::Error { msg, .. }) => {
                        errored = Some(msg);
                        break;
                    }
                    // connection died — the server detaches on close
                    Err(_) => break,
                }
            }
        }
        // The reader only prunes the route on a `Detached` frame; drop
        // it ourselves so the dead id cannot collect stray messages.
        self.shared.routes.lock().unwrap().sessions.remove(&self.id);
        if let Some(msg) = errored {
            bail!("serve: {msg}");
        }
        send
    }

    /// Submit→view latency percentiles (p50, p95) over this session's
    /// recent steps, in seconds — includes the wire round trip.
    pub fn latency(&self) -> (f32, f32) {
        let [p50, p95] = self.latency.percentiles([0.5, 0.95]);
        (p50, p95)
    }

    /// Block for the next `Step` frame and adopt its arrays. Absorbs
    /// shed answers (sleep out the retry-after hint, re-send) and — on
    /// resume-enabled clients — connection deaths (reconnect, resume
    /// the parked lease, keep waiting).
    fn recv_step(&mut self) -> Result<()> {
        loop {
            match self.mailbox.recv() {
                Ok(SessMsg::Step { step, view }) => {
                    let n = self.slots.len();
                    let of = self.obs_floats;
                    if view.obs.len() != n * of
                        || view.goal.len() != n * 3
                        || view.rewards.len() != n
                        || view.dones.len() != n
                        || view.successes.len() != n
                        || view.spl.len() != n
                        || view.scores.len() != n
                    {
                        bail!("server sent a mis-shaped step view");
                    }
                    self.obs = view.obs;
                    self.goal = view.goal;
                    self.rewards = view.rewards;
                    self.dones = view.dones;
                    self.successes = view.successes;
                    self.spl = view.spl;
                    self.scores = view.scores;
                    self.synced = step;
                    self.steps_recv += 1;
                    self.shed_retries = 0;
                    while self
                        .unacked
                        .front()
                        .is_some_and(|&(exp, _)| exp <= self.steps_recv)
                    {
                        self.unacked.pop_front();
                    }
                    return Ok(());
                }
                Ok(SessMsg::Traj { .. }) => {
                    bail!("server sent a trajectory frame to a plain env session")
                }
                Ok(SessMsg::Detached) => bail!("session detached by the server"),
                Ok(SessMsg::Error { code, msg }) => {
                    // An overload shed is transient by contract: honor
                    // the server's retry-after hint and re-send the shed
                    // submit (the most recent unacked one) instead of
                    // surfacing an error.
                    if code == ERR_RETRY_AFTER && self.shed_retries < MAX_SHED_RETRIES {
                        let hint = retry_after_ms(&msg);
                        let resend = self.unacked.back().map(|(_, p)| p.clone());
                        if let (Some(ms), Some(pairs)) = (hint, resend) {
                            self.shed_retries += 1;
                            std::thread::sleep(Duration::from_millis(ms));
                            let f = Frame::Submit {
                                session: self.id,
                                pairs,
                            };
                            if send_frame(&self.shared, &f).is_ok() {
                                continue;
                            }
                        }
                    }
                    bail!("serve: {msg}")
                }
                Err(_) => {
                    // The connection died under us. With resume enabled
                    // this is recoverable: reattach and keep waiting —
                    // the missing step is replayed, or its submit
                    // re-sent, by the resume reconciliation.
                    let cause = death(&self.shared);
                    if self.shared.resume.is_none() {
                        bail!("connection lost: {cause}");
                    }
                    self.try_resume(&cause)?;
                }
            }
        }
    }

    /// Reattach this session after a connection death: reconnect under
    /// the backoff policy, present the resume token, adopt the fresh
    /// mailbox, and reconcile with the server's applied count — it
    /// replays an applied-but-undelivered step; submits it never
    /// applied are re-sent here. On success the delivered observation
    /// stream continues bitwise exactly where it left off.
    fn try_resume(&mut self, cause: &str) -> Result<()> {
        let meta = match self.shared.resume.as_ref() {
            Some(m) => m,
            None => bail!("connection lost: {cause}"),
        };
        let cfg = meta.cfg;
        let mut last = cause.to_string();
        let mut attempt = 0u32;
        'attempts: loop {
            if attempt >= cfg.max_retries {
                bail!(
                    "resume gave up after {} attempts; last error: {last}",
                    cfg.max_retries
                );
            }
            attempt += 1;
            let delay = backoff_delay(&cfg, attempt);
            std::thread::sleep(Duration::from_millis(delay));
            meta.backoff_ms.fetch_add(delay, Ordering::Relaxed);
            if let Err(e) = ensure_connected(&self.shared) {
                last = format!("{e:#}");
                continue;
            }
            let req = (self.shared.next_req.fetch_add(1, Ordering::Relaxed) + 1) | RESUME_REQ_BIT;
            let (stx, mailbox) = channel();
            let (rtx, rrx) = channel();
            {
                let mut r = self.shared.routes.lock().unwrap();
                r.sessions.insert(self.id, stx);
                r.resumes.insert(req, rtx);
            }
            let f = Frame::Resume {
                req,
                session: self.id,
                token: self.token,
                delivered: self.steps_recv,
            };
            if let Err(e) = send_frame(&self.shared, &f) {
                let mut r = self.shared.routes.lock().unwrap();
                r.sessions.remove(&self.id);
                r.resumes.remove(&req);
                last = format!("{e:#}");
                continue;
            }
            let applied = match rrx.recv() {
                Ok(Ok(applied)) => applied,
                Ok(Err(msg)) => {
                    // The server answered and refused (park TTL expired,
                    // parking disabled, token mismatch) — terminal;
                    // retrying cannot help.
                    self.shared.routes.lock().unwrap().sessions.remove(&self.id);
                    bail!("serve: {msg}");
                }
                Err(_) => {
                    // Died again mid-handshake; that reader's teardown
                    // already cleared the routes we inserted.
                    last = death(&self.shared);
                    continue;
                }
            };
            let owed = applied.saturating_sub(self.steps_recv);
            if owed > 1 {
                self.shared.routes.lock().unwrap().sessions.remove(&self.id);
                bail!(
                    "resume cannot reconstruct {owed} applied-but-undelivered \
                     steps (only the latest is replayable; keep at most one \
                     submit in flight across reconnects)"
                );
            }
            // Submits past `applied` never reached the shard: re-send
            // them in order. The one *at* `applied`, if any, is answered
            // by the replay the server queued behind RESUMED.
            for (exp, pairs) in self.unacked.iter() {
                if *exp > applied {
                    let f = Frame::Submit {
                        session: self.id,
                        pairs: pairs.clone(),
                    };
                    if let Err(e) = send_frame(&self.shared, &f) {
                        last = format!("{e:#}");
                        continue 'attempts;
                    }
                }
            }
            self.mailbox = mailbox;
            meta.resumes.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
    }
}

impl Drop for RemoteSession {
    fn drop(&mut self) {
        if !self.detached {
            self.detached = true;
            let _ = send_frame(&self.shared, &Frame::Detach { session: self.id });
        }
    }
}

/// An in-flight remote step: resolves at this submit's `Step` frame
/// (servers send exactly one per accepted submit).
/// [`current`](RemoteTicket::current) still serves the previous step
/// meanwhile, mirroring `Ticket::current`. A ticket dropped without
/// waiting leaves its frame in the mailbox; the next `wait` drains past
/// it, so the session never goes one-behind.
pub struct RemoteTicket<'a> {
    session: &'a mut RemoteSession,
    /// This submit's position in the one-`Step`-per-submit stream.
    seq: u64,
    submitted: Instant,
}

impl<'a> RemoteTicket<'a> {
    /// The session's previous view (valid while the step is in flight).
    pub fn current(&self) -> SessionView<'_> {
        self.session.view()
    }

    /// Block until this submit's view arrives (draining any earlier
    /// unwaited frames), adopt it, and return it. Same latest-wins
    /// semantics as `Ticket::wait` under a `Deadline` policy: the view
    /// is the shard's most recent published step.
    pub fn wait(self) -> Result<SessionView<'a>> {
        let RemoteTicket {
            session,
            seq,
            submitted,
        } = self;
        while session.delivered_seq < seq {
            match session.recv_step() {
                Ok(()) => session.delivered_seq += 1,
                Err(e) => {
                    // An error frame also answers exactly one submit:
                    // count it, or a later wait would block forever on
                    // a step view the server never owed us.
                    session.delivered_seq += 1;
                    return Err(e);
                }
            }
        }
        session.latency.push(submitted.elapsed().as_secs_f32());
        Ok(session.view())
    }
}

/// One server-driven step received by a [`RemoteAgent`]: the actions
/// the server-side policy chose for the leased slots plus the resulting
/// step slice.
#[derive(Debug, Clone, PartialEq)]
pub struct RemoteTraj {
    /// Shard batch step these results belong to.
    pub step: u64,
    /// Action stepped per leased slot, in view order.
    pub actions: Vec<u8>,
    pub view: StepFrame,
}

/// A remote policy tenancy: env slots leased together with a
/// server-side policy ([`RemoteClient::open_agent`]). The client posts
/// goals and drains the trajectory stream; the server runs the whole
/// act→observe loop (`SimServer::connect_with_policy` behind the wire).
pub struct RemoteAgent {
    shared: Arc<ClientShared>,
    id: u64,
    task: Task,
    obs_floats: usize,
    slots: Vec<usize>,
    mailbox: Receiver<SessMsg>,
    initial_step: u64,
    initial: StepFrame,
    steps: u64,
    detached: bool,
}

impl RemoteAgent {
    /// Envs leased by this agent session.
    pub fn num_envs(&self) -> usize {
        self.slots.len()
    }

    /// Floats per env observation tile (shard render config).
    pub fn obs_floats(&self) -> usize {
        self.obs_floats
    }

    pub fn task(&self) -> Task {
        self.task
    }

    /// The shard-absolute slot indices backing this lease, in view order.
    pub fn slots(&self) -> &[usize] {
        &self.slots
    }

    /// The initial observation snapshot (shard step, then the view) —
    /// what the lease saw before the server drove anything.
    pub fn initial(&self) -> (u64, &StepFrame) {
        (self.initial_step, &self.initial)
    }

    /// Trajectory steps received so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Ask the server to drive this lease for `steps` more steps (goals
    /// accumulate). One [`RemoteTraj`] arrives per step; rejection (zero
    /// steps, detached tenancy) comes back asynchronously as an error on
    /// [`next_traj`](RemoteAgent::next_traj).
    pub fn set_goal(&self, steps: u32) -> Result<()> {
        if self.detached {
            bail!("set_goal on a detached agent session");
        }
        send_frame(
            &self.shared,
            &Frame::Goal {
                session: self.id,
                steps,
            },
        )
    }

    fn check_shape(&self, view: &StepFrame) -> Result<()> {
        let n = self.slots.len();
        let of = self.obs_floats;
        if view.obs.len() != n * of
            || view.goal.len() != n * 3
            || view.rewards.len() != n
            || view.dones.len() != n
            || view.successes.len() != n
            || view.spl.len() != n
            || view.scores.len() != n
        {
            bail!("server sent a mis-shaped trajectory view");
        }
        Ok(())
    }

    /// Block for the next server-driven step. `Ok(None)` means the
    /// tenancy ended cleanly (detached); `Err` means the shard or the
    /// policy failed mid-goal, or the connection died.
    pub fn next_traj(&mut self) -> Result<Option<RemoteTraj>> {
        match self.mailbox.recv() {
            Ok(SessMsg::Traj {
                step,
                actions,
                view,
            }) => {
                self.check_shape(&view)?;
                if actions.len() != self.slots.len() {
                    bail!("server sent a mis-shaped trajectory view");
                }
                self.steps += 1;
                Ok(Some(RemoteTraj {
                    step,
                    actions,
                    view,
                }))
            }
            Ok(SessMsg::Step { .. }) => {
                bail!("server sent a plain step frame to an agent session")
            }
            Ok(SessMsg::Detached) => {
                self.detached = true;
                Ok(None)
            }
            Ok(SessMsg::Error { msg, .. }) => bail!("serve: {msg}"),
            Err(_) => bail!("connection lost: {}", death(&self.shared)),
        }
    }

    /// Release the lease and wait for the server's acknowledgement,
    /// draining trajectory frames still in flight. Like
    /// [`RemoteSession::detach`], a server-reported failure encountered
    /// during the drain is returned as an error so "detach at the end"
    /// callers still observe mid-stream failures.
    pub fn detach(&mut self) -> Result<()> {
        if self.detached {
            return Ok(());
        }
        self.detached = true;
        let send = send_frame(&self.shared, &Frame::Detach { session: self.id });
        let mut errored: Option<String> = None;
        if send.is_ok() {
            loop {
                match self.mailbox.recv() {
                    Ok(SessMsg::Detached) => break,
                    Ok(SessMsg::Step { .. }) | Ok(SessMsg::Traj { .. }) => continue,
                    Ok(SessMsg::Error { msg, .. }) => {
                        errored = Some(msg);
                        break;
                    }
                    Err(_) => break,
                }
            }
        }
        self.shared.routes.lock().unwrap().sessions.remove(&self.id);
        if let Some(msg) = errored {
            bail!("serve: {msg}");
        }
        send
    }
}

impl Drop for RemoteAgent {
    fn drop(&mut self) {
        if !self.detached {
            self.detached = true;
            let _ = send_frame(&self.shared, &Frame::Detach { session: self.id });
        }
    }
}
